"""qwen2-7b — the paper's own end-to-end evaluation model (Table 2).
[Qwen2 technical report 2024; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    rope_theta=1_000_000.0,
)
