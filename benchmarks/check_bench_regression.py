"""Bench-regression gate: fail CI when fleet events/s regresses.

Compares a fresh ``bench_sim_scale.py --json`` result file against the
last entry of the checked-in trajectory (repo-root
``BENCH_sim_scale.json``) and exits non-zero if the watched cell's
``events_per_s`` dropped more than ``--tolerance`` (default 20%) below
the baseline.

Baseline selection prefers the most recent trajectory entry whose cell
was measured under a comparable configuration (same smoke flag,
n_requests, instance count, and engine mode); if none matches it falls
back to the most recent entry that has the cell at all and says so —
events/s is a rate, so cross-scale comparison is meaningful, just
noisier.
"""
from __future__ import annotations

import argparse
import json
import sys

COMPARABLE_KEYS = ("n_requests", "instances", "engine_mode",
                   "predictor_backend")


def _cell_cfg(entry: dict, cell: str) -> dict:
    c = entry.get(cell) or {}
    cfg = {k: c.get(k) for k in COMPARABLE_KEYS}
    cfg["smoke"] = entry.get("smoke")
    return cfg


def pick_baseline(trajectory: list, cell: str, fresh_cfg: dict):
    """Most recent comparable entry, else most recent with the cell."""
    with_cell = [e for e in trajectory
                 if isinstance(e.get(cell), dict)
                 and "events_per_s" in e[cell]]
    if not with_cell:
        return None, False
    for e in reversed(with_cell):
        if _cell_cfg(e, cell) == fresh_cfg:
            return e, True
    return with_cell[-1], False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", required=True,
                    help="fresh bench_sim_scale.py --json output")
    ap.add_argument("--trajectory", default="BENCH_sim_scale.json",
                    help="checked-in cross-PR trajectory file")
    ap.add_argument("--cell", default="fleet",
                    help="which result cell to gate on")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="max allowed fractional drop in events_per_s")
    args = ap.parse_args(argv)

    with open(args.results) as f:
        fresh = json.load(f)
    cell = fresh.get(args.cell)
    if not isinstance(cell, dict) or "events_per_s" not in cell:
        print(f"gate: results file has no '{args.cell}' cell with "
              f"events_per_s — nothing to gate")
        return 1

    with open(args.trajectory) as f:
        traj = json.load(f).get("trajectory", [])
    fresh_cfg = _cell_cfg(fresh, args.cell)
    base, comparable = pick_baseline(traj, args.cell, fresh_cfg)
    if base is None:
        print(f"gate: no trajectory entry has cell '{args.cell}' — "
              f"pass (nothing to compare against)")
        return 0

    base_eps = base[args.cell]["events_per_s"]
    fresh_eps = cell["events_per_s"]
    floor = (1.0 - args.tolerance) * base_eps
    note = "" if comparable else (
        "  [non-comparable config: "
        f"baseline={_cell_cfg(base, args.cell)} fresh={fresh_cfg}]")
    print(f"gate: cell={args.cell} baseline={base.get('label', '?')} "
          f"{base_eps:,.0f} ev/s -> fresh {fresh_eps:,.0f} ev/s "
          f"(floor {floor:,.0f}, tolerance {args.tolerance:.0%}){note}")
    if fresh_eps < floor:
        print(f"gate: FAIL — events_per_s dropped "
              f"{1.0 - fresh_eps / base_eps:.1%} "
              f"(> {args.tolerance:.0%} allowed)")
        return 1
    print("gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
