"""rwkv6-1.6b (Finch) — attention-free RNN with data-dependent decay.
[arXiv:2404.05892; unverified]

Attention-free => constant-size recurrent state => runs long_500k.
"""
from repro.configs.base import ModelConfig, RWKV

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,              # wkv heads = d_model / rwkv_head_size
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=(RWKV,),
    gated_mlp=False,           # rwkv channel-mix is its own 2-layer relu^2 FFN
    rwkv_head_size=64,
    tie_embeddings=False,
)
