"""Frontier core: stage-centric, event-driven LLM inference simulation."""
from repro.core.engine import SimEngine  # noqa: F401
from repro.core.events import EV, Event  # noqa: F401
from repro.core.request import Request, RState  # noqa: F401
from repro.core.hardware import (  # noqa: F401
    HARDWARE, A800_SXM4_80G, H100_SXM, TPU_V5E, HardwareSpec, LinkSpec,
    ParallelismConfig,
)
from repro.core.predictor import ExecutionPredictor, StepBreakdown  # noqa: F401
from repro.core.controller import GlobalController  # noqa: F401
from repro.core.cluster import ClusterWorker, ReplicaWorker, Hooks  # noqa: F401
from repro.core.metrics import MetricsCollector, pareto_frontier  # noqa: F401
from repro.core.topology import (  # noqa: F401
    ClusterSpec, StageGraph, SystemHandle, build_system,
)
from repro.core.routing import ROUTERS, resolve_router  # noqa: F401
from repro.core.policies.memory import (  # noqa: F401
    MEMORY, KVCacheManager, KVTransferPlan, MonolithicKVManager,
    PagedKVManager, PrefixCachingKVManager, resolve_memory,
)
from repro.core.pipeline import (  # noqa: F401
    PIPELINES, PipelineConfig, resolve_pipeline,
)
from repro.core.workflows.colocated import build_colocated  # noqa: F401
from repro.core.workflows.pd_disagg import build_pd  # noqa: F401
from repro.core.workflows.af_disagg import (  # noqa: F401
    AFStepStats, build_af, simulate_af_decode_step, AFPipelinePredictor,
)
