"""Tile-level virtual-kernel simulator — the profiling ground truth.

The paper profiles CUDA kernels on A800s.  Without GPUs, we model kernel
execution at tile granularity and use it as ground truth for fitting and
evaluating the operator models (plus real CPU wall-clock measurements, see
calibration.py).  The model captures the phenomena the paper calls out:

- partitioning/tiling: a kernel is a grid of tiles (CTAs); each tile's time
  depends on its own work (per-request kv length, per-expert token count);
- wave quantization: tiles are list-scheduled onto n_cores; heterogeneous
  tile times create ragged tail waves;
- memory-vs-compute regimes per tile (decode attention and small-m expert
  GEMMs are bandwidth-bound).

GPU-profile (many SMs, wave effects) and TPU-profile (few sequential cores,
MXU-tile granularity) instances share the same machinery.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.hardware import HardwareSpec


def _list_schedule(durs: Sequence[float], n_cores: int) -> float:
    """Greedy list scheduling (in submission order, like a HW dispatcher)."""
    if not len(durs):
        return 0.0
    cores = np.zeros(n_cores)
    for d in durs:
        i = int(np.argmin(cores))
        cores[i] += d
    return float(cores.max())


@dataclass
class VirtualKernels:
    hw: HardwareSpec
    bq: int = 128                 # query-block tile rows
    bk: int = 128                 # kv-block tile cols
    tile_n: int = 128             # GEMM tile N
    tile_m: int = 128             # GEMM tile M
    launch_overhead: float = 4e-6
    tile_overhead: float = 1.5e-7  # per-tile scheduling cost

    # ---- core tile timings -------------------------------------------------
    def _core_flops(self) -> float:
        return self.hw.peak_flops / self.hw.n_cores

    def _core_bw(self) -> float:
        return self.hw.hbm_bw / self.hw.n_cores

    # ---- FlashAttention (prefill) ------------------------------------------
    def attention_prefill(self, q_lens: Sequence[int], kv_lens: Sequence[int],
                          n_heads: int, n_kv_heads: int, head_dim: int, *,
                          causal: bool = True, window: int = 0) -> float:
        tiles: List[float] = []
        for q, kv in zip(q_lens, kv_lens):
            eff_kv = min(kv, window) if window else kv
            n_qblocks = math.ceil(q / self.bq)
            for qb in range(n_qblocks):
                # causal: q-block qb attends ~ (qb+1)*bq keys (+ window clip)
                span = min(eff_kv, (qb + 1) * self.bq) if causal else eff_kv
                n_kblocks = max(1, math.ceil(span / self.bk))
                flops = 4.0 * self.bq * self.bk * head_dim * n_kblocks
                byts = 2.0 * (self.bq * head_dim
                              + 2 * n_kblocks * self.bk * head_dim)
                t_tile = max(flops / self._core_flops(),
                             byts / self._core_bw()) + self.tile_overhead
                tiles.extend([t_tile] * n_heads)
        return self.launch_overhead + _list_schedule(tiles, self.hw.n_cores)

    # ---- FlashDecode ----------------------------------------------------------
    def attention_decode(self, context_lens: Sequence[int], n_heads: int,
                         n_kv_heads: int, head_dim: int, *,
                         window: int = 0, kv_split: int = 4) -> float:
        tiles: List[float] = []
        for kv in context_lens:
            eff = min(kv, window) if window else kv
            per_split = math.ceil(eff / kv_split)
            n_kblocks = max(1, math.ceil(per_split / self.bk))
            flops = 4.0 * self.bk * head_dim * n_kblocks
            # decode is KV-read bound: each split streams its KV slice
            t_tile = max(flops / self._core_flops(),
                         2.0 * 2 * per_split * head_dim / self._core_bw())
            t_tile += self.tile_overhead
            tiles.extend([t_tile] * (n_kv_heads * kv_split))
        return self.launch_overhead + _list_schedule(tiles, self.hw.n_cores)

    # ---- GroupedGEMM (MoE experts) -------------------------------------------
    def grouped_gemm(self, tokens_per_expert: Sequence[int], d_in: int,
                     d_out: int, dtype_bytes: int = 2) -> float:
        tiles: List[float] = []
        n_tiles_n = max(1, math.ceil(d_out / self.tile_n))
        for m_e in tokens_per_expert:
            if m_e <= 0:
                continue
            n_tiles_m = max(1, math.ceil(m_e / self.tile_m))
            # each (m,n) tile runs the full k-loop
            flops = 2.0 * self.tile_m * self.tile_n * d_in
            byts = dtype_bytes * (self.tile_m * d_in + self.tile_n * d_in
                                  + self.tile_m * self.tile_n)
            t_tile = max(flops / self._core_flops(),
                         byts / self._core_bw()) + self.tile_overhead
            tiles.extend([t_tile] * (n_tiles_m * n_tiles_n))
        return self.launch_overhead + _list_schedule(tiles, self.hw.n_cores)

    # ---- plain GEMM -------------------------------------------------------------
    def gemm(self, m: int, n: int, k: int, dtype_bytes: int = 2) -> float:
        return self.grouped_gemm([m], k, n, dtype_bytes)
