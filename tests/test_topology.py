"""StageGraph/SystemBuilder topology layer + routing/cache satellites."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    A800_SXM4_80G, H100_SXM, ClusterSpec, LinkSpec, ParallelismConfig,
    StageGraph, build_af, build_colocated, build_pd, build_system,
)
from repro.core.predictor import ExecutionPredictor
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.routing import (
    ROUTERS, TraceRouting, ZipfRouting, resolve_router, split_by_rank,
)
from repro.workload.generator import WorkloadConfig, fixed_batch, generate

CFG = get_config("qwen2-7b")
MCFG = get_config("mixtral-8x7b")
HW = A800_SXM4_80G


# --------------------------------------------------------- split_by_rank --
def test_split_by_rank_conserves_experts_with_remainder():
    counts = np.arange(1, 11)          # 10 experts
    for ep in (1, 2, 3, 4, 6, 7, 10, 16):
        shards = split_by_rank(counts, ep)
        assert len(shards) == ep
        assert sum(int(s.sum()) for s in shards) == int(counts.sum())
        sizes = [len(s) for s in shards]
        assert max(sizes) - min(sizes) <= 1    # balanced shard sizes


def test_split_by_rank_even_case_unchanged():
    counts = np.arange(8)
    shards = split_by_rank(counts, 4)
    assert [list(s) for s in shards] == [[0, 1], [2, 3], [4, 5], [6, 7]]


# ---------------------------------------------------------------- routers --
def test_trace_routing_registered_and_names_resolve():
    assert ROUTERS["trace"] is TraceRouting
    assert isinstance(resolve_router("zipf"), ZipfRouting)
    r = ZipfRouting(1.4)
    assert resolve_router(r) is r
    assert resolve_router(None) is None
    with pytest.raises(KeyError):
        resolve_router("nope")
    # trace needs measured fractions: name resolution fails with a clear hint
    with pytest.raises(TypeError, match="pass an instance"):
        resolve_router("trace")


def test_builders_accept_string_router_names():
    for build in (
        lambda: build_colocated(MCFG, HW, routing="zipf",
                                par=ParallelismConfig(tp=8, ep=8)),
        lambda: build_pd(CFG, HW, routing="uniform"),
        lambda: build_af(MCFG, HW, routing="zipf",
                         ffn_par=ParallelismConfig(tp=1, ep=4)),
    ):
        rep = build().run(fixed_batch(8, 128, 16))
        assert rep["n_completed"] == 8


# ----------------------------------------------------------- stage graph --
def test_presets_are_stagegraph_thin_wrappers():
    sys = build_pd(CFG, HW, n_prefill=2, n_decode=1)
    assert set(sys.clusters) == {"prefill", "decode"}
    assert len(sys.clusters["prefill"].replicas) == 2
    # replica names preserved from the pre-StageGraph builders
    assert sys.clusters["prefill"].replicas[0].name == "prefill0"
    colo = build_colocated(CFG, HW, n_replicas=2)
    assert colo.clusters["colocated"].replicas[1].name == "colo1"


def test_stagegraph_validation_errors():
    with pytest.raises(ValueError):
        StageGraph(clusters=[ClusterSpec("a", "prefill"),
                             ClusterSpec("a", "decode")]).validate()
    with pytest.raises(ValueError):
        StageGraph(clusters=[ClusterSpec("a", "wizard")]).validate()
    with pytest.raises(ValueError):
        StageGraph(clusters=[ClusterSpec("a", "colocated")],
                   links=[LinkSpec("a", "b", 1e9)]).validate()
    with pytest.raises(ValueError):
        StageGraph(clusters=[ClusterSpec("d", "decode")]).validate()
    # prefill without decode (or mixed with colocated) cannot be routed
    with pytest.raises(ValueError):
        StageGraph(clusters=[ClusterSpec("p", "prefill")]).validate()
    with pytest.raises(ValueError):
        StageGraph(clusters=[ClusterSpec("p", "prefill"),
                             ClusterSpec("c", "colocated")]).validate()
    # expert placement knobs without remote ranks would silently do nothing
    with pytest.raises(ValueError, match="no effect"):
        StageGraph(clusters=[ClusterSpec(
            "c", "colocated", step="af",
            expert_cluster_hw=H100_SXM)]).validate()
    # remote expert ranks must fit the EP degree
    with pytest.raises(ValueError, match="out of range"):
        StageGraph(clusters=[ClusterSpec(
            "c", "colocated", step="af",
            ffn_par=ParallelismConfig(tp=1, ep=4),
            remote_expert_ranks=(9,))]).validate()


def test_remote_expert_ranks_require_moe_model():
    graph = StageGraph(clusters=[
        ClusterSpec("prefill", "prefill"),
        ClusterSpec("decode", "decode", step="af",
                    ffn_par=ParallelismConfig(tp=1, ep=4),
                    remote_expert_ranks=(2,),
                    expert_link=LinkSpec("decode", "experts", 25e9))])
    with pytest.raises(ValueError, match="requires an MoE"):
        build_system(CFG, HW, graph)    # qwen2-7b is dense


def test_multiple_decode_pools_share_load():
    graph = StageGraph(clusters=[
        ClusterSpec("prefill", "prefill", n_replicas=1),
        ClusterSpec("decode-a", "decode", n_replicas=1, seed_offset=100),
        ClusterSpec("decode-b", "decode", n_replicas=1, seed_offset=200),
    ])
    sys = build_system(CFG, HW, graph)
    rep = sys.run(generate(WorkloadConfig(n_requests=40, rate=40.0, seed=2)))
    assert rep["n_completed"] == 40
    toks = {n: sum(w.stats["tokens"] for w in c.replicas)
            for n, c in sys.clusters.items() if c.role == "decode"}
    assert toks["decode-a"] > 0 and toks["decode-b"] > 0


def test_heterogeneous_pd_af_cross_cluster_ep_end_to_end():
    """The tentpole one-liner: PD front on A800, AF decode with H100
    attention, two EP ranks on a remote expert cluster over an asymmetric
    link — runs end-to-end through the controller."""
    graph = StageGraph(
        clusters=[
            ClusterSpec("prefill", "prefill", n_replicas=1,
                        par=ParallelismConfig(tp=2)),
            ClusterSpec("decode", "decode", step="af", m=2,
                        hardware=H100_SXM,
                        par=ParallelismConfig(tp=2),
                        attn_par=ParallelismConfig(tp=2),
                        ffn_par=ParallelismConfig(tp=1, ep=4),
                        remote_expert_ranks=(2, 3),
                        expert_cluster_hw=A800_SXM4_80G,
                        expert_link=LinkSpec("decode", "experts",
                                             bandwidth=10e9, latency=10e-6),
                        seed_offset=50),
        ],
        links=[LinkSpec("prefill", "decode", bandwidth=50e9),
               LinkSpec("decode", "prefill", bandwidth=25e9)])
    sys = build_system(MCFG, HW, graph, routing="zipf")
    rep = sys.run([r for r in fixed_batch(6, 256, 8)])
    assert rep["n_completed"] == 6
    pred = sys.clusters["decode"].replicas[0].predictor
    assert pred.last_stats is not None
    assert pred.last_stats.ep_straggler_excess > 0
    assert pred.last_stats.cross_cluster_bytes > 0


def test_asymmetric_link_bandwidth_prices_kv_transfer():
    slow = StageGraph(clusters=[
        ClusterSpec("prefill", "prefill"),
        ClusterSpec("decode", "decode", seed_offset=100)],
        links=[LinkSpec("prefill", "decode", bandwidth=1e9)])
    fast = StageGraph(clusters=[
        ClusterSpec("prefill", "prefill"),
        ClusterSpec("decode", "decode", seed_offset=100)],
        links=[LinkSpec("prefill", "decode", bandwidth=400e9)])
    r_slow = build_system(CFG, HW, slow).run(fixed_batch(8, 2048, 8))
    r_fast = build_system(CFG, HW, fast).run(fixed_batch(8, 2048, 8))
    # first token is emitted at prefill completion, so the slower KV link
    # shows up in time-per-output-token and end-to-end duration
    assert r_slow["tpot_p50_s"] > r_fast["tpot_p50_s"]
    assert r_slow["duration_s"] > r_fast["duration_s"]


# ------------------------------------------------------------- memo cache --
def test_step_time_memo_cache_hits_and_is_consistent():
    ops = OperatorModelSet(HW)
    pred = ExecutionPredictor(CFG, ParallelismConfig(tp=2), HW, ops)
    exact = ExecutionPredictor(CFG, ParallelismConfig(tp=2), HW, ops,
                               memoize=False)
    bd1 = pred.step_time([1] * 16, [512] * 16, decode=True)
    bd2 = pred.step_time([1] * 16, [512] * 16, decode=True)
    assert pred.cache_hits == 1 and pred.cache_misses == 1
    # cached result must equal an uncached predictor's (dense model,
    # deterministic routing -> exact), not just itself
    assert bd2.total == exact.step_time([1] * 16, [512] * 16,
                                        decode=True).total == bd1.total
    # a different shape bucket misses
    pred.step_time([1] * 32, [512] * 32, decode=True)
    assert pred.cache_misses == 2


def test_stochastic_router_cache_keeps_multiple_draws():
    """A Zipf-routed predictor must not collapse the straggler barrier to a
    single cached sample: the cache rotates over several draws per bucket."""
    ops = OperatorModelSet(HW)
    pred = ExecutionPredictor(MCFG, ParallelismConfig(tp=8, ep=8), HW, ops,
                              routing=ZipfRouting(1.5))
    # large decode batch: the expert GEMMs are compute-bound, so different
    # routing draws produce different straggler profiles
    excess = {pred.step_time([1] * 512, [1024] * 512,
                             decode=True).moe_straggler_excess
              for _ in range(16)}
    assert len(excess) > 1          # distinct draws survive memoization
    assert pred.cache_hits == 8     # ...while the cache still hits


def test_step_time_cache_can_be_disabled():
    ops = OperatorModelSet(HW)
    pred = ExecutionPredictor(CFG, ParallelismConfig(tp=2), HW, ops,
                              memoize=False)
    pred.step_time([1] * 8, [256] * 8, decode=True)
    pred.step_time([1] * 8, [256] * 8, decode=True)
    assert pred.cache_hits == 0
