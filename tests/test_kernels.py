"""Per-kernel allclose vs ref.py oracles, swept over shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernels   # tier-2: interpreted Pallas on CPU

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def arr(*s, dtype=jnp.float32, scale=0.5):
    return jnp.asarray(RNG.normal(size=s, scale=scale), dtype)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,T,H,K,hd,causal,window", [
    (1, 16, 16, 4, 4, 32, True, 0),      # MHA causal
    (2, 48, 48, 8, 2, 64, True, 0),      # GQA
    (1, 33, 33, 4, 1, 64, True, 0),      # MQA, ragged seq vs block
    (2, 32, 32, 4, 2, 64, True, 12),     # sliding window
    (1, 24, 24, 8, 8, 112, True, 0),     # kimi head_dim 112 (pad path)
    (1, 16, 16, 4, 4, 32, False, 0),     # bidirectional (encoder)
    (1, 32, 32, 8, 8, 112, True, 8),     # pad path + sliding window
    (1, 16, 48, 4, 2, 64, True, 0),      # S != T (q chunk over longer KV)
])
def test_flash_attention_matches_ref(B, S, T, H, K, hd, causal, window, dtype):
    q, k, v = arr(B, S, H, hd, dtype=dtype), arr(B, T, K, hd, dtype=dtype), \
        arr(B, T, K, hd, dtype=dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=16, bk=16)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,K,hd", [
    (2, 64, 8, 2, 64),
    (1, 100, 4, 4, 32),
    (3, 48, 8, 8, 112),
])
def test_decode_attention_matches_ref(B, T, H, K, hd, dtype):
    q = arr(B, H, hd, dtype=dtype)
    k, v = arr(B, T, K, hd, dtype=dtype), arr(B, T, K, hd, dtype=dtype)
    lens = jnp.asarray(RNG.integers(1, T + 1, B), jnp.int32)
    got = ops.decode_attention(q, k, v, lens, bk=32)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,din,dout", [
    (2, 32, 64, 64),
    (5, 40, 96, 128),
    (1, 16, 128, 256),
])
def test_grouped_gemm_matches_ref(E, C, din, dout, dtype):
    x, w = arr(E, C, din, dtype=dtype), arr(E, din, dout, dtype=dtype)
    gs = jnp.asarray(RNG.integers(0, C + 1, E), jnp.int32)
    got = ops.grouped_gemm(x, w, gs, bm=16, bn=64, bkk=32)
    want = ref.grouped_gemm_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@given(st.integers(1, 6).flatmap(
    lambda e: st.tuples(st.just(e),
                        st.lists(st.integers(0, 24), min_size=e, max_size=e))))
@settings(max_examples=15, deadline=None)
def test_grouped_gemm_ragged_property(e_and_sizes):
    E, sizes = e_and_sizes
    C = 24
    x, w = arr(E, C, 32), arr(E, 32, 48)
    gs = jnp.asarray(sizes, jnp.int32)
    got = ops.grouped_gemm(x, w, gs, bm=8, bn=48, bkk=32)
    want = ref.grouped_gemm_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # rows beyond group size must be exactly zero
    for e in range(E):
        assert np.all(np.asarray(got)[e, sizes[e]:] == 0.0)


@pytest.mark.parametrize("fill", ["full", "one"])
def test_decode_attention_length_edges(fill):
    """lengths == T (whole cache valid) and lengths == 1 (single token)."""
    B, T, H, K, hd = 2, 48, 4, 2, 32
    q = arr(B, H, hd)
    k, v = arr(B, T, K, hd), arr(B, T, K, hd)
    lens = jnp.full((B,), T if fill == "full" else 1, jnp.int32)
    got = ops.decode_attention(q, k, v, lens, bk=16)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_grouped_gemm_all_empty_groups():
    E, C, din, dout = 3, 16, 32, 48
    x, w = arr(E, C, din), arr(E, din, dout)
    gs = jnp.zeros((E,), jnp.int32)
    got = ops.grouped_gemm(x, w, gs, bm=8, bn=48, bkk=32)
    assert np.all(np.asarray(got) == 0.0)


def test_pallas_oracle_times_real_kernels():
    """The calibration oracle drives ops.py end to end (interpret mode on
    CPU) and caches per bucketed shape."""
    from repro.calib import PallasOracle
    from repro.core.hardware import HARDWARE
    orc = PallasOracle(HARDWARE["A800-SXM4-80G"], reps=1)
    t_pre = orc.attention_prefill([16, 24], [16, 24], 2, 2, 16)
    t_dec = orc.attention_decode([16, 32], 2, 2, 16)
    t_gg = orc.grouped_gemm([8, 16], 32, 32)
    assert t_pre > 0 and t_dec > 0 and t_gg > 0
    n_cached = len(orc._cache)
    assert orc.attention_prefill([16, 24], [16, 24], 2, 2, 16) == t_pre
    assert len(orc._cache) == n_cached   # second call is a pure cache hit


def test_flash_vs_decode_consistency():
    """decode(q over cache) == last row of causal flash with same data."""
    B, T, H, K, hd = 1, 32, 4, 2, 32
    k, v = arr(B, T, K, hd), arr(B, T, K, hd)
    q_all = arr(B, T, H, hd)
    full = ref.flash_attention_ref(q_all, k, v, causal=True)
    got = ops.decode_attention(q_all[:, -1], k, v,
                               jnp.asarray([T], jnp.int32), bk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, -1]),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,hs,chunk", [
    (1, 16, 2, 16, 8),
    (2, 32, 3, 16, 8),
    (1, 48, 2, 32, 16),
])
def test_wkv_chunk_kernel_matches_sequential_ref(B, T, H, hs, chunk, dtype):
    r = arr(B, T, H, hs, dtype=dtype)
    k = arr(B, T, H, hs, dtype=dtype)
    v = arr(B, T, H, hs, dtype=dtype)
    # decays in a realistic (0.35, 0.95) band
    w = jnp.asarray(1 / (1 + np.exp(-RNG.normal(size=(B, T, H, hs))))
                    * 0.6 + 0.35, dtype)
    u = arr(H, hs, dtype=dtype, scale=0.3)
    got = ops.wkv_chunked(r, k, v, w, u, chunk=chunk)
    want = ref.wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **(dict(atol=5e-2, rtol=5e-2)
                                  if dtype == jnp.bfloat16
                                  else dict(atol=5e-5, rtol=5e-5)))
