"""AF (Attention/FFN) disaggregation — MegaScale-Infer / Step-3 style.

One decode step is simulated as an *event dependency graph*: the global
batch is partitioned into m micro-batches; ATTN_COMPUTE(i,k) runs on the
attention cluster, A2F_TRANSFER(i,k) ships activations, FFN_COMPUTE(i,k)
runs on the FFN cluster (optionally MoE/EP), F2A_TRANSFER(i,k) returns.
The event engine schedules each node as soon as its dependencies are met,
capturing the ping-pong latency hiding: while A2F(i,k) is in flight the
attention cluster computes ATTN(i+1,k).  The step time is the timestamp of
the final FFN/F2A event — the critical path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig
from repro.core.cluster import ClusterWorker, ReplicaWorker
from repro.core.controller import GlobalController
from repro.core.engine import SimEngine
from repro.core.events import EV
from repro.core.hardware import HardwareSpec, ParallelismConfig
from repro.core.metrics import MetricsCollector
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.policies.batching import ContinuousBatching
from repro.core.policies.memory import PagedKVManager
from repro.core.predictor import ExecutionPredictor, StepBreakdown
from repro.core.routing import RoutingModule, split_by_rank
from repro.core.workflows.colocated import SystemHandle, _kv_budget
from repro.core.workflows.pd_disagg import build_pd


@dataclass
class AFStepStats:
    makespan: float = 0.0
    attn_busy: float = 0.0
    ffn_busy: float = 0.0
    transfer_bytes: float = 0.0
    attn_bubble_frac: float = 0.0
    ffn_bubble_frac: float = 0.0
    events: int = 0


def simulate_af_decode_step(cfg: ModelConfig, hw: HardwareSpec,
                            ops: OperatorModelSet,
                            context_lens: Sequence[int], *,
                            m: int, attn_par: ParallelismConfig,
                            ffn_par: ParallelismConfig,
                            routing: Optional[RoutingModule] = None,
                            rng: Optional[np.random.Generator] = None,
                            ) -> AFStepStats:
    """Event-dependency-graph simulation of ONE decode step (one token)."""
    rng = rng or np.random.default_rng(0)
    eng = SimEngine()
    L = cfg.num_layers
    micro = [list(c) for c in np.array_split(np.asarray(context_lens), m)]
    micro = [c for c in micro if len(c)]
    m_eff = len(micro)
    d = cfg.d_model

    # ---- per-(microbatch, layer) task durations --------------------------
    def t_attn(lens: List[int], kind: str) -> float:
        tp = max(attn_par.tp, 1)
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        t = ops.gemm(len(lens), (H + 2 * K) * hd // tp, d)
        t += ops.attention_decode(lens, H // tp, max(K // tp, 1), hd,
                                  window=window)
        t += ops.gemm(len(lens), d, H * hd // tp)
        t += ops.all_reduce(2.0 * len(lens) * d, tp)
        return t

    def t_ffn(n_tok: int) -> float:
        n_mats = 3 if cfg.gated_mlp else 2
        if cfg.moe is None:
            tp = max(ffn_par.tp, 1)
            return (n_mats * ops.gemm(n_tok, cfg.d_ff // tp, d)
                    + ops.all_reduce(2.0 * n_tok * d, tp))
        moe = cfg.moe
        ep = max(ffn_par.ep, ffn_par.tp, 1)
        t = ops.gemm(n_tok, moe.num_experts, d)
        counts = (routing.assign(n_tok, moe.num_experts, moe.top_k, rng)
                  if routing is not None else
                  np.full(moe.num_experts, n_tok * moe.top_k // moe.num_experts))
        per_rank = split_by_rank(np.asarray(counts), ep)
        times = [n_mats * ops.grouped_gemm(list(rc), d, moe.expert_d_ff)
                 for rc in per_rank]
        t += max(times) if times else 0.0
        if moe.num_shared_experts:
            t += n_mats * ops.gemm(n_tok, moe.expert_d_ff * moe.num_shared_experts, d)
        return t

    def t_xfer(n_tok: int) -> float:
        return ops.p2p(2.0 * n_tok * d, inter_node=True)

    attn_kinds = [k for k in cfg.pattern]
    stats = AFStepStats()

    # ---- resources & dependency-driven scheduling -------------------------
    attn_free = [0.0]   # next-available times (single pipeline per cluster)
    ffn_free = [0.0]
    done_f2a = {i: 0.0 for i in range(m_eff)}  # F2A(i, k-1) completion

    # we iterate layers in order; within a layer, micro-batches are admitted
    # in index order — the event engine resolves the interleaving.
    pending = {}

    def schedule_attn(i: int, k: int, ev=None):
        kind = attn_kinds[k]
        if kind not in (ATTN_GLOBAL, ATTN_LOCAL):
            # recurrent block: runs on the attention cluster too
            dur = ops.gemm(len(micro[i]), d, d) * 3
        else:
            dur = t_attn(micro[i], kind)
        start = max(eng.now, attn_free[0], done_f2a[i])
        attn_free[0] = start + dur
        stats.attn_busy += dur
        eng.at(start + dur, EV.ATTN_COMPUTE_DONE,
               lambda ev: schedule_a2f(i, k), i=i, k=k)

    def schedule_a2f(i: int, k: int):
        dur = t_xfer(len(micro[i]))
        stats.transfer_bytes += 2.0 * len(micro[i]) * d
        eng.at(eng.now + dur, EV.A2F_TRANSFER_DONE,
               lambda ev: schedule_ffn(i, k), i=i, k=k)

    def schedule_ffn(i: int, k: int):
        dur = t_ffn(len(micro[i]))
        start = max(eng.now, ffn_free[0])
        ffn_free[0] = start + dur
        stats.ffn_busy += dur
        eng.at(start + dur, EV.FFN_COMPUTE_DONE,
               lambda ev: schedule_f2a(i, k), i=i, k=k)

    def schedule_f2a(i: int, k: int):
        dur = t_xfer(len(micro[i]))
        stats.transfer_bytes += 2.0 * len(micro[i]) * d

        def done(ev):
            done_f2a[i] = eng.now
            if k + 1 < L:
                schedule_attn(i, k + 1)
        eng.at(eng.now + dur, EV.F2A_TRANSFER_DONE, done, i=i, k=k)

    for i in range(m_eff):
        schedule_attn(i, 0)
    eng.run()

    stats.makespan = eng.now
    stats.events = eng.processed
    if stats.makespan > 0:
        stats.attn_bubble_frac = 1.0 - stats.attn_busy / stats.makespan
        stats.ffn_bubble_frac = 1.0 - stats.ffn_busy / stats.makespan
    return stats


class AFPipelinePredictor(ExecutionPredictor):
    """ExecutionPredictor whose decode step runs the AF event graph."""

    def __init__(self, *args, m: int = 2,
                 attn_par: Optional[ParallelismConfig] = None,
                 ffn_par: Optional[ParallelismConfig] = None, **kw):
        super().__init__(*args, **kw)
        self.m = m
        self.attn_par = attn_par or self.par
        self.ffn_par = ffn_par or self.par
        self.last_stats: Optional[AFStepStats] = None

    def step_time(self, q_lens, kv_lens, *, decode: bool) -> StepBreakdown:
        if not decode:
            return super().step_time(q_lens, kv_lens, decode=False)
        stats = simulate_af_decode_step(
            self.cfg, self.hw, self.ops, list(kv_lens), m=self.m,
            attn_par=self.attn_par, ffn_par=self.ffn_par,
            routing=self.routing, rng=self.rng)
        self.last_stats = stats
        bd = StepBreakdown()
        bd.add("af_pipeline", stats.makespan)
        bd.add("engine_overhead", self.engine_overhead)
        bd.parts["attn_bubble_frac"] = stats.attn_bubble_frac
        bd.parts["ffn_bubble_frac"] = stats.ffn_bubble_frac
        return bd


def build_af(cfg: ModelConfig, hw: HardwareSpec, *,
             n_prefill: int = 1, n_decode: int = 1, m: int = 2,
             attn_par: Optional[ParallelismConfig] = None,
             ffn_par: Optional[ParallelismConfig] = None,
             prefill_par: Optional[ParallelismConfig] = None,
             ops: Optional[OperatorModelSet] = None,
             routing=None, seed: int = 0) -> SystemHandle:
    """PD front + AF-disaggregated decode (as deployed by MegaScale-Infer)."""
    engine = SimEngine()
    ops = ops or OperatorModelSet(hw)
    attn_par = attn_par or ParallelismConfig(tp=1)
    ffn_par = ffn_par or ParallelismConfig(tp=1, ep=1)
    prefill_par = prefill_par or ParallelismConfig(tp=1)
    metrics = MetricsCollector()

    pred0 = ExecutionPredictor(cfg, attn_par, hw, ops)
    controller = GlobalController(
        engine, mode="pd", clusters={},
        kv_bytes_per_token=pred0.kv_bytes_per_token(),
        transfer_bw=hw.inter_node_bw, metrics=metrics)
    hooks = controller.hooks()

    pre = []
    for i in range(n_prefill):
        p = ExecutionPredictor(cfg, prefill_par, hw, ops, routing=routing,
                               seed=seed + i)
        mem = PagedKVManager(_kv_budget(cfg, hw, prefill_par, p),
                             p.kv_bytes_per_token())
        pre.append(ReplicaWorker(engine, f"prefill{i}", p,
                                 ContinuousBatching(max_batched_tokens=16384),
                                 mem, hooks, role="prefill"))
    dec = []
    for i in range(n_decode):
        p = AFPipelinePredictor(cfg, attn_par, hw, ops, routing=routing,
                                seed=seed + 50 + i, m=m,
                                attn_par=attn_par, ffn_par=ffn_par)
        mem = PagedKVManager(_kv_budget(cfg, hw, attn_par, p),
                             p.kv_bytes_per_token())
        dec.append(ReplicaWorker(engine, f"af-decode{i}", p,
                                 ContinuousBatching(max_num_seqs=512),
                                 mem, hooks, role="decode"))

    prefill = ClusterWorker("prefill", "prefill", pre)
    decode = ClusterWorker("decode", "decode", dec)
    controller.clusters.update({"prefill": prefill, "decode": decode})
    n_dev = (n_prefill * prefill_par.devices
             + n_decode * (attn_par.devices + ffn_par.devices))
    return SystemHandle(engine, controller,
                        {"prefill": prefill, "decode": decode}, n_dev)
