"""Report metrics must stay valid JSON: empty-sample statistics are None
(JSON null), never a bare NaN literal (which json.dumps happily emits and
every strict parser rejects — the bug that corrupted sweep artifacts)."""
import json
import math

from repro.api import ModelRef, SimSpec, TopologySpec, WorkloadSpec, run
from repro.core.metrics import MetricsCollector, _pct


def test_pct_empty_returns_none_not_nan():
    assert _pct([], 50) is None
    assert _pct([1.0, 2.0], 50) == 1.5


def test_empty_collector_report_is_valid_json():
    rep = MetricsCollector().report(n_devices=4)
    blob = json.dumps(rep, allow_nan=False)      # raises on NaN
    back = json.loads(blob)
    assert back["n_completed"] == 0
    assert back["ttft_p50_s"] is None
    assert back["tpot_p99_s"] is None
    assert not any(isinstance(v, float) and math.isnan(v)
                   for v in rep.values())


def test_queue_percentiles_count_zero_wait_requests():
    """Regression: requests that were scheduled the instant they arrived
    (no ``first_scheduled`` stamp) used to be silently DROPPED from the
    queue-delay percentiles, biasing them upward over exactly the
    fastest requests.  They must contribute 0.0 instead."""
    from repro.core.request import Request
    mc = MetricsCollector()
    waits = {0: None, 1: 0.2, 2: 0.4, 3: None}   # None = never stamped
    for rid, wait in waits.items():
        r = Request(rid=rid, arrival=1.0, prompt_len=8, output_len=4)
        if wait is not None:
            r.timestamps["first_scheduled"] = r.arrival + wait
        mc.on_complete(r, replica=None)
    rep = mc.report()
    # hand-computed over [0.0, 0.0, 0.2, 0.4] (zero-wait requests in)
    assert abs(rep["queue_mean_s"] - 0.15) < 1e-12
    assert abs(rep["queue_p50_s"] - 0.1) < 1e-12
    assert abs(rep["queue_p99_s"] - (0.2 + 0.97 * 0.2)) < 1e-12
    # the old behaviour dropped the two unstamped requests -> p50 0.3


def test_zero_completed_run_produces_parseable_report():
    """A run cut off before any request completes (until ~ 0) must still
    serialize to strict JSON and round-trip through Report.from_dict."""
    spec = SimSpec(
        model=ModelRef("qwen2-7b", smoke=True),
        topology=TopologySpec(preset="pd"),
        workload=WorkloadSpec(n_requests=5, rate=1.0, seed=0),
        until=1e-9)
    rep = run(spec)
    assert rep.summary["n_completed"] == 0
    assert not rep.all_complete
    blob = rep.to_json()
    parsed = json.loads(blob, parse_constant=lambda c: (_ for _ in ()).throw(
        ValueError(f"non-finite JSON constant {c!r} in report")))
    assert parsed["summary"]["ttft_p50_s"] is None
    from repro.api import Report
    again = Report.from_dict(json.loads(blob))
    assert again.summary == rep.summary
