"""Mixture-of-Experts layer with expert parallelism.

Design (see DESIGN.md):
- The router runs under plain pjit (dense GEMM, auto-sharded).
- Dispatch/expert-compute/combine run inside ``shard_map``:
  tokens are sharded over the batch ("pod","data") axes and *replicated*
  over the "model" axis, so each model shard **locally selects** the tokens
  routed to its expert slice (zero dispatch communication), computes the
  capacity-padded batched expert GEMMs, and the combine is a single
  ``psum`` over "model" — the same all-reduce megatron TP pays for a dense
  FFN.  Token load imbalance therefore shows up as *compute imbalance
  across expert shards*, which is exactly the straggler effect Frontier's
  MoE micro-workflow models.
- Capacity: slots per expert per token-shard C_e = ceil(cf * T_l * k / E)
  (train) or a generous effectively-dropless bound (decode).  Overflowing
  assignments are dropped, GShard-style; the drop fraction is surfaced.

Two weight layouts, one code path:
- EP   (E % tp == 0):  expert axis sharded over "model"; e_offset = rank*E_l.
- TPFF (E  < tp):      experts replicated, expert d_ff sharded over "model"
                       (mixtral's 8 experts on a 16-way axis).

FLOP cost is exactly cf x the ideal expert GEMMs — there is no O(T^2)
one-hot dispatch einsum anywhere.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import PD, AxisRules, activation

try:  # jax >= 0.4.35 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


def moe_pds(cfg: ModelConfig) -> Dict[str, PD]:
    moe = cfg.moe
    d, ff, E = cfg.d_model, moe.expert_d_ff, moe.num_experts
    p = {
        "router": PD((d, E), ("embed", None), 0.02),
        "w_in": PD((E, d, ff), ("expert", "embed", "mlp")),
        "w_out": PD((E, ff, d), ("expert", "mlp", "embed")),
    }
    if cfg.gated_mlp:
        p["w_gate"] = PD((E, d, ff), ("expert", "embed", "mlp"))
    return p


def _capacity(T_l: int, k: int, E: int, cf: float, *, train: bool) -> int:
    A = T_l * k
    if train:
        return max(1, math.ceil(cf * A / E))
    return min(A, max(16, math.ceil(cf * A / E)))


def _expert_ffn(cfg: ModelConfig, xb, w_in, w_gate, w_out):
    """xb (E_l, C, D) -> (E_l, C, D) via batched expert GEMMs."""
    act = activation(cfg.mlp_act)
    h = jnp.einsum("ecd,edf->ecf", xb, w_in)
    if cfg.gated_mlp:
        h = act(jnp.einsum("ecd,edf->ecf", xb, w_gate)) * h
    else:
        h = act(h)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _dispatch_compute_combine(cfg: ModelConfig, x_flat, ids, gates,
                              w_in, w_gate, w_out, *,
                              E: int, E_l: int, e_offset, C_e: int):
    """Local (per-shard) capacity dispatch -> expert FFN -> combine.

    x_flat (T_l, D); ids/gates (T_l, k).  Returns (y (T_l, D), kept scalar).
    """
    T_l, D = x_flat.shape
    k = ids.shape[-1]
    A = T_l * k
    flat_ids = ids.reshape(A)
    tok = jnp.arange(A, dtype=jnp.int32) // k

    local = (flat_ids >= e_offset) & (flat_ids < e_offset + E_l)
    le = jnp.where(local, flat_ids - e_offset, E_l).astype(jnp.int32)

    order = jnp.argsort(le, stable=True)          # locals first, by expert
    s_le = le[order]
    s_tok = tok[order]
    s_gate = gates.reshape(A)[order]

    counts = jnp.bincount(le, length=E_l + 1)[:E_l]
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(A, dtype=jnp.int32) - starts[jnp.minimum(s_le, E_l - 1)]
    valid = (s_le < E_l) & (pos < C_e)
    dst = jnp.where(valid, s_le * C_e + pos, E_l * C_e)

    # slot -> source-token map (int scatters are cheap; float traffic below
    # is exactly buffer-sized).
    slot_src = jnp.full((E_l * C_e + 1,), T_l, jnp.int32).at[dst].set(s_tok)[:-1]
    slot_gate = jnp.zeros((E_l * C_e + 1,), gates.dtype).at[dst].set(s_gate)[:-1]

    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, D), x_flat.dtype)], axis=0)
    xb = x_pad[slot_src].reshape(E_l, C_e, D)

    yb = _expert_ffn(cfg, xb, w_in, w_gate, w_out).reshape(E_l * C_e, D)
    yb = yb * slot_gate[:, None].astype(yb.dtype)

    y = jnp.zeros((T_l + 1, D), x_flat.dtype).at[slot_src].add(yb)[:T_l]
    kept = jnp.sum(valid.astype(jnp.float32))
    return y, kept


def _a2a_body(cfg: ModelConfig, xs, idss, gatess, w_in, w_gate, w_out, *,
              E: int, E_l: int, tp: int, C_r: int, C_e: int, mesh):
    """Sequence-sharded EP with all-to-all dispatch (MegaScale-style).

    Tokens enter sharded over BOTH batch ("pod","data") and sequence
    ("model").  Each rank routes its own T_ls tokens into per-destination
    capacity buffers, ships them with one `all_to_all`, computes its local
    experts, and ships results back.  Gates never travel: the return buffer
    is slot-aligned with the send buffer, so weighting happens at the
    source.  Collectives per layer drop from two (B,S,D) all-reduces
    (EP-as-TP combine) to two (B,S,D)*k*cf/tp all-to-alls + one all-gather
    at the sequence-reshard boundary.
    """
    D = xs.shape[-1]
    k = idss.shape[-1]
    x_flat = xs.reshape(-1, D)
    T_ls = x_flat.shape[0]
    A = T_ls * k
    flat_ids = idss.reshape(A)
    tok = jnp.arange(A, dtype=jnp.int32) // k

    # ---- source-side: per-destination-rank capacity buffers ---------------
    dest = (flat_ids // E_l).astype(jnp.int32)
    order = jnp.argsort(dest, stable=True)
    s_dest = dest[order]
    s_tok = tok[order]
    s_gate = gatess.reshape(A)[order]
    s_eid = (flat_ids % E_l)[order].astype(jnp.int32)
    counts = jnp.bincount(dest, length=tp)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(A, dtype=jnp.int32) - starts[s_dest]
    valid = pos < C_r
    dst = jnp.where(valid, s_dest * C_r + pos, tp * C_r)

    slot_src = jnp.full((tp * C_r + 1,), T_ls, jnp.int32).at[dst].set(s_tok)[:-1]
    slot_gate = jnp.zeros((tp * C_r + 1,), gatess.dtype).at[dst].set(s_gate)[:-1]
    slot_eid = jnp.full((tp * C_r + 1,), E_l, jnp.int32).at[dst].set(s_eid)[:-1]

    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, D), x_flat.dtype)], 0)
    xbuf = x_pad[slot_src].reshape(tp, C_r, D)
    eidbuf = slot_eid.reshape(tp, C_r)

    # ---- ship tokens + local-expert ids ------------------------------------
    xr = jax.lax.all_to_all(xbuf, "model", split_axis=0, concat_axis=0,
                            tiled=True)
    eidr = jax.lax.all_to_all(eidbuf, "model", split_axis=0, concat_axis=0,
                              tiled=True)

    # ---- dest-side: per-expert capacity buffers + expert FFN ---------------
    A_r = tp * C_r
    le = eidr.reshape(A_r)
    order2 = jnp.argsort(le, stable=True)
    s_le = le[order2]
    s_slot = jnp.arange(A_r, dtype=jnp.int32)[order2]
    counts2 = jnp.bincount(le, length=E_l + 1)[:E_l]
    starts2 = jnp.concatenate([jnp.zeros((1,), counts2.dtype),
                               jnp.cumsum(counts2)[:-1]])
    pos2 = jnp.arange(A_r, dtype=jnp.int32) - starts2[jnp.minimum(s_le, E_l - 1)]
    valid2 = (s_le < E_l) & (pos2 < C_e)
    dst2 = jnp.where(valid2, s_le * C_e + pos2, E_l * C_e)
    eslot_src = jnp.full((E_l * C_e + 1,), A_r, jnp.int32).at[dst2].set(s_slot)[:-1]

    xr_flat = xr.reshape(A_r, D)
    xr_pad = jnp.concatenate([xr_flat, jnp.zeros((1, D), xr_flat.dtype)], 0)
    xe = xr_pad[eslot_src].reshape(E_l, C_e, D)
    ye = _expert_ffn(cfg, xe, w_in, w_gate, w_out).reshape(E_l * C_e, D)

    yr = jnp.zeros((A_r + 1, D), xs.dtype).at[eslot_src].add(
        ye.astype(xs.dtype))[:A_r]

    # ---- ship back (slot-aligned) and combine at the source ----------------
    ybuf = jax.lax.all_to_all(yr.reshape(tp, C_r, D), "model",
                              split_axis=0, concat_axis=0, tiled=True)
    ybuf = ybuf.reshape(tp * C_r, D) * slot_gate[:, None].astype(xs.dtype)
    y = jnp.zeros((T_ls + 1, D), xs.dtype).at[slot_src].add(ybuf)[:T_ls]

    kept = jax.lax.psum(jnp.sum(valid.astype(jnp.float32)), mesh.axis_names) \
        - jax.lax.psum(jnp.sum((~valid2 & (s_le < E_l)).astype(jnp.float32)),
                       mesh.axis_names)
    return y.reshape(xs.shape), kept


def moe_apply(cfg: ModelConfig, p, x: jax.Array, ax: AxisRules, *,
              train: bool) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B,S,D) -> (y (B,S,D), aux metrics incl. load-balance loss)."""
    moe = cfg.moe
    B, S, D = x.shape
    E, k = moe.num_experts, moe.top_k
    cf = moe.capacity_factor_train if train else moe.capacity_factor_eval

    # ---- router under pjit ------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    gates = gates.astype(x.dtype)

    # load-balance aux (switch-style) + router z-loss
    flat_probs = probs.reshape(-1, E)
    count_e = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f_e = count_e / jnp.maximum(count_e.sum(), 1.0)
    P_e = jnp.mean(flat_probs, axis=0)
    lb_loss = E * jnp.sum(jax.lax.stop_gradient(f_e) * P_e)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- dispatch/compute/combine under shard_map -------------------------
    mesh = ax.mesh
    tp = ax.model_size()
    ep_mode = tp > 1 and E % tp == 0

    if mesh is None or mesh.empty or tp <= 1:
        x_flat = x.reshape(B * S, D)
        C_e = _capacity(B * S, k, E, cf, train=train)
        y, kept = _dispatch_compute_combine(
            cfg, x_flat, ids.reshape(B * S, k), gates.reshape(B * S, k),
            p["w_in"], p.get("w_gate"), p["w_out"],
            E=E, E_l=E, e_offset=0, C_e=C_e)
        y = y.reshape(B, S, D)
        total = jnp.float32(B * S * k)
    else:
        bspec = ax.batch(B)
        bspec_t = bspec if isinstance(bspec, tuple) else ((bspec,) if bspec else ())
        n_b = 1
        for a in bspec_t:
            n_b *= ax.axis_sizes[a]
        T_l = (B // n_b) * S
        E_l = E // tp if ep_mode else E
        C_e = _capacity(T_l, k, E, cf, train=train)
        xspec = P(bspec, None, None)
        # EP: expert axis sharded.  TPFF: expert d_ff sharded (w_in on its
        # last axis, w_out on its middle axis).
        wspec_in = P("model", None, None) if ep_mode else P(None, None, "model")
        wspec_out = P("model", None, None) if ep_mode else P(None, "model", None)
        a2a_mode = (ep_mode and S % tp == 0
                    and ax.opt("moe_dispatch", "psum") == "a2a")

        def body(xs, idss, gatess, w_in, w_gate, w_out):
            e_off = (jax.lax.axis_index("model") * E_l) if ep_mode else 0
            xf = xs.reshape(-1, D)
            y, kept = _dispatch_compute_combine(
                cfg, xf, idss.reshape(-1, k), gatess.reshape(-1, k),
                w_in, w_gate, w_out, E=E, E_l=E_l, e_offset=e_off, C_e=C_e)
            y = jax.lax.psum(y, "model")
            kept = jax.lax.psum(kept, mesh.axis_names)
            if not ep_mode:  # TPFF ranks duplicate the same assignments
                kept = kept / tp
            return y.reshape(xs.shape), kept

        w_gate = p.get("w_gate")
        if w_gate is None:  # keep arity static for shard_map
            w_gate = jnp.zeros((E, 1, 1), x.dtype)
            gspec = P("model", None, None) if ep_mode else P(None, None, None)
        else:
            gspec = wspec_in
        if a2a_mode:
            import functools as _ft
            T_ls = max(T_l // tp, 1)
            C_r = max(1, math.ceil(cf * T_ls * k / tp))
            xspec_a = P(bspec, "model", None)
            body_a = _ft.partial(_a2a_body, cfg, E=E, E_l=E_l, tp=tp,
                                 C_r=C_r, C_e=C_e, mesh=mesh)
            y, kept = shard_map(
                body_a, mesh=mesh,
                in_specs=(xspec_a, xspec_a, xspec_a, wspec_in, gspec,
                          wspec_out),
                out_specs=(xspec_a, P()),
                check_vma=False,
            )(x, ids, gates, p["w_in"], w_gate, p["w_out"])
        else:
            y, kept = shard_map(
                body, mesh=mesh,
                in_specs=(xspec, xspec, xspec, wspec_in, gspec, wspec_out),
                out_specs=(xspec, P()),
                check_vma=False,
            )(x, ids, gates, p["w_in"], w_gate, p["w_out"])
        total = jnp.float32(B * S * k)

    aux = {
        "moe_lb_loss": lb_loss,
        "moe_z_loss": z_loss,
        "moe_drop_frac": 1.0 - kept / total,
        "moe_load_cv": jnp.std(count_e) / jnp.maximum(jnp.mean(count_e), 1e-9),
    }
    return y, aux
