"""Latency-hiding pipelining layer: AF overlap modes (serial / legacy /
two-batch), chunked prefill with piggybacked decode, EP comm-compute
overlap, the PipelineConfig/PipelineSpec plumbing, and the new Report
observables (bubble_time, overlap_efficiency, exposed-comm fractions)."""
import numpy as np
import pytest

from repro.api import (
    ModelRef, PipelineSpec, SimSpec, SpecError, TopologySpec, WorkloadSpec,
    run,
)
from repro.configs import get_config
from repro.core import A800_SXM4_80G, ParallelismConfig, \
    simulate_af_decode_step
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.pipeline import (
    PIPELINES, PipelineConfig, resolve_pipeline,
)
from repro.core.predictor import ExecutionPredictor
from repro.core.routing import BalancedRouting

HW = A800_SXM4_80G
MCFG = get_config("mixtral-8x7b")
OPS = OperatorModelSet(HW)
LENS = [512] * 64


def _step(pipeline=None, **kw):
    args = dict(m=2, attn_par=ParallelismConfig(tp=2),
                ffn_par=ParallelismConfig(tp=1, ep=4),
                routing=BalancedRouting(),
                rng=np.random.default_rng(0))
    args.update(kw)
    return simulate_af_decode_step(MCFG, HW, OPS, LENS, pipeline=pipeline,
                                   **args)


# --------------------------------------------------------- config layer --
def test_resolve_pipeline_accepts_all_spellings():
    assert resolve_pipeline(None) is None
    cfg = PipelineConfig(af_overlap="two_batch")
    assert resolve_pipeline(cfg) is cfg
    assert resolve_pipeline("serial").af_overlap == "serial"
    byname = resolve_pipeline({"name": "two_batch", "nic_lanes": 2})
    assert byname.af_overlap == "two_batch" and byname.nic_lanes == 2
    plain = resolve_pipeline({"chunked_prefill": True, "prefill_chunk": 64})
    assert plain.chunked_prefill and plain.prefill_chunk == 64


def test_resolve_pipeline_rejects_bad_input():
    with pytest.raises(KeyError, match="unknown pipeline preset"):
        resolve_pipeline("warp_speed")
    with pytest.raises(TypeError):
        resolve_pipeline(42)
    with pytest.raises(ValueError, match="ep_overlap"):
        resolve_pipeline({"ep_overlap": 1.5})
    with pytest.raises(ValueError, match="af_overlap"):
        PipelineConfig(af_overlap="bogus").validate()


def test_registered_presets_are_valid():
    for name, cfg in PIPELINES.items():
        cfg.validate()
        assert cfg.enabled, name
    assert not PipelineConfig().enabled


# ------------------------------------------------------- AF step overlap --
def test_disabled_pipeline_is_bit_identical_to_legacy():
    """pipeline=None and a default PipelineConfig must reproduce exactly
    the same event graph (the acceptance bit-for-bit requirement)."""
    legacy = _step()
    off = _step(pipeline=PipelineConfig())
    assert off.makespan == legacy.makespan
    assert off.attn_busy == legacy.attn_busy
    assert off.ffn_busy == legacy.ffn_busy
    assert off.events == legacy.events
    assert off.rank_busy == legacy.rank_busy


def test_serial_mode_makespan_equals_sum_of_durations():
    st = _step(pipeline=PipelineConfig(af_overlap="serial"))
    assert st.makespan == pytest.approx(st.serial_makespan, rel=1e-9)
    assert st.overlap_efficiency == pytest.approx(0.0, abs=1e-9)


@pytest.mark.parametrize("m", [2, 4, 8])
def test_two_batch_overlap_strictly_beats_serial(m):
    serial = _step(m=m, pipeline=PipelineConfig(af_overlap="serial"))
    two = _step(m=m, pipeline=PipelineConfig(af_overlap="two_batch"))
    assert two.makespan < serial.makespan
    assert two.overlap_efficiency > 0.0
    assert two.serial_makespan == pytest.approx(serial.serial_makespan,
                                                rel=1e-9)


def test_single_microbatch_cannot_overlap():
    serial = _step(m=1, pipeline=PipelineConfig(af_overlap="serial"))
    two = _step(m=1, pipeline=PipelineConfig(af_overlap="two_batch"))
    assert two.makespan == pytest.approx(serial.makespan, rel=1e-9)


def test_overlap_metrics_well_formed():
    for pipe in (None, PipelineConfig(af_overlap="serial"),
                 PipelineConfig(af_overlap="two_batch"),
                 PipelineConfig(af_overlap="two_batch", ep_overlap=0.7)):
        st = _step(pipeline=pipe)
        assert st.bubble_time >= 0.0
        assert st.makespan <= st.serial_makespan * (1 + 1e-9)
        assert 0.0 <= st.overlap_efficiency <= 1.0
        assert st.attn_exposed_comm >= 0.0
        assert st.ffn_exposed_comm >= 0.0
        assert st.bubble_time == pytest.approx(
            st.makespan - st.attn_busy, abs=1e-12)


def test_nic_lane_contention_never_beats_free_transfers():
    """two_batch adds finite NIC lanes, so it can only be >= the legacy
    un-contended model; extra lanes monotonically relieve the contention."""
    free = _step(m=8)
    one_lane = _step(m=8, pipeline=PipelineConfig(af_overlap="two_batch",
                                                  nic_lanes=1))
    four_lanes = _step(m=8, pipeline=PipelineConfig(af_overlap="two_batch",
                                                    nic_lanes=4))
    assert one_lane.makespan >= free.makespan - 1e-15
    assert four_lanes.makespan <= one_lane.makespan + 1e-15


def test_ep_overlap_hides_comm_monotonically():
    mk = [
        _step(pipeline=PipelineConfig(ep_overlap=eta)).makespan
        for eta in (0.0, 0.4, 0.8, 1.0)
    ]
    assert all(a >= b - 1e-15 for a, b in zip(mk, mk[1:]))
    assert mk[-1] < mk[0]
    st = _step(pipeline=PipelineConfig(ep_overlap=0.8))
    assert st.ep_overlap_hidden > 0.0


def test_ep_overlap_zero_is_bit_identical():
    legacy = _step()
    eta0 = _step(pipeline=PipelineConfig(ep_overlap=0.0))
    assert eta0.makespan == legacy.makespan
    assert eta0.ep_overlap_hidden == 0.0


# --------------------------------------------- chunked prefill (mixed) --
def test_mixed_step_prices_attention_per_class():
    """A mixed chunked-prefill step = prefill attention for the chunk rows
    + decode attention for the piggybacked rows + GEMMs over the union."""
    cfg = get_config("qwen2-7b")
    pred = ExecutionPredictor(cfg, ParallelismConfig(tp=1), HW,
                              OperatorModelSet(HW), memoize=False)
    q = [256, 256] + [1] * 8
    kv = [256, 256] + [1000] * 8
    mixed = pred.step_time(q, kv, decode=False, n_prefill=2)
    pure_prefill = pred.step_time(q, kv, decode=False)
    # decode rows priced with the decode kernel differ from prefill pricing
    assert mixed.total != pure_prefill.total
    assert mixed.total > 0
    # and the class split covers the whole batch: attention equals the sum
    # of its per-class prices
    pf = pred.step_time(q[:2], kv[:2], decode=False)
    dc = pred.step_time(q[2:], kv[2:], decode=True)
    assert mixed.parts["attn"] == pytest.approx(
        pf.parts["attn"] + dc.parts["attn"], rel=1e-9)


def test_mixed_step_memo_keys_do_not_alias_pure_steps():
    pred = ExecutionPredictor(get_config("qwen2-7b"),
                              ParallelismConfig(tp=1), HW,
                              OperatorModelSet(HW))
    q = [128] + [1] * 4
    kv = [128] + [512] * 4
    a = pred.step_time(q, kv, decode=False)
    b = pred.step_time(q, kv, decode=False, n_prefill=1)
    assert a.total != b.total       # cached pure step must not be replayed


def test_chunked_prefill_piggybacks_decode_end_to_end():
    base = dict(
        model=ModelRef("qwen2-7b", smoke=True),
        topology=TopologySpec(preset="colocated", n_replicas=1),
        workload=WorkloadSpec(n_requests=40, rate=30.0, prompt_mean=1024,
                              output_mean=64, seed=2))
    off = run(SimSpec(**base))
    on = run(SimSpec(**base, pipeline=PipelineSpec(chunked_prefill=True,
                                                   prefill_chunk=256)))
    assert off.all_complete and on.all_complete
    piggy = sum(r.get("piggyback_tokens", 0)
                for r in on.clusters["colocated"]["replicas"].values())
    assert piggy > 0, "mixed prefill+decode batches should have formed"
    assert all("piggyback_tokens" not in r
               for r in off.clusters["colocated"]["replicas"].values())


def test_chunked_prefill_respects_explicit_policy():
    """An explicit batching policy wins over the pipeline's chunking."""
    spec = SimSpec(
        model=ModelRef("qwen2-7b", smoke=True),
        topology=TopologySpec(preset="colocated"),
        workload=WorkloadSpec(n_requests=10, rate=20.0, seed=0),
        policy={"batching": "static"},
        pipeline=PipelineSpec(chunked_prefill=True))
    from repro.api import build
    handle = build(SimSpec.from_dict(spec.to_dict()))
    pol = handle.clusters["colocated"].replicas[0].policy
    assert pol.name == "static"


# ------------------------------------------------------------ API layer --
def _af_base(**pipeline):
    return dict(
        model=ModelRef("mixtral-8x7b", smoke=True),
        topology=TopologySpec(preset="af", n_prefill=1, n_decode=1, m=4,
                              ffn_ep=4),
        workload=WorkloadSpec(n_requests=30, rate=20.0, prompt_mean=256,
                              output_mean=32, seed=1),
        **({"pipeline": PipelineSpec(**pipeline)} if pipeline else {}))


def test_af_report_carries_overlap_observables():
    rep = run(SimSpec(**_af_base(preset="two_batch")))
    assert "bubble_time_s" in rep.summary
    assert "overlap_efficiency" in rep.summary
    assert rep.summary["bubble_time_s"] >= 0.0
    af = rep.clusters["decode"]["af"]
    for key in ("serial_makespan_s", "bubble_time_s", "overlap_efficiency",
                "attn_exposed_comm_frac", "ffn_exposed_comm_frac"):
        assert key in af, key
    assert af["makespan_s"] <= af["serial_makespan_s"] * (1 + 1e-9)


def test_af_two_batch_beats_serial_end_to_end():
    serial = run(SimSpec(**_af_base(preset="serial")))
    two = run(SimSpec(**_af_base(preset="two_batch")))
    assert (two.clusters["decode"]["af"]["makespan_s"]
            < serial.clusters["decode"]["af"]["makespan_s"])
    assert two.summary["overlap_efficiency"] > \
        serial.summary["overlap_efficiency"]


def test_disabling_pipeline_reproduces_legacy_report_bit_for_bit():
    """spec.pipeline=None must equal the pre-pipelining simulator."""
    off = run(SimSpec(**_af_base()))
    off2 = run(SimSpec(**_af_base()))
    assert off.summary == off2.summary
    # the only additions with pipelining off are the new observables
    two = run(SimSpec(**_af_base(preset="two_batch", ep_overlap=0.0,
                                 nic_lanes=64)))
    # with more NIC lanes than in-flight transfers, two_batch == free-NIC
    assert two.summary["tpot_p50_s"] == off.summary["tpot_p50_s"]


def test_pipeline_spec_validation_and_roundtrip():
    spec = SimSpec(**_af_base(preset="full_overlap", prefill_chunk=128))
    again = SimSpec.from_yaml(spec.to_yaml())
    assert again.spec_hash() == spec.spec_hash()
    cfg = again.pipeline.to_config()
    assert cfg.af_overlap == "two_batch" and cfg.chunked_prefill
    assert cfg.prefill_chunk == 128
    with pytest.raises(SpecError, match="pipeline.preset"):
        SimSpec(pipeline=PipelineSpec(preset="bogus")).validate()
    with pytest.raises(SpecError, match="pipeline.af_overlap"):
        SimSpec(pipeline=PipelineSpec(af_overlap="bogus")).validate()
    with pytest.raises(SpecError, match="pipeline"):
        SimSpec(pipeline=PipelineSpec(ep_overlap=2.0)).validate()
    named = SimSpec.from_dict({"pipeline": "two_batch"})
    assert named.pipeline.to_config().af_overlap == "two_batch"
    # to_config() itself must refuse unknown presets, not silently
    # compile them to the no-op legacy config
    with pytest.raises(KeyError, match="unknown pipeline preset"):
        PipelineSpec(preset="two_bach").to_config()


def test_inline_cluster_pipeline_key():
    spec = SimSpec.from_dict({
        "model": {"name": "mixtral-8x7b", "smoke": True},
        "topology": {"preset": None, "clusters": [
            {"name": "prefill", "role": "prefill",
             "pipeline": "chunked_prefill"},
            {"name": "decode", "role": "decode", "step": "af", "m": 2,
             "ffn_ep": 4,
             "pipeline": {"name": "two_batch", "ep_overlap": 0.5}},
        ], "links": [
            {"src": "prefill", "dst": "decode", "bandwidth": 5.0e10},
        ]},
        "workload": {"n_requests": 15, "rate": 20.0, "prompt_mean": 128,
                     "output_mean": 16},
    })
    rep = run(spec)
    assert rep.all_complete
    assert rep.clusters["decode"]["af"]["ep_overlap_hidden_s"] > 0.0
