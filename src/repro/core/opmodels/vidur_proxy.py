"""Vidur-style sqrt-proxy operator model (the paper's comparison baseline).

Vidur collapses a heterogeneous batch of sequence lengths into a single
proxy length (the square root of the summed squared lengths spread over the
batch) and predicts runtime for the *homogenized* batch.  This is accurate
for uniform batches but loses tail/imbalance structure — the paper measures
>55% error on skewed FlashAttention batches (Fig. 2).

We give the proxy model the SAME ground-truth oracle (the virtual-kernel
simulator) the RF model is trained on, so the comparison isolates the
*workload representation*, exactly as in the paper.
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from repro.core.opmodels.kernelsim import VirtualKernels


class VidurProxyModel:
    def __init__(self, kernels: VirtualKernels):
        self.kernels = kernels

    def attention_prefill(self, q_lens: Sequence[int], kv_lens: Sequence[int],
                          n_heads: int, n_kv_heads: int, head_dim: int, *,
                          causal: bool = True, window: int = 0) -> float:
        kv = np.minimum(kv_lens, window) if window else np.asarray(kv_lens)
        q = np.asarray(q_lens, np.float64)
        # proxy: one homogenized batch at sqrt of mean squared length
        proxy = float(np.sqrt(np.mean(np.asarray(kv, np.float64) ** 2)))
        proxy = max(int(round(proxy)), 1)
        b = max(int(round(q.sum() / proxy)), 1)
        return self.kernels.attention_prefill(
            [proxy] * b, [proxy] * b, n_heads, n_kv_heads, head_dim,
            causal=causal, window=window)

    def attention_decode(self, context_lens: Sequence[int], n_heads: int,
                         n_kv_heads: int, head_dim: int, *,
                         window: int = 0) -> float:
        kv = np.minimum(context_lens, window) if window \
            else np.asarray(context_lens)
        proxy = float(np.sqrt(np.mean(np.asarray(kv, np.float64) ** 2)))
        proxy = max(int(round(proxy)), 1)
        return self.kernels.attention_decode(
            [proxy] * len(context_lens), n_heads, n_kv_heads, head_dim,
            window=window)

    def grouped_gemm(self, tokens_per_expert: Sequence[int], d_in: int,
                     d_out: int) -> float:
        """Vidur has no GroupedGEMM model (Table 1) — homogenized fallback."""
        c = np.asarray(tokens_per_expert, np.float64)
        mean = max(int(round(c.mean())), 1) if len(c) else 1
        return self.kernels.grouped_gemm([mean] * len(c), d_in, d_out)
