"""AF (Attention/FFN) disaggregation — MegaScale-Infer / Step-3 style.

One decode step is simulated as an *event dependency graph*: the global
batch is partitioned into m micro-batches; ATTN_COMPUTE(i,k) runs on the
attention cluster, A2F_TRANSFER(i,k) ships activations, the FFN stage runs
on the FFN cluster, F2A_TRANSFER(i,k) returns.  The event engine schedules
each node as soon as its dependencies are met, capturing the ping-pong
latency hiding: while A2F(i,k) is in flight the attention cluster computes
ATTN(i+1,k).  The step time is the timestamp of the final event — the
critical path.

Expert parallelism is first-class: an MoE FFN stage is not a scalar max()
but an explicit per-EP-rank sub-graph per micro-batch —

    gate -> EXPERT_DISPATCH(r) [all-to-all, per rank]
         -> EXPERT_RANK(r)     [heterogeneous GroupedGEMM per rank]
         -> barrier            [straggler: last rank gates the combine]
         -> EXPERT_COMBINE     [all-to-all + shared experts]

Ranks listed in ``remote_ranks`` host their expert shards on a *different
cluster*: their dispatch/combine legs traverse an inter-cluster LinkSpec
(lower bandwidth, extra latency) and their GroupedGEMM runs on that
cluster's operator models (heterogeneous hardware) — the cross-cluster
expert-routing regime.  Because dispatch and combine are collectives, the
EP group advances in lockstep: micro-batch i+1's experts start only after
micro-batch i's combine has completed on every rank.

The *resource model* of one step is selected by a
:class:`repro.core.pipeline.PipelineConfig` (see that module):
``af_overlap="none"`` keeps the legacy lanes (attention compute + FFN
lockstep, un-contended transfers), ``"serial"`` chains every task on one
resource (the no-latency-hiding baseline; step time = sum of durations),
and ``"two_batch"`` adds per-direction NIC lanes so transfers contend but
hide behind the other micro-batch's attention.  ``ep_overlap`` hides the
per-rank dispatch/combine legs behind GroupedGEMM compute at a configured
efficiency.  Every step also books its serial (no-overlap) makespan, so
``overlap_efficiency = 1 - makespan/serial_makespan`` and the exposed-comm
fractions are first-class observables.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig
from repro.core.engine import SimEngine
from repro.core.events import EV
from repro.core.hardware import HardwareSpec, LinkSpec, ParallelismConfig
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.pipeline import PipelineConfig
from repro.core.predictor import ExecutionPredictor, StepBreakdown
from repro.core.routing import RoutingModule, split_by_rank


@dataclass
class AFStepStats:
    makespan: float = 0.0
    attn_busy: float = 0.0
    ffn_busy: float = 0.0
    transfer_bytes: float = 0.0
    attn_bubble_frac: float = 0.0
    ffn_bubble_frac: float = 0.0
    events: int = 0
    # latency-hiding observability (pipelining layer)
    serial_makespan: float = 0.0      # sum of all task durations (no overlap)
    bubble_time: float = 0.0          # attention-lane idle within makespan
    overlap_efficiency: float = 0.0   # 1 - makespan / serial_makespan
    attn_exposed_comm: float = 0.0    # F2A time that stalled the attn lane
    ffn_exposed_comm: float = 0.0     # A2F time that stalled the FFN group
    ep_overlap_hidden: float = 0.0    # EP a2a time hidden behind GEMMs
    # expert-parallel observability (per-EP-rank event graph)
    ep_dispatch_time: float = 0.0     # sum over stages of the dispatch leg
    ep_combine_time: float = 0.0      # sum over stages of the combine leg
    ep_straggler_excess: float = 0.0  # sum of (last rank - mean rank) waits
    rank_busy: List[float] = field(default_factory=list)  # GEMM time per rank
    cross_cluster_bytes: float = 0.0  # dispatch+combine bytes on remote link


def simulate_af_decode_step(cfg: ModelConfig, hw: HardwareSpec,
                            ops: OperatorModelSet,
                            context_lens: Sequence[int], *,
                            m: int, attn_par: ParallelismConfig,
                            ffn_par: ParallelismConfig,
                            routing: Optional[RoutingModule] = None,
                            rng: Optional[np.random.Generator] = None,
                            remote_ranks: Sequence[int] = (),
                            remote_link: Optional[LinkSpec] = None,
                            remote_ops: Optional[OperatorModelSet] = None,
                            pipeline: Optional[PipelineConfig] = None,
                            trace: Optional[Callable] = None,
                            ) -> AFStepStats:
    """Event-dependency-graph simulation of ONE decode step (one token).

    By default the per-EP-rank EXPERT_DISPATCH_DONE / EXPERT_RANK_DONE
    markers are *virtual*: their timestamps and stats are computed exactly
    but no Event objects enter the engine (they carry no callbacks, and
    materializing 2·ep of them per stage dominated MoE stepping).
    ``stats.events`` still counts them.  Pass ``trace`` (an event callback,
    as for :class:`SimEngine`) to emit them as real events at identical
    timestamps in identical per-rank order — they then drain through the
    engine's same-timestamp batch dispatch instead of one callback per
    marker.
    """
    rng = rng or np.random.default_rng(0)
    virtual_markers = 0
    mode = pipeline.af_overlap if pipeline is not None else "none"
    eta = pipeline.ep_overlap if pipeline is not None else 0.0
    nic_lanes = pipeline.nic_lanes if pipeline is not None else 1
    L = cfg.num_layers
    # np.array_split semantics by hand (first n % m chunks get one extra
    # element) — the values are identical, without the per-call numpy cost
    lens_list = list(context_lens)
    n_req = len(lens_list)
    q_sz, r_sz = divmod(n_req, max(m, 1))
    micro = []
    off = 0
    for j in range(max(m, 1)):
        sz = q_sz + (1 if j < r_sz else 0)
        if sz:
            micro.append(lens_list[off:off + sz])
        off += sz
    m_eff = len(micro)
    d = cfg.d_model
    ep = max(ffn_par.ep, ffn_par.tp, 1) if cfg.moe is not None else 1
    remote = frozenset(int(r) for r in remote_ranks)
    if remote and not all(0 <= r < ep for r in remote):
        raise ValueError(f"remote_ranks {sorted(remote)} out of range for "
                         f"ep={ep}")
    if remote and remote_link is None:
        raise ValueError("remote_ranks given without a remote_link — the "
                         "cross-cluster legs would not be modeled")
    r_ops = remote_ops or ops

    # ---- per-step pricing precompute -------------------------------------
    # The same micro-batch shapes are re-priced once per layer per stage;
    # compute operators and intra-node collectives are pure for every
    # model set (FabricOps delegates them verbatim), so their
    # per-(micro, kind) results are computed once up front.  Inter-node
    # transfer pricing (m2n/p2p) may account per call into a fabric, so it
    # is pre-priced only for the base analytical methods and stays a
    # per-event call otherwise.
    ops_t = type(ops)
    xfer_cacheable = (ops_t.m2n is OperatorModelSet.m2n
                      and ops_t.p2p is OperatorModelSet.p2p)

    # ---- per-(microbatch, layer) task durations --------------------------
    def t_attn(lens: List[int], kind: str) -> float:
        tp = max(attn_par.tp, 1)
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        t = ops.gemm(len(lens), (H + 2 * K) * hd // tp, d)
        t += ops.attention_decode(lens, H // tp, max(K // tp, 1), hd,
                                  window=window)
        t += ops.gemm(len(lens), d, H * hd // tp)
        t += ops.all_reduce(2.0 * len(lens) * d, tp)
        return t

    def t_ffn_dense(n_tok: int) -> float:
        n_mats = 3 if cfg.gated_mlp else 2
        tp = max(ffn_par.tp, 1)
        return (n_mats * ops.gemm(n_tok, cfg.d_ff // tp, d)
                + ops.all_reduce(2.0 * n_tok * d, tp))

    # A2F/F2A is MegaScale's M2N fan: attention ranks to FFN ranks (EP
    # group for MoE, TP group for dense).  The flat model prices it exactly
    # as p2p; FabricOps spreads the payload over the narrow side's NICs.
    n_attn = max(attn_par.devices, 1)
    n_ffn = max(ep, ffn_par.devices, 1)

    tb = [2.0 * len(c) * d for c in micro]   # A2F/F2A payload per micro
    xfer_dur = ([ops.m2n(tbi, n_attn, n_ffn) for tbi in tb]
                if xfer_cacheable else None)

    attn_kinds = [k for k in cfg.pattern]
    stats = AFStepStats()
    stats.rank_busy = [0.0] * ep
    moe = cfg.moe
    n_mats_moe = 3 if cfg.gated_mlp else 2

    # attention duration per (micro, layer) — pure pricing, computed once
    attn_dur: List[List[float]] = []
    for c in micro:
        per_kind: dict = {}
        row = []
        for kind in attn_kinds:
            v = per_kind.get(kind)
            if v is None:
                if kind not in (ATTN_GLOBAL, ATTN_LOCAL):
                    # recurrent block: runs on the attention cluster too
                    v = ops.gemm(len(c), d, d) * 3
                else:
                    v = t_attn(c, kind)
                per_kind[kind] = v
            row.append(v)
        attn_dur.append(row)
    ffn_dense_dur = ([t_ffn_dense(len(c)) for c in micro]
                     if moe is None else None)
    # MoE fixed stage pricing per micro: (gate, a2a leg, shared tail,
    # gate + a2a leg)
    moe_fixed: List[tuple] = []
    if moe is not None:
        for c in micro:
            n_tok = len(c)
            t_gate = ops.gemm(n_tok, moe.num_experts, d)
            a2a_base = ops.all_to_all(2.0 * n_tok * moe.top_k * d / ep, ep)
            t_shared = (n_mats_moe * ops.gemm(
                n_tok, moe.expert_d_ff * moe.num_shared_experts, d)
                if moe.num_shared_experts else 0.0)
            moe_fixed.append((t_gate, a2a_base, t_shared, t_gate + a2a_base))

    # ---- fused per-EP-rank GroupedGEMM pricing ---------------------------
    # With base-analytical (or pure-delegating fabric) models the per-rank
    # straggler pricing collapses to scalar roofline arithmetic inside the
    # stage loop: the flop/byte tallies are exact integers, so
    # coefficient-times-token-sum is bit-identical to the scalar
    # grouped_gemm walk.  Heterogeneous remote expert clusters contribute
    # per-rank (peak, hbm, overhead) triples.
    if cfg.moe is not None:
        from repro.core.opmodels.batch import analytic_roofline_hw
        E = cfg.moe.num_experts
        base_sz, rem_sz = divmod(E, ep)
        rank_bounds = []
        off = 0
        for r in range(ep):
            n = base_sz + (1 if r < rem_sz else 0)
            rank_bounds.append((off, off + n))
            off += n
        rank_groups = [b - a for a, b in rank_bounds]
        local_hw = analytic_roofline_hw(ops)
        rem_hw = analytic_roofline_hw(r_ops)
        if local_hw is not None and rem_hw is not None:
            gg_hw = [rem_hw if r in remote else local_hw for r in range(ep)]
        else:
            gg_hw = None
        gg_cf = 2.0 * d * cfg.moe.expert_d_ff   # flops per routed token
        gg_cb1 = 2 * (d + cfg.moe.expert_d_ff)  # activation bytes per token
        gg_cb2 = 2 * d * cfg.moe.expert_d_ff    # weight bytes per group
        # hot-loop specialization: analytic roofline, one expert shard per
        # rank, all ranks on the local cluster (no per-rank legs/hw)
        gg_fast = gg_hw is not None and not remote and E == ep
        if gg_fast:
            gg_peak, gg_hbm, gg_oh = gg_hw[0]
        is_rem = [r in remote for r in range(ep)]
        if remote:
            # remote_link is guaranteed non-None here (validated above);
            # its pricing is latency + nbytes/bandwidth, inlined in the
            # stage loop (surface the canonical bandwidth error up front)
            link_lat = remote_link.latency
            link_bw = remote_link.bandwidth
            if link_bw <= 0:
                remote_link.transfer_time(0.0)

    # ---- resources & dependency-driven scheduling -------------------------
    # "none":      attention lane + FFN lockstep lane; transfers free.
    # "serial":    ONE chain shared by everything (no-latency-hiding
    #              baseline — makespan == sum of task durations).
    # "two_batch": attention lane + FFN lane + per-direction NIC lanes
    #              (transfers contend but overlap compute — ping-pong).
    if mode == "serial":
        chain = [0.0]
        attn_free = ffn_free = chain
    else:
        attn_free = [0.0]    # attention cluster: single pipeline
        ffn_free = [0.0]     # FFN/EP group: lockstep (collectives barrier it)
    a2f_nic = [0.0] * nic_lanes
    f2a_nic = [0.0] * nic_lanes

    not_serial = mode != "serial"
    serial_mode = not not_serial
    nic_free = mode == "none"
    two_batch = mode == "two_batch"

    if trace is not None:
        # ---- traced path: real marker events drain through SimEngine ------
        eng = SimEngine(trace=trace)
        # markers are observational: batch-drain contiguous runs (the
        # replay order and per-event trace callbacks are unchanged)
        eng.register_batch_handler(EV.EXPERT_DISPATCH_DONE, lambda evs: None)
        eng.register_batch_handler(EV.EXPERT_RANK_DONE, lambda evs: None)
        done_f2a = {i: 0.0 for i in range(m_eff)}  # F2A(i, k-1) completion
        f2a_dur = {i: 0.0 for i in range(m_eff)}   # its transfer duration

        def xfer_start(lanes: List[float], dur: float) -> float:
            """Transfer start time under the mode's NIC resource model."""
            if serial_mode:
                start = max(eng.now, attn_free[0])   # the one shared chain
                attn_free[0] = start + dur
                return start
            if two_batch:
                j = min(range(len(lanes)), key=lambda n: lanes[n])
                start = max(eng.now, lanes[j])
                lanes[j] = start + dur
                return start
            return eng.now                           # legacy: un-contended

        def schedule_attn(i: int, k: int, ev=None):
            dur = attn_dur[i][k]
            if k > 0 and not_serial:
                # F2A return time that the attention lane could not hide
                stats.attn_exposed_comm += max(
                    0.0, min(done_f2a[i] - attn_free[0], f2a_dur[i]))
            start = max(eng.now, attn_free[0], done_f2a[i])
            attn_free[0] = start + dur
            stats.attn_busy += dur
            stats.serial_makespan += dur
            eng.at(start + dur, EV.ATTN_COMPUTE_DONE,
                   lambda ev: schedule_a2f(i, k), i=i, k=k)

        def schedule_a2f(i: int, k: int):
            dur = (xfer_dur[i] if xfer_dur is not None
                   else ops.m2n(tb[i], n_attn, n_ffn))
            stats.transfer_bytes += tb[i]
            stats.serial_makespan += dur
            if serial_mode:
                stats.ffn_exposed_comm += dur  # nothing hides on one chain
            start = eng.now if nic_free else xfer_start(a2f_nic, dur)
            eng.at(start + dur, EV.A2F_TRANSFER_DONE,
                   lambda ev: schedule_ffn(i, k, dur), i=i, k=k)

        def schedule_ffn(i: int, k: int, xfer: float = 0.0):
            if not_serial:
                # A2F delivery time that stalled the (idle) FFN group
                stats.ffn_exposed_comm += max(
                    0.0, min(eng.now - ffn_free[0], xfer))
            if moe is None:
                dur = ffn_dense_dur[i]
                start = max(eng.now, ffn_free[0])
                ffn_free[0] = start + dur
                stats.ffn_busy += dur
                stats.serial_makespan += dur
                eng.at(start + dur, EV.FFN_COMPUTE_DONE,
                       lambda ev: schedule_f2a(i, k), i=i, k=k)
            else:
                schedule_experts(i, k)

        # ---- the per-EP-rank expert sub-graph -----------------------------

        def schedule_experts(i: int, k: int):
            t0 = max(eng.now, ffn_free[0])
            t_gate, a2a_base, t_shared, tgb = moe_fixed[i]
            # the routing draw stays at event-execution time: stage order
            # is dynamic, so pre-drawing would reorder the rng sequence
            counts = (routing.assign(len(micro[i]), moe.num_experts,
                                     moe.top_k, rng)
                      if routing is not None else
                      np.full(moe.num_experts,
                              len(micro[i]) * moe.top_k // moe.num_experts))
            counts_l = counts.tolist()

            # dispatch and combine are collectives: the group advances in
            # lockstep, so the whole stage timeline is fixed once the
            # dispatch starts — compute it, reserve the group through the
            # combine, and book the per-rank events at their true
            # timestamps.  With ep_overlap=eta the a2a legs hide behind
            # GroupedGEMM compute (chunked dispatch): comm+compute pairs
            # cost (1-eta)*(comm+compute) + eta*max(comm, compute).
            rank_busy = stats.rank_busy
            eh = stats.ep_overlap_hidden
            t0g = t0 + t_gate
            serial_finish = 0.0
            barrier = 0.0
            fin_sum = 0.0
            max_leg = a2a_base
            if gg_fast:
                # one expert shard per rank, uniform local hardware,
                # constant dispatch leg — scalar roofline per rank
                cf, cb1, cb2 = gg_cf, gg_cb1, gg_cb2
                peak, hbm, oh = gg_peak, gg_hbm, gg_oh
                nm = n_mats_moe
                for r, s_r in enumerate(counts_l):
                    rf = cf * s_r / peak
                    rb = (cb1 * s_r + cb2) / hbm
                    dur = nm * ((rf if rf > rb else rb) + oh)
                    rank_busy[r] += dur
                    sf = tgb + dur
                    if sf > serial_finish:
                        serial_finish = sf
                    if eta != 0.0:
                        hidden = eta * (a2a_base if a2a_base < dur else dur)
                        eh += hidden
                        t_ready = t0g + (a2a_base - hidden)
                    else:
                        t_ready = t0g + a2a_base
                    fin = t_ready + dur
                    fin_sum += fin
                    if fin > barrier:
                        barrier = fin
                    eng.at(t_ready, EV.EXPERT_DISPATCH_DONE, None,
                           i=i, k=k, r=r)
                    eng.at(fin, EV.EXPERT_RANK_DONE, None, i=i, k=k, r=r)
            else:
                # general path: remote per-rank legs (cross-cluster link),
                # multi-expert shards, heterogeneous hw, non-analytic models
                per_rank = None if gg_hw is not None else \
                    split_by_rank(np.asarray(counts), ep)
                for r in range(ep):
                    a, b = rank_bounds[r]
                    s_r = counts_l[a] if b - a == 1 else sum(counts_l[a:b])
                    if gg_hw is not None:
                        peak, hbm, oh = gg_hw[r]
                        rf = gg_cf * s_r / peak
                        rb = (gg_cb1 * s_r + gg_cb2 * rank_groups[r]) / hbm
                        dur = n_mats_moe * ((rf if rf > rb else rb) + oh)
                    else:
                        dur = n_mats_moe * (
                            r_ops if r in remote else ops).grouped_gemm(
                                list(per_rank[r]), d, moe.expert_d_ff)
                    rank_busy[r] += dur
                    if is_rem[r]:
                        nbytes = 2.0 * float(s_r) * d
                        # dispatch + combine each traverse the link once
                        stats.cross_cluster_bytes += 2.0 * nbytes
                        leg = a2a_base + (link_lat + nbytes / link_bw)
                        t_gl = t_gate + leg
                        if leg > max_leg:
                            max_leg = leg
                    else:
                        leg = a2a_base
                        t_gl = tgb
                    sf = t_gl + dur
                    if sf > serial_finish:
                        serial_finish = sf
                    hidden = eta * (leg if leg < dur else dur)
                    eh += hidden
                    t_ready = t0g + (leg - hidden)
                    fin = t_ready + dur
                    fin_sum += fin
                    if fin > barrier:
                        barrier = fin
                    eng.at(t_ready, EV.EXPERT_DISPATCH_DONE, None,
                           i=i, k=k, r=r)
                    eng.at(fin, EV.EXPERT_RANK_DONE, None, i=i, k=k, r=r)
            stats.ep_overlap_hidden = eh
            stats.ep_straggler_excess += barrier - fin_sum / ep
            stats.ep_dispatch_time += max_leg
            t_comb = max_leg
            if eta > 0.0:
                # combine a2a overlaps the shared-expert GEMM tail at eta
                tail = ((1.0 - eta) * (t_comb + t_shared)
                        + eta * max(t_comb, t_shared))
                stats.ep_overlap_hidden += (t_comb + t_shared) - tail
            else:
                tail = t_comb + t_shared
            end = barrier + tail
            # combine leg + the serial shared-expert tail (dispatch_time
            # covers only the inbound collective, so the fields stay
            # distinct)
            stats.ep_combine_time += t_comb + t_shared
            # the no-overlap baseline runs EP ranks in parallel but
            # overlaps nothing else: gate + slowest (dispatch + GEMM) +
            # combine + shared
            stats.serial_makespan += serial_finish + t_comb + t_shared
            ffn_free[0] = end
            stats.ffn_busy += end - t0
            eng.at(end, EV.EXPERT_COMBINE_DONE,
                   lambda ev: schedule_f2a(i, k), i=i, k=k)

        def schedule_f2a(i: int, k: int):
            dur = (xfer_dur[i] if xfer_dur is not None
                   else ops.m2n(tb[i], n_attn, n_ffn))
            stats.transfer_bytes += tb[i]
            stats.serial_makespan += dur
            if serial_mode:
                stats.attn_exposed_comm += dur
            start = eng.now if nic_free else xfer_start(f2a_nic, dur)

            def done(ev):
                done_f2a[i] = eng.now
                f2a_dur[i] = dur
                if k + 1 < L:
                    schedule_attn(i, k + 1)
            eng.at(start + dur, EV.F2A_TRANSFER_DONE, done, i=i, k=k)

        for i in range(m_eff):
            schedule_attn(i, 0)
        eng.run()
        makespan_now = eng.now
        processed = eng.processed
    else:
        # ---- untraced fast path: inline stage state machine ---------------
        # The AF graph keeps exactly one pending event per live micro-batch
        # chain (every dispatch schedules at most one successor), so the
        # engine collapses to picking the earliest (time, creation-seq)
        # continuation among the chains and running its handler inline.
        # Events are clamped below `now` at scheduling time exactly like
        # SimEngine.at, so the dynamic stage order (and therefore the
        # routing rng draw order) is bit-for-bit the traced engine's; every
        # float expression below mirrors the traced closures verbatim, so
        # all stats agree bit-for-bit too (asserted by
        # test_virtual_markers_bit_identical_to_traced_event_path).
        now = 0.0
        seq = 0
        processed = 0
        live = 0
        # per-chain continuation: 1=A2F transfer, 2=FFN/expert stage,
        # 3=F2A transfer, 4=next-stage attention; 0=chain complete
        c_time = [0.0] * m_eff
        c_seq = [0] * m_eff
        c_phase = [0] * m_eff
        c_k = [0] * m_eff
        c_x = [0.0] * m_eff          # carried A2F/F2A transfer duration
        rank_busy = stats.rank_busy
        attn_busy = 0.0
        ffn_busy = 0.0
        transfer_bytes = 0.0
        serial_mk = 0.0
        attn_exposed = 0.0
        ffn_exposed = 0.0
        eh = 0.0
        ep_disp = 0.0
        ep_comb = 0.0
        straggler = 0.0
        cross_bytes = 0.0
        if moe is not None:
            micro_n = [len(c) for c in micro]
            assign = routing.assign if routing is not None else None
            n_experts = moe.num_experts
            top_k = moe.top_k
            d_ff_moe = moe.expert_d_ff
            if assign is None:
                fb_counts = [np.full(n_experts, n * top_k // n_experts)
                             for n in micro_n]
                fb_counts_l = [c.tolist() for c in fb_counts]
            gg_tab = None
            gg_tabs = None
            smax = max(micro_n) * top_k

            def _gg_table(peak, hbm, oh):
                # dur is a pure function of the per-rank token sum, which
                # is bounded by n_tok * top_k — tabulate the roofline once
                # per step (identical expression, identical bits; valid
                # for one expert shard per rank, where the weight-bytes
                # term gg_cb2 * rank_groups[r] is exactly gg_cb2)
                tab = []
                for s in range(smax + 1):
                    rf = gg_cf * s / peak
                    rb = (gg_cb1 * s + gg_cb2) / hbm
                    tab.append(n_mats_moe * ((rf if rf > rb else rb) + oh))
                return tab

            if gg_fast:
                gg_tab = _gg_table(gg_peak, gg_hbm, gg_oh)
            elif gg_hw is not None and moe.num_experts == ep:
                # analytic per-rank hw with one shard per rank but remote
                # ranks / heterogeneous clusters: one table per distinct
                # (peak, hbm, overhead), plus tabulated link legs
                by_hw = {}
                gg_tabs = []
                for t in gg_hw:
                    tab = by_hw.get(t)
                    if tab is None:
                        tab = by_hw[t] = _gg_table(*t)
                    gg_tabs.append(tab)
                if remote:
                    lk_tab = []
                    cross_tab = []
                    for s in range(smax + 1):
                        nbytes = 2.0 * float(s) * d
                        cross_tab.append(2.0 * nbytes)
                        lk_tab.append(link_lat + nbytes / link_bw)

        def xfer_start_u(lanes: List[float], dur: float,
                         now_: float) -> float:
            """Transfer start time under the mode's NIC resource model."""
            if serial_mode:
                start = max(now_, attn_free[0])      # the one shared chain
                attn_free[0] = start + dur
                return start
            if two_batch:
                j = min(range(len(lanes)), key=lambda n: lanes[n])
                start = max(now_, lanes[j])
                lanes[j] = start + dur
                return start
            return now_                              # legacy: un-contended

        # kick off stage 0 on every chain, in chain order (matches the
        # traced path's schedule_attn(i, 0) loop; done_f2a is 0.0 == now)
        for i in range(m_eff):
            dur = attn_dur[i][0]
            start = attn_free[0]
            if start < now:
                start = now
            attn_free[0] = start + dur
            attn_busy += dur
            serial_mk += dur
            seq += 1
            t = start + dur
            c_time[i] = t if t > now else now
            c_seq[i] = seq
            c_phase[i] = 1
            live += 1

        two_chains = m_eff == 2
        while live:
            # earliest (time, creation-seq) continuation — SimEngine order
            if two_chains:
                if c_phase[0]:
                    if c_phase[1]:
                        t0_ = c_time[0]
                        t1_ = c_time[1]
                        if t0_ < t1_ or (t0_ == t1_
                                         and c_seq[0] < c_seq[1]):
                            i = 0
                            now = t0_
                        else:
                            i = 1
                            now = t1_
                    else:
                        i = 0
                        now = c_time[0]
                else:
                    i = 1
                    now = c_time[1]
            else:
                bi = 0
                bt = None
                bs = 0
                for j in range(m_eff):
                    if c_phase[j]:
                        tj = c_time[j]
                        if (bt is None or tj < bt
                                or (tj == bt and c_seq[j] < bs)):
                            bt = tj
                            bs = c_seq[j]
                            bi = j
                i = bi
                now = bt
            processed += 1
            P = c_phase[i]
            if P == 2:
                # FFN/expert stage (A2F_TRANSFER_DONE handler)
                if not_serial:
                    # A2F delivery time that stalled the (idle) FFN group
                    v = now - ffn_free[0]
                    x_ = c_x[i]
                    if x_ < v:
                        v = x_
                    if v > 0.0:
                        ffn_exposed += v
                if moe is None:
                    dur = ffn_dense_dur[i]
                    start = ffn_free[0]
                    if start < now:
                        start = now
                    ffn_free[0] = start + dur
                    ffn_busy += dur
                    serial_mk += dur
                    end = start + dur
                else:
                    t0 = ffn_free[0]
                    if t0 < now:
                        t0 = now
                    mf = moe_fixed[i]
                    t_gate = mf[0]
                    a2a_base = mf[1]
                    t_shared = mf[2]
                    tgb = mf[3]
                    # the routing draw stays at event-execution time:
                    # stage order is dynamic, so pre-drawing would
                    # reorder the rng sequence
                    if assign is not None:
                        counts = assign(micro_n[i], n_experts, top_k, rng)
                        counts_l = counts.tolist()
                    else:
                        counts = fb_counts[i]
                        counts_l = fb_counts_l[i]
                    t0g = t0 + t_gate
                    fin_sum = 0.0
                    max_leg = a2a_base
                    if gg_fast:
                        tab = gg_tab
                        if eta == 0.0:
                            # max()/+const commute bit-wise (rounding is
                            # monotone), so only the max dur is tracked
                            t_ready = t0g + a2a_base
                            max_dur = 0.0
                            r = 0
                            for s_r in counts_l:
                                dur = tab[s_r]
                                rank_busy[r] += dur
                                r += 1
                                if dur > max_dur:
                                    max_dur = dur
                                fin_sum += t_ready + dur
                            serial_finish = tgb + max_dur
                            barrier = t_ready + max_dur
                        else:
                            serial_finish = 0.0
                            barrier = 0.0
                            r = 0
                            for s_r in counts_l:
                                dur = tab[s_r]
                                rank_busy[r] += dur
                                r += 1
                                sf = tgb + dur
                                if sf > serial_finish:
                                    serial_finish = sf
                                hidden = eta * (a2a_base
                                                if a2a_base < dur else dur)
                                eh += hidden
                                t_ready = t0g + (a2a_base - hidden)
                                fin = t_ready + dur
                                fin_sum += fin
                                if fin > barrier:
                                    barrier = fin
                    elif gg_tabs is not None:
                        # one expert shard per rank with tabulated per-rank
                        # rooflines and link legs (remote / heterogeneous)
                        serial_finish = 0.0
                        barrier = 0.0
                        r = 0
                        if eta == 0.0:
                            # hidden = eta*(...) == +0.0 and leg - 0.0 ==
                            # leg for the non-negative legs, so the eta
                            # terms drop out bit-exactly
                            for s_r in counts_l:
                                dur = gg_tabs[r][s_r]
                                rank_busy[r] += dur
                                if is_rem[r]:
                                    cross_bytes += cross_tab[s_r]
                                    leg = a2a_base + lk_tab[s_r]
                                    t_gl = t_gate + leg
                                    if leg > max_leg:
                                        max_leg = leg
                                else:
                                    leg = a2a_base
                                    t_gl = tgb
                                r += 1
                                sf = t_gl + dur
                                if sf > serial_finish:
                                    serial_finish = sf
                                fin = t0g + leg + dur
                                fin_sum += fin
                                if fin > barrier:
                                    barrier = fin
                        else:
                            for s_r in counts_l:
                                dur = gg_tabs[r][s_r]
                                rank_busy[r] += dur
                                if is_rem[r]:
                                    cross_bytes += cross_tab[s_r]
                                    leg = a2a_base + lk_tab[s_r]
                                    t_gl = t_gate + leg
                                    if leg > max_leg:
                                        max_leg = leg
                                else:
                                    leg = a2a_base
                                    t_gl = tgb
                                r += 1
                                sf = t_gl + dur
                                if sf > serial_finish:
                                    serial_finish = sf
                                hidden = eta * (leg if leg < dur else dur)
                                eh += hidden
                                t_ready = t0g + (leg - hidden)
                                fin = t_ready + dur
                                fin_sum += fin
                                if fin > barrier:
                                    barrier = fin
                    else:
                        per_rank = None if gg_hw is not None else \
                            split_by_rank(np.asarray(counts), ep)
                        serial_finish = 0.0
                        barrier = 0.0
                        for r in range(ep):
                            a, b = rank_bounds[r]
                            s_r = (counts_l[a] if b - a == 1
                                   else sum(counts_l[a:b]))
                            if gg_hw is not None:
                                peak, hbm, oh = gg_hw[r]
                                rf = gg_cf * s_r / peak
                                rb = (gg_cb1 * s_r
                                      + gg_cb2 * rank_groups[r]) / hbm
                                dur = n_mats_moe * (
                                    (rf if rf > rb else rb) + oh)
                            else:
                                dur = n_mats_moe * (
                                    r_ops if r in remote
                                    else ops).grouped_gemm(
                                        list(per_rank[r]), d, d_ff_moe)
                            rank_busy[r] += dur
                            if is_rem[r]:
                                nbytes = 2.0 * float(s_r) * d
                                # dispatch + combine each traverse the link
                                cross_bytes += 2.0 * nbytes
                                leg = a2a_base + (link_lat
                                                  + nbytes / link_bw)
                                t_gl = t_gate + leg
                                if leg > max_leg:
                                    max_leg = leg
                            else:
                                leg = a2a_base
                                t_gl = tgb
                            sf = t_gl + dur
                            if sf > serial_finish:
                                serial_finish = sf
                            hidden = eta * (leg if leg < dur else dur)
                            eh += hidden
                            t_ready = t0g + (leg - hidden)
                            fin = t_ready + dur
                            fin_sum += fin
                            if fin > barrier:
                                barrier = fin
                    virtual_markers += 2 * ep
                    straggler += barrier - fin_sum / ep
                    ep_disp += max_leg
                    t_comb = max_leg
                    if eta > 0.0:
                        # combine a2a overlaps the shared-expert GEMM tail
                        tail = ((1.0 - eta) * (t_comb + t_shared)
                                + eta * max(t_comb, t_shared))
                        eh += (t_comb + t_shared) - tail
                    else:
                        tail = t_comb + t_shared
                    end = barrier + tail
                    ep_comb += t_comb + t_shared
                    serial_mk += serial_finish + t_comb + t_shared
                    ffn_free[0] = end
                    ffn_busy += end - t0
                seq += 1
                c_time[i] = end if end > now else now
                c_seq[i] = seq
                c_phase[i] = 3
            elif P == 1:
                # A2F transfer (ATTN_COMPUTE_DONE handler)
                dur = (xfer_dur[i] if xfer_dur is not None
                       else ops.m2n(tb[i], n_attn, n_ffn))
                transfer_bytes += tb[i]
                serial_mk += dur
                if serial_mode:
                    ffn_exposed += dur  # nothing hides on one chain
                start = now if nic_free else xfer_start_u(a2f_nic, dur, now)
                c_x[i] = dur
                seq += 1
                t = start + dur
                c_time[i] = t if t > now else now
                c_seq[i] = seq
                c_phase[i] = 2
            elif P == 3:
                # F2A transfer (FFN/EXPERT_COMBINE_DONE handler)
                dur = (xfer_dur[i] if xfer_dur is not None
                       else ops.m2n(tb[i], n_attn, n_ffn))
                transfer_bytes += tb[i]
                serial_mk += dur
                if serial_mode:
                    attn_exposed += dur
                start = now if nic_free else xfer_start_u(f2a_nic, dur, now)
                c_x[i] = dur
                seq += 1
                t = start + dur
                c_time[i] = t if t > now else now
                c_seq[i] = seq
                c_phase[i] = 4
            else:
                # F2A delivered (done_f2a == now); next layer's attention
                k = c_k[i] + 1
                if k < L:
                    c_k[i] = k
                    dur = attn_dur[i][k]
                    if not_serial:
                        # F2A return time the attention lane could not hide
                        v = now - attn_free[0]
                        x_ = c_x[i]
                        if x_ < v:
                            v = x_
                        if v > 0.0:
                            attn_exposed += v
                    # max(now, attn_free, done_f2a): done_f2a == now here
                    start = attn_free[0]
                    if start < now:
                        start = now
                    attn_free[0] = start + dur
                    attn_busy += dur
                    serial_mk += dur
                    seq += 1
                    t = start + dur
                    c_time[i] = t if t > now else now
                    c_seq[i] = seq
                    c_phase[i] = 1
                else:
                    c_phase[i] = 0
                    live -= 1

        stats.attn_busy = attn_busy
        stats.ffn_busy = ffn_busy
        stats.transfer_bytes = transfer_bytes
        stats.serial_makespan = serial_mk
        stats.attn_exposed_comm = attn_exposed
        stats.ffn_exposed_comm = ffn_exposed
        stats.ep_overlap_hidden = eh
        stats.ep_dispatch_time = ep_disp
        stats.ep_combine_time = ep_comb
        stats.ep_straggler_excess = straggler
        stats.cross_cluster_bytes = cross_bytes
        makespan_now = now

    stats.makespan = makespan_now
    # virtual markers are still *counted* events — the step's event-graph
    # size is an observable and must not depend on trace mode
    stats.events = processed + virtual_markers
    if stats.makespan > 0:
        stats.attn_bubble_frac = 1.0 - stats.attn_busy / stats.makespan
        stats.ffn_bubble_frac = 1.0 - stats.ffn_busy / stats.makespan
    stats.bubble_time = max(stats.makespan - stats.attn_busy, 0.0)
    if stats.serial_makespan > 0:
        stats.overlap_efficiency = max(
            1.0 - stats.makespan / stats.serial_makespan, 0.0)
    return stats


class AFPipelinePredictor(ExecutionPredictor):
    """ExecutionPredictor whose decode step runs the AF event graph."""

    def __init__(self, *args, m: int = 2,
                 attn_par: Optional[ParallelismConfig] = None,
                 ffn_par: Optional[ParallelismConfig] = None,
                 remote_ranks: Sequence[int] = (),
                 remote_link: Optional[LinkSpec] = None,
                 remote_ops: Optional[OperatorModelSet] = None,
                 pipeline: Optional[PipelineConfig] = None, **kw):
        super().__init__(*args, **kw)
        self.m = m
        self.attn_par = attn_par or self.par
        self.ffn_par = ffn_par or self.par
        self.remote_ranks = tuple(remote_ranks)
        self.remote_link = remote_link
        self.remote_ops = remote_ops
        self.pipeline = pipeline
        # set to a callable to emit the per-rank marker events for real
        # (inner-engine event tracing); None keeps the fast virtual path
        self.af_trace: Optional[Callable] = None
        self.last_stats: Optional[AFStepStats] = None
        # run-level EP observability totals (cache hits replay the cached
        # step's stats, so totals stay consistent with simulated time)
        self.af_totals = {
            "decode_steps": 0, "makespan_s": 0.0, "ep_dispatch_time_s": 0.0,
            "ep_combine_time_s": 0.0, "ep_straggler_excess_s": 0.0,
            "cross_cluster_bytes": 0.0, "transfer_bytes": 0.0,
            # latency-hiding observability (pipelining layer)
            "serial_makespan_s": 0.0, "bubble_time_s": 0.0,
            "attn_exposed_comm_s": 0.0, "ffn_exposed_comm_s": 0.0,
            "ep_overlap_hidden_s": 0.0,
        }

    def _accumulate(self, stats: AFStepStats) -> None:
        t = self.af_totals
        t["decode_steps"] += 1
        t["makespan_s"] += float(stats.makespan)
        t["ep_dispatch_time_s"] += float(stats.ep_dispatch_time)
        t["ep_combine_time_s"] += float(stats.ep_combine_time)
        t["ep_straggler_excess_s"] += float(stats.ep_straggler_excess)
        t["cross_cluster_bytes"] += float(stats.cross_cluster_bytes)
        t["transfer_bytes"] += float(stats.transfer_bytes)
        t["serial_makespan_s"] += float(stats.serial_makespan)
        t["bubble_time_s"] += float(stats.bubble_time)
        t["attn_exposed_comm_s"] += float(stats.attn_exposed_comm)
        t["ffn_exposed_comm_s"] += float(stats.ffn_exposed_comm)
        t["ep_overlap_hidden_s"] += float(stats.ep_overlap_hidden)

    def _on_cache_hit(self, bd: StepBreakdown) -> None:
        # cached prefill steps carry no AF stats; keep the last decode stats
        if hasattr(bd, "af_stats"):
            self.last_stats = bd.af_stats
            self._accumulate(bd.af_stats)

    def _step_time_impl(self, q_lens, kv_lens, *, decode: bool,
                        n_prefill=None) -> StepBreakdown:
        if not decode:
            return super()._step_time_impl(q_lens, kv_lens, decode=False,
                                           n_prefill=n_prefill)
        stats = simulate_af_decode_step(
            self.cfg, self.hw, self.ops, list(kv_lens), m=self.m,
            attn_par=self.attn_par, ffn_par=self.ffn_par,
            routing=self.routing, rng=self.rng,
            remote_ranks=self.remote_ranks, remote_link=self.remote_link,
            remote_ops=self.remote_ops, pipeline=self.pipeline,
            trace=self.af_trace)
        self.last_stats = stats
        self._accumulate(stats)
        bd = StepBreakdown()
        bd.add("af_pipeline", stats.makespan)
        bd.add("engine_overhead", self.engine_overhead)
        bd.parts["attn_bubble_frac"] = stats.attn_bubble_frac
        bd.parts["ffn_bubble_frac"] = stats.ffn_bubble_frac
        bd.parts["ep_straggler_excess"] = stats.ep_straggler_excess
        bd.af_stats = stats
        return bd


def build_af(cfg: ModelConfig, hw: HardwareSpec, *,
             n_prefill: int = 1, n_decode: int = 1, m: int = 2,
             attn_par: Optional[ParallelismConfig] = None,
             ffn_par: Optional[ParallelismConfig] = None,
             prefill_par: Optional[ParallelismConfig] = None,
             ops: Optional[OperatorModelSet] = None,
             engine=None,
             routing=None, seed: int = 0,
             expert_cluster_hw: Optional[HardwareSpec] = None,
             remote_expert_ranks: Sequence[int] = (),
             expert_link: Optional[LinkSpec] = None,
             memory=None, queue_policy=None,
             memoize: bool = True,
             pipeline=None, transfer_overlap: float = 0.0,
             kv_frac: float = 0.9, fabric=None):
    """PD front + AF-disaggregated decode (as deployed by MegaScale-Infer).

    .. deprecated::
        ``build_af`` is kept as a thin shim over the declarative experiment
        API; prefer ``repro.api.SimSpec`` with
        ``TopologySpec(preset="af", ...)`` and ``repro.api.run`` — specs
        serialize, validate, and sweep.

    Preset over :func:`repro.core.topology.build_system`.  Pass
    ``remote_expert_ranks`` (+ optionally ``expert_cluster_hw`` /
    ``expert_link``) to place some EP ranks on a separate expert cluster
    reached over an inter-cluster link (cross-cluster expert routing).
    """
    from repro.core.topology import ClusterSpec, StageGraph, build_system
    attn_par = attn_par or ParallelismConfig(tp=1)
    ffn_par = ffn_par or ParallelismConfig(tp=1, ep=1)
    prefill_par = prefill_par or ParallelismConfig(tp=1)
    graph = StageGraph(clusters=[
        ClusterSpec("prefill", "prefill", n_replicas=n_prefill,
                    par=prefill_par, seed_offset=0, memoize=memoize),
        ClusterSpec("decode", "decode", n_replicas=n_decode,
                    par=attn_par, step="af", m=m,
                    attn_par=attn_par, ffn_par=ffn_par, seed_offset=50,
                    expert_cluster_hw=expert_cluster_hw,
                    remote_expert_ranks=tuple(remote_expert_ranks),
                    expert_link=expert_link, memoize=memoize),
    ], fabric=fabric)
    return build_system(cfg, hw, graph, ops=ops, routing=routing,
                        engine=engine,
                        memory=memory, queue_policy=queue_policy, seed=seed,
                        pipeline=pipeline, transfer_overlap=transfer_overlap,
                        kv_frac=kv_frac)
