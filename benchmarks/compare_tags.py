"""Compare roofline terms between dry-run tags (hillclimb bookkeeping).

    PYTHONPATH=src python -m benchmarks.compare_tags yi-9b train_4k pod \
        baseline bw1024 bw1024_rdots
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.roofline import ARTIFACTS, analyze_cell


def load(arch, shape, mesh, tag):
    f = ARTIFACTS / f"{arch}__{shape}__{mesh}__{tag}.json"
    if not f.exists():
        return None
    return analyze_cell(json.loads(f.read_text()))


def main():
    arch, shape, mesh = sys.argv[1:4]
    tags = sys.argv[4:]
    cols = ("t_compute_s", "t_memory_s", "t_collective_s", "bottleneck",
            "roofline_frac", "useful_ratio", "temp_gb_dev", "args_gb_dev")
    print(f"{'tag':20s}" + "".join(f"{c:>16s}" for c in cols))
    for tag in tags:
        a = load(arch, shape, mesh, tag)
        if a is None or a.get("status") != "ok":
            print(f"{tag:20s}  missing/{a and a.get('status')}")
            continue
        row = f"{tag:20s}"
        for c in cols:
            v = a[c]
            row += f"{v:16.4f}" if isinstance(v, float) else f"{v:>16s}"
        print(row)


if __name__ == "__main__":
    main()
