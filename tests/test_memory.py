"""The KV-cache memory subsystem: managers, preemption/restore, streamed
transfer.

Covers the watermark regression (grow must honor the reserve admit keeps),
monolithic per-request reservation, prefix-cache sharing/eviction
accounting, KVTransferPlan exposure bounds (overlap=0 == legacy lump sum),
and end-to-end preemption sweeps (recompute + swap) with request
conservation.
"""
import numpy as np
import pytest

from repro.api import MemorySpec, SimSpec, SpecError, run
from repro.core.policies.memory import (
    KVTransferPlan, MonolithicKVManager, PagedKVManager,
    PrefixCachingKVManager, resolve_memory,
)
from repro.core.request import Request, RState


def _req(rid, prompt=256, out=64, prefix_id=None, prefix_len=0):
    return Request(rid=rid, arrival=0.0, prompt_len=prompt, output_len=out,
                   prefix_id=prefix_id, prefix_len=prefix_len)


# ------------------------------------------------------------- watermark --
def test_grow_honors_watermark_like_admit():
    # 100 blocks, watermark 10: admit leaves the reserve, growth must too
    mgr = PagedKVManager(total_bytes=100 * 160, kv_bytes_per_token=10,
                         block_tokens=16, watermark=0.10)
    assert mgr.watermark_blocks == 10
    assert mgr.admit(0, 80 * 16)          # 80 blocks; 20 free
    assert mgr.grow(0, 90 * 16)           # 90 blocks; exactly at reserve
    assert mgr.free_blocks == 10
    # regression: growth below the watermark must fail (it used to drain
    # the reserve admit enforces)
    assert not mgr.grow(0, 91 * 16)
    assert mgr.free_blocks == 10
    # the explicit escape hatch (last resort before preempting the only
    # resident request) may dip into the reserve
    assert mgr.grow(0, 95 * 16, ignore_watermark=True)
    assert mgr.free_blocks == 5


def test_admit_still_honors_watermark():
    mgr = PagedKVManager(total_bytes=100 * 160, kv_bytes_per_token=10,
                         block_tokens=16, watermark=0.10)
    assert not mgr.admit(0, 95 * 16)
    assert mgr.admit(0, 90 * 16)


# ------------------------------------------------------------ monolithic --
def test_monolithic_reserves_per_request_bound_not_max_len():
    mgr = MonolithicKVManager(total_bytes=10_000 * 10,
                              kv_bytes_per_token=10, max_len=4096,
                              watermark=0.0)
    # regression: a 256+64 request must reserve 320 tokens, not max_len
    r = _req(0, prompt=256, out=64)
    assert mgr.admit_request(r)
    assert mgr.held_blocks() == 320
    # growth inside the reserve is free; the reserve covers every context
    for ctx in (300, 320):
        assert mgr.grow(0, ctx)
        assert mgr.held_blocks() == 320
    assert mgr.free(0) == 320
    # a raw admit with no bound falls back to max_len
    small = MonolithicKVManager(total_bytes=1000 * 10,
                                kv_bytes_per_token=10, max_len=4096,
                                watermark=0.0)
    assert not small.admit(1, 100)        # max_len 4096 > 1000 total
    assert small.admit(2, 100, max_tokens=200)
    assert small.held_blocks() == 200


# ---------------------------------------------------------- prefix cache --
def _prefix_mgr(blocks=1000, block_tokens=16, watermark=0.0):
    return PrefixCachingKVManager(
        total_bytes=blocks * block_tokens * 10, kv_bytes_per_token=10,
        block_tokens=block_tokens, watermark=watermark)


def _conserved(m):
    return m.free_blocks + m.held_blocks() + m.cached_blocks() \
        == m.total_blocks


def test_prefix_cache_hit_after_free():
    m = _prefix_mgr()
    a = _req(0, prompt=512, out=8, prefix_id=7, prefix_len=256)
    assert m.admit_request(a)
    assert a.prefill_progress == 0        # nothing cached yet
    assert m.prefix_hit(_req(1, prefix_id=7, prefix_len=256)) == 0
    m.free(0)                             # computed context folds into cache
    assert m.cached_blocks() == 512 // 16  # radix: the full prompt extent
    assert _conserved(m)
    b = _req(1, prompt=512, out=8, prefix_id=7, prefix_len=256)
    assert m.prefix_hit(b) == 256
    assert m.admit_request(b)
    assert b.prefill_progress == 256      # cached prefill skipped
    assert m.hit_tokens == 256
    assert m.prefix_hit_rate > 0
    assert _conserved(m)
    # the shared blocks are held once: b holds only its unique suffix
    assert m.held_blocks() == m.blocks_for(512) - 16


def test_prefix_hit_capped_one_token_short():
    """A full-prompt hit must still compute >= 1 token (the first output
    token comes from the last prompt position)."""
    m = _prefix_mgr()
    a = _req(0, prompt=256, out=4, prefix_id=1, prefix_len=256)
    assert m.admit_request(a)
    m.free(0)
    b = _req(1, prompt=256, out=4, prefix_id=1, prefix_len=256)
    assert m.admit_request(b)
    assert b.prefill_progress < b.prompt_len


def test_prefix_referenced_blocks_survive_pressure_cold_are_evicted():
    m = _prefix_mgr(blocks=100)
    a = _req(0, prompt=320, out=8, prefix_id=1, prefix_len=320)  # 20 blocks
    assert m.admit_request(a)
    m.free(0)
    cold = _req(1, prompt=320, out=8, prefix_id=2, prefix_len=320)
    assert m.admit_request(cold)
    m.free(1)
    assert m.cached_blocks() == 40        # 2 full 20-block extents cached
    hot = _req(2, prompt=320, out=8, prefix_id=1, prefix_len=320)
    assert m.admit_request(hot)           # references prefix 1
    # demand more than free: the cold prefix 2 must be evicted LRU, the
    # referenced prefix 1 must survive
    big = _req(3, prompt=70 * 16, out=8)
    assert m.admit_request(big)
    assert m.evictions >= 1
    assert m.prefix_hit(_req(4, prefix_id=2, prefix_len=320)) == 0
    assert m.prefix_hit(_req(5, prefix_id=1, prefix_len=320)) > 0
    assert _conserved(m)


def test_prefix_cache_raw_admit_path_has_no_sharing():
    m = _prefix_mgr()
    assert m.admit(0, 256)                # decode-side admit: plain blocks
    assert m.held_blocks() == m.blocks_for(256)
    m.free(0)
    assert m.cached_blocks() == 0


# -------------------------------------------------- conservation property --
def test_block_conservation_under_random_schedules():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.lists(st.tuples(
        st.sampled_from(["admit", "grow", "free", "preempt_free"]),
        st.integers(0, 15), st.integers(1, 2048), st.integers(0, 5)),
        min_size=1, max_size=200))
    @settings(max_examples=50, deadline=None)
    def inner(ops):
        m = _prefix_mgr(blocks=300, watermark=0.05)
        live = {}
        for kind, rid, toks, group in ops:
            if kind == "admit" and rid not in live:
                r = _req(rid, prompt=toks, out=16, prefix_id=group,
                         prefix_len=min(toks, 256))
                if m.admit_request(r):
                    live[rid] = toks
            elif kind == "grow" and rid in live:
                if m.grow(rid, live[rid] + toks):
                    live[rid] += toks
            elif kind in ("free", "preempt_free") and rid in live:
                # a preemption IS a free from the manager's perspective
                m.free(rid, insert=(kind == "free"))
                del live[rid]
            assert 0 <= m.free_blocks <= m.total_blocks
            assert _conserved(m)
        for rid in list(live):
            m.free(rid)
        assert _conserved(m)
        assert m.held_blocks() == 0

    inner()


# ---------------------------------------------------- PREEMPTED lifecycle --
def test_preempted_transitions_legal_and_illegal():
    r = _req(0)
    r.state = RState.DECODING
    r.to(RState.PREEMPTED, 1.0)
    r.to(RState.QUEUED_PREFILL, 1.0)      # recompute restore
    r2 = _req(1)
    r2.state = RState.DECODING
    r2.to(RState.PREEMPTED, 1.0)
    r2.to(RState.QUEUED_DECODE, 2.0)      # swap-in restore
    for bad in (RState.COMPLETE, RState.KV_TRANSFER, RState.DECODING,
                RState.PREFILL_COMPLETE):
        r3 = _req(2)
        r3.state = RState.PREEMPTED
        with pytest.raises(ValueError):
            r3.to(bad, 0.0)
    # only memory pressure puts a request into PREEMPTED
    r4 = _req(3)
    with pytest.raises(ValueError):
        r4.to(RState.PREEMPTED, 0.0)


def test_begin_recompute_resets_prefill_to_full_context():
    r = _req(0, prompt=100, out=50)
    r.generated = 20
    r.prefill_progress = 100
    r.state = RState.DECODING
    r.to(RState.PREEMPTED, 3.0)
    r.begin_recompute(3.0)
    assert r.state is RState.QUEUED_PREFILL
    assert r.prefill_total == 120         # prompt + generated
    assert r.prefill_progress == 0
    assert r.restore_pending


# --------------------------------------------------------- transfer plan --
def test_transfer_plan_overlap_zero_is_lump_sum():
    plan = KVTransferPlan(n_layers=32, bytes_per_layer=1e6,
                          bandwidth=25e9, latency=5e-6, overlap=0.0)
    assert plan.exposed_time(compute_window=10.0) == plan.serial_time
    assert plan.serial_time == 5e-6 + 32e6 / 25e9


def test_transfer_plan_exposure_bounds():
    mk = lambda ov: KVTransferPlan(n_layers=32, bytes_per_layer=1e6,
                                   bandwidth=25e9, latency=5e-6, overlap=ov)
    window = 0.01
    serial = mk(0.0).serial_time
    prev = serial
    for ov in (0.25, 0.5, 1.0):
        t = mk(ov).exposed_time(window)
        assert t <= prev + 1e-15          # monotone in overlap
        assert t >= mk(ov).latency + mk(ov).layer_time - 1e-15
        prev = t
    # a zero compute window hides nothing
    assert mk(1.0).exposed_time(0.0) == serial
    # one layer cannot stream
    one = KVTransferPlan(n_layers=1, bytes_per_layer=32e6, bandwidth=25e9,
                         latency=5e-6, overlap=1.0)
    assert one.exposed_time(10.0) == one.serial_time


# -------------------------------------------------------------- resolve --
def test_resolve_memory_registry():
    cls, kw = resolve_memory("prefix")
    assert cls is PrefixCachingKVManager and kw == {}
    cls, kw = resolve_memory({"name": "paged", "preemption": "swap",
                              "swap_bw": 1e9})
    assert cls is PagedKVManager
    assert kw == {"preemption": "swap", "swap_bw": 1e9}
    with pytest.raises(KeyError):
        resolve_memory({"name": "paged", "preemption": "abort"})


def test_memory_spec_validation():
    SimSpec.from_dict({"memory": {"manager": "prefix",
                                  "transfer_overlap": 0.5}}).validate()
    with pytest.raises(SpecError):
        SimSpec.from_dict({"memory": {"preemption": "abort"}}).validate()
    with pytest.raises(SpecError):
        SimSpec.from_dict({"memory": {"transfer_overlap": 1.5}}).validate()
    with pytest.raises(SpecError):
        SimSpec.from_dict({"memory": {"capacity_frac": 0.0}}).validate()
    with pytest.raises(SpecError):   # both manager knobs set
        SimSpec.from_dict({"memory": {"manager": "paged"},
                           "policy": {"memory": "paged"}}).validate()
    with pytest.raises(SpecError):   # shared prefix needs a length
        SimSpec.from_dict({"workload": {"prefix_groups": 4}}).validate()
    with pytest.raises(SpecError):   # conversation prefixes already share
        SimSpec.from_dict({"workload": {"turns": 3, "prefix_groups": 2,
                                        "prefix_len": 64}}).validate()
    with pytest.raises(SpecError):   # closed-loop re-stamping breaks turns
        SimSpec.from_dict({"workload": {"turns": 3, "arrival": "closed",
                                        "concurrency": 4}}).validate()


# ------------------------------------------------------------------ e2e --
_PRESSURE = {
    "model": {"name": "qwen2-7b", "smoke": True},
    "topology": {"preset": "pd", "n_prefill": 1, "n_decode": 1},
    "workload": {"n_requests": 40, "arrival": "burst", "burst_size": 40,
                 "burst_period": 1.0, "prompt": "fixed", "prompt_mean": 64,
                 "output": "fixed", "output_mean": 2048, "seed": 7},
    "seed": 7,
}


@pytest.mark.parametrize("mode", ["recompute", "swap"])
def test_preemption_sweep_conserves_and_completes(mode):
    d = dict(_PRESSURE)
    d["memory"] = {"manager": "paged", "capacity_frac": 0.0002,
                   "preemption": mode}
    rep = run(SimSpec.from_dict(d))
    assert rep.all_complete, rep.conservation
    assert rep.conservation == {"complete": 40}
    assert rep.summary["preemptions"] > 0
    mem = rep.clusters["decode"]["memory"]
    if mode == "swap":
        assert mem["swap_outs"] > 0
        assert mem["swap_outs"] == mem["swap_ins"]
    # no replica leaked residency and every manager balances its books
    assert rep.summary["request_preemptions"] >= \
        rep.summary["preempted_requests"] > 0


def test_preemption_with_monolithic_never_triggers():
    """Monolithic reserves the full bound up front: admission backpressure
    replaces preemption entirely."""
    d = dict(_PRESSURE)
    d["memory"] = {"manager": "monolithic", "capacity_frac": 0.0002}
    rep = run(SimSpec.from_dict(d))
    assert rep.all_complete
    assert rep.summary["preemptions"] == 0


def test_streamed_transfer_overlap_zero_matches_legacy_bit_for_bit():
    legacy = dict(_PRESSURE)
    legacy["policy"] = {"memory": "paged"}
    lump = run(SimSpec.from_dict(legacy))
    d = dict(_PRESSURE)
    d["memory"] = {"manager": "paged", "transfer_overlap": 0.0}
    streamed_off = run(SimSpec.from_dict(d))
    assert streamed_off.summary == lump.summary


def test_streamed_transfer_reduces_exposure_and_keeps_conservation():
    fracs = {}
    for ov in (0.0, 0.5, 1.0):
        d = dict(_PRESSURE)
        d["memory"] = {"manager": "paged", "transfer_overlap": ov}
        rep = run(SimSpec.from_dict(d))
        assert rep.all_complete
        fracs[ov] = rep.summary["kv_transfer_exposed_frac"]
    assert fracs[0.0] == 1.0
    assert fracs[1.0] < fracs[0.5] < fracs[0.0]


def test_prefix_caching_beats_paged_under_pressure_e2e():
    base = {
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "pd", "n_prefill": 1, "n_decode": 1},
        "workload": {"n_requests": 60, "rate": 120.0, "prompt_mean": 512,
                     "output_mean": 32, "prefix_groups": 4,
                     "prefix_len": 2048, "seed": 5},
        "seed": 5,
    }
    reports = {}
    for mgr in ("paged", "prefix"):
        d = dict(base)
        d["memory"] = {"manager": mgr, "capacity_frac": 0.001}
        reports[mgr] = run(SimSpec.from_dict(d))
        assert reports[mgr].all_complete
    assert "prefix_hit_token_frac" not in reports["paged"].summary
    assert reports["prefix"].summary["prefix_hit_token_frac"] > 0.3
    # skipped prefill compute shows up as fewer prefill tokens and lower
    # tail TTFT under load
    tok = lambda rep: sum(r["prefill_tokens"] for r in
                          rep.clusters["prefill"]["replicas"].values())
    assert tok(reports["prefix"]) < 0.6 * tok(reports["paged"])
    assert reports["prefix"].summary["ttft_p99_s"] <= \
        reports["paged"].summary["ttft_p99_s"]


def test_multiturn_workload_hits_prefix_cache():
    d = {
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "colocated", "n_replicas": 1},
        "workload": {"n_requests": 24, "rate": 4.0, "prompt_mean": 256,
                     "output_mean": 32, "turns": 4, "turn_gap": 2.0,
                     "seed": 9},
        "memory": {"manager": "prefix"},
        "seed": 9,
    }
    rep = run(SimSpec.from_dict(d))
    assert rep.all_complete
    assert rep.summary["prefix_hit_token_frac"] > 0.2


def test_never_fitting_request_fails_loudly():
    """A request whose max context exceeds the whole pool must raise a
    clear config error at preemption time, not strand itself silently."""
    d = dict(_PRESSURE)
    d["workload"] = dict(_PRESSURE["workload"], n_requests=2,
                         burst_size=2, output_mean=200_000)
    d["memory"] = {"manager": "paged", "capacity_frac": 0.0002}
    with pytest.raises(RuntimeError, match="raise memory capacity"):
        run(SimSpec.from_dict(d))


def test_recompute_preempt_folds_only_declared_prefix():
    """Preempting a grown request must not pin its whole context inside a
    ref-held shared prefix entry (blocks no consumer could ever hit)."""
    m = _prefix_mgr(blocks=200)
    a = _req(0, prompt=320, out=8, prefix_id=1, prefix_len=320)
    assert m.admit_request(a)
    m.free(0)                                   # entry: 20 blocks
    sibling = _req(1, prompt=320, out=8, prefix_id=1, prefix_len=320)
    assert m.admit_request(sibling)             # pins the entry (refs=1)
    victim = _req(2, prompt=320, out=8, prefix_id=1, prefix_len=320)
    assert m.admit_request(victim)
    assert m.grow(2, 1280)                      # decode grew to 80 blocks
    m.free(2, insert=True, full_extent=False)   # recompute preemption
    assert m.cached_blocks() == 20              # fold capped at declared
    assert _conserved(m)


def test_prefix_manager_with_swap_does_not_double_count_kv():
    """A swap moves the whole KV to host: the device must not also fold it
    into the prefix cache, or swap-in re-reserves bytes the cache still
    holds (double residency) and pressure snowballs."""
    d = dict(_PRESSURE)
    wl = dict(_PRESSURE["workload"], prefix_groups=4, prefix_len=48)
    d["workload"] = wl
    d["memory"] = {"manager": "prefix", "capacity_frac": 0.0002,
                   "preemption": "swap"}
    rep = run(SimSpec.from_dict(d))
    assert rep.all_complete, rep.conservation
    assert rep.conservation == {"complete": 40}


def test_replica_failure_during_swap_pressure_conserves():
    """A decode replica failing while requests are preempted/swapped must
    re-route everything (epoch-guarded swap events, freed residency) and
    still complete the whole workload."""
    d = dict(_PRESSURE)
    d["topology"] = {"preset": "pd", "n_prefill": 1, "n_decode": 2}
    d["memory"] = {"manager": "paged", "capacity_frac": 0.0002,
                   "preemption": "swap"}
    d["faults"] = [{"kind": "failure", "cluster": "decode", "replica": 0,
                    "at": 2.0, "downtime": 5.0}]
    rep = run(SimSpec.from_dict(d))
    assert rep.all_complete, rep.conservation
    assert rep.conservation == {"complete": 40}


def test_memory_spec_yaml_round_trip():
    spec = SimSpec.from_dict({
        "memory": {"manager": {"name": "prefix", "block_tokens": 32},
                   "preemption": "swap", "swap_bw": 1e9,
                   "transfer_overlap": 0.7, "capacity_frac": 0.25},
        "workload": {"prefix_groups": 8, "prefix_len": 512},
    })
    back = SimSpec.from_yaml(spec.to_yaml())
    assert back.memory == spec.memory
    assert back.workload.prefix_groups == 8
    assert back.spec_hash() == spec.spec_hash()
    assert isinstance(back.memory, MemorySpec)
