"""The scan-aware HLO cost parser: corrected totals must match unrolled."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "tiny.hlo")


# ---------------------------------------------------------------------------
# Checked-in text fixture: a hand-written module (dot inside a while with
# known_trip_count=5, a fusion, an all-reduce) with hand-computed totals —
# no compiler in the loop, so these pin the parser itself.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_hlo():
    with open(FIXTURE) as f:
        return f.read()


def test_fixture_parse_structure(tiny_hlo):
    comps = hlo_cost.parse_hlo(tiny_hlo)
    assert set(comps) == {"main", "body", "cond", "add", "fused_add"}
    by_op = {i.op: i for i in comps["main"].instrs}
    assert by_op["while"].trip_count == 5
    assert sorted(by_op["while"].called) == ["body", "cond"]
    assert by_op["fusion"].called == ["fused_add"]
    assert by_op["all-reduce"].called == ["add"]
    root = [i for i in comps["main"].instrs if i.is_root]
    assert len(root) == 1 and root[0].op == "copy"
    dot = [i for i in comps["body"].instrs if i.op == "dot"][0]
    assert dot.out_shapes == [("f32", [8, 16])]


def test_fixture_analyze_totals(tiny_hlo):
    costs = hlo_cost.analyze(tiny_hlo)
    # dot: 2 * (8*16) * 16 = 4096 flops, times trip_count 5
    assert costs["flops"] == pytest.approx(5 * 4096)
    # all-reduce output: 8*16*4 = 512 bytes
    assert costs["collective_bytes"] == pytest.approx(512)
    assert costs["coll_all-reduce"] == pytest.approx(512)
    # bytes: dot (512 out + 512 + 1024 operands) * 5 iterations
    #      + fusion (512 out + 512 + 512 operands, internal add free)
    #      + all-reduce (512 + 512) + root copy (512 + 512);
    # parameter/tuple/gte/while are free under XLA's fusion byte model
    assert costs["bytes"] == pytest.approx(5 * 2048 + 1536 + 1024 + 1024)


def test_fixture_entry_selection_and_override(tiny_hlo):
    # entry auto-detected as the never-called computation ("main"); an
    # explicit entry restricts the walk to that computation
    full = hlo_cost.analyze(tiny_hlo)
    body_only = hlo_cost.analyze(tiny_hlo, entry="body")
    assert body_only["flops"] == pytest.approx(4096)   # one iteration
    assert body_only["collective_bytes"] == 0.0
    assert full["flops"] == pytest.approx(5 * body_only["flops"])


def test_fixture_roofline_terms(tiny_hlo):
    costs = hlo_cost.analyze(tiny_hlo)
    terms = hlo_cost.roofline_terms(costs, n_chips=1, peak_flops=1e12,
                                    hbm_bw=1e11, ici_bw=1e10)
    assert terms["t_compute_s"] == pytest.approx(20480 / 1e12)
    assert terms["t_memory_s"] == pytest.approx(13824 / 1e11)
    assert terms["t_collective_s"] == pytest.approx(512 / 1e10)
    assert terms["bottleneck"] == "memory"


def _costs(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze(comp.as_text()), comp


def test_scan_flops_match_unrolled():
    N = 6
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((N, 128, 128), jnp.float32)

    def body(c, w):
        return jnp.tanh(c @ w), None

    def f_scan(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    def f_unroll(x, ws):
        for i in range(N):
            x, _ = body(x, ws[i])
        return x

    c_scan, comp = _costs(f_scan, x, ws)
    c_unroll, _ = _costs(f_unroll, x, ws)
    assert c_scan["flops"] == pytest.approx(c_unroll["flops"], rel=0.01)
    # raw cost_analysis undercounts the scan (the bug this parser fixes)
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] < c_scan["flops"] / (N - 1)


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 48), jnp.float32)
    c, _ = _costs(lambda a, b: a @ b, a, b)
    assert c["flops"] == pytest.approx(2 * 32 * 64 * 48, rel=1e-6)


def test_nested_scan_multiplies_trip_counts():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def inner(c, _):
        return jnp.tanh(c @ c), None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c, _ = _costs(f, x)
    assert c["flops"] == pytest.approx(12 * 2 * 16 * 16 * 16, rel=0.01)


def test_dus_bytes_not_quadratic():
    """Scan ys-accumulation must be charged per-slice, not per-buffer."""
    N, D = 64, 256
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def f(x):
        def body(c, _):
            c = jnp.tanh(c)
            return c, c        # ys: (N, D, D) accumulator
        _, ys = jax.lax.scan(body, x, None, length=N)
        return ys

    c, _ = _costs(f, x)
    buf = N * D * D * 4
    # in-place model: O(N * slice) == O(buf); quadratic would be N * buf
    assert c["bytes"] < 8 * buf
