"""Event tracing: in-memory ring + Chrome trace-event export."""
from __future__ import annotations

import json
from collections import deque
from typing import Deque, List, Optional

from repro.core.events import EV, Event


class EventTrace:
    def __init__(self, capacity: int = 200_000):
        self.events: Deque[tuple] = deque(maxlen=capacity)

    def __call__(self, ev: Event) -> None:
        d = ev.data
        if not isinstance(d, dict):      # timeline payloads are raw objects
            d = {} if d is None else {"data": d}
        self.events.append((ev.time, ev.kind.value, dict(d)))

    def filter(self, kind: EV) -> List[tuple]:
        return [e for e in self.events if e[1] == kind.value]

    def to_chrome_trace(self, path: str) -> None:
        """Chrome trace-event export of the raw ring.

        .. deprecated::
            Thin shim over
            :func:`repro.obs.sinks.engine_events_to_chrome` (which fixed
            the negative-``ts`` clamp and honours ``dur`` on any event
            kind, not just BATCH_DONE).  Prefer the span-level
            observability layer: ``SimSpec(obs=ObsSpec())`` +
            ``repro.obs.write_chrome_trace``.
        """
        from repro.obs.sinks import engine_events_to_chrome
        with open(path, "w") as f:
            json.dump({"traceEvents": engine_events_to_chrome(self.events)},
                      f)
