from repro.core.opmodels.analytical import OperatorModelSet, AnalyticalModels  # noqa: F401
from repro.core.opmodels.forest import RandomForest  # noqa: F401
from repro.core.opmodels.kernelsim import VirtualKernels  # noqa: F401
from repro.core.opmodels.vidur_proxy import VidurProxyModel  # noqa: F401
from repro.core.opmodels.refined import RefinedModels, calibrate_refined  # noqa: F401
