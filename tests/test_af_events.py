"""AF event-graph properties: per-EP-rank dispatch/compute/combine events,
straggler behaviour, determinism, and cross-cluster expert routing."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    A800_SXM4_80G, H100_SXM, LinkSpec, ParallelismConfig,
    simulate_af_decode_step,
)
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.routing import BalancedRouting, ZipfRouting

HW = A800_SXM4_80G
MCFG = get_config("mixtral-8x7b")
OPS = OperatorModelSet(HW)
LENS = [512] * 64


def _step(**kw):
    args = dict(m=2, attn_par=ParallelismConfig(tp=2),
                ffn_par=ParallelismConfig(tp=1, ep=4),
                routing=BalancedRouting(),
                rng=np.random.default_rng(0))
    args.update(kw)
    return simulate_af_decode_step(MCFG, HW, OPS, LENS, **args)


def test_makespan_bounded_by_serialized_sum():
    st = _step()
    serial = (st.attn_busy + st.ffn_busy
              + st.transfer_bytes / HW.inter_node_bw
              + 4 * MCFG.num_layers * HW.op_overhead + 1e-6)
    assert st.makespan <= serial
    assert st.makespan >= max(st.attn_busy, st.ffn_busy) - 1e-9


def test_bubble_fractions_in_unit_interval():
    for m in (1, 2, 4):
        st = _step(m=m)
        assert 0.0 <= st.attn_bubble_frac <= 1.0
        assert 0.0 <= st.ffn_bubble_frac <= 1.0


def test_bit_identical_across_repeated_runs_same_seed():
    runs = [_step(routing=ZipfRouting(1.3), rng=np.random.default_rng(7))
            for _ in range(3)]
    for st in runs[1:]:
        assert st.makespan == runs[0].makespan
        assert st.ep_straggler_excess == runs[0].ep_straggler_excess
        assert st.rank_busy == runs[0].rank_busy
        assert st.events == runs[0].events


def test_per_rank_events_are_emitted():
    """Every (microbatch, layer) MoE stage emits per-rank dispatch +
    compute events and one combine — the EP graph is really simulated."""
    ep = 4
    st = _step(m=1, ffn_par=ParallelismConfig(tp=1, ep=ep))
    n_stages = MCFG.num_layers  # m=1 -> one stage per layer
    # attn + a2f + f2a per stage, plus 2*ep + 1 expert events per stage
    assert st.events >= n_stages * (2 * ep + 1)
    assert len(st.rank_busy) == ep
    assert all(b > 0 for b in st.rank_busy)


def test_virtual_markers_bit_identical_to_traced_event_path():
    """Golden assertion for the batched event path: running the step with
    real per-rank marker events (trace mode) and with virtual markers
    (default) must agree bit-for-bit on every stat — straggler excess,
    per-rank busy, makespan, event counts."""
    for kw in ({}, {"routing": ZipfRouting(1.3)},
               {"remote_ranks": (2, 3),
                "remote_link": LinkSpec("decode", "experts",
                                        bandwidth=5e9, latency=20e-6)}):
        seen = []
        fast = _step(rng=np.random.default_rng(3), **kw)
        traced = _step(rng=np.random.default_rng(3), trace=seen.append,
                       **kw)
        assert traced.makespan == fast.makespan
        assert traced.ep_straggler_excess == fast.ep_straggler_excess
        assert traced.rank_busy == fast.rank_busy
        assert traced.ep_overlap_hidden == fast.ep_overlap_hidden
        assert traced.serial_makespan == fast.serial_makespan
        assert traced.events == fast.events == len(seen)


def test_traced_markers_preserve_per_rank_identities():
    """Trace mode must emit one EXPERT_DISPATCH_DONE and one
    EXPERT_RANK_DONE per (stage, rank), with the rank id on the event —
    the identities fabric/cross-cluster accounting relies on."""
    from repro.core.events import EV
    ep = 4
    seen = []
    _step(m=1, ffn_par=ParallelismConfig(tp=1, ep=ep), trace=seen.append)
    n_stages = MCFG.num_layers
    disp = [e for e in seen if e.kind is EV.EXPERT_DISPATCH_DONE]
    rank = [e for e in seen if e.kind is EV.EXPERT_RANK_DONE]
    assert len(disp) == len(rank) == n_stages * ep
    assert sorted({e.data["r"] for e in disp}) == list(range(ep))
    assert sorted({e.data["r"] for e in rank}) == list(range(ep))


def test_ep_straggler_monotone_under_zipf_skew():
    """More skew -> more straggler excess (and balanced ~ zero)."""
    excess = {}
    for name, router in (("bal", BalancedRouting()),
                         ("z_mild", ZipfRouting(0.6)),
                         ("z_heavy", ZipfRouting(1.6))):
        sts = [
            simulate_af_decode_step(
                MCFG, HW, OPS, LENS, m=2,
                attn_par=ParallelismConfig(tp=2),
                ffn_par=ParallelismConfig(tp=1, ep=4),
                routing=router, rng=np.random.default_rng(s))
            for s in range(5)
        ]
        excess[name] = np.mean([s.ep_straggler_excess for s in sts])
    assert excess["bal"] <= excess["z_mild"] + 1e-12
    assert excess["z_mild"] < excess["z_heavy"]


def test_zipf_skew_inflates_makespan():
    bal = _step(rng=np.random.default_rng(1))
    zipf = _step(routing=ZipfRouting(1.6), rng=np.random.default_rng(1))
    assert zipf.makespan > bal.makespan
    assert zipf.ep_straggler_excess > bal.ep_straggler_excess


def test_cross_cluster_expert_ranks_slow_the_barrier():
    """Remote EP ranks pay the inter-cluster link on dispatch+combine, so
    the straggler barrier (and the makespan) must grow."""
    link = LinkSpec("decode", "experts", bandwidth=5e9, latency=20e-6)
    local = _step()
    xc = _step(remote_ranks=(2, 3), remote_link=link)
    assert xc.makespan > local.makespan
    assert xc.cross_cluster_bytes > 0
    assert local.cross_cluster_bytes == 0


def test_remote_rank_misconfiguration_raises():
    link = LinkSpec("decode", "experts", bandwidth=25e9)
    with pytest.raises(ValueError, match="out of range"):
        _step(remote_ranks=(9,), remote_link=link)   # ep=4
    with pytest.raises(ValueError, match="without a remote_link"):
        _step(remote_ranks=(1,))


def test_cross_cluster_heterogeneous_expert_hardware():
    """Remote ranks on faster hardware shrink their GEMM time (visible in
    rank_busy) even though the link still gates dispatch/combine."""
    link = LinkSpec("decode", "experts", bandwidth=200e9)
    slow = _step(remote_ranks=(0, 1), remote_link=link)
    fast = _step(remote_ranks=(0, 1), remote_link=link,
                 remote_ops=OperatorModelSet(H100_SXM))
    assert fast.rank_busy[0] < slow.rank_busy[0]
    assert fast.rank_busy[3] == pytest.approx(slow.rank_busy[3])
