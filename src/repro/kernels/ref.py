"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        scale: Optional[float] = None) -> jax.Array:
    """q (B,S,H,hd); k/v (B,T,K,hd) with H % K == 0.  f32 accumulation."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, S, K, g, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= qi >= ki
    if window:
        ok &= (qi - ki) < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array, *,
                         scale: Optional[float] = None) -> jax.Array:
    """q (B,H,hd); k/v (B,T,K,hd); lengths (B,) valid prefix per row."""
    B, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, K, g, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ok = jnp.arange(T)[None, :] < lengths[:, None]
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def wkv_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
            u: jax.Array) -> jax.Array:
    """Sequential RWKV6 recurrence oracle.  r/k/v/w (B,T,H,hs); u (H,hs).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;  y_t = r_t (S_{t-1} + u k_t^T v_t)
    """
    B, T, H, hs = r.shape
    rf = r.astype(jnp.float32).transpose(1, 0, 2, 3)
    kf = k.astype(jnp.float32).transpose(1, 0, 2, 3)
    vf = v.astype(jnp.float32).transpose(1, 0, 2, 3)
    wf = w.astype(jnp.float32).transpose(1, 0, 2, 3)
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", rt, S + uf[None, :, :, None] * kv)
        S = S * wt[..., :, None] + kv
        return S, y

    S0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    _, ys = jax.lax.scan(step, S0, (rf, kf, vf, wf))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype)


def grouped_gemm_ref(x: jax.Array, w: jax.Array,
                     group_sizes: jax.Array) -> jax.Array:
    """x (E,C,din); w (E,din,dout); rows >= group_sizes[e] are masked to 0."""
    E, C, _ = x.shape
    y = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    mask = jnp.arange(C)[None, :] < group_sizes[:, None]
    return (y * mask[..., None]).astype(x.dtype)
