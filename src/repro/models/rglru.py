"""RG-LRU recurrent block (Griffin / recurrentgemma, arXiv:2402.19427).

Block: y = W_out( GeLU(W_gate x)  ⊙  RG-LRU( conv1d( W_x x ) ) )

RG-LRU recurrence (per channel, f32):
    r_t = sigmoid(W_r u_t + b_r)            recurrence gate
    i_t = sigmoid(W_i u_t + b_i)            input gate
    a_t = exp(-c * softplus(Λ) * r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ u_t)

State is O(d) per layer => recurrentgemma runs the long_500k decode shape.
The temporal conv1d (width 4) keeps a (width-1)-token tail as decode state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import PD, AxisRules


def rglru_pds(cfg: ModelConfig) -> Dict[str, PD]:
    d = cfg.d_model
    w = cfg.conv1d_width
    return {
        "w_x": PD((d, d), ("embed", "mlp")),
        "w_gate": PD((d, d), ("embed", "mlp")),
        "conv_w": PD((w, d), (None, "mlp"), 0.02),
        "conv_b": PD((d,), ("mlp",), "zeros"),
        "w_r": PD((d, d), ("mlp", "mlp")),
        "b_r": PD((d,), ("mlp",), "zeros"),
        "w_i": PD((d, d), ("mlp", "mlp")),
        "b_i": PD((d,), ("mlp",), "zeros"),
        "lam": PD((d,), ("mlp",), 0.5),      # Λ (softplus'd)
        "w_out": PD((d, d), ("mlp", "embed")),
    }


def _conv1d(u: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Causal depthwise conv.  u (B,T,D); tail (B,W-1,D) from previous chunk."""
    W = w.shape[0]
    ext = jnp.concatenate([tail, u], axis=1)            # (B, T+W-1, D)
    out = jnp.zeros_like(u)
    for i in range(W):
        out = out + ext[:, i:i + u.shape[1], :] * w[W - 1 - i]
    new_tail = ext[:, -(W - 1):, :] if W > 1 else tail
    return out + b, new_tail


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_r"].astype(jnp.float32) + p["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    c = 8.0
    log_a = -c * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * uf)
    return a, gated_in


def rglru_apply(cfg: ModelConfig, p, x, ax: AxisRules, *,
                conv_tail, h0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence recurrent block.  Returns (y, new_conv_tail, h_last)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    u = jnp.einsum("bsd,df->bsf", x, p["w_x"])
    u = ax.constrain(u, "batch", None, "mlp")
    u, new_tail = _conv1d(u, p["conv_w"], p["conv_b"], conv_tail)

    a, gin = _gates(p, u)                               # (B,T,D) f32
    aT, ginT = a.transpose(1, 0, 2), gin.transpose(1, 0, 2)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), (aT, ginT))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    h = ax.constrain(h, "batch", None, "mlp")

    y = jnp.einsum("bsf,fd->bsd", gate * h, p["w_out"])
    return ax.constrain(y, "batch", None, "embed"), new_tail, h_last


def rglru_decode(cfg: ModelConfig, p, x, ax: AxisRules, *,
                 conv_tail, h0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token step.  x (B,1,D); conv_tail (B,W-1,D); h0 (B,D)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    u = jnp.einsum("bsd,df->bsf", x, p["w_x"])
    W = p["conv_w"].shape[0]
    ext = jnp.concatenate([conv_tail, u], axis=1)       # (B,W,D)
    # ext[:, -1] is the current token and must pair with conv_w[0] (train
    # path pairs w[j] with u_{t-j}), hence the flip.
    conv = jnp.einsum("bwd,wd->bd", ext, p["conv_w"][::-1]) + p["conv_b"]
    new_tail = ext[:, 1:, :]

    a, gin = _gates(p, conv[:, None, :])
    h = a[:, 0] * h0.astype(jnp.float32) + gin[:, 0]
    y = jnp.einsum("bf,fd->bd", (gate[:, 0] * h.astype(x.dtype)), p["w_out"])[:, None]
    return ax.constrain(y, "batch", None, "embed"), new_tail, h
