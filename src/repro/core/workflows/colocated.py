"""Colocated serving system (the traditional deployment baseline)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.configs.base import ModelConfig
from repro.core.cluster import ClusterWorker, ReplicaWorker
from repro.core.controller import GlobalController
from repro.core.engine import SimEngine
from repro.core.hardware import HardwareSpec, ParallelismConfig
from repro.core.metrics import MetricsCollector
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.policies.batching import BatchingPolicy, ContinuousBatching
from repro.core.policies.memory import PagedKVManager
from repro.core.predictor import ExecutionPredictor
from repro.core.request import Request


@dataclass
class SystemHandle:
    engine: SimEngine
    controller: GlobalController
    clusters: dict
    n_devices: int

    def run(self, requests: List[Request], until: float = float("inf")):
        self.controller.metrics.start = 0.0
        self.controller.submit_all(requests)
        self.engine.run(until)
        return self.controller.metrics.report(n_devices=self.n_devices)


def _kv_budget(cfg: ModelConfig, hw: HardwareSpec, par: ParallelismConfig,
               pred: ExecutionPredictor, frac: float = 0.9) -> float:
    """KV memory per replica = devices*(HBM - weights) * frac."""
    total = hw.hbm_capacity * par.devices
    weights = 2.0 * cfg.param_count()
    return max((total - weights) * frac, hw.hbm_capacity * 0.05)


def build_colocated(cfg: ModelConfig, hw: HardwareSpec, *,
                    n_replicas: int = 1,
                    par: Optional[ParallelismConfig] = None,
                    policy: Optional[BatchingPolicy] = None,
                    ops: Optional[OperatorModelSet] = None,
                    engine: Optional[SimEngine] = None,
                    routing=None, seed: int = 0) -> SystemHandle:
    engine = engine or SimEngine()
    par = par or ParallelismConfig(tp=1)
    ops = ops or OperatorModelSet(hw)
    metrics = MetricsCollector()
    controller = GlobalController(engine, mode="colocated", clusters={},
                                  metrics=metrics)
    hooks = controller.hooks()
    replicas = []
    for i in range(n_replicas):
        pred = ExecutionPredictor(cfg, par, hw, ops, routing=routing,
                                  seed=seed + i)
        mem = PagedKVManager(_kv_budget(cfg, hw, par, pred),
                             pred.kv_bytes_per_token())
        replicas.append(ReplicaWorker(
            engine, f"colo{i}", pred,
            policy or ContinuousBatching(), mem, hooks, role="colocated"))
    cluster = ClusterWorker("colocated", "colocated", replicas)
    controller.clusters["colocated"] = cluster
    return SystemHandle(engine, controller, {"colocated": cluster},
                        n_devices=n_replicas * par.devices)
