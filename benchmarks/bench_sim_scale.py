"""Simulator performance & feature coverage.

- events/second and simulated-vs-wall time for large serving simulations
  (the practicality argument: exploring an 18k-GPU-hour config space needs
  a fast simulator);
- Table-1 feature matrix exercised programmatically (PD, AF, PP/TP/DP/EP,
  cross-cluster EP, pluggable scheduling, prefix caching, preemption) —
  each cell is an actual simulation run through the declarative
  ``SimSpec -> run`` API.

``--smoke`` shrinks the workloads for CI (same code paths, seconds not
minutes); ``--json PATH`` writes a machine-readable result file
(events/s, wall time, per-cell status) — the benchmark artifact CI
uploads to seed the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Tuple

from repro.api import SimSpec, run


def _spec(name: str, body: dict) -> SimSpec:
    d = dict(body)
    d["name"] = name
    return SimSpec.from_dict(d)


def _cells(n_cell: int) -> Dict[str, dict]:
    wl = {"n_requests": n_cell, "rate": 20.0, "seed": 1}
    moe = {"name": "mixtral-8x7b"}
    return {
        "pd": {
            "topology": {"preset": "pd", "n_prefill": 2, "n_decode": 2,
                         "prefill_tp": 2, "decode_tp": 2},
            "workload": wl},
        "af": {
            "model": moe,
            "topology": {"preset": "af", "m": 2, "attn_tp": 2, "ffn_ep": 8},
            "policy": {"router": {"name": "zipf", "alpha": 1.1}},
            "workload": wl},
        "af_cross_cluster_ep": {
            "model": moe,
            "topology": {"preset": "af", "m": 2, "attn_tp": 2, "ffn_ep": 8,
                         "remote_expert_ranks": [6, 7],
                         "expert_link_bw": 25e9,
                         "expert_link_latency": 5e-6},
            "policy": {"router": {"name": "zipf", "alpha": 1.1}},
            "workload": wl},
        "tp_pp": {
            "topology": {"preset": "colocated", "tp": 4, "pp": 2},
            "workload": wl},
        "dp": {
            "topology": {"preset": "colocated", "n_replicas": 4},
            "workload": wl},
        "ep": {
            "model": moe,
            "topology": {"preset": "colocated", "tp": 8, "ep": 8},
            "policy": {"router": "zipf"},
            "workload": wl},
        "sched_chunked_prefill": {
            "topology": {"preset": "colocated"},
            "policy": {"batching": {"name": "chunked_prefill",
                                    "chunk": 256}},
            "workload": wl},
        "sched_continuous": {
            "topology": {"preset": "colocated"},
            "policy": {"batching": "continuous"},
            "workload": wl},
        "mem_prefix_cache": {
            "topology": {"preset": "pd"},
            "memory": {"manager": "prefix", "transfer_overlap": 0.8},
            "workload": dict(wl, prefix_groups=4, prefix_len=512)},
        "mem_preemption": {
            "topology": {"preset": "pd"},
            "memory": {"manager": "paged", "capacity_frac": 0.005,
                       "preemption": "recompute"},
            "workload": dict(wl, arrival="burst",
                             burst_size=max(n_cell // 2, 1),
                             prompt="fixed", prompt_mean=64,
                             output="fixed", output_mean=1024)},
    }


def run_bench(smoke: bool = False) -> Tuple[List[str], dict]:
    lines: List[str] = []
    results: dict = {"smoke": smoke, "cells": {}}

    # ---- scale: 16-replica cluster ----------------------------------------
    n_scale = 200 if smoke else 2000
    rep = run(_spec("sim-scale", {
        "topology": {"preset": "colocated", "n_replicas": 16, "tp": 4},
        "workload": {"n_requests": n_scale, "rate": 200.0,
                     "prompt_mean": 512, "output_mean": 128, "seed": 0},
    }))
    ev, wall = rep.sim_events, rep.wall_clock_s
    results["scale"] = {
        "n_requests": n_scale, "events": ev, "wall_s": wall,
        "events_per_s": ev / wall,
        "sim_speedup": rep.sim_duration_s / wall,
        "completed": rep.summary["n_completed"],
    }
    lines.append(
        f"sim_scale_16replica_{n_scale}req,{wall * 1e6 / max(ev, 1):.2f},"
        f"events={ev};events_per_s={ev / wall:,.0f};"
        f"sim_speedup={rep.sim_duration_s / wall:.1f}x;"
        f"completed={rep.summary['n_completed']}")

    # ---- Table-1 feature matrix -------------------------------------------
    n_cell = 20 if smoke else 100
    for name, body in _cells(n_cell).items():
        rep = run(_spec(f"table1-{name}", body))
        ok = rep.summary["n_completed"] == n_cell
        results["cells"][name] = {
            "supported": ok, "wall_s": rep.wall_clock_s,
            "events": rep.sim_events,
            "tok_s_per_device": rep.summary["throughput_tok_s_per_device"],
            "ttft_p50_s": rep.summary["ttft_p50_s"],
            "preemptions": rep.summary.get("preemptions", 0),
            "prefix_hit_token_frac":
                rep.summary.get("prefix_hit_token_frac"),
        }
        ttft = rep.summary["ttft_p50_s"]
        lines.append(
            f"table1_{name},{rep.wall_clock_s * 1e6:.0f},"
            f"supported={'yes' if ok else 'NO'};"
            f"tok_s_dev={rep.summary['throughput_tok_s_per_device']:.1f};"
            f"ttft_p50={'n/a' if ttft is None else f'{ttft * 1e3:.1f}ms'}")
    return lines, results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workloads for CI")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable results (events/s, wall "
                         "time, per-cell status) to PATH")
    args = ap.parse_args()
    out_lines, out_results = run_bench(smoke=args.smoke)
    for l in out_lines:
        print(l)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out_results, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
