"""MoE + AF disaggregation study (MegaScale-Infer / Step-3 style).

Sweeps the attention:FFN device ratio and micro-batch count for
mixtral-8x7b decode under skewed (Zipf) expert routing, reporting the
pipeline critical path, bubbles, and the MoE straggler penalty — the three
phenomena Frontier's event-graph + micro-workflow models capture.

    PYTHONPATH=src python examples/moe_af_simulation.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import A800_SXM4_80G, ParallelismConfig
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.routing import BalancedRouting, ZipfRouting
from repro.core.workflows.af_disagg import simulate_af_decode_step


def main():
    cfg = get_config("mixtral-8x7b")
    hw = A800_SXM4_80G
    ops = OperatorModelSet(hw)
    lens = [2048] * 256          # decode batch: 256 seqs @ 2k context

    print(f"{'attn:ffn':>9s} {'m':>3s} {'routing':>9s} {'step(ms)':>9s} "
          f"{'attn idle':>9s} {'ffn idle':>9s}")
    for n_attn, n_ffn in ((2, 6), (4, 4), (6, 2)):
        for m in (1, 2, 4):
            for rname, router in (("balanced", BalancedRouting()),
                                  ("zipf1.2", ZipfRouting(1.2))):
                st = simulate_af_decode_step(
                    cfg, hw, ops, lens, m=m,
                    attn_par=ParallelismConfig(tp=n_attn),
                    ffn_par=ParallelismConfig(tp=1, ep=n_ffn),
                    routing=router, rng=np.random.default_rng(0))
                print(f"{n_attn}:{n_ffn:>7} {m:3d} {rname:>9s} "
                      f"{st.makespan*1e3:9.2f} {st.attn_bubble_frac:9.1%} "
                      f"{st.ffn_bubble_frac:9.1%}")
    print("\nReading: ffn-heavy ratios waste attention GPUs (idle%); "
          "zipf routing inflates the FFN stage via the straggler max().")


if __name__ == "__main__":
    main()
