"""Heterogeneous StageGraph topologies in one-liners.

What used to require a bespoke builder is now a declarative graph:

1. PD front on A800 + AF-disaggregated MoE decode on H100, with two of
   eight EP ranks hosted on a remote A800 expert cluster reached over an
   asymmetric inter-cluster link (cross-cluster expert routing);
2. the same system with TWO decode pools of different hardware, fed by one
   prefill cluster — the controller picks the least-loaded pool with free
   KV memory per transfer.

    PYTHONPATH=src python examples/heterogeneous_topology.py
"""
from repro.configs import get_config
from repro.core import (
    A800_SXM4_80G, H100_SXM, ClusterSpec, LinkSpec, ParallelismConfig,
    StageGraph, build_system,
)
from repro.workload.generator import WorkloadConfig, generate


def pd_af_cross_cluster(cfg):
    return StageGraph(
        clusters=[
            ClusterSpec("prefill", "prefill", n_replicas=2,
                        par=ParallelismConfig(tp=2)),
            ClusterSpec("decode", "decode", step="af", m=2,
                        hardware=H100_SXM,
                        par=ParallelismConfig(tp=2),
                        attn_par=ParallelismConfig(tp=2),
                        ffn_par=ParallelismConfig(tp=1, ep=8),
                        remote_expert_ranks=(6, 7),
                        expert_cluster_hw=A800_SXM4_80G,
                        expert_link=LinkSpec("decode", "experts",
                                             bandwidth=25e9, latency=5e-6),
                        seed_offset=50),
        ],
        links=[LinkSpec("prefill", "decode", bandwidth=50e9),
               LinkSpec("decode", "prefill", bandwidth=25e9)])


def two_decode_pools(cfg):
    return StageGraph(
        clusters=[
            ClusterSpec("prefill", "prefill", n_replicas=1,
                        par=ParallelismConfig(tp=2)),
            ClusterSpec("decode-h100", "decode", hardware=H100_SXM,
                        par=ParallelismConfig(tp=2), seed_offset=100),
            ClusterSpec("decode-a800", "decode",
                        par=ParallelismConfig(tp=2), seed_offset=200),
        ],
        links=[LinkSpec("prefill", "decode-h100", bandwidth=50e9),
               LinkSpec("prefill", "decode-a800", bandwidth=25e9)])


def main():
    mcfg = get_config("mixtral-8x7b")
    cfg = get_config("qwen2-7b")
    wl = WorkloadConfig(n_requests=60, rate=15.0, prompt_mean=512,
                        output_mean=32, seed=0)

    sys = build_system(mcfg, A800_SXM4_80G, pd_af_cross_cluster(mcfg),
                       routing="zipf")
    rep = sys.run(generate(wl))
    pred = sys.clusters["decode"].replicas[0].predictor
    st = pred.last_stats
    print("1) PD front + AF decode (H100) + cross-cluster EP (A800):")
    print(f"   completed={rep['n_completed']}  "
          f"tok/s/dev={rep['throughput_tok_s_per_device']:.1f}  "
          f"tpot_p50={rep['tpot_p50_s']*1e3:.1f}ms")
    print(f"   last decode step: straggler={st.ep_straggler_excess*1e3:.2f}ms"
          f"  cross-cluster={st.cross_cluster_bytes/1e6:.2f}MB"
          f"  ffn idle={st.ffn_bubble_frac:.1%}")

    sys = build_system(cfg, A800_SXM4_80G, two_decode_pools(cfg))
    rep = sys.run(generate(wl))
    print("\n2) one prefill cluster feeding two heterogeneous decode pools:")
    print(f"   completed={rep['n_completed']}  "
          f"tpot_p50={rep['tpot_p50_s']*1e3:.1f}ms")
    for name in ("decode-h100", "decode-a800"):
        toks = sum(w.stats["tokens"] for w in sys.clusters[name].replicas)
        print(f"   {name}: {toks} tokens decoded")


if __name__ == "__main__":
    main()
