"""Step construction + sharding trees shared by dryrun.py, train.py, tests.

``build_step(cfg, mesh, shape)`` returns everything needed to lower one
(architecture x input-shape) cell on a mesh without allocating anything:
the step callable, ShapeDtypeStruct args, NamedSharding in/out trees and
donation indices.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import AxisRules, is_pd, shape_tree
from repro.models.model import build_model
from repro.training.optimizer import AdamW, AdamWConfig, make_train_step


@dataclass
class StepBundle:
    name: str
    fn: Callable
    args: Tuple[Any, ...]                 # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    model: Any
    meta: Dict[str, Any]


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), spec_tree)


def batch_shardings(ax: AxisRules, mesh, specs: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in specs.items():
        dims = [None] * len(v.shape)
        if len(v.shape) >= 1:
            dims[0] = ax.batch(v.shape[0])
        out[k] = NamedSharding(mesh, P(*dims))
    return out


def scan_trip_counts(cfg: ModelConfig) -> Dict[str, int]:
    period = len(cfg.block_pattern)
    tc = {"layer_groups": cfg.num_layers // period}
    if cfg.encoder_layers:
        tc["encoder_groups"] = cfg.encoder_layers
    return tc


def build_step(cfg: ModelConfig, mesh, shape: ShapeConfig, *,
               remat: str = "none", param_dtype=jnp.bfloat16,
               zero1: bool = True,
               options: Optional[Dict[str, Any]] = None) -> StepBundle:
    ax = AxisRules(mesh, options)
    model = build_model(cfg, ax, remat=remat)
    pds = model.pds()
    params_sds = shape_tree(pds, param_dtype)
    params_specs = ax.spec_tree(pds)
    params_sh = _ns(mesh, params_specs)
    in_specs = model.input_specs(shape)
    batch_sh = batch_shardings(ax, mesh, in_specs)

    meta = {
        "arch": cfg.name, "shape": shape.name, "kind": shape.kind,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "param_count": None,  # filled below
        "scan_trip_counts": scan_trip_counts(cfg),
    }
    n_params = sum(
        int(jnp.prod(jnp.array(l.shape))) if l.shape else 1
        for l in jax.tree_util.tree_leaves(params_sds))
    meta["param_count"] = n_params

    if shape.kind == "train":
        opt = AdamW(AdamWConfig(zero1=zero1), ax)
        opt_pds = opt.state_pds(pds)
        opt_sds = shape_tree(opt_pds, jnp.float32)
        opt_sh = _ns(mesh, ax.spec_tree(opt_pds))
        step = make_train_step(model, opt)
        return StepBundle(
            name="train_step", fn=step,
            args=(params_sds, opt_sds, in_specs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            donate_argnums=(0, 1), model=model, meta=meta)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch)
        return StepBundle(
            name="prefill_step", fn=prefill_step,
            args=(params_sds, in_specs),
            in_shardings=(params_sh, batch_sh),
            donate_argnums=(), model=model, meta=meta)

    # decode: one new token against a KV cache of shape.seq_len
    B = shape.global_batch
    cache_pds = model.cache_pds(B, shape.seq_len)
    cache_sds = shape_tree(cache_pds, param_dtype)
    cache_sh = _ns(mesh, AxisRules(mesh).spec_tree(cache_pds))
    tok_sds = in_specs["tokens"]
    tok_sh = NamedSharding(mesh, P(AxisRules(mesh).batch(B), None))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())

    def serve_step(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    meta["cache_bytes_global"] = sum(
        int(jnp.prod(jnp.array(l.shape))) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(cache_sds))
    return StepBundle(
        name="serve_step", fn=serve_step,
        args=(params_sds, cache_sds, tok_sds, pos_sds),
        in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
        donate_argnums=(1,), model=model, meta=meta)


def lower_step(bundle: StepBundle, mesh):
    jfn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                  donate_argnums=bundle.donate_argnums)
    with jax.set_mesh(mesh):
        lowered = jfn.lower(*bundle.args)
    return lowered
