"""Vectorized batch evaluation of the analytical step-time model.

The scalar :meth:`ExecutionPredictor.step_time` walks the layer pattern
per call, looping over per-request shapes in Python — fine for one step,
ruinous for thousands of candidate batches (sweeps, router cache probes,
bench cells).  This module evaluates the SAME closed-form roofline math
over whole arrays of ``(q_lens, kv_lens)`` batch shapes at once:

- every roofline operator (GEMM / attention / membound) contributes one
  ``(flops, bytes)`` row per layer term, vectorized across the B steps;
- per-request attention reductions use one concatenation plus
  ``np.add.reduceat`` instead of B Python loops;
- the fused cost kernel — ``sum_t mult_t * max(F_t/peak, B_t/bw)`` — runs
  either in numpy (float64, matches the scalar path to ~1e-12 relative)
  or, behind the ``jit`` backend flag, as one ``jax.jit``-compiled
  evaluation (float32 on CPU jax; looser tolerance).

Only the base analytical model vectorizes: MoE layers draw routing
assignments from the predictor RNG (bit-exact equivalence requires the
per-step draw order), and refined/subclassed operator models may override
arbitrary operators.  :func:`supports_vectorized` gates those cases; the
predictor falls back to the scalar walk per step.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV
from repro.core.opmodels.analytical import OperatorModelSet

#: methods whose analytical closed form the vectorizer replicates; any
#: override on the installed OperatorModelSet disables vectorization
_ANALYTICAL_METHODS = ("gemm", "attention_prefill", "attention_decode",
                       "all_reduce", "all_to_all", "p2p", "membound",
                       "_roof")


def supports_vectorized(pred) -> bool:
    """True when ``batch_step_totals`` reproduces ``pred.step_time``."""
    from repro.core.predictor import ExecutionPredictor
    if type(pred)._step_time_impl is not ExecutionPredictor._step_time_impl:
        return False                      # subclassed step walk (AF events)
    if pred.cfg.moe is not None:
        return False                      # RNG-driven expert routing
    ops_t = type(pred.ops)
    return all(getattr(ops_t, m, None) is getattr(OperatorModelSet, m)
               for m in _ANALYTICAL_METHODS)


class _Terms:
    """Accumulator translating the scalar ``bd.add`` sequence into roof
    rows (vectorized max) plus a linear part (collectives, overheads)."""

    def __init__(self, B: int, hw):
        self.F: List[np.ndarray] = []     # roof flops rows, each (B,)
        self.Bt: List[np.ndarray] = []    # roof bytes rows
        self.mult: List[float] = []       # per-row multiplier (n_mats etc.)
        self.lin = np.zeros(B)            # linear terms + op overheads
        self.hw = hw
        self._b = B

    def roof(self, flops, bytes_, mult: float = 1.0) -> None:
        self.F.append(np.broadcast_to(np.asarray(flops, float), (self._b,)))
        self.Bt.append(np.broadcast_to(np.asarray(bytes_, float),
                                       (self._b,)))
        self.mult.append(mult)
        self.lin = self.lin + mult * self.hw.op_overhead

    def gemm(self, m, n: int, k: int, mult: float = 1.0,
             dtype_bytes: int = 2) -> None:
        m = np.asarray(m, float)
        self.roof(2.0 * m * n * k,
                  dtype_bytes * (m * k + k * n + m * n), mult)

    def membound(self, nbytes, mult: float = 1.0) -> None:
        # max(0/peak, b/hbm) + oh == b/hbm + oh: bitwise the scalar path
        self.roof(0.0, nbytes, mult)

    def all_reduce(self, nbytes, n: int) -> None:
        if n <= 1:
            return
        bw = self.hw.intra_node_bw
        self.lin = self.lin + (2.0 * np.asarray(nbytes, float)
                               * (n - 1) / n / bw + self.hw.op_overhead)

    def evaluate(self, backend: str) -> np.ndarray:
        if not self.F:
            return self.lin.copy()
        F = np.stack(self.F)
        Bt = np.stack(self.Bt)
        mult = np.asarray(self.mult, float)
        hw = self.hw
        if backend == "jit":
            fn = _fused_kernel(hw.peak_flops, hw.hbm_bw)
            if fn is not None:
                return np.asarray(fn(F, Bt, mult), float) + self.lin
        roofs = np.maximum(F / hw.peak_flops, Bt / hw.hbm_bw)
        return mult @ roofs + self.lin


_KERNELS = {}


def _fused_kernel(peak: float, hbm: float):
    """One jit-compiled fused roofline evaluation per hardware point.
    Returns None when jax is unavailable (callers fall back to numpy)."""
    key = (peak, hbm)
    if key in _KERNELS:
        return _KERNELS[key]
    try:
        import jax
        import jax.numpy as jnp
    except ImportError:                   # gated dep: numpy fallback
        _KERNELS[key] = None
        return None

    @jax.jit
    def fused(F, Bt, mult):
        return (mult[:, None]
                * jnp.maximum(F / peak, Bt / hbm)).sum(axis=0)

    _KERNELS[key] = fused
    return fused


def batch_step_totals(pred, steps: Sequence[Tuple[Sequence[int],
                                                  Sequence[int]]],
                      *, decode: bool,
                      backend: str = "numpy") -> np.ndarray:
    """Vectorized ``[pred.step_time(q, kv, decode=...).total for q, kv in
    steps]`` for analytical-model predictors (see module doc).

    ``steps`` is a sequence of ``(q_lens, kv_lens)`` pairs; returns a
    float64 array of per-step totals in seconds.  Requires
    ``supports_vectorized(pred)``.
    """
    cfg, par, hw = pred.cfg, pred.par, pred.ops.hw
    B = len(steps)
    if B == 0:
        return np.zeros(0)
    tp = max(par.tp, 1)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads

    lens = np.array([len(q) for q, _ in steps])
    live = lens > 0                       # zero-token steps price to 0.0
    idx = np.flatnonzero(live)
    if len(idx) == 0:
        return np.zeros(B)
    Q = np.concatenate([np.asarray(steps[i][0], float) for i in idx])
    KV = np.concatenate([np.asarray(steps[i][1], float) for i in idx])
    offs = np.concatenate(([0], np.cumsum(lens[idx])))[:-1]
    n_req = lens[idx].astype(float)
    toks = np.add.reduceat(Q, offs)

    # per-window attention reductions, computed once and reused per layer
    attn_cache = {}

    def attn_sums(window: int):
        if window in attn_cache:
            return attn_cache[window]
        eff = np.minimum(KV, window) if window else KV
        if decode:
            pairs_sum = None
        else:
            factor = (np.where(Q == KV, 0.5, 1.0)
                      if not window else np.ones_like(Q))
            pairs_sum = np.add.reduceat(Q * eff * factor, offs)
        sums = (pairs_sum, np.add.reduceat(eff, offs),
                np.add.reduceat(Q, offs))
        attn_cache[window] = sums
        return sums

    t = _Terms(len(idx), hw)
    t.membound(2.0 * toks * d)                                    # embed
    for kind in cfg.pattern:
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            window = cfg.sliding_window if kind == ATTN_LOCAL else 0
            t.gemm(toks, (H + 2 * K) * hd // tp, d)               # qkv
            pairs_sum, eff_sum, q_sum = attn_sums(window)
            if decode:
                t.roof(4.0 * (H // tp) * hd * eff_sum,
                       4.0 * eff_sum * max(K // tp, 1) * hd)
            else:
                t.roof(4.0 * (H // tp) * hd * pairs_sum,
                       2.0 * (q_sum * (H // tp)
                              + 2.0 * eff_sum * max(K // tp, 1)) * hd)
            t.gemm(toks, d, H * hd // tp)                         # o_gemm
            t.all_reduce(2.0 * toks * d, tp)
            n_mats = 3 if cfg.gated_mlp else 2                    # dense ffn
            t.gemm(toks, cfg.d_ff // tp, d, mult=n_mats)
            t.all_reduce(2.0 * toks * d, tp)
        elif kind == RWKV:
            t.gemm(toks, d // tp, d, mult=5)
            Hh, hs = d // cfg.rwkv_head_size, cfg.rwkv_head_size
            t.membound(4.0 * toks * Hh * hs * hs / tp)
            t.gemm(toks, d, d // tp)
            t.all_reduce(2.0 * toks * d, tp)
            t.gemm(toks, cfg.d_ff // tp, d, mult=2)               # chan-mix
        else:                                                     # RG-LRU
            t.gemm(toks, d // tp, d, mult=2)
            t.gemm(toks, d // tp, d // tp, mult=2)
            t.membound(4.0 * toks * d / tp)
            t.gemm(toks, d, d // tp)
            t.all_reduce(2.0 * toks * d, tp)
            if kind == RECURRENT:
                n_mats = 3 if cfg.gated_mlp else 2
                t.gemm(toks, cfg.d_ff // tp, d, mult=n_mats)
                t.all_reduce(2.0 * toks * d, tp)
    n_logits = toks if decode else n_req
    t.gemm(n_logits, cfg.padded_vocab // tp, d)                   # head

    totals = t.evaluate(backend)
    pp = max(par.pp, 1)
    if pp > 1:
        m = np.maximum(n_req, 1.0)
        totals = totals * (pp + m - 1) / (m * pp) * pp
        totals = totals + ((2.0 * toks * d) / hw.inter_node_bw
                           + hw.op_overhead) * (pp - 1)
    totals = totals + pred.engine_overhead

    out = np.zeros(B)
    out[idx] = totals
    return out
