"""Trace study: record a run, export it, read it back, explain the tail.

Runs the traced PD spec (``examples/specs/trace.yaml``), fans the
recorded telemetry out to all three sinks (Perfetto chrome trace, spans
JSONL, text summary), then — deliberately — reads its *own* JSONL back
with :func:`read_spans_jsonl` and reconstructs the critical path of the
five slowest requests span by span.  That round trip is the point: the
artifact on disk, not the in-memory recorder, is what post-hoc analysis
tooling gets to see.

    PYTHONPATH=src python examples/trace_study.py

Open ``artifacts/trace-study-pd.trace.json`` at https://ui.perfetto.dev
to see the same requests on the instance/replica timeline.
"""
import os

from repro.api import SimSpec
from repro.obs import (ATTRIBUTION_KEYS, read_spans_jsonl, render_summary,
                       run_traced, write_chrome_trace, write_spans_jsonl,
                       write_summary)

HERE = os.path.dirname(os.path.abspath(__file__))
SPEC = os.path.join(HERE, "specs", "trace.yaml")
OUT = os.path.join(HERE, "..", "artifacts")


def record(out_dir: str) -> str:
    """Run the traced spec, write all three artifacts, return the jsonl."""
    spec = SimSpec.load(SPEC)
    rep, tel = run_traced(spec)
    assert rep.all_complete, rep.conservation

    os.makedirs(out_dir, exist_ok=True)
    base = os.path.join(out_dir, spec.name or "trace")
    write_chrome_trace(tel, base + ".trace.json")
    write_spans_jsonl(tel, base + ".spans.jsonl")
    write_summary(tel, base + ".summary.txt")

    print(render_summary(tel))
    fracs = {k: rep.summary[f"attribution_{k[:-2]}_frac"]
             for k in ATTRIBUTION_KEYS}
    assert abs(sum(fracs.values()) - 1.0) < 1e-6, fracs
    print(f"\nartifacts under {os.path.relpath(out_dir)}/ "
          f"(load the .trace.json in Perfetto)")
    return base + ".spans.jsonl"


def critical_paths(jsonl_path: str, top_n: int = 5) -> None:
    """Reconstruct the slowest requests' lifecycles from the file alone."""
    data = read_spans_jsonl(jsonl_path)
    print(f"\n== read back {data['header']['n_spans']} spans / "
          f"{data['header']['n_requests']} requests from "
          f"{os.path.basename(jsonl_path)} ==")

    by_rid = {}
    for s in data["spans"]:
        by_rid.setdefault(s.rid, []).append(s)
    slowest = sorted(data["requests"], key=lambda r: r["e2e"],
                     reverse=True)[:top_n]

    for rec in slowest:
        a = rec["attribution"]
        print(f"\nrid={rec['rid']} e2e={rec['e2e'] * 1e3:.1f}ms  "
              + "  ".join(f"{k[:-2]}={a[k] * 1e3:.1f}ms"
                          for k in ATTRIBUTION_KEYS if a[k] > 0))
        for s in sorted(by_rid.get(rec["rid"], []),
                        key=lambda s: (s.start, s.end)):
            extra = ""
            if s.kind == "prefill_chunk":
                extra = (f" chunk={s.meta.get('chunk')}"
                         f"/{s.meta.get('total')}")
            elif s.kind == "decode":
                extra = f" epochs={s.meta.get('epochs')}"
            elif s.kind == "kv_transfer":
                extra = (f" bytes={s.meta.get('bytes')}"
                         f" exposed={s.meta.get('exposed_s')}")
            print(f"  [{s.start * 1e3:9.2f} -> {s.end * 1e3:9.2f} ms] "
                  f"{s.kind:<15s} {s.replica or '-':<10s}"
                  f" ({s.category or 'detail'}){extra}")
    print("\nReading: the tail requests queue behind the burst, then pay "
          "chunked prefill and the PD KV hop before decode; attribution "
          "says how much of each e2e was queue vs compute vs comm.")


def main():
    jsonl = record(OUT)
    critical_paths(jsonl, top_n=5)


if __name__ == "__main__":
    main()
