"""KV-cache memory management policies (PagedAttention-style block manager).

The decode cluster's ClusterScheduler tracks memory through one of these
managers; `free` events trigger MEMORY_AVAILABLE signals to the
GlobalController — the backpressure mechanism of PD disaggregation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple


class PagedKVManager:
    """vLLM-style paged allocator: fixed-size token blocks per request."""

    def __init__(self, total_bytes: float, kv_bytes_per_token: float, *,
                 block_tokens: int = 16, watermark: float = 0.02):
        self.block_tokens = block_tokens
        self.block_bytes = kv_bytes_per_token * block_tokens
        self.total_blocks = int(total_bytes // max(self.block_bytes, 1))
        self.free_blocks = self.total_blocks
        self.watermark_blocks = int(self.total_blocks * watermark)
        self._held: Dict[int, int] = {}   # rid -> blocks

    def blocks_for(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.block_tokens))

    def can_admit(self, tokens: int) -> bool:
        return (self.free_blocks - self.blocks_for(tokens)
                >= self.watermark_blocks)

    def admit(self, rid: int, tokens: int) -> bool:
        need = self.blocks_for(tokens)
        if self.free_blocks - need < self.watermark_blocks:
            return False
        self.free_blocks -= need
        self._held[rid] = need
        return True

    def grow(self, rid: int, new_tokens: int) -> bool:
        """Ensure rid holds enough blocks for new total token count."""
        need = self.blocks_for(new_tokens)
        have = self._held.get(rid, 0)
        if need <= have:
            return True
        extra = need - have
        if self.free_blocks < extra:
            return False
        self.free_blocks -= extra
        self._held[rid] = need
        return True

    def free(self, rid: int) -> int:
        blocks = self._held.pop(rid, 0)
        self.free_blocks += blocks
        assert self.free_blocks <= self.total_blocks
        return blocks

    @property
    def utilization(self) -> float:
        if self.total_blocks == 0:
            return 1.0
        return 1.0 - self.free_blocks / self.total_blocks

    def held_blocks(self) -> int:
        return sum(self._held.values())


class MonolithicKVManager(PagedKVManager):
    """Contiguous per-request allocation at max length (TensorRT-LLM v1
    style static memory): admits reserve output_len upfront."""

    def __init__(self, total_bytes: float, kv_bytes_per_token: float,
                 max_len: int, **kw):
        super().__init__(total_bytes, kv_bytes_per_token, block_tokens=1, **kw)
        self.max_len = max_len

    def blocks_for(self, tokens: int) -> int:  # always reserve max_len
        return self.max_len


MEMORY = {"paged": PagedKVManager, "monolithic": MonolithicKVManager}


def resolve_memory(spec) -> Tuple[type, dict]:
    """Resolve a memory-manager spec to ``(cls, constructor_kwargs)``.

    Unlike batching/routing, KV managers need build-time arguments (the
    per-replica byte budget), so resolution returns the class plus any
    extra kwargs; the system builder supplies budget/kv_bytes_per_token.
    Accepts None (paged defaults), a registered name, or a mapping
    ``{"name": ..., **kwargs}`` (e.g. block_tokens, watermark).
    """
    if spec is None:
        return PagedKVManager, {}
    if isinstance(spec, str):
        spec = {"name": spec}
    if isinstance(spec, dict):
        kw = dict(spec)
        name = kw.pop("name", None)
        if name not in MEMORY:
            raise KeyError(f"unknown memory manager {name!r}; "
                           f"registered: {sorted(MEMORY)}")
        return MEMORY[name], kw
    raise TypeError(f"memory must be None, a name, or a mapping; "
                    f"got {type(spec).__name__}")
