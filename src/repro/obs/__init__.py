"""Observability: request spans, sim-time counters, trace export.

Everything here is off-by-default.  A simulation run pays nothing unless
a :class:`~repro.obs.telemetry.Telemetry` recorder is attached — every
instrumentation site in the core guards on ``telemetry is not None``, so
``obs: off`` runs are byte-identical to pre-observability builds.

Enable declaratively::

    spec = SimSpec(..., obs=ObsSpec())
    rep, tel = run_traced(spec)
    write_chrome_trace(tel, "out.trace.json")   # load in Perfetto
    write_spans_jsonl(tel, "out.spans.jsonl")
    print(render_summary(tel))

or from the CLI: ``python -m repro trace spec.yaml --out artifacts/t``.
"""
from repro.obs.attribution import ATTRIBUTION_KEYS, attribution_for
from repro.obs.counters import CounterBoard
from repro.obs.sinks import (
    SINKS,
    SPANS_SCHEMA_VERSION,
    TraceSink,
    engine_events_to_chrome,
    read_spans_jsonl,
    render_summary,
    write_chrome_trace,
    write_spans_jsonl,
    write_summary,
)
from repro.obs.spans import SPAN_CATEGORY, Span
from repro.obs.telemetry import RequestRecord, Telemetry, attach_telemetry

__all__ = [
    "ATTRIBUTION_KEYS", "CounterBoard", "RequestRecord", "SINKS",
    "SPANS_SCHEMA_VERSION", "SPAN_CATEGORY", "Span", "Telemetry",
    "TraceSink", "attach_telemetry", "attribution_for",
    "engine_events_to_chrome", "read_spans_jsonl", "render_summary",
    "run_traced", "write_chrome_trace", "write_spans_jsonl",
    "write_summary",
]


def run_traced(spec):
    """Run ``spec`` with telemetry attached; return ``(report, tel)``.

    Forces observability on (a default ``ObsSpec`` is injected when the
    spec carries none; other obs options are preserved), so this is the
    one-call entry point for trace studies and the ``repro trace`` CLI
    verb.
    """
    from dataclasses import asdict

    from repro.obs.telemetry import Telemetry

    if spec.obs is None or not spec.obs.enabled:
        obs = asdict(spec.obs) if spec.obs is not None else {}
        obs["enabled"] = True
        spec = spec.with_(obs=obs)
    tel = Telemetry.from_spec(spec.obs)
    if spec.fleet is not None:
        from repro.fleet.report import run_fleet
        rep = run_fleet(spec, telemetry=tel)
    else:
        from repro.api.run import run
        rep = run(spec, telemetry=tel)
    return rep, tel
