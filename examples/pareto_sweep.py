"""Design-space exploration: throughput/interactivity Pareto frontier.

The motivating use-case of the paper — finding the optimal serving config
without burning 18,000 GPU-hours.  Sweeps (topology x parallelism x
batching policy) for qwen2-7b on a 16-GPU budget with the declarative
sweep API: the whole study is one `sweep()` over a topology/policy axis,
fanned out across processes, and `pareto()` reads the frontier.

    PYTHONPATH=src python examples/pareto_sweep.py [--jobs N]
"""
import argparse

from repro.api import ModelRef, SimSpec, WorkloadSpec, pareto, sweep

BUDGET = 16   # devices


def candidate_axes():
    """Zip-mode axes: (topology, batching policy) pairs per candidate."""
    topologies, batchings, names = [], [], []
    for tp in (1, 2, 4):
        n = BUDGET // tp
        for pol in ("cont", "chunked"):
            topologies.append({"preset": "colocated", "n_replicas": n,
                               "tp": tp})
            batchings.append({"name": "continuous"} if pol == "cont" else
                             {"name": "chunked_prefill", "chunk": 512})
            names.append(f"colo x{n} tp{tp} {pol}")
    for n_p in (4, 8, 12):
        topologies.append({"preset": "pd", "n_prefill": n_p,
                           "n_decode": BUDGET - n_p})
        batchings.append(None)     # role defaults
        names.append(f"pd {n_p}P:{BUDGET - n_p}D")
    return topologies, batchings, names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=1)
    args = ap.parse_args()

    base = SimSpec(model=ModelRef("qwen2-7b"),
                   workload=WorkloadSpec(n_requests=150, rate=25.0,
                                         prompt_mean=1024, output_mean=128),
                   seed=0)
    topologies, batchings, names = candidate_axes()
    reports = sweep(base, {"topology": topologies,
                           "policy.batching": batchings},
                    mode="zip", jobs=args.jobs)

    print(f"{'config':24s} {'tok/s/dev':>10s} {'tpot_p50(ms)':>13s} "
          f"{'ttft_p99(ms)':>13s}")
    for name, rep in zip(names, reports):
        print(f"{name:24s} {rep['throughput_tok_s_per_device']:10.1f} "
              f"{rep['tpot_p50_s'] * 1e3:13.2f} "
              f"{rep['ttft_p99_s'] * 1e3:13.1f}")

    front = pareto(reports)
    print("\nPareto frontier (throughput x interactivity):")
    for rep in front:
        print("  *", names[reports.index(rep)])


if __name__ == "__main__":
    main()
