from repro.models.model import LM, EncDec, build_model  # noqa: F401
from repro.models.common import AxisRules, init_tree, shape_tree, NO_RULES  # noqa: F401
