"""FleetController: the control plane over N serving instances.

Owns the shared SimEngine's fleet-level events: request arrivals (tenant
assignment + global routing), instance lifecycle (cold-started scale-up,
drain-then-release scale-down, P:D pool rebalancing), and the autoscaler
tick loop.  Every instance is a full single-deployment build
(:mod:`repro.fleet.instance`); the controller only ever talks to the
instance surface (``outstanding`` / ``prefix_probe`` / ``accept``), never
to replicas directly — intra-instance scheduling stays the
GlobalController's job.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.engine import SimEngine
from repro.core.events import EV
from repro.fleet.instance import (
    ACTIVE, DRAINING, STARTING, Instance, instance_subspec,
)
from repro.fleet.router import resolve_fleet_router


class FleetController:
    def __init__(self, spec, engine: SimEngine, *,
                 hardware=None, ops=None, engine_overhead=None,
                 telemetry=None):
        from repro.fleet.autoscaler import Autoscaler
        self.spec = spec
        self.fleet = spec.fleet
        self.engine = engine
        self._hardware = hardware
        self._ops = ops
        self._engine_overhead = engine_overhead
        # set before the initial instance builds below so every instance
        # (initial and scaled-up alike) is wired through _build_instance
        self.telemetry = telemetry
        self.rng = np.random.default_rng([spec.seed, 0xF1EE7])
        # windowed mode: every instance runs on its OWN sub-engine and the
        # fleet engine only carries control-plane events (arrivals, ticks,
        # lifecycle); repro.fleet.windowed advances them in conservative
        # time windows.  Serial mode shares ONE engine across everything.
        self.windowed = getattr(self.fleet, "engine", "serial") == "windowed"
        self.router = resolve_fleet_router(self.fleet.router)
        self.router.fleet = self     # O(1) aggregate load signals
        self.instances: Dict[str, Instance] = {}
        self._built = 0                   # lifetime instance counter (seeds)
        self.scale_events: List[dict] = []
        self.recent_completed: List = []  # completions since last tick
        self.peak_devices = 0
        self.total_requests = 0
        self.last_arrival = 0.0
        self._moves_in_flight = 0         # pending P:D reconfigurations
        # O(1) load signals for routers: exact mirrors of
        # sum(i.outstanding()) and of the any-non-ACTIVE-instance test,
        # maintained at accept/complete and on lifecycle transitions
        self.outstanding_total = 0
        self._non_active = 0
        # tenant classes: weighted assignment, priorities via timestamps
        self.tenants = list(self.fleet.tenants)
        w = np.array([t.weight for t in self.tenants], float)
        self._tenant_p = w / w.sum() if len(w) else None
        self.autoscaler = (Autoscaler(self.fleet.autoscaler, self)
                           if self.fleet.autoscaler is not None else None)
        for group in self.fleet.instances:
            for _ in range(group.count):
                self._build_instance(group, state=ACTIVE)
        self._track_peak()
        self._apply_faults()

    # ------------------------------------------------------------ building --
    def _build_instance(self, group, state: str) -> Instance:
        from repro.api.run import build
        self._built += 1
        name = f"{group.name}-{self._built - 1}"
        sub = instance_subspec(self.spec, group,
                               seed=self.spec.seed + 7919 * self._built)
        a = self.fleet.autoscaler
        has_spares = (a is not None and a.pd_rebalance and a.pd_spares > 0
                      and sub.topology.preset == "pd")
        if has_spares:
            # standby capacity for P:D rebalancing: build each pool with
            # pd_spares extra replicas; provision_spares parks the extras
            # inactive (they hold no GPUs until a pool move enables them).
            # Only the pd preset's pool knobs support this — inline PD
            # graphs keep their declared replica counts untouched.
            from dataclasses import replace
            sub.topology = replace(sub.topology,
                                   n_prefill=sub.topology.n_prefill
                                   + a.pd_spares,
                                   n_decode=sub.topology.n_decode
                                   + a.pd_spares)
        inst_engine = SimEngine() if self.windowed else self.engine
        handle = build(sub, hardware=self._hardware, ops=self._ops,
                       engine=inst_engine)
        if self.windowed:
            # a scale-up mid-run starts the sub-engine at the fleet clock
            inst_engine.advance_to(self.engine.now)
        if self._engine_overhead is not None:
            for cluster in handle.clusters.values():
                for w in cluster.replicas:
                    w.predictor.engine_overhead = self._engine_overhead
        if has_spares:
            # park the extras BEFORE the Instance samples its device
            # count, so standbys never enter peak/GPU-second accounting
            for cluster in handle.clusters.values():
                pool = cluster.active_replicas()
                for w in pool[len(pool) - a.pd_spares:]:
                    w.active = False
        if self.telemetry is not None:
            from repro.obs import attach_telemetry
            attach_telemetry(handle, self.telemetry, instance=name)
        inst = Instance(name, group, handle,
                        created_at=self.engine.now, state=state)
        if state != ACTIVE:
            self._non_active += 1
        inst.has_spares = has_spares
        handle.controller.observer = \
            lambda r, w, inst=inst: self._on_complete(inst, r)
        self.instances[name] = inst
        inst.touch(self.engine.now)
        self._tel_burn(self.engine.now)
        return inst

    def _apply_faults(self) -> None:
        """Faults land on the FIRST instance of the named group (or of the
        first group when ``instance`` is unset)."""
        from repro.api.spec import SpecError
        for i, f in enumerate(self.spec.faults):
            group = self.fleet.instance_by_name(f.instance)
            inst = next(x for x in self.instances.values()
                        if x.group is group)
            cluster = inst.handle.clusters.get(f.cluster)
            if cluster is None:
                raise SpecError(
                    f"faults[{i}].cluster: instance group "
                    f"{group.name!r} has no cluster {f.cluster!r} "
                    f"(clusters: {sorted(inst.handle.clusters)})")
            if f.replica >= len(cluster.replicas):
                raise SpecError(
                    f"faults[{i}].replica: index {f.replica} out of range "
                    f"— cluster {f.cluster!r} of {inst.name!r} has "
                    f"{len(cluster.replicas)} replicas")
            if f.kind == "failure":
                inst.controller.inject_failure(f.cluster, f.replica,
                                               at=f.at, downtime=f.downtime)
            else:
                cluster.replicas[f.replica].slowdown = f.slowdown

    def _track_peak(self) -> None:
        now = sum(i.provisioned_devices() for i in self.instances.values())
        if now > self.peak_devices:
            self.peak_devices = now

    # ------------------------------------------------------------ arrivals --
    def submit_all(self, requests: List) -> None:
        """Stamp tenants (rid order, so assignment is independent of event
        interleaving) and schedule one fleet-level arrival per request."""
        self.total_requests = len(requests)
        self.last_arrival = max((r.arrival for r in requests), default=0.0)
        if self.tenants:
            draws = self.rng.choice(len(self.tenants), size=len(requests),
                                    p=self._tenant_p)
            for r, d in zip(requests, draws):
                t = self.tenants[int(d)]
                r.tenant = t.name
                r.timestamps["priority"] = float(t.priority)
        arr = [r.arrival for r in requests]
        if any(a > b for a, b in zip(arr, arr[1:])):
            for r in requests:
                self.engine.at(r.arrival, EV.REQUEST_ARRIVAL,
                               lambda ev, r=r: self._arrive(r), rid=r.rid,
                               fleet=True)
        else:
            # sorted arrivals ride the engine's bulk timeline (no heap
            # traffic; seqs assigned in request order => identical ties)
            self.engine.schedule_timeline(
                (r.arrival, EV.REQUEST_ARRIVAL, self._arrive_ev, r)
                for r in requests)
        if self.autoscaler is not None:
            self.autoscaler.start()

    def _arrive_ev(self, ev) -> None:
        self._arrive(ev.data)

    def routable_instances(self) -> List[Instance]:
        return [i for i in self.instances.values() if i.routable]

    def _arrive(self, r) -> None:
        now = self.engine.now
        candidates = self.routable_instances()
        if not candidates:
            raise RuntimeError("fleet: no active instances to route to")
        chosen = self.router.select(r, candidates, now, self.rng)
        # an instance whose entry replicas are all down (fault injection)
        # rejects; spill to the remaining instances before giving up
        if self._accept(chosen, r, now):
            self._tel_route(r, chosen, now)
            return
        for inst in candidates:
            if inst is not chosen and self._accept(inst, r, now):
                self._tel_route(r, inst, now, spilled=True)
                return
        raise RuntimeError("fleet: no instance has healthy entry replicas")

    def _tel_route(self, r, inst: Instance, now: float,
                   spilled: bool = False) -> None:
        tel = self.telemetry
        if tel is None:
            return
        meta = {"instance": inst.name}
        if getattr(r, "tenant", None) is not None:
            meta["tenant"] = r.tenant
        if spilled:
            meta["spilled"] = True
        tel.span("fleet_route", r.rid, now, now, **meta)
        tel.counter("outstanding", now, inst.outstanding(),
                    instance=inst.name)
        tel.counter("fleet_outstanding", now, self.outstanding_total)

    def _accept(self, inst: Instance, r, now: float) -> bool:
        if self.windowed and inst.engine is not self.engine \
                and inst.engine.now < now:
            # conservative windows: the instance's clock is still behind
            # this arrival, so the hand-off fires on ITS engine at the
            # true arrival time.  Registration is eager — the router's
            # load signals must see this request immediately, exactly as
            # in serial mode — only the scheduling side is deferred.
            ctrl = inst.controller
            r.arrival = now
            ctrl.requests[r.rid] = r
            inst.routed += 1
            self.outstanding_total += 1
            inst.engine.at(now, EV.REQUEST_ARRIVAL,
                           lambda ev, inst=inst, r=r:
                           self._deferred_arrive(inst, r),
                           rid=r.rid, fleet=True)
            return True
        try:
            inst.accept(r, now)
        except RuntimeError:
            return False
        self.outstanding_total += 1
        return True

    def _deferred_arrive(self, inst: Instance, r) -> None:
        """Fire an eagerly-registered arrival on the instance engine; a
        rejection (entry replicas all failed) rolls the registration back
        and spills to the surviving instances."""
        ctrl = inst.controller
        prev_start = ctrl.metrics.start
        try:
            ctrl._arrive(r)
        except RuntimeError:
            del ctrl.requests[r.rid]
            ctrl.metrics.start = prev_start
            inst.routed -= 1
            self.outstanding_total -= 1
            for other in self.routable_instances():
                if other is not inst and self._accept(other, r, r.arrival):
                    return
            raise RuntimeError(
                "fleet: no instance has healthy entry replicas")

    # --------------------------------------------------------- completions --
    def _on_complete(self, inst: Instance, r) -> None:
        if self.autoscaler is not None:     # its attainment window is the
            self.recent_completed.append(r)  # only consumer of this list
        # the instance's own clock: identical to self.engine.now in serial
        # mode (one shared engine), and the *correct* completion time in
        # windowed mode, where the fleet engine waits at a barrier
        now = inst.engine.now
        self.outstanding_total -= 1
        tel = self.telemetry
        if tel is not None:
            tel.counter("outstanding", now, inst.outstanding(),
                        instance=inst.name)
            tel.counter("fleet_outstanding", now, self.outstanding_total)
        inst.touch(now)
        if inst.state == DRAINING and inst.outstanding() == 0:
            inst.stop(now)
            self._record_at("drained", inst, now)

    def outstanding(self) -> int:
        return sum(i.outstanding() for i in self.instances.values())

    def all_active(self) -> bool:
        """True iff every built instance is routable — the condition under
        which ``outstanding_total`` equals the sum of ``outstanding()``
        over exactly the router's candidate set."""
        return self._non_active == 0

    # ------------------------------------------------------- scale actions --
    def _record(self, kind: str, inst: Instance, **extra) -> None:
        self._record_at(kind, inst, self.engine.now, **extra)

    def _record_at(self, kind: str, inst: Instance, t: float,
                   **extra) -> None:
        self.scale_events.append(dict(
            t=t, kind=kind, instance=inst.name, **extra))
        if self.telemetry is not None:
            self._tel_burn(t)
            self.telemetry.span(kind, -1, t, t, instance=inst.name)

    def _tel_burn(self, t: float) -> None:
        """Sample the fleet $/hr staircase — the rate steps exactly at
        instance builds and lifecycle transitions, so sampling there
        captures it completely."""
        tel = self.telemetry
        if tel is not None:
            rate = sum(i.dollar_rate() for i in self.instances.values()
                       if i.stopped_at is None)
            tel.counter("fleet_dollars_per_hour", t, rate)

    def _replica_rate(self, inst: Instance, w) -> float:
        """Provisioned $/hr one replica represents (its cluster's per-
        replica device count times that cluster's hardware price)."""
        for cluster in inst.handle.clusters.values():
            if w in cluster.replicas:
                per = cluster.spec.devices_per_replica() \
                    if getattr(cluster, "spec", None) is not None else 1
                return per * getattr(getattr(cluster, "hw", None),
                                     "dollars_per_hour", 0.0)
        return 0.0

    def scale_up(self, group) -> Instance:
        """Provision one more instance of ``group`` with a modeled cold
        start: per-device weight bytes over the provision bandwidth plus
        the runtime bring-up floor.  Routable once INSTANCE_READY fires."""
        a = self.fleet.autoscaler
        inst = self._build_instance(group, state=STARTING)
        first = next(iter(inst.handle.clusters.values())).replicas[0]
        cold = (first.predictor.weight_bytes_per_device() / a.provision_bw
                + a.startup_base_s)
        self.engine.after(cold, EV.INSTANCE_READY,
                          lambda ev, inst=inst: self._instance_ready(inst),
                          instance=inst.name)
        self._record("scale_up", inst, cold_start_s=cold,
                     dollars_per_hour_delta=inst.dollar_rate())
        self._track_peak()
        return inst

    def _instance_ready(self, inst: Instance) -> None:
        inst.activate(self.engine.now)
        self._non_active -= 1
        self._record("ready", inst)
        self._track_peak()

    def scale_down(self, inst: Instance) -> None:
        """Drain: stop routing to ``inst``; it finishes residents and then
        releases its GPUs (``_on_complete`` notices the drain emptying)."""
        # price the decision when it is made: the drained capacity keeps
        # burning $ until residents finish, but this is the rate the
        # autoscaler chose to give up
        rate = inst.dollar_rate()
        inst.drain(self.engine.now)
        self._non_active += 1
        self._record("scale_down", inst, dollars_per_hour_delta=-rate)
        if inst.outstanding() == 0:
            inst.stop(self.engine.now)
            self._record("drained", inst)

    def rebalance_pd(self, inst: Instance, donor_role: str,
                     needy_role: str) -> bool:
        """Move one replica of capacity between an instance's P and D
        pools: drain one ``donor_role`` replica now, enable a standby
        ``needy_role`` replica after the modeled weight reload."""
        spares = inst.pool_replicas(needy_role, active=False)
        spare = next((w for w in spares
                      if not (w.waiting or w.running or w.busy)), None)
        donors = inst.pool_replicas(donor_role, active=True)
        if spare is None or len(donors) <= 1:
            return False
        donor = max(donors, key=lambda w: (w.load(), w.name))
        donor.active = False
        self._moves_in_flight += 1

        def enable(ev, w=spare, inst=inst):
            self._moves_in_flight -= 1
            w.active = True
            w.kick()
            inst.touch(self.engine.now)
            self._track_peak()

        self.engine.after(self.fleet.autoscaler.reconfigure_s,
                          EV.POOL_RECONFIGURED, enable,
                          instance=inst.name, role=needy_role)
        self._record("rebalance", inst, moved=f"{donor_role}->{needy_role}",
                     donor=donor.name, spare=spare.name,
                     dollars_per_hour_delta=(
                         self._replica_rate(inst, spare)
                         - self._replica_rate(inst, donor)))
        inst.touch(self.engine.now)
        return True

    # ----------------------------------------------------------- finishing --
    def finalize(self) -> None:
        """Close the GPU-second integrals at the END OF THE WORKLOAD (the
        last completion/token), not at engine.now — trailing autoscaler
        ticks drain the event heap up to interval_s past the last
        completion, and charging that tail as idle capacity would make
        autoscaler-on runs look wasteful even when it never acted."""
        end = max((i.controller.metrics.end
                   for i in self.instances.values()), default=0.0)
        if end <= 0.0:          # horizon cut before any token: use now
            end = self.engine.now
        for inst in self.instances.values():
            inst.touch(end)
        self._track_peak()

    def conservation_check(self) -> Dict[str, int]:
        states: Dict[str, int] = {}
        for inst in self.instances.values():
            for k, v in inst.controller.conservation_check().items():
                states[k] = states.get(k, 0) + v
        return states
