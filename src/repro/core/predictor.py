"""ExecutionPredictor: decomposes a model step into a data-dependent
micro-workflow of operator events and predicts its runtime.

Key paper features implemented here:
- per-operator decomposition (qkv/attn/wo/ffn/gate/collectives) instead of a
  monolithic batch model;
- the MoE micro-workflow: gate GEMM -> pluggable routing module ->
  token-to-expert assignment map -> heterogeneous per-expert GroupedGEMM
  tasks per EP rank -> implicit synchronization barrier modeled as
  max[T_rank_1..T_rank_ep] (straggler effect);
- TP collectives (2 all-reduces per layer), EP all-to-alls, PP micro-batch
  pipelining at the replica level.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import (
    ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV, ModelConfig,
)
from repro.core.hardware import HardwareSpec, ParallelismConfig
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.routing import BalancedRouting, RoutingModule, split_by_rank


@dataclass
class StepBreakdown:
    total: float = 0.0
    parts: Dict[str, float] = field(default_factory=dict)
    moe_straggler_excess: float = 0.0   # time lost to the max() barrier
    dropped_token_frac: float = 0.0

    def add(self, name: str, t: float) -> None:
        self.parts[name] = self.parts.get(name, 0.0) + t
        self.total += t


_CACHE_QUANTUM = 1.05   # geometric bucket ratio for memo-cache shape keys


_LOG_QUANTUM = math.log(_CACHE_QUANTUM)
_QTZ_MEMO: Dict[int, int] = {}


def _qtz(x: float) -> int:
    """Quantize a positive magnitude into ~5% geometric buckets.

    Memoized on the exact argument: cache-key construction is on the
    per-event hot path and token totals recur heavily, so the log()
    usually collapses to one dict probe.
    """
    v = _QTZ_MEMO.get(x)
    if v is None:
        v = int(x) if x <= 1 else int(round(math.log(x) / _LOG_QUANTUM))
        _QTZ_MEMO[x] = v
    return v


class ExecutionPredictor:
    def __init__(self, cfg: ModelConfig, par: ParallelismConfig,
                 hw: HardwareSpec, ops: OperatorModelSet, *,
                 routing: Optional[RoutingModule] = None,
                 engine_overhead: float = 2e-3,
                 seed: int = 0,
                 memoize: bool = True,
                 cache_size: int = 4096,
                 backend: str = "python"):
        self.cfg = cfg
        self.par = par
        self.hw = hw
        self.ops = ops
        self.routing = routing or BalancedRouting()
        self.engine_overhead = engine_overhead
        if backend not in ("python", "numpy", "jit"):
            raise ValueError(f"predictor backend must be 'python', 'numpy' "
                             f"or 'jit', got {backend!r}")
        # cost-evaluation backend: "python" walks the operator graph per
        # call (exact parts breakdown, the default); "numpy"/"jit" price
        # cache-miss steps through the vectorized fused roofline kernel
        # (total only; falls back to python when the model/ops don't
        # vectorize — subclassed operator models or step walks.  MoE
        # models vectorize for every routing module: the batch path
        # consumes routing draws in the scalar call order)
        self.backend = backend
        self._vec_supported: Optional[bool] = None
        self.rng = np.random.default_rng(seed)
        # step-time memoization: event-graph decode steps are expensive, and
        # serving batches recur in (quantized) shape — cache on the shape key
        # so finer-grained simulation does not regress simulator throughput.
        # Stochastic routers cycle over several cached draws per bucket so
        # the straggler distribution isn't collapsed to one sample.
        self._cache: Optional[OrderedDict] = OrderedDict() if memoize else None
        self._cache_size = cache_size
        self._cache_variants = 8 if self.routing.stochastic else 1
        # rotation counters live in an LRU-bounded map: million-request
        # runs see unboundedly many distinct shape buckets, and the
        # counter must not leak one entry per bucket forever
        self._bucket_calls: "OrderedDict[Tuple, int]" = OrderedDict()
        self._bucket_calls_cap = max(8 * cache_size, 64)
        # per-(counts, ep) grouped-GEMM rank pricing memo (MoE hot path)
        self._gg_cache: OrderedDict = OrderedDict()
        self._gg_cache_size = max(cache_size // 4, 64)
        self.cache_hits = 0
        self.cache_misses = 0

    # -------------------------------------------------------------- caching --
    def _cache_key(self, q_lens: Sequence[int], kv_lens: Sequence[int],
                   decode: bool, n_prefill: Optional[int] = None) -> Tuple:
        sq, skv = int(sum(q_lens)), int(sum(kv_lens))
        mkv = int(max(kv_lens, default=0))
        base = (decode, len(q_lens), _qtz(sq), _qtz(skv), _qtz(mkv))
        if n_prefill is not None:
            # mixed chunked-prefill step: keyed apart from pure steps (the
            # tuple is longer, so mixed keys can never alias pure ones)
            base = base + ("mix", n_prefill)
        if self._cache_variants == 1:
            # deterministic routing: no rotation, no counter to maintain
            return base + (0,)
        # rotate stochastic-routing draws per bucket (not per call, which
        # would alias with periodic prefill/decode interleavings); evict
        # cold buckets alongside the step cache so the counter stays
        # bounded (a restarted bucket merely re-enters rotation at 0)
        calls = self._bucket_calls
        n = calls.get(base, 0)
        calls[base] = n + 1
        calls.move_to_end(base)
        if len(calls) > self._bucket_calls_cap:
            calls.popitem(last=False)
        return base + (n % self._cache_variants,)

    def _on_cache_hit(self, bd: "StepBreakdown") -> None:
        """Subclass hook: restore side-band state for a cached step."""

    # ------------------------------------------------------------ weights --
    def weight_bytes_per_device(self, dtype_bytes: int = 2) -> float:
        n = self.cfg.param_count()
        return dtype_bytes * n / max(self.par.tp * self.par.pp, 1)

    def kv_bytes_per_token(self) -> float:
        return self.kv_bytes_per_token_per_layer() * self.kv_layer_count()

    def kv_layer_count(self) -> int:
        """Attention layers holding KV — the chunk count for layer-wise
        streamed KV transfer (recurrent layers carry no paged KV)."""
        return sum(1 for k in self.cfg.pattern
                   if k in (ATTN_GLOBAL, ATTN_LOCAL))

    def kv_bytes_per_token_per_layer(self) -> float:
        cfg = self.cfg
        return 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2  # bf16 k+v

    # ------------------------------------------------------------- layers --
    def _attn_layer(self, kind: str, q_lens: Sequence[int],
                    kv_lens: Sequence[int], decode: bool,
                    bd: StepBreakdown,
                    n_prefill: Optional[int] = None) -> None:
        cfg, par, ops = self.cfg, self.par, self.ops
        tp = max(par.tp, 1)
        d, hd = cfg.d_model, cfg.resolved_head_dim
        H, K = cfg.num_heads, cfg.num_kv_heads
        toks = sum(q_lens)
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0

        # projections (TP-sharded over heads)
        bd.add("qkv_gemm", ops.gemm(toks, (H + 2 * K) * hd // tp, d))
        if n_prefill is not None:
            # mixed chunked-prefill step: prefill-chunk rows run the prefill
            # attention kernel, piggybacked decode rows the decode kernel —
            # the fused batch shares every GEMM but not the attention math
            if n_prefill:
                bd.add("attn", ops.attention_prefill(
                    q_lens[:n_prefill], kv_lens[:n_prefill], H // tp,
                    max(K // tp, 1), hd, causal=True, window=window))
            if len(q_lens) > n_prefill:
                bd.add("attn", ops.attention_decode(
                    kv_lens[n_prefill:], H // tp, max(K // tp, 1), hd,
                    window=window))
        elif decode:
            bd.add("attn", ops.attention_decode(
                kv_lens, H // tp, max(K // tp, 1), hd, window=window))
        else:
            bd.add("attn", ops.attention_prefill(
                q_lens, kv_lens, H // tp, max(K // tp, 1), hd,
                causal=True, window=window))
        bd.add("o_gemm", ops.gemm(toks, d, H * hd // tp))
        bd.add("tp_coll", ops.all_reduce(2.0 * toks * d, tp))

    def _dense_ffn(self, toks: int, bd: StepBreakdown) -> None:
        cfg, tp, ops = self.cfg, max(self.par.tp, 1), self.ops
        n_mats = 3 if cfg.gated_mlp else 2
        bd.add("ffn_gemm", n_mats * ops.gemm(toks, cfg.d_ff // tp, cfg.d_model))
        bd.add("tp_coll", ops.all_reduce(2.0 * toks * cfg.d_model, tp))

    def _moe_ffn(self, toks: int, bd: StepBreakdown) -> None:
        """The MoE micro-workflow with straggler barrier."""
        cfg, ops = self.cfg, self.ops
        moe = cfg.moe
        ep = max(self.par.ep, 1)
        tp_in_expert = max(self.par.tp // ep, 1)
        E, k = moe.num_experts, moe.top_k

        # (1) gate GEMM
        bd.add("moe_gate", ops.gemm(toks, E, cfg.d_model))
        # (2) routing module -> assignment map
        counts = self.routing.assign(toks, E, k, self.rng)
        # capacity drops (same policy as models/moe.py)
        cap = math.ceil(moe.capacity_factor_eval * toks * k / E)
        kept = np.minimum(counts, cap)
        bd.dropped_token_frac = 1.0 - kept.sum() / max(counts.sum(), 1)
        # (3) dispatch all-to-all over EP group
        a2a_bytes = 2.0 * toks * k * cfg.d_model / ep
        bd.add("moe_a2a", ops.all_to_all(a2a_bytes, ep))
        # (4) heterogeneous per-rank GroupedGEMM tasks -> max() barrier
        n_mats = 3 if cfg.gated_mlp else 2
        t_max, t_mean = self._grouped_gemm_rank_stats(
            kept, ep, n_mats, cfg.d_model,
            moe.expert_d_ff // tp_in_expert)
        bd.add("moe_expert_gemm", t_max)
        bd.moe_straggler_excess += t_max - t_mean
        # (5) combine all-to-all + shared experts + TP reduce
        bd.add("moe_a2a", ops.all_to_all(a2a_bytes, ep))
        if moe.num_shared_experts:
            ff = moe.expert_d_ff * moe.num_shared_experts
            bd.add("ffn_gemm", n_mats * ops.gemm(
                toks, ff // max(self.par.tp, 1), cfg.d_model))
        if tp_in_expert > 1:
            bd.add("tp_coll", ops.all_reduce(2.0 * toks * cfg.d_model, tp_in_expert))

    def _grouped_gemm_rank_stats(self, kept: np.ndarray, ep: int,
                                 n_mats: int, d_in: int,
                                 d_out: int) -> Tuple[float, float]:
        """(straggler max, mean) of per-EP-rank GroupedGEMM times.

        Memoized on the exact kept-count histogram — routing draws recur
        heavily under capacity clipping, and replaying the per-rank walk
        per miss dominated MoE stepping.  Exact counts in the key keep
        every cached value bit-identical to an uncached evaluation (the
        variant-rotation scheme upstream already diversifies the draws
        feeding this cache).  For the base analytical model the per-rank
        loop itself collapses to one array expression; overridden
        grouped_gemm/_roof models keep the scalar loop.
        """
        key = (kept.tobytes(), ep, n_mats, d_in, d_out)
        hit = self._gg_cache.get(key)
        if hit is not None:
            self._gg_cache.move_to_end(key)
            return hit
        ops = self.ops
        from repro.core.opmodels.batch import (analytic_roofline_hw,
                                               expert_rank_map,
                                               grouped_gemm_rank_times)
        hw3 = analytic_roofline_hw(ops)
        if hw3 is not None:
            rank_of = expert_rank_map(len(kept), ep)
            sums = np.bincount(rank_of, weights=kept, minlength=ep)
            groups = np.bincount(rank_of, minlength=ep)
            times = grouped_gemm_rank_times(
                hw3, sums, groups, d_in, d_out, n_mats).tolist()
        else:
            times = [n_mats * ops.grouped_gemm(list(rc), d_in, d_out)
                     for rc in split_by_rank(kept, ep)]
        # python-ordered mean: bit-identical to the historical walk
        out = (max(times), sum(times) / len(times))
        self._gg_cache[key] = out
        if len(self._gg_cache) > self._gg_cache_size:
            self._gg_cache.popitem(last=False)
        return out

    def _recurrent_layer(self, kind: str, toks: int, bd: StepBreakdown) -> None:
        cfg, ops, tp = self.cfg, self.ops, max(self.par.tp, 1)
        d = cfg.d_model
        if kind == RWKV:
            bd.add("rwkv_proj", 5 * ops.gemm(toks, d // tp, d))
            # sequential state update: memory-bound state traffic
            H, hs = d // cfg.rwkv_head_size, cfg.rwkv_head_size
            state_bytes = 4.0 * toks * H * hs * hs / tp
            bd.add("rwkv_scan", ops.membound(state_bytes))
            bd.add("rwkv_out", ops.gemm(toks, d, d // tp))
        else:  # RG-LRU
            bd.add("rglru_proj", 2 * ops.gemm(toks, d // tp, d))
            bd.add("rglru_gates", 2 * ops.gemm(toks, d // tp, d // tp))
            bd.add("rglru_scan", ops.membound(4.0 * toks * d / tp))
            bd.add("rglru_out", ops.gemm(toks, d, d // tp))
        bd.add("tp_coll", ops.all_reduce(2.0 * toks * d, tp))

    # -------------------------------------------------------------- steps --
    def step_time(self, q_lens: Sequence[int], kv_lens: Sequence[int], *,
                  decode: bool,
                  n_prefill: Optional[int] = None) -> StepBreakdown:
        """One full model step for a (micro-)batch on one PP stage set.

        q_lens: new tokens per request (1s for decode; prompt lens/chunks for
        prefill).  kv_lens: context lengths (== q_lens for fresh prefill).
        ``n_prefill`` marks a *mixed* chunked-prefill step: the first
        ``n_prefill`` rows are prefill chunks, the rest piggybacked decode
        tokens — attention is priced per class, GEMMs over the fused batch.

        Results are memoized on a quantized batch-shape key (~5% geometric
        buckets on token totals): two batches in the same bucket replay the
        cached breakdown instead of re-walking the operator graph.  With a
        stochastic router the cache holds 8 rotating draws per bucket, so
        straggler variance is subsampled, not collapsed; pass
        ``memoize=False`` for exact per-step sampling.
        """
        if self._cache is None:
            return self._price_step(q_lens, kv_lens, decode=decode,
                                    n_prefill=n_prefill)
        key = self._cache_key(q_lens, kv_lens, decode, n_prefill)
        bd = self._cache.get(key)
        if bd is not None:
            self._cache.move_to_end(key)
            self.cache_hits += 1
            self._on_cache_hit(bd)
            return bd
        self.cache_misses += 1
        bd = self._price_step(q_lens, kv_lens, decode=decode,
                              n_prefill=n_prefill)
        self._cache[key] = bd
        if len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        return bd

    def _price_step(self, q_lens, kv_lens, *, decode: bool,
                    n_prefill: Optional[int]) -> StepBreakdown:
        """Cache-miss pricing: the configured backend when it can
        reproduce the scalar walk, else the exact python path."""
        if (self.backend != "python" and n_prefill is None
                and self._vectorized_ok()):
            from repro.core.opmodels.batch import batch_step_totals
            total = float(batch_step_totals(
                self, [(q_lens, kv_lens)], decode=decode,
                backend=self.backend)[0])
            bd = StepBreakdown()
            if total:
                bd.add("step", total)   # coarse: no per-operator parts
            return bd
        return self._step_time_impl(q_lens, kv_lens, decode=decode,
                                    n_prefill=n_prefill)

    def _vectorized_ok(self) -> bool:
        if self._vec_supported is None:
            from repro.core.opmodels.batch import supports_vectorized
            self._vec_supported = supports_vectorized(self)
        return self._vec_supported

    def step_time_batch(self, steps: Sequence[Tuple[Sequence[int],
                                                    Sequence[int]]],
                        *, decode: bool,
                        backend: Optional[str] = None) -> np.ndarray:
        """Per-step totals (seconds) for many batch shapes at once.

        ``steps`` is a sequence of ``(q_lens, kv_lens)`` pairs; the result
        is ``np.array([self.step_time(q, kv, decode=decode).total ...])``
        evaluated exactly (no memo-cache quantization).  With the
        ``numpy``/``jit`` backends the whole grid — MoE included, with
        routing draws consumed from ``self.rng`` in the scalar call
        order — prices through the fused roofline kernel in one shot;
        the ``python`` backend, and any model the kernel can't reproduce
        (subclassed operator models or step walks), walks the scalar
        path per step.
        """
        backend = backend or self.backend
        if backend != "python" and self._vectorized_ok():
            from repro.core.opmodels.batch import batch_step_totals
            return batch_step_totals(self, steps, decode=decode,
                                     backend=backend)
        return np.array([self._step_time_impl(list(q), list(kv),
                                              decode=decode).total
                         for q, kv in steps])

    def _step_time_impl(self, q_lens: Sequence[int], kv_lens: Sequence[int],
                        *, decode: bool,
                        n_prefill: Optional[int] = None) -> StepBreakdown:
        cfg = self.cfg
        bd = StepBreakdown()
        toks = int(sum(q_lens))
        if toks == 0:
            return bd
        layers_per_stage = [len(cfg.pattern) // max(self.par.pp, 1)] * max(self.par.pp, 1)
        # embed + head (memory-bound lookups + final GEMM)
        bd.add("embed", self.ops.membound(2.0 * toks * cfg.d_model))
        for kind in cfg.pattern:
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                self._attn_layer(kind, q_lens, kv_lens, decode, bd,
                                 n_prefill=n_prefill)
                if cfg.moe is not None:
                    self._moe_ffn(toks, bd)
                else:
                    self._dense_ffn(toks, bd)
            else:
                self._recurrent_layer(kind, toks, bd)
                if kind == RECURRENT:
                    self._dense_ffn(toks, bd)
                # RWKV channel-mix counted inside rwkv ops via d_ff GEMMs:
                if kind == RWKV:
                    tp = max(self.par.tp, 1)
                    bd.add("ffn_gemm", 2 * self.ops.gemm(
                        toks, cfg.d_ff // tp, cfg.d_model))
        n_logits = len(q_lens) if not decode else toks
        bd.add("head", self.ops.gemm(n_logits, cfg.padded_vocab // max(self.par.tp, 1),
                                     cfg.d_model))
        # PP pipeline: with m microbatches the critical path is
        # (pp + m - 1)/m x the per-stage time; callers pass microbatches via
        # replica-level pipelining, here we fold the bubble factor.
        pp = max(self.par.pp, 1)
        if pp > 1:
            m = max(len(q_lens), 1)
            bd.total = bd.total * (pp + m - 1) / (m * pp) * pp
            bd.add("pp_p2p", self.ops.p2p(2.0 * toks * cfg.d_model,
                                          inter_node=True) * (pp - 1))
        bd.add("engine_overhead", self.engine_overhead)
        return bd

    # convenience wrappers -------------------------------------------------
    def prefill_time(self, prompt_lens: Sequence[int],
                     context_lens: Optional[Sequence[int]] = None) -> StepBreakdown:
        kv = list(context_lens) if context_lens is not None else list(prompt_lens)
        return self.step_time(list(prompt_lens), kv, decode=False)

    def decode_time(self, context_lens: Sequence[int]) -> StepBreakdown:
        return self.step_time([1] * len(context_lens), list(context_lens),
                              decode=True)
