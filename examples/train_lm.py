"""End-to-end training driver: ~100M-class LM for a few hundred steps with
checkpoints (restart-safe).  On this CPU container use --steps to taste;
the same code path jit-lowers on the production meshes (launch/dryrun.py).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--size", default="100m", choices=["smoke", "100m"])
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt_100m")
    a = ap.parse_args()
    out = run("yi-9b", size=a.size, steps=a.steps, seq_len=256,
              global_batch=4, lr=3e-4, ckpt_dir=a.ckpt_dir, ckpt_every=50,
              resume=True, log_every=10)
    print(f"\nloss {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"over {a.steps} steps; checkpoints in {a.ckpt_dir}")


if __name__ == "__main__":
    main()
