"""Declarative experiment API: SimSpec -> run -> Report, and sweeps.

This package is the public surface of the simulator:

- :mod:`repro.api.spec` — ``SimSpec`` and its serializable sub-specs
  (model / topology / workload / policy / opmodel / SLO / faults);
- :mod:`repro.api.run` — ``run(spec) -> Report`` (typed, self-describing);
- :mod:`repro.api.sweep` — grid/zip expansion with process-pool fan-out,
  JSONL streaming, and ``pareto`` / ``best_under_slo`` helpers;
- :mod:`repro.api.cli` — the ``python -m repro`` command line.
"""
from repro.api.run import Report, build, run  # noqa: F401
from repro.api.spec import (  # noqa: F401
    AutoscalerSpec, FaultSpec, FleetSpec, InstanceSpec, MemorySpec,
    ModelRef, ObsSpec, OpModelSpec, PipelineSpec, PolicySpec, SimSpec,
    SLOSpec, SpecError, TenantSpec, TopologySpec, WorkloadSpec,
)
from repro.api.sweep import best_under_slo, expand, pareto, sweep  # noqa: F401

__all__ = [
    "SimSpec", "ModelRef", "TopologySpec", "WorkloadSpec", "PolicySpec",
    "OpModelSpec", "PipelineSpec", "MemorySpec", "SLOSpec", "FaultSpec",
    "FleetSpec", "InstanceSpec", "TenantSpec", "AutoscalerSpec",
    "ObsSpec", "SpecError",
    "run", "build", "Report",
    "sweep", "expand", "pareto", "best_under_slo",
]
