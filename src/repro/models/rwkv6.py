"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Faithful structure (arXiv:2404.05892): token-shift interpolation, per-channel
data-dependent decay ``w_t = exp(-exp(w0 + lora(x_t)))``, per-head (64-wide)
linear-attention state ``S_t = diag(w_t) S_{t-1} + k_t^T v_t`` with the
first-token bonus ``u``, output gated and group-normalized.  The five-way
ddlerp of the reference implementation is simplified to learned per-channel
mixes for r/k/v/g plus the data-dependent mix for the decay (noted in
DESIGN.md) — the *data-dependent decay*, Finch's defining feature, is exact.

Training uses ``lax.scan`` over time (a chunked-parallel Pallas kernel is the
optimized path, see kernels/).  Decode is a single state update — O(1) in
context length, which is why rwkv6 runs the long_500k shape.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import PD, AxisRules, rms_norm

LORA_DIM = 64


def timemix_pds(cfg: ModelConfig) -> Dict[str, PD]:
    d = cfg.d_model
    return {
        "mix": PD((5, d), (None, "embed"), 0.02),        # r,k,v,g,w token-shift mixes
        "w0": PD((d,), ("embed",), "zeros"),             # decay base
        "w_a": PD((d, LORA_DIM), ("embed", None), 0.02), # decay lora in
        "w_b": PD((LORA_DIM, d), (None, "embed"), 0.02), # decay lora out
        "u": PD((d,), ("embed",), 0.02),                 # first-token bonus
        "wr": PD((d, d), ("embed", "heads")),
        "wk": PD((d, d), ("embed", "heads")),
        "wv": PD((d, d), ("embed", "heads")),
        "wg": PD((d, d), ("embed", "heads")),
        "wo": PD((d, d), ("heads", "embed")),
        "ln_x": PD((d,), ("embed",), "ones"),            # per-head group norm scale
    }


def channelmix_pds(cfg: ModelConfig) -> Dict[str, PD]:
    d = cfg.d_model
    return {
        "mix_k": PD((d,), ("embed",), 0.02),
        "wk": PD((d, cfg.d_ff), ("embed", "mlp")),
        "wv": PD((cfg.d_ff, d), ("mlp", "embed")),
    }


def _shifted(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x (B,T,D), prev (B,D) = last token of previous chunk -> x_{t-1}."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _tm_project(cfg: ModelConfig, p, x, xz):
    """Compute r,k,v,g,w streams from x and shifted xz.  All (B,T,...)."""
    B, T, d = x.shape
    H = d // cfg.rwkv_head_size
    hs = cfg.rwkv_head_size
    mix = p["mix"].astype(jnp.float32)
    xf, xzf = x.astype(jnp.float32), xz.astype(jnp.float32)

    def lerp(i):
        # bf16-safe: the mix is a convex blend of two bf16 tensors; doing it
        # in input precision halves the traffic of five (B,T,D) streams
        # (perf iteration rwkv-it3; f32 is kept only for the decay chain)
        return x + (xz - x) * mix[i].astype(x.dtype)

    r = (lerp(0) @ p["wr"]).reshape(B, T, H, hs)
    k = (lerp(1) @ p["wk"]).reshape(B, T, H, hs)
    v = (lerp(2) @ p["wv"]).reshape(B, T, H, hs)
    g = jax.nn.silu(lerp(3) @ p["wg"])
    # data-dependent decay (f32 for stability)
    wx = lerp(4).astype(jnp.float32)
    dec = p["w0"].astype(jnp.float32) + jnp.tanh(
        wx @ p["w_a"].astype(jnp.float32)) @ p["w_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(B, T, H, hs)  # in (0,1)
    return r, k, v, g, w


def _wkv_step(state, rkvw, u):
    """state (B,H,hs,hs); r,k,v,w (B,H,hs).  Returns (state', y (B,H,hs))."""
    r, k, v, w = rkvw
    kv = k[..., :, None] * v[..., None, :]              # (B,H,hs,hs)
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    state = state * w[..., :, None] + kv
    return state, y


def timemix_apply(cfg: ModelConfig, p, x, ax: AxisRules, *,
                  prev_shift, prev_state) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence time-mix.  Returns (y, last_x, last_state)."""
    B, T, d = x.shape
    H, hs = d // cfg.rwkv_head_size, cfg.rwkv_head_size
    xz = _shifted(x, prev_shift)
    r, k, v, g, w = _tm_project(cfg, p, x, xz)
    u = p["u"].astype(jnp.float32).reshape(H, hs)
    state0 = prev_state.astype(jnp.float32)

    if ax.opt("rwkv_impl", "scan") == "chunked":
        y, state = _wkv_chunked(r, k, v, w, u, state0,
                                chunk=int(ax.opt("rwkv_chunk", 16)))
    else:
        rf = r.astype(jnp.float32).transpose(1, 0, 2, 3)    # (T,B,H,hs)
        kf = k.astype(jnp.float32).transpose(1, 0, 2, 3)
        vf = v.astype(jnp.float32).transpose(1, 0, 2, 3)
        wf = w.transpose(1, 0, 2, 3)

        def step(s, inp):
            return _wkv_step(s, inp, u)

        state, ys = jax.lax.scan(step, state0, (rf, kf, vf, wf))
        y = ys.transpose(1, 0, 2, 3)                        # (B,T,H,hs)

    y = _headnorm(cfg, p, y, B, T, d).astype(x.dtype) * g
    out = y @ p["wo"]
    return ax.constrain(out, "batch", None, "embed"), x[:, -1, :], state


def _wkv_chunked(r, k, v, w, u, state0, *, chunk: int = 128):
    """Chunked-parallel WKV6 (GLA-style) — the beyond-paper optimization.

    Per chunk of length C the recurrence
        S_t = diag(w_t) S_{t-1} + k_t^T v_t ;  y_t = r_t (S_{t-1} + u k_t^T v_t)
    is evaluated as (i) an inter-chunk term through the chunk-entry state and
    (ii) an intra-chunk attention-like product with per-channel decay ratios
    folded into r and k:
        cw_t   = prod_{s<=t} w_s            (cumprod within the chunk)
        y[t]   = (r_t*cw_{t-1}) S_in + [(r_t*cw_{t-1}) @ (k_s/cw_s)^T]_{s<t} v_s
                 + u * (r_t.k_t) v_t
        S_out  = diag(cw_{C-1}) S_in + sum_s (k_s * cw_{C-1}/cw_s)^T v_s
    This replaces T sequential O(hs^2) state updates with T/C chunk steps of
    dense (C x C) matmuls: the memory-roofline term drops ~C x and the MXU
    does the work.  Decay ratios are formed in log space for stability.
    """
    B, T, H, hs = r.shape
    C = min(chunk, T)
    nb = (T + C - 1) // C
    assert T % C == 0, (T, C)
    # iteration rwkv-it3: scan over chunk INDICES and dynamic-slice each
    # chunk out of the (B,T,H,hs) tensors — avoids materializing transposed
    # (nb,B,H,C,hs) copies of r/k/v/w (4 full-sequence copies per layer).
    lw_full = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))

    def body(S, idx):
        def chunk_of(t):
            return jax.lax.dynamic_slice_in_dim(t, idx * C, C, axis=1) \
                .astype(jnp.float32).transpose(0, 2, 1, 3)      # (B,H,C,hs)
        rc, kc, vc = chunk_of(r), chunk_of(k), chunk_of(v)
        lwc = chunk_of(lw_full)
        clw = jnp.cumsum(lwc, axis=2)          # log cw_t
        cw_prev = jnp.exp(clw - lwc)           # cw_{t-1}
        r_dec = rc * cw_prev
        # clamp guards f32 overflow for extreme decays (their contribution
        # to any later in-chunk position is negligible); with C<=16 and
        # typical w=exp(-exp(~1)) the clamp never triggers.
        k_dec = kc * jnp.exp(jnp.minimum(-clw, 60.0))
        # inter-chunk via entry state
        y = jnp.einsum("bhci,bhij->bhcj", r_dec, S)
        # intra-chunk (strictly lower-triangular) + bonus diagonal
        att = jnp.einsum("bhci,bhsi->bhcs", r_dec, k_dec)
        tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        y = y + jnp.einsum("bhcs,bhsj->bhcj", att, vc)
        bonus = jnp.einsum("bhci,hi,bhci->bhc", rc, u, kc)
        y = y + bonus[..., None] * vc
        # state propagation to chunk exit
        cw_last = jnp.exp(clw[:, :, -1:, :])   # (B,H,1,hs)
        k_carry = kc * (cw_last * jnp.exp(-clw))
        S = S * cw_last.transpose(0, 1, 3, 2) + \
            jnp.einsum("bhsi,bhsj->bhij", k_carry, vc)
        return S, y.transpose(0, 2, 1, 3)      # (B,C,H,hs)

    S, ys = jax.lax.scan(body, state0, jnp.arange(nb))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hs)
    return y, S


def _headnorm(cfg, p, y, B, T, d):
    H, hs = d // cfg.rwkv_head_size, cfg.rwkv_head_size
    yf = y.reshape(B, T, H, hs)
    mu = jnp.mean(yf, -1, keepdims=True)
    var = jnp.var(yf, -1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    return (yf.reshape(B, T, d) * p["ln_x"].astype(jnp.float32))


def timemix_decode(cfg: ModelConfig, p, x, ax: AxisRules, *,
                   prev_shift, prev_state):
    """Single-token step.  x (B,1,D)."""
    B, _, d = x.shape
    H, hs = d // cfg.rwkv_head_size, cfg.rwkv_head_size
    xz = prev_shift[:, None, :]
    r, k, v, g, w = _tm_project(cfg, p, x, xz)
    u = p["u"].astype(jnp.float32).reshape(H, hs)
    state, y = _wkv_step(
        prev_state.astype(jnp.float32),
        (r.astype(jnp.float32)[:, 0], k.astype(jnp.float32)[:, 0],
         v.astype(jnp.float32)[:, 0], w[:, 0]), u)
    y = _headnorm(cfg, p, y[:, None].reshape(B, 1, H, hs), B, 1, d).astype(x.dtype) * g
    out = y @ p["wo"]
    return ax.constrain(out, "batch", None, "embed"), x[:, -1, :], state


def channelmix_apply(cfg: ModelConfig, p, x, ax: AxisRules, *, prev_shift):
    """RWKV channel-mix (relu^2 FFN with token shift)."""
    xz = _shifted(x, prev_shift)
    mix = p["mix_k"].astype(jnp.float32)
    xm = (x.astype(jnp.float32) + (xz.astype(jnp.float32) - x.astype(jnp.float32)) * mix).astype(x.dtype)
    h = jnp.square(jax.nn.relu(xm @ p["wk"]))
    h = ax.constrain(h, "batch", None, "mlp")
    y = h @ p["wv"]
    return ax.constrain(y, "batch", None, "embed"), x[:, -1, :]
