"""Event-engine determinism + causality properties."""
import numpy as np
import pytest

from repro.core.engine import SimEngine
from repro.core.events import EV

try:        # property tests only where hypothesis is installed (CI);
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # the deterministic tests below always run
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @given(st.lists(st.floats(min_value=0.0, max_value=100.0,
                              allow_nan=False),
                    min_size=1, max_size=200))
    @settings(max_examples=50, deadline=None)
    def test_events_processed_in_time_order(times):
        eng = SimEngine()
        seen = []
        for t in times:
            eng.at(t, EV.SCHEDULE_TICK, lambda ev: seen.append(ev.time))
        eng.run()
        assert seen == sorted(seen)
        assert len(seen) == len(times)


def test_ties_break_in_schedule_order():
    eng = SimEngine()
    seen = []
    for i in range(50):
        eng.at(1.0, EV.SCHEDULE_TICK, lambda ev, i=i: seen.append(i))
    eng.run()
    assert seen == list(range(50))


def test_nested_scheduling_is_causal():
    eng = SimEngine()
    log = []

    def spawn(ev):
        log.append(eng.now)
        if eng.now < 5:
            eng.after(1.0, EV.SCHEDULE_TICK, spawn)

    eng.at(0.0, EV.SCHEDULE_TICK, spawn)
    eng.run()
    assert log == [float(i) for i in range(6)]


def test_run_until_pauses_clock():
    eng = SimEngine()
    eng.at(10.0, EV.SCHEDULE_TICK, lambda ev: None)
    eng.run(until=5.0)
    assert eng.now == 5.0
    assert eng.pending == 1
    eng.run()
    assert eng.now == 10.0


# ------------------------------------------------------------ event budget --
def test_budget_raises_before_executing_over_budget_event():
    eng = SimEngine(max_events=5)
    ran = []
    for i in range(7):
        eng.at(float(i), EV.SCHEDULE_TICK, lambda ev, i=i: ran.append(i))
    with pytest.raises(RuntimeError) as exc:
        eng.run()
    # the 6th event must NOT have executed — the budget check precedes
    # the pop, so a budget blow-up never leaves a half-applied event
    assert ran == [0, 1, 2, 3, 4]
    assert eng.processed == 5
    assert eng.pending == 2
    msg = str(exc.value)
    assert "processed=5" in msg and "pending=2" in msg and "now=" in msg


# ---------------------------------------------------------------- timeline --
def test_timeline_interleaves_with_heap_events():
    eng = SimEngine()
    seen = []
    n = eng.schedule_timeline(
        (float(t), EV.REQUEST_ARRIVAL, lambda ev: seen.append(("tl", ev.time)),
         None) for t in (1, 3, 5))
    assert n == 3
    for t in (2, 4):
        eng.at(float(t), EV.SCHEDULE_TICK,
               lambda ev: seen.append(("heap", ev.time)))
    eng.run()
    assert seen == [("tl", 1.0), ("heap", 2.0), ("tl", 3.0),
                    ("heap", 4.0), ("tl", 5.0)]
    assert eng.processed == 5 and eng.pending == 0


def test_timeline_wins_ties_against_later_heap_pushes():
    # seqs are assigned when schedule_timeline runs, so a heap event pushed
    # AFTERWARDS at the same timestamp must lose the tie
    eng = SimEngine()
    seen = []
    eng.schedule_timeline([(1.0, EV.REQUEST_ARRIVAL,
                            lambda ev: seen.append("tl"), None)])
    eng.at(1.0, EV.SCHEDULE_TICK, lambda ev: seen.append("heap"))
    eng.run()
    assert seen == ["tl", "heap"]


def test_timeline_rejects_unsorted_and_past_items():
    eng = SimEngine()
    with pytest.raises(ValueError, match="sorted"):
        eng.schedule_timeline([(2.0, EV.REQUEST_ARRIVAL, None, None),
                               (1.0, EV.REQUEST_ARRIVAL, None, None)])
    eng2 = SimEngine()
    eng2.at(1.0, EV.SCHEDULE_TICK, lambda ev: None)
    eng2.run()
    with pytest.raises(ValueError, match="past"):
        eng2.schedule_timeline([(0.5, EV.REQUEST_ARRIVAL, None, None)])


def test_timeline_payload_passes_through_event_data():
    eng = SimEngine()
    payload = object()
    got = []
    eng.schedule_timeline([(1.0, EV.REQUEST_ARRIVAL,
                            lambda ev: got.append(ev.data), payload)])
    eng.run()
    assert got == [payload]


# ---------------------------------------------------------- batch dispatch --
def test_batch_handler_groups_contiguous_same_timestamp_runs():
    eng = SimEngine()
    calls = []
    eng.register_batch_handler(
        EV.REQUEST_ARRIVAL, lambda evs: calls.append([e.data for e in evs]))
    eng.schedule_timeline([(1.0, EV.REQUEST_ARRIVAL, None, i)
                           for i in range(3)])
    # a different-kind event at the same timestamp splits the run
    eng.at(1.0, EV.SCHEDULE_TICK, lambda ev: calls.append("tick"))
    eng.at(1.0, EV.REQUEST_ARRIVAL, None, i=3)
    eng.at(1.0, EV.REQUEST_ARRIVAL, None, i=4)
    eng.run()
    assert calls == [[0, 1, 2], "tick", [{"i": 3}, {"i": 4}]]
    assert eng.processed == 6       # every drained event is counted


def test_no_batch_handler_means_per_event_dispatch():
    eng = SimEngine()
    seen = []
    eng.schedule_timeline([(1.0, EV.REQUEST_ARRIVAL,
                            lambda ev: seen.append(ev.data), i)
                           for i in range(3)])
    eng.run()
    assert seen == [0, 1, 2]


# ------------------------------------------------------------- advance_to --
def test_advance_to_moves_clock_without_dispatch():
    eng = SimEngine()
    eng.at(5.0, EV.SCHEDULE_TICK, lambda ev: None)
    eng.advance_to(3.0)
    assert eng.now == 3.0 and eng.pending == 1
    eng.advance_to(1.0)             # never rewinds
    assert eng.now == 3.0
    with pytest.raises(AssertionError):
        eng.advance_to(7.0)         # refuses to skip pending events
