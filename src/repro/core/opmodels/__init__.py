from repro.core.opmodels.analytical import OperatorModelSet, AnalyticalModels  # noqa: F401
from repro.core.opmodels.forest import RandomForest  # noqa: F401
from repro.core.opmodels.kernelsim import VirtualKernels  # noqa: F401
from repro.core.opmodels.vidur_proxy import VidurProxyModel  # noqa: F401
from repro.core.opmodels.refined import RefinedModels, calibrate_refined  # noqa: F401

# name-keyed registry: operator-model families constructible from a
# HardwareSpec alone (fitted/calibrated variants are injected as instances)
OPMODELS = {
    "analytical": AnalyticalModels,
    "refined": RefinedModels,
}


def resolve_opmodels(spec, hw) -> "OperatorModelSet":
    """Resolve an operator-model spec to an OperatorModelSet for ``hw``.

    Accepts an instance (returned as-is; caller owns hw consistency), a
    registered name ("analytical", "refined"), a mapping
    ``{"name": ..., **kwargs}``, or None (analytical roofline default).
    """
    if isinstance(spec, OperatorModelSet):
        return spec
    if spec is None:
        return OperatorModelSet(hw)
    if isinstance(spec, str):
        spec = {"name": spec}
    if isinstance(spec, dict):
        kw = dict(spec)
        name = kw.pop("name", None)
        if name not in OPMODELS:
            raise KeyError(f"unknown operator model {name!r}; "
                           f"registered: {sorted(OPMODELS)}")
        return OPMODELS[name](hw, **kw)
    raise TypeError(f"opmodel must be None, a name, a mapping, or an "
                    f"OperatorModelSet; got {type(spec).__name__}")
