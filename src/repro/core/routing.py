"""Pluggable MoE routing modules.

A routing module produces the token-to-expert assignment map (as per-expert
token counts) for a batch — the input to the GroupedGEMM model and the
straggler max() barrier.  Implementations model different imbalance regimes;
`TraceRouting` replays counts measured from the real JAX MoE layer
(models/moe.py surfaces them as metrics).
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Union

import numpy as np


class RoutingModule:
    #: True when assign() consumes RNG draws — consumers that memoize step
    #: times use this to keep several samples per shape bucket instead of
    #: freezing a single draw.
    stochastic = True

    def assign(self, n_tokens: int, n_experts: int, top_k: int,
               rng: np.random.Generator) -> np.ndarray:
        """Return integer token counts per expert, sum == n_tokens * top_k."""
        raise NotImplementedError


class BalancedRouting(RoutingModule):
    """Perfectly load-balanced (the idealized lower bound)."""

    stochastic = False

    def assign(self, n_tokens, n_experts, top_k, rng):
        total = n_tokens * top_k
        base = total // n_experts
        counts = np.full(n_experts, base, np.int64)
        counts[: total - base * n_experts] += 1
        return counts


class UniformRouting(RoutingModule):
    """Multinomial over uniform expert probabilities (mild imbalance)."""

    _p: Optional[dict] = None   # n_experts -> probability vector (read-only)

    def assign(self, n_tokens, n_experts, top_k, rng):
        cache = self._p
        if cache is None:
            cache = self._p = {}   # lazy: subclasses need not call __init__
        p = cache.get(n_experts)
        if p is None:
            p = np.full(n_experts, 1.0 / n_experts)
            cache[n_experts] = p
        return rng.multinomial(n_tokens * top_k, p)


class ZipfRouting(RoutingModule):
    """Zipf-skewed expert popularity (hot experts; heavy stragglers)."""

    def __init__(self, alpha: float = 1.2):
        self.alpha = alpha
        self._p_base: dict = {}  # n_experts -> unshuffled rank^-alpha

    def assign(self, n_tokens, n_experts, top_k, rng):
        # assign() is the MoE hot path: the power law is deterministic per
        # n_experts, so only the shuffle + draw touch the rng per call
        base = self._p_base.get(n_experts)
        if base is None:
            ranks = np.arange(1, n_experts + 1, dtype=np.float64)
            base = ranks ** -self.alpha
            self._p_base[n_experts] = base
        p = base.copy()
        rng.shuffle(p)
        # np.add.reduce is ndarray.sum's own reduction (same pairwise
        # order, bit-identical) minus the method-dispatch wrappers
        p /= np.add.reduce(p)
        return rng.multinomial(n_tokens * top_k, p)


class TraceRouting(RoutingModule):
    """Replay expert-load distributions captured from the real MoE layer."""

    def __init__(self, fractions: Sequence[float]):
        f = np.asarray(fractions, np.float64)
        self.fractions = f / f.sum()

    def assign(self, n_tokens, n_experts, top_k, rng):
        assert len(self.fractions) == n_experts
        return rng.multinomial(n_tokens * top_k, self.fractions)


def split_by_rank(counts: np.ndarray, ep: int) -> List[np.ndarray]:
    """Partition per-expert counts into EP-rank slices (contiguous shards).

    When ``n_experts % ep != 0`` the remainder experts are spread across the
    first ranks (shard sizes differ by at most one) — no expert is dropped.
    """
    counts = np.asarray(counts)
    ep = max(int(ep), 1)
    base, rem = divmod(len(counts), ep)
    out: List[np.ndarray] = []
    off = 0
    for r in range(ep):
        n = base + (1 if r < rem else 0)
        out.append(counts[off:off + n])
        off += n
    return out


ROUTERS = {
    "balanced": BalancedRouting,
    "uniform": UniformRouting,
    "zipf": ZipfRouting,
    "trace": TraceRouting,
}


def resolve_router(spec: Union[None, str, dict, RoutingModule],
                   ) -> Optional[RoutingModule]:
    """Uniform router argument handling for all builders.

    Accepts an instance (returned as-is), a registered name ("balanced",
    "uniform", "zipf", ...), a mapping ``{"name": ..., **kwargs}`` whose
    kwargs go to the router constructor (e.g. ``{"name": "zipf",
    "alpha": 1.4}``), or None.  Bare names construct the router with its
    default arguments; TraceRouting needs measured fractions, so it must be
    given its ``fractions`` kwarg or passed as an instance.
    """
    if spec is None or isinstance(spec, RoutingModule):
        return spec
    if isinstance(spec, str):
        spec = {"name": spec}
    if isinstance(spec, dict):
        kw = dict(spec)
        name = kw.pop("name", None)
        try:
            cls = ROUTERS[name]
        except KeyError:
            raise KeyError(
                f"unknown router {name!r}; registered: {sorted(ROUTERS)}")
        try:
            return cls(**kw)
        except TypeError as e:
            raise TypeError(
                f"router {name!r} could not be constructed from {kw!r} "
                f"({e}) — pass an instance instead of the name"
            ) from e
    raise TypeError(f"routing must be None, a name, a mapping, or a "
                    f"RoutingModule; got {type(spec).__name__}")
