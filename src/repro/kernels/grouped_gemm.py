"""GroupedGEMM Pallas kernel for MoE expert compute (megablox-style).

Layout matches models/moe.py's capacity buffers: x (E, C, d_in),
w (E, d_in, d_out), y (E, C, d_out) with per-expert valid row counts
``group_sizes``.  Grid (E, C/bm, d_out/bn, d_in/bk) with an f32 VMEM
accumulator over the contraction dimension.  Tiles whose m-range lies
entirely beyond group_sizes[e] are SKIPPED — imbalanced expert loads cost
only their own tiles, which is precisely the heterogeneous-task behavior
Frontier's GroupedGEMM operator model predicts (wave quantization over
ragged tiles).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gg_kernel(gs_ref, x_ref, w_ref, y_ref, acc_ref, *,
               bm: int, bn: int, bkk: int, nk: int):
    e = pl.program_id(0)
    im = pl.program_id(1)
    ik = pl.program_id(3)

    rows = gs_ref[0]
    live = (im * bm) < rows

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _compute():
        x = x_ref[...]
        w = w_ref[...]
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        mrow = im * bm + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        ok = mrow < rows
        y_ref[...] = jnp.where(ok, acc_ref[...], 0.0).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bkk", "interpret"))
def grouped_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                 bm: int = 128, bn: int = 128, bkk: int = 512,
                 interpret: bool = True) -> jax.Array:
    """x (E,C,din) @ w (E,din,dout) with per-expert row validity."""
    E, C, din = x.shape
    dout = w.shape[2]
    bm = min(bm, max(C, 8))
    bn = min(bn, max(dout, 128))
    bkk = min(bkk, max(din, 128))
    Cp = math.ceil(C / bm) * bm
    Np = math.ceil(dout / bn) * bn
    Kp = math.ceil(din / bkk) * bkk
    xr = jnp.pad(x, ((0, 0), (0, Cp - C), (0, Kp - din)))
    wr = jnp.pad(w, ((0, 0), (0, Kp - din), (0, Np - dout)))
    gs = group_sizes.astype(jnp.int32).reshape(E, 1)
    nk = Kp // bkk

    kernel = functools.partial(_gg_kernel, bm=bm, bn=bn, bkk=bkk, nk=nk)
    y = pl.pallas_call(
        kernel,
        grid=(E, Cp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((None, 1), lambda e, im, jn, ik: (e, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, bm, bkk), lambda e, im, jn, ik: (e, im, ik)),
            pl.BlockSpec((None, bkk, bn), lambda e, im, jn, ik: (e, ik, jn)),
        ],
        out_specs=pl.BlockSpec((None, bm, bn), lambda e, im, jn, ik: (e, im, jn)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(gs, xr, wr)
    return y[:, :C, :dout]
