"""One serving instance inside a fleet.

An :class:`Instance` wraps a full single-deployment build (a
``SystemHandle``: GlobalController + clusters + replicas + KV managers)
compiled onto the fleet's SHARED SimEngine, so cross-instance event
ordering stays deterministic.  It adds the control-plane lifecycle the
single-deployment world has no notion of:

- ``starting`` — provisioned, loading weights (modeled cold start); not
  routable yet;
- ``active`` — serving traffic;
- ``draining`` — removed from routing, finishing residents (scale-down);
- ``stopped`` — drained empty; GPUs released.

The instance also integrates provisioned GPU-seconds over its lifetime
(piecewise-constant between state changes), which the FleetReport turns
into the provisioned-but-idle capacity metric.
"""
from __future__ import annotations

from typing import Dict, List, Optional

STARTING = "starting"
ACTIVE = "active"
DRAINING = "draining"
STOPPED = "stopped"


def instance_subspec(spec, group, seed: int):
    """The per-instance SimSpec an InstanceSpec group compiles to: the
    fleet spec's sections with the group's topology/pipeline/memory
    overrides applied and the ``fleet`` section removed (an instance is a
    plain single deployment)."""
    from repro.api.spec import SimSpec
    return SimSpec(
        model=spec.model,
        topology=group.topology if group.topology is not None
        else spec.topology,
        workload=spec.workload,
        policy=spec.policy,
        opmodel=spec.opmodel,
        pipeline=group.pipeline if group.pipeline is not None
        else spec.pipeline,
        memory=group.memory if group.memory is not None else spec.memory,
        slo=spec.slo,
        obs=spec.obs,
        seed=seed,
        name=group.name)


class Instance:
    def __init__(self, name: str, group, handle, *, created_at: float,
                 state: str = ACTIVE):
        self.name = name
        self.group = group              # the InstanceSpec it was built from
        self.handle = handle
        self.state = state
        self.created_at = created_at
        self.active_at: Optional[float] = created_at if state == ACTIVE \
            else None
        self.stopped_at: Optional[float] = None
        self.routed = 0                 # arrivals the global router sent here
        self.has_spares = False         # built with standby P:D replicas
        # GPU-second integrator (piecewise-constant between touches), plus
        # the parallel provisioned-$ integrator (per-cluster $/GPU-hr)
        self._t_last = created_at
        self._dev_last = self.provisioned_devices()
        self._rate_last = self.dollar_rate()
        self.peak_devices = self._dev_last
        self.gpu_seconds = 0.0
        self.provisioned_dollars = 0.0

    # ------------------------------------------------------------- wiring --
    @property
    def controller(self):
        return self.handle.controller

    @property
    def engine(self):
        return self.handle.engine

    @property
    def routable(self) -> bool:
        return self.state == ACTIVE

    # ------------------------------------------------------- load signals --
    def outstanding(self) -> int:
        return self.controller.outstanding()

    def prefix_probe(self, r) -> int:
        return self.controller.prefix_probe(r)

    # ----------------------------------------------------------- arrivals --
    def accept(self, r, now: float) -> None:
        """Hand an arrived request to this instance's controller (the
        fleet already scheduled the arrival event; no re-stamping).

        A rejection (all entry replicas failed) must leave NO trace: a
        half-registered request would pin ``outstanding()`` above zero
        forever — hanging the autoscaler tick loop and drain logic — so
        registration rolls back before the error propagates to the
        fleet's spill path.
        """
        ctrl = self.controller
        r.arrival = now
        prev_start = ctrl.metrics.start
        ctrl.requests[r.rid] = r
        try:
            ctrl._arrive(r)
        except RuntimeError:
            del ctrl.requests[r.rid]
            ctrl.metrics.start = prev_start
            raise
        self.routed += 1

    # ----------------------------------------------------- GPU accounting --
    def provisioned_devices(self) -> int:
        """Devices this instance currently holds: every replica that is
        routable or still finishing work (a drained-empty replica's GPUs
        are released; standby P:D spares consume nothing until enabled)."""
        if self.state == STOPPED:
            return 0
        n = 0
        for cluster in self.handle.clusters.values():
            per = cluster.spec.devices_per_replica() \
                if getattr(cluster, "spec", None) is not None else 1
            for w in cluster.replicas:
                if w.active or w.waiting or w.running or w.swapped \
                        or w._swapping_out or w._swapping_in or w.busy:
                    n += per
        return n

    def dollar_rate(self) -> float:
        """Current provisioned $/hr: held devices weighted by each
        cluster's hardware pricing (mirrors ``provisioned_devices``)."""
        if self.state == STOPPED:
            return 0.0
        rate = 0.0
        for cluster in self.handle.clusters.values():
            per = cluster.spec.devices_per_replica() \
                if getattr(cluster, "spec", None) is not None else 1
            dph = getattr(getattr(cluster, "hw", None),
                          "dollars_per_hour", 0.0)
            for w in cluster.replicas:
                if w.active or w.waiting or w.running or w.swapped \
                        or w._swapping_out or w._swapping_in or w.busy:
                    rate += per * dph
        return rate

    def touch(self, now: float) -> None:
        """Advance the GPU-second and provisioned-$ integrals to ``now``
        and re-sample the (piecewise-constant) provisioned capacity."""
        if now > self._t_last:
            dt = now - self._t_last
            self.gpu_seconds += self._dev_last * dt
            self.provisioned_dollars += self._rate_last * dt / 3600.0
            self._t_last = now
        self._dev_last = self.provisioned_devices()
        self._rate_last = self.dollar_rate()
        if self._dev_last > self.peak_devices:
            self.peak_devices = self._dev_last

    def busy_gpu_seconds(self) -> float:
        total = 0.0
        for cluster in self.handle.clusters.values():
            per = cluster.spec.devices_per_replica() \
                if getattr(cluster, "spec", None) is not None else 1
            total += sum(w.stats["busy_time"] for w in cluster.replicas) * per
        return total

    # ---------------------------------------------------------- lifecycle --
    def activate(self, now: float) -> None:
        assert self.state == STARTING, self.state
        self.state = ACTIVE
        self.active_at = now
        self.touch(now)

    def drain(self, now: float) -> None:
        assert self.state == ACTIVE, self.state
        self.state = DRAINING
        self.touch(now)

    def stop(self, now: float) -> None:
        assert self.outstanding() == 0, (self.name, self.outstanding())
        self.state = STOPPED
        self.stopped_at = now
        self.touch(now)

    # ----------------------------------------------------------- topology --
    @property
    def mode(self) -> str:
        return self.controller.mode

    def pool_replicas(self, role: str, active: bool) -> List:
        """Replicas of ``role`` clusters filtered by routing eligibility
        (the P:D-rebalance working set)."""
        out = []
        for cluster in self.handle.clusters.values():
            if cluster.role != role:
                continue
            for w in cluster.replicas:
                if w.failed:
                    continue
                if w.active == active:
                    out.append(w)
        return out
