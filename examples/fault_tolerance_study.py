"""Case study: replica failures and stragglers in a serving cluster.

Demonstrates the large-scale-operations machinery: inject a replica
failure (lost KV, re-routing, recovery) and a chronic straggler replica,
and quantify their throughput/latency cost — the kind of what-if a fleet
operator runs in Frontier before changing production.

    PYTHONPATH=src python examples/fault_tolerance_study.py
"""
from repro.configs import get_config
from repro.core import A800_SXM4_80G, ParallelismConfig
from repro.core.workflows.colocated import build_colocated
from repro.workload.generator import WorkloadConfig, generate


def run_case(name, *, fail=False, straggler=False):
    cfg = get_config("qwen2-7b")
    hw = A800_SXM4_80G
    sys = build_colocated(cfg, hw, n_replicas=4, par=ParallelismConfig(tp=1))
    if straggler:
        sys.clusters["colocated"].replicas[1].slowdown = 3.0
    if fail:
        # replica 0 dies 1s in, recovers after 10s of downtime
        sys.controller.inject_failure("colocated", 0, at=1.0, downtime=10.0)
    wl = WorkloadConfig(n_requests=300, rate=40.0, prompt_mean=512,
                        output_mean=96, seed=0)
    rep = sys.run(generate(wl))
    print(f"{name:22s} tok/s {rep['throughput_tok_s']:8.0f}   "
          f"ttft_p99 {rep['ttft_p99_s']*1e3:8.1f} ms   "
          f"tpot_p99 {rep['tpot_p99_s']*1e3:7.1f} ms   "
          f"completed {rep['n_completed']}")
    return rep


def main():
    base = run_case("healthy x4")
    f = run_case("1 failure (10s)", fail=True)
    s = run_case("1 straggler (3x)", straggler=True)
    print(f"\nfailure throughput cost: "
          f"{1 - f['throughput_tok_s']/base['throughput_tok_s']:.1%}; "
          f"straggler cost: "
          f"{1 - s['throughput_tok_s']/base['throughput_tok_s']:.1%} "
          f"(all requests still complete — conservation holds)")


if __name__ == "__main__":
    main()
