"""Analytical (roofline) operator models + the OperatorModelSet interface.

This closed-form model is the "simplified roofline" baseline the paper
criticizes intra-framework simulators for (§2.2) — kept both as a fallback
and as the comparison point for the refined RF models.  Every operator time
is ``max(flops/peak, bytes/hbm_bw) + op_overhead``.

The refined models (attention_model.py / grouped_gemm_model.py) subclass
OperatorModelSet and override the two operators the paper targets.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from repro.core.hardware import HardwareSpec


@dataclass
class GemmShape:
    m: int
    n: int
    k: int
    dtype_bytes: int = 2


class OperatorModelSet:
    """Interface queried by the ExecutionPredictor."""

    def __init__(self, hw: HardwareSpec):
        self.hw = hw

    # ---- dense algebra ----------------------------------------------------
    def gemm(self, m: int, n: int, k: int, dtype_bytes: int = 2) -> float:
        flops = 2.0 * m * n * k
        bytes_ = dtype_bytes * (m * k + k * n + m * n)
        return self._roof(flops, bytes_)

    # ---- attention ----------------------------------------------------------
    def attention_prefill(self, q_lens: Sequence[int], kv_lens: Sequence[int],
                          n_heads: int, n_kv_heads: int, head_dim: int,
                          causal: bool = True, window: int = 0) -> float:
        flops = 0.0
        bytes_ = 0.0
        for q, kv in zip(q_lens, kv_lens):
            eff_kv = min(kv, window) if window else kv
            pairs = q * eff_kv * (0.5 if causal and q == kv and not window else 1.0)
            flops += 4.0 * n_heads * head_dim * pairs
            bytes_ += 2.0 * (q * n_heads + 2 * eff_kv * n_kv_heads) * head_dim
        return self._roof(flops, bytes_)

    def attention_decode(self, context_lens: Sequence[int], n_heads: int,
                         n_kv_heads: int, head_dim: int,
                         window: int = 0) -> float:
        flops = 0.0
        bytes_ = 0.0
        for kv in context_lens:
            eff = min(kv, window) if window else kv
            flops += 4.0 * n_heads * head_dim * eff
            bytes_ += 2.0 * 2 * eff * n_kv_heads * head_dim  # KV read
        return self._roof(flops, bytes_)

    # ---- MoE ---------------------------------------------------------------
    def grouped_gemm(self, tokens_per_group: Sequence[int], d_in: int,
                     d_out: int, dtype_bytes: int = 2) -> float:
        """One grouped GEMM over expert groups on a single device."""
        flops = sum(2.0 * t * d_in * d_out for t in tokens_per_group)
        bytes_ = sum(dtype_bytes * (t * d_in + t * d_out)
                     for t in tokens_per_group)
        bytes_ += dtype_bytes * d_in * d_out * len(tokens_per_group)  # weights
        return self._roof(flops, bytes_)

    # ---- collectives ---------------------------------------------------------
    def all_reduce(self, nbytes: float, n: int, *, inter_node: bool = False) -> float:
        if n <= 1:
            return 0.0
        bw = self.hw.inter_node_bw if inter_node else self.hw.intra_node_bw
        return 2.0 * nbytes * (n - 1) / n / bw + self.hw.op_overhead

    def all_gather(self, nbytes: float, n: int, *, inter_node: bool = False) -> float:
        if n <= 1:
            return 0.0
        bw = self.hw.inter_node_bw if inter_node else self.hw.intra_node_bw
        return nbytes * (n - 1) / n / bw + self.hw.op_overhead

    def all_to_all(self, nbytes_per_device: float, n: int, *,
                   inter_node: bool = False) -> float:
        if n <= 1:
            return 0.0
        bw = self.hw.inter_node_bw if inter_node else self.hw.intra_node_bw
        return nbytes_per_device * (n - 1) / n / bw + self.hw.op_overhead

    def p2p(self, nbytes: float, *, inter_node: bool = True) -> float:
        bw = self.hw.inter_node_bw if inter_node else self.hw.intra_node_bw
        return nbytes / bw + self.hw.op_overhead

    def m2n(self, nbytes: float, m: int, n: int, *,
            inter_node: bool = True) -> float:
        """M2N dispatch/combine (m senders fan nbytes into n receivers).
        The flat baseline ignores the fan shape — exactly p2p — so callers
        switching from p2p to m2n stay bit-identical without a fabric;
        FabricOps overrides this with the NIC-lane-aware model."""
        return self.p2p(nbytes, inter_node=inter_node)

    # ---- helpers -------------------------------------------------------------
    def membound(self, nbytes: float) -> float:
        return nbytes / self.hw.hbm_bw + self.hw.op_overhead

    def _roof(self, flops: float, bytes_: float) -> float:
        return max(flops / self.hw.peak_flops, bytes_ / self.hw.hbm_bw) \
            + self.hw.op_overhead


class AnalyticalModels(OperatorModelSet):
    """Alias for clarity at call sites."""
