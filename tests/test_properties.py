"""Property-based invariants (hypothesis): event/token conservation over
random StageGraphs, and overlap bounds over random pipelining configs.

Guarded by importorskip like the kernel suite — the properties run
wherever hypothesis is installed (the CI image has it)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.api import ModelRef, SimSpec, TopologySpec, WorkloadSpec, run
from repro.configs import get_config
from repro.core import A800_SXM4_80G, ParallelismConfig, \
    simulate_af_decode_step
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.pipeline import PipelineConfig

HW = A800_SXM4_80G
MCFG = get_config("mixtral-8x7b", smoke=True)
OPS = OperatorModelSet(HW)

# keep each drawn simulation small: hypothesis multiplies examples
_SETTINGS = dict(max_examples=15, deadline=None)


# ------------------------------------------------- random pipeline steps --
pipeline_configs = st.builds(
    PipelineConfig,
    af_overlap=st.sampled_from(("none", "serial", "two_batch")),
    nic_lanes=st.integers(min_value=1, max_value=4),
    chunked_prefill=st.booleans(),
    prefill_chunk=st.sampled_from((64, 256, 1024)),
    ep_overlap=st.floats(min_value=0.0, max_value=1.0,
                         allow_nan=False))


@given(pipe=pipeline_configs,
       m=st.integers(min_value=1, max_value=6),
       n_seq=st.integers(min_value=1, max_value=48),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(**_SETTINGS)
def test_af_step_overlap_bounds_hold_for_random_configs(pipe, m, n_seq,
                                                        seed):
    rng = np.random.default_rng(seed)
    lens = list(rng.integers(16, 4096, n_seq))
    st_ = simulate_af_decode_step(
        MCFG, HW, OPS, lens, m=m,
        attn_par=ParallelismConfig(tp=2),
        ffn_par=ParallelismConfig(tp=1, ep=4),
        rng=np.random.default_rng(seed), pipeline=pipe)
    # overlapped makespan never exceeds the serial (sum-of-durations) one
    assert st_.makespan <= st_.serial_makespan * (1 + 1e-9)
    assert st_.bubble_time >= 0.0
    assert 0.0 <= st_.overlap_efficiency <= 1.0
    assert st_.attn_exposed_comm >= -1e-12
    assert st_.ffn_exposed_comm >= -1e-12
    assert st_.ep_overlap_hidden >= -1e-12
    assert st_.makespan >= max(st_.attn_busy / max(m, 1), 0.0) - 1e-9


# ---------------------------------------------- random topologies (e2e) --
def _graph_strategy():
    colocated = st.fixed_dictionaries({
        "preset": st.just("colocated"),
        "n_replicas": st.integers(1, 3),
        "tp": st.sampled_from((1, 2)),
    })
    pd = st.fixed_dictionaries({
        "preset": st.just("pd"),
        "n_prefill": st.integers(1, 2),
        "n_decode": st.integers(1, 3),
    })
    af = st.fixed_dictionaries({
        "preset": st.just("af"),
        "n_prefill": st.integers(1, 2),
        "n_decode": st.integers(1, 2),
        "m": st.sampled_from((1, 2, 4)),
        "ffn_ep": st.sampled_from((2, 4)),
    })
    return st.one_of(colocated, pd, af)


pipeline_specs = st.one_of(
    st.none(),
    st.sampled_from(("serial", "two_batch", "chunked_prefill",
                     "full_overlap")))


@given(topo=_graph_strategy(), pipe=pipeline_specs,
       n_requests=st.integers(min_value=5, max_value=25),
       seed=st.integers(min_value=0, max_value=10**6))
@settings(**_SETTINGS)
def test_random_topology_and_pipeline_conserves_requests(topo, pipe,
                                                         n_requests, seed):
    """No request is ever lost or duplicated, whatever graph/pipelining
    strategy is drawn — and every generated token is accounted for."""
    model = "mixtral-8x7b" if topo["preset"] == "af" else "qwen2-7b"
    spec = SimSpec.from_dict({
        "model": {"name": model, "smoke": True},
        "topology": topo,
        "workload": {"n_requests": n_requests, "rate": 50.0,
                     "prompt_mean": 128, "prompt_max": 512,
                     "output_mean": 16, "output_max": 64, "seed": seed},
        "pipeline": pipe,
        "seed": seed,
    })
    rep = run(spec)
    assert rep.conservation == {"complete": n_requests}, rep.conservation
    assert rep.all_complete
    tokens = sum(r["tokens"] for c in rep.clusters.values()
                 for r in c["replicas"].values())
    assert rep.summary["n_completed"] == n_requests
    # every completed request generated at least one token, all counted
    # by exactly one replica
    assert tokens >= n_requests
    if "bubble_time_s" in rep.summary:
        assert rep.summary["bubble_time_s"] >= 0.0
