"""Dense FFN: gated (SwiGLU/GeGLU) or plain two-layer, megatron TP over d_ff."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import PD, AxisRules, activation


def mlp_pds(cfg: ModelConfig, d_ff: int | None = None) -> Dict[str, PD]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    p = {
        "w_in": PD((d, ff), ("embed", "mlp")),
        "w_out": PD((ff, d), ("mlp", "embed")),
    }
    if cfg.gated_mlp:
        p["w_gate"] = PD((d, ff), ("embed", "mlp"))
    return p


def mlp_apply(cfg: ModelConfig, p, x: jax.Array, ax: AxisRules) -> jax.Array:
    act = activation(cfg.mlp_act)
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = act(g) * h
    else:
        h = act(h)
    h = ax.constrain(h, "batch", None, "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    return ax.constrain(y, "batch", None, "embed")
