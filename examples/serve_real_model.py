"""End-to-end driver: REAL serving of a small model with batched requests,
then the simulator predicting the same system (paper Table-2 protocol).

    PYTHONPATH=src python examples/serve_real_model.py
"""
from repro.launch.serve import run


def main():
    out = run("qwen2-7b", batch=4, prompt_len=32, output_len=24,
              calibrate=False)
    m, p = out["measured"], out["predicted"]
    print("real MiniEngine (JAX, CPU):")
    print(f"  throughput {m['throughput_tok_s']:8.1f} tok/s   "
          f"ttft {m['ttft_mean_s']*1e3:7.1f} ms   "
          f"tpot {m['tpot_mean_s']*1e3:6.1f} ms")
    print("Frontier simulation (CPU-calibrated hardware profile):")
    print(f"  throughput {p['throughput_tok_s']:8.1f} tok/s   "
          f"ttft {p['ttft_p50_s']*1e3:7.1f} ms   "
          f"tpot {p['tpot_p50_s']*1e3:6.1f} ms")
    err = abs(p["throughput_tok_s"] - m["throughput_tok_s"]) \
        / m["throughput_tok_s"]
    print(f"relative error: {err:.1%}")


if __name__ == "__main__":
    main()
