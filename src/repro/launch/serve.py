"""End-to-end serving driver: run the real MiniEngine on a small model and
compare measured throughput against the Frontier simulator's prediction
(the paper's Table-2 protocol, CPU edition).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.core.hardware import ParallelismConfig
from repro.core.opmodels.calibration import measure_cpu_hardware
from repro.core.opmodels.refined import RefinedModels, calibrate_refined
from repro.core.workflows.colocated import build_colocated
from repro.serving.engine import MiniEngine
from repro.workload.generator import fixed_batch


def run(arch: str = "qwen2-7b", *, batch: int = 4, prompt_len: int = 32,
        output_len: int = 32, max_seq: int = 256, seed: int = 0,
        calibrate: bool = True):
    cfg = get_config(arch, smoke=True)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len) for _ in range(batch)]

    engine = MiniEngine(cfg, max_slots=batch, max_seq=max_seq, seed=seed)
    engine.submit(list(prompts), output_len)
    engine.run()                      # warm pass: compiles prefill/decode jits
    engine.step_log.clear()
    engine.submit(list(prompts), output_len)
    measured = engine.run()           # steady-state measurement

    hw = measure_cpu_hardware()
    ops = (calibrate_refined(hw, n_heads=cfg.num_heads,
                             n_kv_heads=cfg.num_kv_heads,
                             head_dim=cfg.resolved_head_dim,
                             n_samples=200)
           if calibrate else None)
    sim = build_colocated(cfg, hw, n_replicas=1,
                          par=ParallelismConfig(tp=1), ops=ops)
    # calibration (paper flow): the engine's steady-state per-step floor on
    # THIS hardware feeds the predictor — at smoke scale on CPU the step is
    # dispatch/framework dominated, which operator models must carry.
    step_floor = min(s["dur"] for s in engine.step_log
                     if s["kind"] == "decode")
    for rep_w in sim.clusters["colocated"].replicas:
        rep_w.predictor.engine_overhead = step_floor
    predicted = sim.run(fixed_batch(batch, prompt_len, output_len))
    return {"measured": measured, "predicted": predicted}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--output-len", type=int, default=32)
    a = ap.parse_args()
    out = run(a.arch, batch=a.batch, prompt_len=a.prompt_len,
              output_len=a.output_len)
    m, p = out["measured"], out["predicted"]
    print(f"measured  : {m['throughput_tok_s']:.1f} tok/s "
          f"(ttft {m['ttft_mean_s']*1e3:.1f} ms)")
    print(f"predicted : {p['throughput_tok_s']:.1f} tok/s "
          f"(ttft {p['ttft_p50_s']*1e3:.1f} ms)")
    err = abs(p["throughput_tok_s"] - m["throughput_tok_s"]) / m["throughput_tok_s"]
    print(f"relative error: {err:.1%}")


if __name__ == "__main__":
    main()
