"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh helper (tests/examples; e.g. (2,4) on 8 CPU devices)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
