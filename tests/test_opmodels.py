"""Operator models: RF quality, proxy comparison, kernelsim properties."""
import numpy as np
import pytest

from repro.core.hardware import A800_SXM4_80G, TPU_V5E
from repro.core.opmodels.calibration import (
    fit_attention_model, fit_grouped_gemm_model, sample_attention_batch,
)
from repro.core.opmodels.features import (
    attention_features, grouped_gemm_features,
)
from repro.core.opmodels.forest import RandomForest
from repro.core.opmodels.kernelsim import VirtualKernels
from repro.core.opmodels.vidur_proxy import VidurProxyModel

HW = A800_SXM4_80G


def test_forest_fits_nonlinear_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(-2, 2, (600, 4))
    y = np.sin(X[:, 0]) * 3 + X[:, 1] ** 2 + 0.5 * X[:, 2] * X[:, 3]
    rf = RandomForest(n_trees=12, seed=1).fit(X[:500], y[:500])
    pred = rf.predict(X[500:])
    mean_base = np.mean((y[500:] - y[:500].mean()) ** 2)
    assert np.mean((pred - y[500:]) ** 2) < 0.2 * mean_base


def test_forest_deterministic_given_seed():
    rng = np.random.default_rng(1)
    X, y = rng.normal(size=(200, 3)), rng.normal(size=200)
    p1 = RandomForest(n_trees=5, seed=9).fit(X, y).predict(X[:10])
    p2 = RandomForest(n_trees=5, seed=9).fit(X, y).predict(X[:10])
    np.testing.assert_array_equal(p1, p2)


def test_kernelsim_wave_quantization():
    """Crossing a core-count multiple of tiles must bump runtime."""
    vk = VirtualKernels(HW)
    # homogeneous decode: batch tiles = B * kv_heads * kv_split
    t_under = vk.attention_decode([2048] * 26, 32, 8, 128)   # < 108*2 tiles?
    t_over = vk.attention_decode([2048] * 28, 32, 8, 128)
    assert t_over >= t_under


def test_kernelsim_monotone_in_work():
    vk = VirtualKernels(HW)
    a = vk.attention_prefill([512] * 4, [512] * 4, 32, 8, 128)
    b = vk.attention_prefill([1024] * 4, [1024] * 4, 32, 8, 128)
    assert b > a
    g1 = vk.grouped_gemm([128] * 8, 4096, 14336)
    g2 = vk.grouped_gemm([256] * 8, 4096, 14336)
    assert g2 > g1


def test_grouped_gemm_imbalance_costs():
    vk = VirtualKernels(TPU_V5E)
    balanced = [256] * 8
    skewed = [2048 - 7 * 8] + [8] * 7   # same total tokens
    assert vk.grouped_gemm(skewed, 4096, 2048) > \
        vk.grouped_gemm(balanced, 4096, 2048)


def test_rf_beats_vidur_proxy_on_skewed_batches():
    vk = VirtualKernels(HW)

    def oracle(q, kv, H, K, hd, causal, window):
        if any(x > 1 for x in q):
            return vk.attention_prefill(q, kv, H, K, hd, causal=causal,
                                        window=window)
        return vk.attention_decode(kv, H, K, hd, window=window)

    model, stats = fit_attention_model(oracle, n_heads=28, n_kv_heads=4,
                                       head_dim=128, n_samples=300, seed=0)
    proxy = VidurProxyModel(vk)
    rng = np.random.default_rng(7)
    rf_err, px_err = [], []
    for _ in range(40):
        q, kv = sample_attention_batch(rng, decode=False)
        t = oracle(q, kv, 28, 4, 128, True, 0)
        rf_err.append(abs(model.predict(q, kv, causal=True, window=0) - t) / t)
        px_err.append(abs(proxy.attention_prefill(q, kv, 28, 4, 128) - t) / t)
    assert np.mean(rf_err) < np.mean(px_err)


def test_feature_extractors_shapes():
    f = attention_features([4, 4], [128, 2048], 32, 8, 128, causal=True,
                           window=0)
    assert f.shape == (16,) and np.isfinite(f).all()
    g = grouped_gemm_features([0, 10, 300], 1024, 4096)
    assert g.shape == (11,) and np.isfinite(g).all()
    # load CV reflects imbalance
    g_bal = grouped_gemm_features([100, 100, 100], 1024, 4096)
    assert g[8] > g_bal[8]
