"""Common model machinery: param descriptors, init, norms, RoPE, sharding.

Parameters are declared as trees of :class:`PD` (param descriptors) carrying
shape, *logical axis names*, and init scale.  A single descriptor tree yields
both the materialized param pytree (``init_tree``) and the PartitionSpec
pytree (``spec_tree``) so the two can never drift structurally.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axes.  Physical mapping is decided by AxisRules (launch/shardings).
# ---------------------------------------------------------------------------
# "vocab"    -> model-parallel vocab shard
# "heads"    -> model-parallel attention heads (q)
# "kv"       -> kv heads
# "mlp"      -> model-parallel FFN hidden
# "expert"   -> expert-parallel axis
# "embed"    -> d_model (replicated in megatron-style TP)
# "layers"   -> stacked layer axis for lax.scan (never sharded)
# None       -> replicated


@dataclass(frozen=True)
class PD:
    """Param descriptor: shape + logical axes + init (+ dtype override)."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: Union[str, float] = "fan_in"   # "fan_in" | "zeros" | "ones" | const std
    dtype: Any = None                    # None -> caller-provided default

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pd(x: Any) -> bool:
    return isinstance(x, PD)


def _init_one(key: jax.Array, pd: PD, dtype) -> jax.Array:
    dtype = pd.dtype or dtype
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, dtype)
    if pd.init == "fan_in":
        fan_in = pd.shape[0] if len(pd.shape) == 1 else 1
        for d, a in zip(pd.shape[:-1], pd.axes[:-1]):
            if a != "layers":
                fan_in = fan_in * d if len(pd.shape) > 1 else fan_in
        # use product of all but last non-layer dims as fan-in
        dims = [d for d, a in zip(pd.shape[:-1], pd.axes[:-1]) if a != "layers"]
        fan_in = 1
        for d in dims:
            fan_in *= d
        fan_in = max(fan_in, 1)
        std = fan_in ** -0.5
    else:
        std = float(pd.init)
    return (jax.random.normal(key, pd.shape, jnp.float32) * std).astype(dtype)


def init_tree(key: jax.Array, tree, dtype=jnp.bfloat16):
    """Materialize a PD tree into a param pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_pd)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [_init_one(k, pd, dtype) for k, pd in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def shape_tree(tree, dtype=jnp.bfloat16):
    """PD tree -> ShapeDtypeStruct tree (no allocation; for dry-runs)."""
    return jax.tree_util.tree_map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype or dtype),
        tree, is_leaf=is_pd)


def stack_pds(tree, n: int):
    """Add a leading scanned 'layers' axis of length n to every descriptor."""
    def f(pd: PD) -> PD:
        return PD((n,) + pd.shape, ("layers",) + pd.axes, pd.init, pd.dtype)
    return jax.tree_util.tree_map(f, tree, is_leaf=is_pd)


# ---------------------------------------------------------------------------
# Axis rules: logical axis name -> mesh axis (with divisibility fallbacks)
# ---------------------------------------------------------------------------
class AxisRules:
    """Resolves logical param/activation axes to PartitionSpecs for a mesh.

    ``batch_axes`` covers DP ("pod","data"); ``model_axis`` covers TP/EP.
    An axis maps to its mesh axis only when the dimension is divisible by the
    mesh-axis size — otherwise it falls back to replication (documented in
    DESIGN.md, e.g. recurrentgemma's 10 heads on a 16-way model axis).
    """

    def __init__(self, mesh: Optional[jax.sharding.Mesh],
                 options: Optional[Dict[str, Any]] = None):
        self.mesh = mesh
        if mesh is None:
            self.axis_sizes: Dict[str, int] = {}
        else:
            self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.batch_axes: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if a in self.axis_sizes)
        self.model_axis: Optional[str] = "model" if "model" in self.axis_sizes else None
        # execution options threaded to layer implementations (perf levers):
        #   attn_impl: "naive" | "blockwise";  attn_block: int
        #   rwkv_impl: "scan" | "chunked";     rwkv_chunk: int
        self.options: Dict[str, Any] = dict(options or {})

    def opt(self, key: str, default: Any = None) -> Any:
        return self.options.get(key, default)

    # -- helpers ----------------------------------------------------------
    def _batch_size_product(self) -> int:
        p = 1
        for a in self.batch_axes:
            p *= self.axis_sizes[a]
        return p

    def batch(self, dim: int):
        """Mesh mapping for a batch dimension of size `dim` (best effort)."""
        axes = list(self.batch_axes)
        while axes:
            prod = 1
            for a in axes:
                prod *= self.axis_sizes[a]
            if dim % prod == 0:
                return tuple(axes) if len(axes) > 1 else axes[0]
            axes.pop(0)  # drop "pod" first, then "data"
        return None

    def model(self, dim: int):
        if self.model_axis and dim % self.axis_sizes[self.model_axis] == 0:
            return self.model_axis
        return None

    def model_size(self) -> int:
        return self.axis_sizes.get("model", 1)

    # -- resolution --------------------------------------------------------
    def resolve(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        """Logical axes + concrete dims -> PartitionSpec.

        A mesh axis may appear at most once per spec: the first logical axis
        that claims it wins, later claimants replicate (e.g. MoE expert
        weights (E, d, ff): 'expert' takes "model" so 'mlp' replicates under
        EP; when E is not divisible 'expert' falls back and 'mlp' takes
        "model" — the TP-over-d_ff layout moe_apply uses for mixtral).
        """
        out = []
        used = set()
        for a, d in zip(axes, shape):
            m = None
            if a in ("vocab", "heads", "kv", "mlp", "expert", "kv_seq"):
                m = self.model(d)
            elif a == "batch":
                m = self.batch(d)
            elif a == "zero":  # ZeRO-1 optimizer-state sharding over data
                ds = self.axis_sizes.get("data", 1)
                m = "data" if ds > 1 and d % ds == 0 else None
            elif a in ("embed", "layers", None):
                m = None
            else:
                raise ValueError(f"unknown logical axis {a!r}")
            flat = m if isinstance(m, tuple) else (m,)
            if m is not None and any(f in used for f in flat):
                m = None
            if m is not None:
                used.update(flat)
            out.append(m)
        return P(*out)

    def spec_tree(self, pd_tree):
        return jax.tree_util.tree_map(
            lambda pd: self.resolve(pd.axes, pd.shape), pd_tree, is_leaf=is_pd)

    def constrain(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        """with_sharding_constraint by logical axes (no-op without a mesh)."""
        if self.mesh is None or self.mesh.empty:
            return x
        spec = self.resolve(axes, x.shape)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))


NO_RULES = AxisRules(None)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             zero_centered: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if zero_centered else scale.astype(jnp.float32)
    return (y * s).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin of shape (..., head_dim//2), float32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., n_heads, head_dim); cos/sin: broadcastable (..., 1, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


def activation(name: str) -> Callable[[jax.Array], jax.Array]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL in f32.  logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
