"""AdamW from scratch (no optax in this environment), ZeRO-1 shardable.

Optimizer moments are declared as PD trees so they participate in the same
logical-axis sharding machinery as params.  With ``zero1=True`` each moment
tensor additionally shards its first data-divisible replicated axis over the
"data" mesh axis (logical axis "zero") — XLA then materializes the classic
ZeRO-1 schedule (reduce-scattered moment update + all-gathered param delta)
without any hand-written collectives.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import PD, AxisRules, is_pd


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    zero1: bool = True
    moment_dtype: Any = jnp.float32


def _zero1_pd(pd: PD, data_size: int) -> PD:
    """Extend a moment PD's axes with the 'zero' logical axis if possible."""
    if data_size <= 1:
        return pd
    axes = list(pd.axes)
    for i, (a, d) in enumerate(zip(axes, pd.shape)):
        if a in (None, "embed") and d % data_size == 0 and d >= data_size:
            axes[i] = "zero"
            return PD(pd.shape, tuple(axes), "zeros")
    return PD(pd.shape, pd.axes, "zeros")


class AdamW:
    def __init__(self, cfg: AdamWConfig, ax: AxisRules):
        self.cfg = cfg
        self.ax = ax
        self.data_size = ax.axis_sizes.get("data", 1) if cfg.zero1 else 1

    # ---- descriptor plumbing (keeps dry-run allocation-free) -------------
    def state_pds(self, param_pds) -> Dict[str, Any]:
        def mom(pd: PD) -> PD:
            z = _zero1_pd(PD(pd.shape, pd.axes, "zeros"), self.data_size)
            return z
        m = jax.tree_util.tree_map(mom, param_pds, is_leaf=is_pd)
        v = jax.tree_util.tree_map(mom, param_pds, is_leaf=is_pd)
        return {"m": m, "v": v, "step": PD((), (), "zeros")}

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, self.cfg.moment_dtype)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    # ---- update -----------------------------------------------------------
    def update(self, params, grads, state) -> Tuple[Any, Any]:
        c = self.cfg
        step = state["step"] + 1
        # global-norm clip in f32
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree_util.tree_leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-12)) \
            if c.grad_clip else jnp.float32(1.0)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - c.b1 ** t
        bc2 = 1.0 - c.b2 ** t

        def upd(p, g, m, v):
            gf = g.astype(self.cfg.moment_dtype) * scale
            m = c.b1 * m + (1.0 - c.b1) * gf
            v = c.b2 * v + (1.0 - c.b2) * jnp.square(gf)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(mh.dtype)
            p = (p.astype(jnp.float32) - c.lr * delta.astype(jnp.float32)).astype(p.dtype)
            return p, m, v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(state["m"])
        flat_v = jax.tree_util.tree_leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}


def make_train_step(model, optimizer: AdamW):
    """(params, opt_state, batch) -> (params', opt_state', metrics)."""
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state = optimizer.update(params, grads, opt_state)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics
    return train_step
