"""pixtral-12b — pixtral-ViT frontend (stub) + mistral-nemo-like backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

The modality frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings for the first frontend_fraction of the sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    frontend="patch",
    frontend_dim=5120,
    frontend_fraction=0.25,
)
