"""seamless-m4t-large-v2 — encoder-decoder multimodal (audio frontend stub).
[arXiv:2308.11596; hf]

24 encoder + 24 decoder layers, d_model=1024, 16 heads (kv=16), standard
(non-gated) FFN.  The speech frontend is a STUB: input_specs() provides
precomputed w2v-BERT-style frame embeddings (B, S_src, 1024).
vocab 256206 is padded to 256256 for 16-way TP (see base.padded_vocab).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,             # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    gated_mlp=False,
    mlp_act="relu",
    encoder_layers=24,
    cross_attention=True,
    frontend="frames",
    frontend_dim=1024,
    tie_embeddings=True,
)
