"""GQA attention: training/prefill (full-sequence) and decode (KV cache).

Variants covered (per assigned archs): GQA with any (H, K), qk_norm (qwen3),
attention-logit softcap (gemma2), sliding-window/local attention (gemma2,
mixtral, recurrentgemma), cross-attention (seamless enc-dec), bidirectional
encoders.

TP mapping (megatron-style over the "model" mesh axis, see DESIGN.md):
- train/prefill: KV heads are *replicated* ``rep = tp/gcd(K, tp)`` times —
  exactly what real TP serving engines do when ``kv_heads < tp`` — so the
  q-head axis shards evenly.  If H itself is not divisible by tp
  (recurrentgemma's 10 heads), attention runs replicated on the model axis.
- decode: the KV cache shards over (batch -> data, seq -> model); the
  per-step softmax over the sequence-sharded axis costs two tiny
  all-reduces (flash-decode-style TP).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    PD, AxisRules, apply_rope, rms_norm, rope_freqs, softcap,
)

NEG_INF = -2.0e38


def attn_pds(cfg: ModelConfig, cross: bool = False) -> Dict[str, PD]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    p = {
        "wq": PD((d, H, hd), ("embed", "heads", None)),
        "wk": PD((d, K, hd), ("embed", "kv", None)),
        "wv": PD((d, K, hd), ("embed", "kv", None)),
        "wo": PD((H, hd, d), ("heads", None, "embed")),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = PD((hd,), (None,), "zeros")
        p["k_norm"] = PD((hd,), (None,), "zeros")
    return p


def kv_replication(cfg: ModelConfig, ax: AxisRules) -> int:
    """How many times KV heads are replicated for TP train/prefill."""
    tp = ax.model_size()
    H, K = cfg.num_heads, cfg.num_kv_heads
    if tp <= 1 or H % tp != 0:
        return 1
    rep = tp // math.gcd(K, tp)
    return rep if (H // K) % rep == 0 else 1


def _project_qkv(cfg: ModelConfig, p, x, positions, ax: AxisRules,
                 rope: bool = True):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,S,K,hd) with qk_norm + RoPE."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps, zero_centered=True)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps, zero_centered=True)
    if rope:
        cos, sin = rope_freqs(positions, cfg.resolved_head_dim, cfg.rope_theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = ax.constrain(q, "batch", None, "heads", None)
    return q, k, v


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int) -> jax.Array:
    """(S, T) additive bias in f32: 0 where attendable, -inf elsewhere."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(cfg: ModelConfig, q, k, v, bias, ax: AxisRules) -> jax.Array:
    """Grouped-head attention.  q (B,S,Kr,G,hd); k,v (B,T,Kr,hd)."""
    scale = cfg.resolved_head_dim ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    if cfg.attn_logit_softcap:
        scores = softcap(scores, cfg.attn_logit_softcap)
    scores = scores + bias[None, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out


def _sdpa_blockwise(cfg: ModelConfig, q, k, v, q_pos, k_pos, ax: AxisRules,
                    *, causal: bool, window: int, block: int = 1024
                    ) -> jax.Array:
    """Flash-style online-softmax attention in pure XLA (lax.scan over KV
    blocks).  Never materializes the (S, T) score matrix to HBM: per step
    only a (B,Kr,G,S,block) tile lives inside the (rematerialized) scan
    body, so both the memory-roofline term and peak temp drop by ~T/block.
    The backward pass recomputes block scores (jax.checkpoint on the body),
    exactly like FlashAttention's backward — this is the XLA-lowerable
    twin of kernels/flash_attention.py for the 512-device dry-run.
    """
    B, S, Kr, G, hd = q.shape
    T = k.shape[1]
    scale = hd ** -0.5
    nb = (T + block - 1) // block
    Tp = nb * block
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, Tp - T), constant_values=2**30)
    kb = k.reshape(B, nb, block, Kr, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block, Kr, hd).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(nb, block)
    qf = q.astype(jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, pblk = inp
        s = jnp.einsum("bskgd,btkd->bkgst", qf,
                       kblk.astype(jnp.float32)) * scale
        if cfg.attn_logit_softcap:
            s = softcap(s, cfg.attn_logit_softcap)
        ok = jnp.ones((S, block), bool)
        if causal:
            ok &= q_pos[:, None] >= pblk[None, :]
        if window:
            ok &= (q_pos[:, None] - pblk[None, :]) < window
        ok &= pblk[None, :] < 2**30
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where((m_new == NEG_INF)[..., None], 0.0, p)
        alpha = jnp.where(m == NEG_INF, 0.0, jnp.exp(m - m_new))
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, Kr, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kr, G, S), jnp.float32)
    a0 = jnp.zeros((B, Kr, G, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable),
        (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,S,Kr,G,hd)


def attention_train(cfg: ModelConfig, p, x, ax: AxisRules, *,
                    window: int = 0, causal: bool = True,
                    positions: Optional[jax.Array] = None,
                    memory: Optional[jax.Array] = None,
                    memory_positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention.  memory != None => cross-attention."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if memory is None:
        q, k, v = _project_qkv(cfg, p, x, positions, ax)
        k_pos = positions
    else:
        # cross-attention: q from x, k/v from encoder memory; no RoPE on q/k
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
        q = ax.constrain(q, "batch", None, "heads", None)
        k_pos = (memory_positions if memory_positions is not None
                 else jnp.broadcast_to(jnp.arange(memory.shape[1]), (B, memory.shape[1])))
        causal, window = False, 0

    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    rep = kv_replication(cfg, ax)
    if rep > 1:  # replicate KV heads across TP ranks
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    Kr = K * rep
    k = ax.constrain(k, "batch", None, "heads" if Kr % max(ax.model_size(), 1) == 0 else None, None)
    v = ax.constrain(v, "batch", None, "heads" if Kr % max(ax.model_size(), 1) == 0 else None, None)
    q = q.reshape(B, S, Kr, H // Kr, hd)

    if ax.opt("attn_impl", "naive") == "blockwise":
        out = _sdpa_blockwise(cfg, q, k, v, positions[0], k_pos[0], ax,
                              causal=causal, window=window,
                              block=int(ax.opt("attn_block", 1024)))
    else:
        bias = _mask_bias(positions[0], k_pos[0], causal=causal, window=window)
        out = _sdpa(cfg, q, k, v, bias, ax)
    out = out.reshape(B, S, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return ax.constrain(y, "batch", None, "embed")


# ---------------------------------------------------------------------------
# Decode path (single new token against a KV cache)
# ---------------------------------------------------------------------------
def cache_pds(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, PD]:
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": PD((batch, cache_len, K, hd), ("batch", "kv_seq", None, None), "zeros"),
        "v": PD((batch, cache_len, K, hd), ("batch", "kv_seq", None, None), "zeros"),
    }


def attention_decode(cfg: ModelConfig, p, x, cache: Dict[str, jax.Array],
                     pos: jax.Array, ax: AxisRules, *, window: int = 0,
                     memory_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode.  x (B,1,D); cache k/v (B,Sc,K,hd); pos scalar int.

    Sliding-window caches are ring buffers of length ``min(window, S)``;
    entries carry RoPE at their absolute positions so no re-rotation is
    needed.  Cross-attention (enc-dec) passes precomputed ``memory_kv``.
    """
    B = x.shape[0]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    scale = hd ** -0.5

    def attend(q, ck, cv, bias):
        # q (B,H,hd); ck/cv (B,T,K,hd); bias (T,) or per-row (B,T), f32
        qg = q.reshape(B, K, H // K, hd)
        s = jnp.einsum("bkgd,btkd->bkgt", qg, ck).astype(jnp.float32) * scale
        if cfg.attn_logit_softcap:
            s = softcap(s, cfg.attn_logit_softcap)
        s = s + (bias[:, None, None, :] if bias.ndim == 2
                 else bias[None, None, None, :])
        pr = jax.nn.softmax(s, axis=-1).astype(cv.dtype)
        o = jnp.einsum("bkgt,btkd->bkgd", pr, cv)
        return o.reshape(B, H, hd)

    if memory_kv is not None:  # cross-attention: cache is static memory KV
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0]
        ck, cv = memory_kv
        o = attend(q, ck, cv, jnp.zeros((ck.shape[1],), jnp.float32))
        y = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
        return ax.constrain(y, "batch", None, "embed"), cache

    # pos: scalar (uniform batch, dry-run decode) or (B,) per-row positions
    # (continuous batching in the real serving engine).
    per_row = getattr(pos, "ndim", 0) == 1
    pos_b = (pos[:, None] if per_row
             else jnp.broadcast_to(pos[None, None], (B, 1)))
    q, k_new, v_new = _project_qkv(cfg, p, x, pos_b, ax)
    q = q[:, 0]  # (B,H,hd)

    ck, cv = cache["k"], cache["v"]
    Sc = ck.shape[1]
    t = jnp.arange(Sc)
    if per_row:
        slot = pos % Sc                                   # (B,)
        hit = (t[None, :] == slot[:, None])[..., None, None]
        ck = jnp.where(hit, k_new, ck)
        cv = jnp.where(hit, v_new, cv)
        valid = (t[None, :] <= pos[:, None]) | (pos[:, None] + 1 >= Sc)
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)  # (B,Sc)
    else:
        slot = pos % Sc  # ring semantics; Sc == full length when window == 0
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k_new, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v_new, slot, axis=1)
        # validity: ring buffer is fully valid once pos+1 >= Sc; otherwise
        # only the first pos+1 slots hold real entries.
        valid = (t <= pos) | (pos + 1 >= Sc)
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    ck = ax.constrain(ck, "batch", "kv_seq", None, None)
    cv = ax.constrain(cv, "batch", "kv_seq", None, None)

    o = attend(q, ck, cv, bias)
    y = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
    return ax.constrain(y, "batch", None, "embed"), {"k": ck, "v": cv}
