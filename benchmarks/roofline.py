"""Roofline table driver: reads artifacts/dryrun/*.json (written by
launch/dryrun.py) and derives the three roofline terms per (arch x shape x
mesh) cell, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs utilization and
the roofline fraction.  TPU v5e constants per the assignment:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import REGISTRY

PEAK = 197e12
HBM = 819e9
ICI = 50e9

ARTIFACTS = Path(os.environ.get("REPRO_ARTIFACTS", "artifacts")) / "dryrun"


_N_CACHE: Dict[str, float] = {}


def active_matmul_params(arch: str) -> float:
    """N for MODEL_FLOPS=6ND: parameters touched by matmuls per token —
    derived from the REAL param descriptor tree (not an analytic formula).
    Expert tensors count at the top_k/E activation fraction; the input
    embedding gather is excluded; a tied embedding still counts once as the
    LM head."""
    if arch in _N_CACHE:
        return _N_CACHE[arch]
    import numpy as _np
    from repro.models.common import AxisRules, is_pd
    from repro.models.model import build_model
    import jax as _jax

    cfg = REGISTRY[arch]
    model = build_model(cfg, AxisRules(None))
    pds = model.pds()
    total = 0.0
    moe = cfg.moe
    for pd in _jax.tree_util.tree_leaves(pds, is_leaf=is_pd):
        n = float(_np.prod(pd.shape))
        if "expert" in pd.axes:
            n *= moe.top_k / moe.num_experts
        total += n
    emb = cfg.padded_vocab * cfg.d_model
    total -= emb if not cfg.tie_embeddings else 0.0  # input-embed gather
    _N_CACHE[arch] = total
    return total


def model_flops(arch: str, shape_kind: str, seq: int, batch: int) -> float:
    """6ND (train) / 2ND (inference)."""
    n = active_matmul_params(arch)
    if shape_kind == "train":
        return 6.0 * n * batch * seq
    if shape_kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch                    # decode: one token per seq


SHAPE_DIMS = {
    "train_4k": (4096, 256), "prefill_32k": (32768, 32),
    "decode_32k": (32768, 128), "long_500k": (524288, 1),
}


def load_cells(tag: str = "baseline", art_dir: Optional[Path] = None
               ) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(str((art_dir or ARTIFACTS) / f"*__{tag}.json"))):
        rows.append(json.loads(Path(f).read_text()))
    return rows


def analyze_cell(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "status": rec["status"],
                "reason": rec.get("reason", rec.get("error", ""))[:80]}
    hc = rec["hlo_corrected"]
    n_chips = rec["n_chips"]
    t_c = hc["flops"] / PEAK
    t_m = hc["bytes"] / HBM
    # link traffic: ring all-reduce moves ~2x its payload per device; AG/RS/
    # A2A move ~1x.  Fall back to raw collective_bytes if no breakdown.
    link_bytes = 0.0
    for k, v in hc.items():
        if k.startswith("coll_"):
            link_bytes += (2.0 if "all-reduce" in k else 1.0) * v
    if link_bytes == 0.0:
        link_bytes = hc["collective_bytes"]
    t_x = link_bytes / ICI
    dom = max([(t_c, "compute"), (t_m, "memory"), (t_x, "collective")])[1]
    seq, batch = SHAPE_DIMS[rec["shape"]]
    kind = rec["meta"]["kind"]
    mf = model_flops(rec["arch"], kind, seq, batch)
    hlo_global = hc["flops"] * n_chips
    t_model = mf / (n_chips * PEAK)
    frac = t_model / max(t_c, t_m, t_x, 1e-30)
    args_gb = rec["memory_analysis"]["argument_bytes"] / 1e9
    temp_gb = rec["memory_analysis"]["temp_bytes"] / 1e9
    # decode cells are intrinsically memory-bound: the honest efficiency
    # metric is useful-bytes (params + KV/state read once) / HLO bytes.
    bytes_eff = None
    if kind == "decode":
        cfg = REGISTRY[rec["arch"]]
        min_bytes = 2.0 * cfg.param_count() + rec["meta"].get(
            "cache_bytes_global", 0)
        bytes_eff = min_bytes / max(hc["bytes"] * n_chips, 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok", "step": rec["step"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "bottleneck": dom,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": mf / max(hlo_global, 1e-30),
        "roofline_frac": frac,
        "bytes_eff": bytes_eff,
        "args_gb_dev": args_gb, "temp_gb_dev": temp_gb,
        "compile_s": rec.get("compile_s", 0.0),
    }


def run(tag: str = "baseline") -> List[str]:
    lines = []
    cells = load_cells(tag)
    if not cells:
        return [f"roofline_{tag},0,NO_ARTIFACTS (run launch/dryrun.py first)"]
    ok = skipped = 0
    worst = None
    for rec in cells:
        a = analyze_cell(rec)
        if a is None:
            continue
        if a["status"] != "ok":
            skipped += 1
            lines.append(f"roofline_{a['arch']}__{a['shape']}__{a['mesh']},0,"
                         f"status={a['status']}")
            continue
        ok += 1
        extra = (f";bytes_eff={a['bytes_eff']:.3f}"
                 if a.get("bytes_eff") is not None else "")
        lines.append(
            f"roofline_{a['arch']}__{a['shape']}__{a['mesh']},"
            f"{max(a['t_compute_s'], a['t_memory_s'], a['t_collective_s']) * 1e6:.0f},"
            f"bottleneck={a['bottleneck']};frac={a['roofline_frac']:.3f};"
            f"useful={a['useful_ratio']:.2f};tc={a['t_compute_s']:.4f};"
            f"tm={a['t_memory_s']:.4f};tx={a['t_collective_s']:.4f}" + extra)
        if a["mesh"] == "pod" and (worst is None
                                   or a["roofline_frac"] < worst[1]):
            worst = (f"{a['arch']}__{a['shape']}", a["roofline_frac"])
    lines.append(f"roofline_summary_{tag},0,ok={ok};skipped={skipped};"
                 f"worst={worst[0] if worst else 'n/a'}"
                 f"({worst[1]:.4f})" if worst else f"roofline_summary,0,ok={ok}")
    return lines


if __name__ == "__main__":
    for l in run():
        print(l)
