"""Perf-lever equivalence: the optimized execution paths must be exact.

Every §Perf optimization (blockwise online-softmax attention, chunked WKV6,
all-to-all expert dispatch) is only admissible because it computes the SAME
function as the baseline — asserted here (fwd + grad).
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernels   # tier-2: kernel-path equivalence on CPU

from repro.configs import get_config
from repro.models import build_model, init_tree
from repro.models.common import AxisRules

ROOT = Path(__file__).resolve().parent.parent


def _loss_and_grad(cfg, params, batch, options):
    m = build_model(cfg, AxisRules(None, options))
    loss, _ = m.loss(params, batch)
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    return float(loss), g


def _maxdiff(g1, g2):
    return max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree_util.tree_leaves(g1),
                               jax.tree_util.tree_leaves(g2)))


@pytest.mark.parametrize("arch", ["yi-9b", "gemma2-27b", "mixtral-8x7b"])
def test_blockwise_attention_equiv(arch):
    cfg = get_config(arch, smoke=True)
    params = init_tree(jax.random.PRNGKey(0),
                       build_model(cfg, AxisRules(None)).pds(), jnp.float32)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 24)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    l1, g1 = _loss_and_grad(cfg, params, batch, {})
    l2, g2 = _loss_and_grad(cfg, params, batch,
                            {"attn_impl": "blockwise", "attn_block": 8})
    assert abs(l1 - l2) < 2e-5
    assert _maxdiff(g1, g2) < 2e-5


def test_chunked_wkv_equiv():
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_tree(jax.random.PRNGKey(1),
                       build_model(cfg, AxisRules(None)).pds(), jnp.float32)
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    l1, g1 = _loss_and_grad(cfg, params, batch, {})
    l2, g2 = _loss_and_grad(cfg, params, batch,
                            {"rwkv_impl": "chunked", "rwkv_chunk": 8})
    assert abs(l1 - l2) < 2e-5
    assert _maxdiff(g1, g2) < 2e-4


def test_a2a_moe_dispatch_equiv_multidevice():
    code = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.configs.base import MoEConfig
    from repro.launch.mesh import make_mesh
    from repro.models.common import AxisRules, NO_RULES, init_tree
    from repro.models.moe import moe_apply, moe_pds
    cfg = dataclasses.replace(
        get_config('mixtral-8x7b', smoke=True),
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                      capacity_factor_train=8.0))
    p = init_tree(jax.random.PRNGKey(0), moe_pds(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    y0, _ = jax.jit(lambda p, x: moe_apply(cfg, p, x, NO_RULES, train=True))(p, x)
    mesh = make_mesh((2, 4), ('data', 'model'))
    ax = AxisRules(mesh, {'moe_dispatch': 'a2a'})
    with jax.set_mesh(mesh):
        y1, _ = jax.jit(lambda p, x: moe_apply(cfg, p, x, ax, train=True))(p, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-5,
                               rtol=2e-5)
    print('OK')
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=540,
                       env={"PYTHONPATH": str(ROOT / "src"),
                            "PATH": "/usr/bin:/bin", "HOME": "/root",
                            "JAX_PLATFORMS": "cpu"}, cwd=str(ROOT))
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
