"""Heterogeneous cost study: the cheapest deployment that meets the SLO.

Prices candidate PD deployments that mix hardware generations per role
(H100 prefill + A800 decode and both homogeneous baselines, at several
pool sizes) over a shared 2:1-oversubscribed fabric, then answers the
question an operator actually asks: of the deployments that hold the
TTFT/TPOT SLO, which burns the fewest dollars per hour — and which
serves the most tokens per dollar?

    PYTHONPATH=src python examples/heterogeneous_cost_study.py
"""
import json
import os

from repro.api import SimSpec, run

SMOKE = bool(int(os.environ.get("SMOKE", "1")))
SLO_FLOOR = 0.99


def candidate(prefill_hw: str, decode_hw: str, n_prefill: int,
              n_decode: int) -> SimSpec:
    return SimSpec.from_dict({
        "name": f"{prefill_hw.split('-')[0]}x{n_prefill}"
                f"+{decode_hw.split('-')[0]}x{n_decode}",
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {
            "preset": None,
            "clusters": [
                {"name": "prefill", "role": "prefill",
                 "n_replicas": n_prefill, "hardware": prefill_hw},
                {"name": "decode", "role": "decode",
                 "n_replicas": n_decode, "hardware": decode_hw},
            ],
            "links": [{"src": "prefill", "dst": "decode",
                       "bandwidth": 25.0e9, "latency": 10.0e-6}],
            "fabric": {"mode": "shared", "oversubscription": 2.0,
                       "latency_s": 5.0e-6},
        },
        "workload": {"n_requests": 300 if SMOKE else 3000, "rate": 400.0,
                     "arrival": "burst", "burst_size": 50,
                     "burst_period": 0.125, "prompt_mean": 1024,
                     "output_mean": 64, "seed": 3},
        "slo": {"ttft_s": 0.007, "tpot_s": 0.01},
        "seed": 3,
    })


def main():
    candidates = []
    for pre_hw, dec_hw in (("H100-SXM", "A800-SXM4-80G"),
                           ("H100-SXM", "H100-SXM"),
                           ("A800-SXM4-80G", "A800-SXM4-80G")):
        for n_pre, n_dec in ((1, 2), (2, 2), (2, 4)):
            candidates.append(candidate(pre_hw, dec_hw, n_pre, n_dec))

    rows = []
    for spec in candidates:
        rep = run(spec)
        s = rep.summary
        rows.append({
            "name": spec.name,
            "dollars_per_hour": s["dollars_per_hour"],
            "tok_per_s_per_dollar": s["tok_per_s_per_dollar"],
            "slo_attainment": s.get("slo_attainment"),
            "ttft_p99_s": s["ttft_p99_s"],
            "fabric_contention_delay_s": s.get(
                "fabric_contention_delay_s", 0.0),
            "meets_slo": (s.get("slo_attainment") or 0.0) >= SLO_FLOOR,
        })

    hdr = (f"{'deployment':22s} {'$/hr':>7s} {'tok/s/$':>9s} "
           f"{'slo':>6s} {'ttft_p99':>9s} {'contend_s':>10s} {'ok':>3s}")
    print(hdr + "\n" + "-" * len(hdr))
    for r in sorted(rows, key=lambda r: r["dollars_per_hour"]):
        print(f"{r['name']:22s} {r['dollars_per_hour']:7.2f} "
              f"{r['tok_per_s_per_dollar']:9.1f} "
              f"{r['slo_attainment'] or 0:6.3f} {r['ttft_p99_s']:9.4f} "
              f"{r['fabric_contention_delay_s']:10.4f} "
              f"{'y' if r['meets_slo'] else 'n':>3s}")

    feasible = [r for r in rows if r["meets_slo"]]
    assert feasible, "no candidate met the SLO; retune the study"
    cheapest = min(feasible, key=lambda r: r["dollars_per_hour"])
    best_value = max(feasible, key=lambda r: r["tok_per_s_per_dollar"])
    print(f"\ncheapest meeting SLO>={SLO_FLOOR}: {cheapest['name']} "
          f"at ${cheapest['dollars_per_hour']:.2f}/hr")
    print(f"best tok/s/$ meeting SLO:      {best_value['name']} "
          f"at {best_value['tok_per_s_per_dollar']:.1f} tok/s/$")

    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/heterogeneous_cost.json", "w") as f:
        json.dump({"rows": rows, "cheapest": cheapest["name"],
                   "best_value": best_value["name"]}, f, indent=2,
                  default=float)
    print("rows -> artifacts/heterogeneous_cost.json")


if __name__ == "__main__":
    main()
