"""Workload-generator tests: the diurnal arrival shaper (realized
histogram matches the programmed sinusoid) and its spec plumbing."""
import numpy as np
import pytest

from repro.api import SimSpec, SpecError
from repro.workload.generator import WorkloadConfig, generate


def _arrivals(**kw):
    cfg = WorkloadConfig(**kw)
    return np.array([r.arrival for r in generate(cfg)])


def test_diurnal_histogram_matches_programmed_sinusoid():
    """The realized arrival density tracks lambda(t) = rate*(1+A sin(wt)):
    the per-phase histogram correlates with the programmed curve and the
    amplitude estimator 2*E[sin(wt)] recovers A."""
    rate, period, amp, n = 50.0, 40.0, 0.8, 4000
    t = _arrivals(n_requests=n, arrival="poisson", rate=rate,
                  rate_curve="diurnal", rate_period=period,
                  rate_amplitude=amp, seed=0)
    assert np.all(np.diff(t) >= 0)
    # whole periods only (a partial tail period would bias the phases)
    t = t[t < np.floor(t[-1] / period) * period]
    w = 2 * np.pi / period
    # moment estimator: for density prop. to 1 + A sin(x), E[sin] = A/2
    est = 2.0 * np.mean(np.sin(w * t))
    assert est == pytest.approx(amp, abs=0.12)
    # histogram over phase bins correlates strongly with the programmed rate
    phase = (t % period) / period
    counts, edges = np.histogram(phase, bins=16, range=(0.0, 1.0))
    centers = (edges[:-1] + edges[1:]) / 2
    expected = 1.0 + amp * np.sin(2 * np.pi * centers)
    corr = np.corrcoef(counts, expected)[0, 1]
    assert corr > 0.95
    # peak half-cycle clearly outdraws the trough half-cycle
    peak = counts[(centers > 0.0) & (centers < 0.5)].sum()
    trough = counts[(centers > 0.5) & (centers < 1.0)].sum()
    assert peak > 1.5 * trough


def test_diurnal_mean_rate_is_preserved():
    """Modulation reshapes arrivals but keeps the offered rate: over whole
    periods the integrated rate equals rate * t."""
    rate, period = 40.0, 10.0
    t = _arrivals(n_requests=3000, arrival="poisson", rate=rate,
                  rate_curve="diurnal", rate_period=period,
                  rate_amplitude=0.6, seed=1)
    realized = len(t) / t[-1]
    assert realized == pytest.approx(rate, rel=0.1)


def test_zero_amplitude_is_plain_poisson_bit_for_bit():
    plain = _arrivals(n_requests=500, arrival="poisson", rate=20.0, seed=7)
    flat = _arrivals(n_requests=500, arrival="poisson", rate=20.0,
                     rate_curve="diurnal", rate_amplitude=0.0, seed=7)
    assert np.array_equal(plain, flat)


def test_diurnal_is_deterministic_in_seed():
    kw = dict(n_requests=300, arrival="poisson", rate=30.0,
              rate_curve="diurnal", rate_period=15.0, rate_amplitude=0.5)
    assert np.array_equal(_arrivals(seed=3, **kw), _arrivals(seed=3, **kw))
    assert not np.array_equal(_arrivals(seed=3, **kw),
                              _arrivals(seed=4, **kw))


def test_rate_curve_validation():
    with pytest.raises(ValueError, match="unknown rate_curve"):
        generate(WorkloadConfig(n_requests=10, rate_curve="lunar"))
    with pytest.raises(ValueError, match="poisson"):
        generate(WorkloadConfig(n_requests=10, arrival="burst",
                                rate_curve="diurnal"))
    with pytest.raises(SpecError, match="rate_amplitude"):
        SimSpec.from_dict({"workload": {
            "rate_curve": "diurnal", "rate_amplitude": 1.5}}).validate()
    with pytest.raises(SpecError, match="rate_period"):
        SimSpec.from_dict({"workload": {
            "rate_curve": "diurnal", "rate_period": 0}}).validate()
    with pytest.raises(SpecError, match="poisson"):
        SimSpec.from_dict({"workload": {
            "arrival": "burst", "rate_curve": "diurnal"}}).validate()


def test_diurnal_spec_round_trips():
    spec = SimSpec.from_dict({"workload": {
        "n_requests": 50, "rate": 25.0, "rate_curve": "diurnal",
        "rate_period": 30.0, "rate_amplitude": 0.4}})
    spec.validate()
    assert SimSpec.from_yaml(spec.to_yaml()) == spec
    reqs = spec.workload.build_requests(0)
    assert len(reqs) == 50
