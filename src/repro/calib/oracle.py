"""Operator-latency oracles — the ground truth calibration fits against.

An ``Oracle`` answers "how long does this operator take on this hardware
for this exact heterogeneous batch?" in seconds.  Three backends, one per
rung of the fidelity ladder:

``pallas``     wall-clock timing of the real Pallas kernels in
               ``kernels/ops.py`` (interpret mode on CPU — functional but
               slow, so shape limits shrink; real kernels on TPU/GPU).
``kernelsim``  the ``VirtualKernels`` tile-level simulator: deterministic,
               fast, models wave quantization and head/tile parallelism.
``hlo``        the HLO-cost proxy: jit-lower the jnp reference ops,
               run ``launch/hlo_cost.analyze`` on the compiled module, and
               price flops/bytes on the target hardware roofline.

``resolve_oracle`` picks automatically by environment ("auto"): the real
kernels when an accelerator backend is present, the virtual kernels
otherwise — so `python -m repro calibrate` does the right thing on both a
laptop and a TPU VM.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.hardware import HardwareSpec
from repro.core.opmodels.kernelsim import VirtualKernels


class Oracle:
    """Protocol: per-operator latency (seconds) for one heterogeneous batch.

    ``limits()`` advertises the largest shapes the backend can measure in
    reasonable time — the grid sampler clamps to it, so a slow backend
    (interpreted Pallas on CPU) still calibrates, just on a smaller domain.
    """

    name = "oracle"

    def attention_prefill(self, q_lens: Sequence[int],
                          kv_lens: Sequence[int], n_heads: int,
                          n_kv_heads: int, head_dim: int, *,
                          causal: bool = True, window: int = 0) -> float:
        raise NotImplementedError

    def attention_decode(self, context_lens: Sequence[int], n_heads: int,
                         n_kv_heads: int, head_dim: int, *,
                         window: int = 0) -> float:
        raise NotImplementedError

    def grouped_gemm(self, tokens_per_expert: Sequence[int], d_in: int,
                     d_out: int) -> float:
        raise NotImplementedError

    def limits(self) -> Dict[str, int]:
        return {"max_len": 8192, "max_batch": 128, "max_tokens": 16384}

    # fit_attention_model-compatible entry point: decode batches are the
    # all-q==1 case, matching how the predictor prices decode attention
    def attention(self, q_lens, kv_lens, n_heads, n_kv_heads, head_dim,
                  causal=True, window=0) -> float:
        if any(int(q) > 1 for q in q_lens):
            return self.attention_prefill(q_lens, kv_lens, n_heads,
                                          n_kv_heads, head_dim,
                                          causal=causal, window=window)
        return self.attention_decode(kv_lens, n_heads, n_kv_heads,
                                     head_dim, window=window)


class KernelSimOracle(Oracle):
    """VirtualKernels tile-level simulator as ground truth (default on CPU)."""

    name = "kernelsim"

    def __init__(self, hw: HardwareSpec):
        self.hw = hw
        self.kernels = VirtualKernels(hw)

    def attention_prefill(self, q_lens, kv_lens, n_heads, n_kv_heads,
                          head_dim, *, causal=True, window=0) -> float:
        return self.kernels.attention_prefill(q_lens, kv_lens, n_heads,
                                              n_kv_heads, head_dim,
                                              causal=causal, window=window)

    def attention_decode(self, context_lens, n_heads, n_kv_heads, head_dim,
                         *, window=0) -> float:
        return self.kernels.attention_decode(context_lens, n_heads,
                                             n_kv_heads, head_dim,
                                             window=window)

    def grouped_gemm(self, tokens_per_expert, d_in, d_out) -> float:
        return self.kernels.grouped_gemm(tokens_per_expert, d_in, d_out)


class PallasOracle(Oracle):
    """Wall-clock timing of the real Pallas kernels (``kernels/ops.py``).

    On an accelerator this measures the actual kernels; on CPU the kernels
    run in Pallas interpret mode, which is orders of magnitude slower than
    real silicon — so per-shape timings are cached (bucketed geometrically
    by length) and ``limits()`` shrinks the sampling domain to keep a
    calibration run tractable.  The cache is sound because kernel latency
    is a pure function of the (padded) shape.
    """

    name = "pallas"

    def __init__(self, hw: HardwareSpec, reps: int = 2, bucket: float = 1.25):
        self.hw = hw
        self.reps = reps
        self.bucket = bucket
        self._cache: Dict[tuple, float] = {}
        import jax  # hard dep of the kernels; fail loud at construction
        self._jax = jax
        self._on_accel = jax.default_backend() in ("tpu", "gpu")

    def limits(self) -> Dict[str, int]:
        if self._on_accel:
            return {"max_len": 8192, "max_batch": 64, "max_tokens": 8192}
        return {"max_len": 160, "max_batch": 4, "max_tokens": 512}

    def _round(self, n: int) -> int:
        # geometric bucketing: pads lengths up so the shape cache hits
        if n <= 16:
            return 16
        b = 16
        while b < n:
            b = max(b + 16, int(b * self.bucket) // 16 * 16)
        return b

    def _time(self, fn: Callable, *args) -> float:
        out = fn(*args)
        self._jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(self.reps):
            out = fn(*args)
            self._jax.block_until_ready(out)
        return (time.perf_counter() - t0) / self.reps

    def attention_prefill(self, q_lens, kv_lens, n_heads, n_kv_heads,
                          head_dim, *, causal=True, window=0) -> float:
        import jax.numpy as jnp
        from repro.kernels import ops
        total = 0.0
        for q_len, kv_len in zip(q_lens, kv_lens):
            s, t = self._round(int(q_len)), self._round(int(kv_len))
            key = ("prefill", s, t, n_heads, n_kv_heads, head_dim,
                   causal, window)
            if key not in self._cache:
                q = jnp.ones((1, s, n_heads, head_dim), jnp.float32)
                k = jnp.ones((1, t, n_kv_heads, head_dim), jnp.float32)
                bq = bk = min(128, max(16, s))
                self._cache[key] = self._time(
                    lambda q, k: ops.flash_attention(
                        q, k, k, causal=causal, window=window, bq=bq, bk=bk),
                    q, k)
            total += self._cache[key]
        return total

    def attention_decode(self, context_lens, n_heads, n_kv_heads, head_dim,
                         *, window=0) -> float:
        import jax.numpy as jnp
        from repro.kernels import ops
        # one fused decode kernel over the whole batch: pad contexts to the
        # bucketed max and pass true lengths, exactly how the engine runs it
        b = len(context_lens)
        t = self._round(max(int(x) for x in context_lens))
        key = ("decode", b, t, n_heads, n_kv_heads, head_dim, window)
        if key not in self._cache:
            q = jnp.ones((b, n_heads, head_dim), jnp.float32)
            k = jnp.ones((b, t, n_kv_heads, head_dim), jnp.float32)
            lengths = jnp.asarray([min(int(x), t) for x in context_lens],
                                  jnp.int32)
            self._cache[key] = self._time(
                lambda q, k, lengths: ops.decode_attention(
                    q, k, k, lengths, bk=min(256, t)),
                q, k, lengths)
        return self._cache[key]

    def grouped_gemm(self, tokens_per_expert, d_in, d_out) -> float:
        import jax.numpy as jnp
        from repro.kernels import ops
        e = len(tokens_per_expert)
        cap = self._round(max(1, max(int(x) for x in tokens_per_expert)))
        key = ("grouped", e, cap, d_in, d_out)
        if key not in self._cache:
            x = jnp.ones((e, cap, d_in), jnp.float32)
            w = jnp.ones((e, d_in, d_out), jnp.float32)
            sizes = jnp.asarray([min(int(t), cap)
                                 for t in tokens_per_expert], jnp.int32)
            bm = min(128, max(16, cap))
            self._cache[key] = self._time(
                lambda x, w, sizes: ops.grouped_gemm(
                    x, w, sizes, bm=bm, bn=min(128, d_out),
                    bkk=min(512, d_in)),
                x, w, sizes)
        return self._cache[key]


class HLOCostOracle(Oracle):
    """HLO-cost proxy: lower the jnp reference ops with ``jax.jit``, parse
    the compiled module with ``launch/hlo_cost.analyze``, and price the
    flop/byte totals on the target hardware's roofline.  Compilation is
    the expensive part, so shapes are bucketed and analyses cached.
    """

    name = "hlo"

    def __init__(self, hw: HardwareSpec, bucket: float = 1.25):
        self.hw = hw
        self.bucket = bucket
        self._cache: Dict[tuple, float] = {}
        import jax
        self._jax = jax

    def limits(self) -> Dict[str, int]:
        return {"max_len": 2048, "max_batch": 16, "max_tokens": 4096}

    def _round(self, n: int) -> int:
        if n <= 16:
            return 16
        b = 16
        while b < n:
            b = max(b + 16, int(b * self.bucket) // 16 * 16)
        return b

    def _price(self, fn: Callable, *args) -> float:
        from repro.launch import hlo_cost
        text = self._jax.jit(fn).lower(*args).compile().as_text()
        costs = hlo_cost.analyze(text)
        return max(costs["flops"] / self.hw.peak_flops,
                   costs["bytes"] / self.hw.hbm_bw) + self.hw.op_overhead

    def attention_prefill(self, q_lens, kv_lens, n_heads, n_kv_heads,
                          head_dim, *, causal=True, window=0) -> float:
        import jax.numpy as jnp
        from repro.kernels import ref
        total = 0.0
        for q_len, kv_len in zip(q_lens, kv_lens):
            s, t = self._round(int(q_len)), self._round(int(kv_len))
            key = ("prefill", s, t, n_heads, n_kv_heads, head_dim,
                   causal, window)
            if key not in self._cache:
                q = self._jax.ShapeDtypeStruct((1, s, n_heads, head_dim),
                                               jnp.float32)
                k = self._jax.ShapeDtypeStruct((1, t, n_kv_heads, head_dim),
                                               jnp.float32)
                self._cache[key] = self._price(
                    lambda q, k, v: ref.flash_attention_ref(
                        q, k, v, causal=causal, window=window), q, k, k)
            total += self._cache[key]
        return total

    def attention_decode(self, context_lens, n_heads, n_kv_heads, head_dim,
                         *, window=0) -> float:
        import jax.numpy as jnp
        from repro.kernels import ref
        b = self._round(len(context_lens))
        t = self._round(max(int(x) for x in context_lens))
        key = ("decode", b, t, n_heads, n_kv_heads, head_dim, window)
        if key not in self._cache:
            q = self._jax.ShapeDtypeStruct((b, n_heads, head_dim),
                                           jnp.float32)
            k = self._jax.ShapeDtypeStruct((b, t, n_kv_heads, head_dim),
                                           jnp.float32)
            lengths = self._jax.ShapeDtypeStruct((b,), jnp.int32)
            self._cache[key] = self._price(ref.decode_attention_ref,
                                           q, k, k, lengths)
        return self._cache[key]

    def grouped_gemm(self, tokens_per_expert, d_in, d_out) -> float:
        import jax.numpy as jnp
        from repro.kernels import ref
        e = len(tokens_per_expert)
        cap = self._round(max(1, max(int(x) for x in tokens_per_expert)))
        key = ("grouped", e, cap, d_in, d_out)
        if key not in self._cache:
            x = self._jax.ShapeDtypeStruct((e, cap, d_in), jnp.float32)
            w = self._jax.ShapeDtypeStruct((e, d_in, d_out), jnp.float32)
            sizes = self._jax.ShapeDtypeStruct((e,), jnp.int32)
            self._cache[key] = self._price(ref.grouped_gemm_ref, x, w, sizes)
        return self._cache[key]


ORACLES: Dict[str, type] = {
    "kernelsim": KernelSimOracle,
    "pallas": PallasOracle,
    "hlo": HLOCostOracle,
}


def default_oracle_name() -> str:
    """Real kernels on an accelerator, the virtual-kernel sim elsewhere."""
    try:
        import jax
        if jax.default_backend() in ("tpu", "gpu"):
            return "pallas"
    except Exception:
        pass
    return "kernelsim"


def resolve_oracle(spec, hw: HardwareSpec) -> Oracle:
    """Oracle instance / name / {"name": ..., **kwargs} / None ("auto")."""
    if isinstance(spec, Oracle):
        return spec
    if spec is None or spec == "auto":
        spec = default_oracle_name()
    if isinstance(spec, str):
        name, kwargs = spec, {}
    else:
        kwargs = dict(spec)
        name = kwargs.pop("name", None)
    if name not in ORACLES:
        raise KeyError(f"unknown oracle {name!r}; available: "
                       f"{sorted(ORACLES)} (or 'auto')")
    return ORACLES[name](hw, **kwargs)
