"""Event tracing: in-memory ring + Chrome trace-event export."""
from __future__ import annotations

import json
from collections import deque
from typing import Deque, List, Optional

from repro.core.events import EV, Event


class EventTrace:
    def __init__(self, capacity: int = 200_000):
        self.events: Deque[tuple] = deque(maxlen=capacity)

    def __call__(self, ev: Event) -> None:
        d = ev.data
        if not isinstance(d, dict):      # timeline payloads are raw objects
            d = {} if d is None else {"data": d}
        self.events.append((ev.time, ev.kind.value, dict(d)))

    def filter(self, kind: EV) -> List[tuple]:
        return [e for e in self.events if e[1] == kind.value]

    def to_chrome_trace(self, path: str) -> None:
        """Duration events per replica (BATCH_DONE carries dur) + instants."""
        out = []
        for t, kind, data in self.events:
            if kind == EV.BATCH_DONE.value and "dur" in data:
                out.append({
                    "name": f"batch p{data.get('n_prefill', 0)}"
                            f"/d{data.get('n_decode', 0)}",
                    "ph": "X", "pid": 0, "tid": data.get("replica", "?"),
                    "ts": (t - data["dur"]) * 1e6, "dur": data["dur"] * 1e6,
                })
            else:
                out.append({"name": kind, "ph": "i", "pid": 0, "tid": "events",
                            "ts": t * 1e6, "s": "g"})
        with open(path, "w") as f:
            json.dump({"traceEvents": out}, f)
