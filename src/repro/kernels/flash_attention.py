"""FlashAttention forward Pallas kernel (TPU target, GQA, causal/windowed).

Grid: (B*H, nq, nk) — the innermost kv dimension is sequential on TPU, so
the online-softmax running state (m, l, acc) lives in VMEM scratch and is
carried across kv steps.  BlockSpecs stream one (bq, hd) query tile and one
(bk, hd) KV tile into VMEM per step; GQA maps query head h to KV head
h // (H // K) in the index maps, so KV tiles are fetched once per group.

VMEM working set per step: bq*hd (q) + 2*bk*hd (kv) + bq*hd f32 (acc)
+ O(bq) stats — with bq=bk=128, hd<=256 this is < 0.5 MB, comfortably
inside the ~16 MB v5e VMEM even with double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e38


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale: float, causal: bool, window: int, bq: int, bk: int,
                nk: int, seq_q: int, seq_k: int):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    need = jnp.bool_(True)
    if causal:
        # skip fully-masked kv blocks (upper triangle)
        need = jnp.logical_and(need, (ik * bk) <= (iq * bq + bq - 1))
    if window:
        # skip kv blocks entirely left of the sliding window
        need = jnp.logical_and(
            need, (iq * bq) - ((ik + 1) * bk - 1) < window)

    @pl.when(need)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = jnp.logical_and(q_pos < seq_q, k_pos < seq_k)
        if causal:
            ok = jnp.logical_and(ok, q_pos >= k_pos)
        if window:
            ok = jnp.logical_and(ok, (q_pos - k_pos) < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        # rows with no valid key yet keep m == NEG_INF; zero their p
        p = jnp.where((m_new == NEG_INF)[:, None], 0.0, p)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret: bool = True) -> jax.Array:
    """q (B,S,H,hd); k/v (B,T,K,hd).  Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = hd ** -0.5

    bq = min(bq, max(S, 8))
    bk = min(bk, max(T, 8))
    Sp = math.ceil(S / bq) * bq
    Tp = math.ceil(T / bk) * bk
    nq, nk = Sp // bq, Tp // bk

    qr = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kr = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vr = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qr = qr.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    kr = kr.transpose(0, 2, 1, 3).reshape(B * K, Tp, hd)
    vr = vr.transpose(0, 2, 1, 3).reshape(B * K, Tp, hd)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, seq_q=S, seq_k=T)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((None, bk, hd), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((None, bk, hd), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, H, Sp, hd).transpose(0, 2, 1, 3)
    return out[:, :S]
