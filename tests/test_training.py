"""Training substrate: loss drop, checkpoint round-trip, resume, data."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import run
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticLM


def test_loss_drops_over_training(tmp_path):
    out = run("yi-9b", smoke=True, steps=30, seq_len=64, global_batch=4,
              lr=2e-3, log_every=100)
    assert out["last_loss"] < out["first_loss"] - 0.3


def test_checkpoint_roundtrip_and_resume(tmp_path):
    d = str(tmp_path / "ck")
    out1 = run("qwen3-8b", smoke=True, steps=10, seq_len=32, global_batch=2,
               ckpt_dir=d, ckpt_every=10, log_every=100, seed=7)
    assert ckpt.latest_step(d) == 10
    # a resumed run continues from step 10 deterministically: the combined
    # trajectory must equal a single 20-step run (same seed/data function)
    out2 = run("qwen3-8b", smoke=True, steps=10, seq_len=32, global_batch=2,
               ckpt_dir=d, ckpt_every=0, resume=True, log_every=100, seed=7)
    out_full = run("qwen3-8b", smoke=True, steps=20, seq_len=32,
                   global_batch=2, log_every=100, seed=7)
    np.testing.assert_allclose(out2["last_loss"], out_full["last_loss"],
                               rtol=2e-4, atol=2e-4)


def test_checkpoint_atomicity_prunes_tmp(tmp_path):
    d = tmp_path / "ck2"
    p = {"w": jnp.ones((4, 4))}
    ckpt.save(str(d), params=p, step=1)
    # a stale tmp dir from a "crashed" writer is pruned on the next save
    stale = d / ".tmp_step_00000009_999"
    stale.mkdir()
    ckpt.save(str(d), params=p, step=2)
    assert not stale.exists()
    assert ckpt.latest_step(str(d)) == 2


def test_data_determinism_and_rank_disjointness():
    cfg = DataConfig(vocab_size=256, seq_len=16, global_batch=8, seed=3)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    x1 = a.batch(5, dp_rank=0, dp_size=2)
    x2 = b.batch(5, dp_rank=0, dp_size=2)
    np.testing.assert_array_equal(x1["tokens"], x2["tokens"])
    y = a.batch(5, dp_rank=1, dp_size=2)
    assert not np.array_equal(x1["tokens"], y["tokens"])
    # labels are next-token shifted
    full = a.batch(9)
    np.testing.assert_array_equal(full["tokens"][:, 1:], full["labels"][:, :-1])


def test_zero1_extends_moment_specs():
    from repro.models.common import PD
    from repro.training.optimizer import _zero1_pd
    pd = PD((64, 32), ("embed", "mlp"))
    z = _zero1_pd(pd, 16)
    assert z.axes == ("zero", "mlp")
    pd2 = PD((10,), (None,))          # not divisible -> unchanged
    assert _zero1_pd(pd2, 16).axes == (None,)
