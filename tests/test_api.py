"""Declarative experiment API: SimSpec round-trip, run/sweep, validation."""
import json
import os

import pytest

from repro.api import (
    FaultSpec, ModelRef, PolicySpec, Report, SimSpec, SLOSpec, SpecError,
    TopologySpec, WorkloadSpec, best_under_slo, expand, pareto, run, sweep,
)
from repro.api.cli import main as cli_main
from repro.core import A800_SXM4_80G, ParallelismConfig, build_colocated
from repro.core.policies.batching import (
    BATCHING, ChunkedPrefill, resolve_batching,
)
from repro.core.policies.memory import MEMORY, resolve_memory
from repro.core.policies.scheduling import SCHEDULERS, SJF, resolve_scheduler
from repro.workload.generator import WorkloadConfig, generate, load_trace


def small_spec(**kw):
    base = dict(
        model=ModelRef("qwen2-7b"),
        topology=TopologySpec(preset="colocated", n_replicas=2),
        workload=WorkloadSpec(n_requests=30, rate=20.0),
        seed=0)
    base.update(kw)
    return SimSpec(**base)


# ------------------------------------------------------------ round trip --
def test_yaml_json_round_trip_equality():
    spec = SimSpec(
        model=ModelRef("mixtral-8x7b"),
        topology=TopologySpec(preset="af", m=2, attn_tp=2, ffn_ep=8,
                              remote_expert_ranks=[6, 7],
                              expert_cluster_hw="H100-SXM",
                              expert_link_bw=25e9,
                              expert_link_latency=5e-6),
        workload=WorkloadSpec(n_requests=50, arrival="burst",
                              burst_size=10, burst_period=0.5),
        policy=PolicySpec(router={"name": "zipf", "alpha": 1.1},
                          scheduler="sjf"),
        slo=SLOSpec(ttft_s=1.0, tpot_s=0.05),
        faults=[FaultSpec(kind="straggler", cluster="decode",
                          replica=0, slowdown=2.0)],
        seed=7, name="rt")
    assert SimSpec.from_yaml(spec.to_yaml()) == spec
    assert SimSpec.from_json(spec.to_json()) == spec
    assert SimSpec.from_dict(spec.to_dict()) == spec
    # hash is stable across round trips
    assert SimSpec.from_yaml(spec.to_yaml()).spec_hash() == spec.spec_hash()


def test_inline_topology_round_trip(tmp_path):
    spec = SimSpec(topology=TopologySpec(
        preset=None,
        clusters=[{"name": "pre", "role": "prefill", "n_replicas": 2},
                  {"name": "dec", "role": "decode",
                   "hardware": "H100-SXM"}],
        links=[{"src": "pre", "dst": "dec", "bandwidth": 5e10}]))
    p = tmp_path / "spec.yaml"
    spec.save(str(p))
    assert SimSpec.load(str(p)) == spec
    pj = tmp_path / "spec.json"
    spec.save(str(pj))
    assert SimSpec.load(str(pj)) == spec


# ------------------------------------------------------------ validation --
def test_validation_unknown_model():
    with pytest.raises(SpecError, match="unknown model"):
        small_spec(model=ModelRef("gpt-17")).validate()


def test_validation_bad_link_endpoint():
    spec = small_spec(topology=TopologySpec(
        preset=None,
        clusters=[{"name": "a", "role": "colocated"}],
        links=[{"src": "a", "dst": "nowhere", "bandwidth": 1e9}]))
    with pytest.raises(SpecError, match="unknown cluster 'nowhere'"):
        spec.validate()


def test_validation_closed_loop_without_concurrency():
    spec = small_spec(workload=WorkloadSpec(arrival="closed"))
    with pytest.raises(SpecError, match="concurrency"):
        spec.validate()


def test_validation_unknown_names_and_fields():
    with pytest.raises(SpecError, match="unknown router"):
        small_spec(policy=PolicySpec(router="nope")).validate()
    with pytest.raises(SpecError, match="unknown batching"):
        small_spec(policy=PolicySpec(batching="nope")).validate()
    with pytest.raises(SpecError, match="unknown preset"):
        small_spec(topology=TopologySpec(preset="hybrid")).validate()
    with pytest.raises(SpecError, match="unknown field"):
        SimSpec.from_dict({"modle": {"name": "qwen2-7b"}})
    with pytest.raises(SpecError, match="unknown field"):
        SimSpec.from_dict({"workload": {"ratee": 4.0}})
    with pytest.raises(SpecError, match="unknown fault kind"):
        small_spec(faults=[FaultSpec(kind="meteor",
                                     cluster="colocated")]).validate()
    with pytest.raises(SpecError, match="unknown cluster"):
        small_spec(faults=[FaultSpec(cluster="decode")]).validate()


def test_set_path_through_none_fields_and_coercion():
    # dotted paths must create None-valued sub-specs (slo defaults to None)
    spec = small_spec().with_(**{"slo.ttft_s": 0.5})
    assert spec.slo.ttft_s == 0.5 and spec.slo.tpot_s == 0.1
    # scalar parents are an error, not silent data loss
    s = small_spec(policy=PolicySpec(batching="continuous"))
    with pytest.raises(SpecError, match="not a mapping"):
        s.with_(**{"policy.batching.chunk": 256})
    # YAML 1.1 exponent strings coerce everywhere, including `until`
    spec = SimSpec.from_dict({"until": "1.5e3",
                              "topology": {"transfer_bw": "2.5e10"}})
    assert spec.until == 1500.0
    assert spec.topology.transfer_bw == 2.5e10
    spec.validate()


def test_role_keyed_batching_rejects_unknown_keys():
    spec = small_spec(policy=PolicySpec(batching={"decod": "static"}))
    with pytest.raises(SpecError, match="unknown role/cluster"):
        spec.validate()
    ok = small_spec(topology=TopologySpec(preset="pd"),
                    policy=PolicySpec(batching={
                        "decode": {"name": "chunked_prefill", "chunk": 64}}))
    ok.validate()


def test_arrivals_single_source_of_truth():
    from repro.api.spec import ARRIVALS as api_arrivals
    from repro.workload.generator import ARRIVALS as gen_arrivals
    assert api_arrivals is gen_arrivals


def test_remote_ranks_validated_against_ep():
    spec = small_spec(topology=TopologySpec(
        preset="af", ffn_ep=4, remote_expert_ranks=[3, 9]))
    with pytest.raises(SpecError, match="out of range"):
        spec.validate()


# --------------------------------------------------------- run -> Report --
def test_run_deterministic_and_matches_legacy_builders():
    spec = small_spec()
    r1, r2 = run(spec), run(spec)
    assert r1.summary == r2.summary            # bit-identical
    assert r1.spec_hash == r2.spec_hash
    legacy = build_colocated(
        __import__("repro.configs", fromlist=["get_config"])
        .get_config("qwen2-7b"), A800_SXM4_80G, n_replicas=2,
        par=ParallelismConfig(tp=1), seed=0).run(
            generate(WorkloadConfig(n_requests=30, rate=20.0, seed=0)))
    # faithful wrapper: every legacy metric bit-identical (run() adds
    # observability keys — predictor cache stats — on top)
    assert {k: r1.summary[k] for k in legacy} == legacy
    assert r1.all_complete
    assert r1.conservation == {"complete": 30}
    assert r1.n_devices == 2
    assert r1.sim_events > 0 and r1.wall_clock_s > 0
    assert "e2e_p50_s" in r1.summary and "queue_p99_s" in r1.summary


def test_report_serializes():
    rep = run(small_spec(name="ser"))
    d = json.loads(rep.to_json())
    rep2 = Report.from_dict(d)
    assert rep2.summary == rep.summary
    assert rep2.name == "ser"
    assert rep2.clusters["colocated"]["n_replicas"] == 2


def test_af_report_carries_ep_fields():
    spec = SimSpec(
        model=ModelRef("mixtral-8x7b"),
        topology=TopologySpec(preset="af", attn_tp=2, ffn_ep=8,
                              remote_expert_ranks=[7],
                              expert_link_bw=25e9),
        policy=PolicySpec(router="zipf"),
        workload=WorkloadSpec(n_requests=8, rate=20.0), seed=1)
    rep = run(spec)
    af = rep.clusters["decode"]["af"]
    assert af["decode_steps"] > 0
    assert af["ep_straggler_excess_s"] > 0
    assert af["cross_cluster_bytes"] > 0


def test_faults_via_spec():
    spec = small_spec(faults=[
        FaultSpec(kind="failure", cluster="colocated", replica=0,
                  at=0.2, downtime=1.0),
        FaultSpec(kind="straggler", cluster="colocated", replica=1,
                  slowdown=2.0)])
    rep = run(spec)
    assert rep.all_complete
    healthy = run(small_spec())
    assert rep["duration_s"] >= healthy["duration_s"]


# ---------------------------------------------------------------- sweeps --
def test_expand_grid_and_zip():
    base = small_spec()
    pts = expand(base, {"topology.tp": [1, 2], "workload.rate": [5, 10]})
    assert len(pts) == 4
    assert [p for _, p in pts] == [
        {"topology.tp": 1, "workload.rate": 5},
        {"topology.tp": 1, "workload.rate": 10},
        {"topology.tp": 2, "workload.rate": 5},
        {"topology.tp": 2, "workload.rate": 10}]
    assert pts[2][0].topology.tp == 2 and pts[2][0].workload.rate == 5
    zipped = expand(base, {"topology.tp": [1, 2],
                           "workload.rate": [5, 10]}, mode="zip")
    assert [p for _, p in zipped] == [
        {"topology.tp": 1, "workload.rate": 5},
        {"topology.tp": 2, "workload.rate": 10}]
    with pytest.raises(SpecError, match="equal-length"):
        expand(base, {"topology.tp": [1, 2],
                      "workload.rate": [5]}, mode="zip")
    # shorthand axis names resolve into sections
    assert expand(base, {"tp": [4]})[0][0].topology.tp == 4
    with pytest.raises(SpecError, match="dotted path"):
        expand(base, {"warp": [1]})


def test_sweep_parallel_matches_serial_and_streams(tmp_path):
    base = small_spec(workload=WorkloadSpec(n_requests=20, rate=20.0))
    axes = {"topology.tp": [1, 2], "seed": [0, 1]}
    jsonl = str(tmp_path / "sweep.jsonl")
    serial = sweep(base, axes)
    par = sweep(base, axes, jobs=2, jsonl=jsonl)
    assert [r.summary for r in serial] == [r.summary for r in par]
    assert [r.point for r in serial] == [r.point for r in par]
    lines = [json.loads(l) for l in open(jsonl)]
    assert len(lines) == 4
    assert {json.dumps(l["point"], sort_keys=True) for l in lines} == \
        {json.dumps(r.point, sort_keys=True) for r in par}


def test_sweep_per_point_seed_independence():
    base = small_spec()        # workload.seed=None -> SimSpec.seed
    reps = sweep(base, {}, seeds=[0, 1], jobs=2)
    assert reps[0].summary != reps[1].summary
    # each point is bit-identical to an isolated run with that seed
    assert reps[0].summary == run(base.with_(seed=0)).summary
    assert reps[1].summary == run(base.with_(seed=1)).summary


def test_pareto_and_best_under_slo():
    base = small_spec(workload=WorkloadSpec(n_requests=20, rate=20.0))
    reps = sweep(base, {"topology.tp": [1, 2]})
    front = pareto(reps)
    assert front and set(id(r) for r in front) <= set(id(r) for r in reps)
    best = best_under_slo(reps, ttft_p99=100.0, tpot_p99=100.0)
    assert best is not None
    assert best_under_slo(reps, ttft_p99=1e-12) is None


# ------------------------------------------------- workload satellites --
def test_burst_arrivals_ramp():
    reqs = generate(WorkloadConfig(n_requests=25, arrival="burst",
                                   burst_size=10, burst_period=2.0))
    arrivals = [r.arrival for r in reqs]
    assert arrivals[:10] == [0.0] * 10
    assert arrivals[10:20] == [2.0] * 10
    assert arrivals[20:] == [4.0] * 5


def test_closed_loop_respects_concurrency():
    spec = small_spec(
        topology=TopologySpec(preset="colocated", n_replicas=1),
        workload=WorkloadSpec(n_requests=24, arrival="closed",
                              concurrency=4))
    rep = run(spec)
    assert rep.all_complete
    # reconstruct in-flight count over time from the run? The report can't
    # see requests, so re-run via the builder to inspect them.
    from repro.api.run import build
    handle = build(spec)
    reqs = spec.workload.build_requests(spec.seed)
    handle.run(reqs, closed_concurrency=4)
    events = []
    for r in reqs:
        assert r.finish_time is not None
        events.append((r.arrival, 1))
        events.append((r.finish_time, -1))
    in_flight = peak = 0
    for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
        in_flight += delta
        peak = max(peak, in_flight)
    assert peak <= 4
    # later arrivals were injected on completions, not at t=0
    assert sum(1 for r in reqs if r.arrival == 0.0) == 4


def test_trace_replay_and_metrics_anchoring(tmp_path):
    path = tmp_path / "trace.jsonl"
    with open(path, "w") as f:
        for i in range(10):
            f.write(json.dumps({"arrival": 100.0 + 0.05 * i,
                                "prompt_len": 64,
                                "output_len": 8}) + "\n")
    reqs = load_trace(str(path))
    assert reqs[0].arrival == 0.0          # shifted to trace start
    spec = small_spec(workload=WorkloadSpec(trace=str(path),
                                            n_requests=10))
    rep = run(spec)
    assert rep.all_complete
    # duration measured from the first arrival, not t=0
    assert rep["duration_s"] < 10.0
    with pytest.raises(ValueError, match="bad trace record"):
        bad = tmp_path / "bad.jsonl"
        bad.write_text('{"prompt_len": 1}\n')
        load_trace(str(bad))


def test_metrics_start_anchored_to_first_arrival():
    # identical workload shifted by +50s must report identical duration
    spec_a = small_spec(workload=WorkloadSpec(n_requests=10, rate=10.0))
    rep_a = run(spec_a)
    from repro.api.run import build
    handle = build(spec_a)
    reqs = spec_a.workload.build_requests(0)
    for r in reqs:
        r.arrival += 50.0
    rep_b = handle.run(reqs)
    assert rep_b["duration_s"] == pytest.approx(rep_a["duration_s"],
                                                rel=1e-9)


# ------------------------------------------------------------ registries --
def test_policy_registries_resolve_uniformly():
    assert set(BATCHING) == {"continuous", "chunked_prefill", "static"}
    pol = resolve_batching({"name": "chunked_prefill", "chunk": 128})
    assert isinstance(pol, ChunkedPrefill) and pol.chunk == 128
    assert resolve_batching(pol) is pol
    with pytest.raises(KeyError, match="registered"):
        resolve_batching("nope")
    assert set(SCHEDULERS) == {"fcfs", "sjf", "priority"}
    assert isinstance(resolve_scheduler("sjf"), SJF)
    with pytest.raises(KeyError):
        resolve_scheduler("lifo")
    assert set(MEMORY) == {"paged", "prefix", "monolithic"}
    cls, kw = resolve_memory({"name": "paged", "block_tokens": 32})
    assert kw == {"block_tokens": 32}
    with pytest.raises(KeyError):
        resolve_memory("infinite")


def test_policy_spec_selects_scheduler_and_memory():
    spec = small_spec(policy=PolicySpec(
        scheduler="sjf", memory={"name": "paged", "block_tokens": 32},
        batching={"name": "static", "batch_size": 4}))
    from repro.api.run import build
    handle = build(spec)
    w = handle.clusters["colocated"].replicas[0]
    assert isinstance(w.queue_policy, SJF)
    assert w.memory.block_tokens == 32
    assert w.policy.name == "static"
    assert run(spec).all_complete


# ------------------------------------------------------------------- CLI --
def test_cli_run_and_sweep(tmp_path, capsys):
    spec_path = tmp_path / "s.yaml"
    small_spec(name="cli-test",
               workload=WorkloadSpec(n_requests=10, rate=10.0)
               ).save(str(spec_path))
    out = str(tmp_path / "artifacts")
    assert cli_main(["run", str(spec_path), "-o", out,
                     "--set", "workload.rate=20"]) == 0
    rep = json.load(open(os.path.join(out, "cli-test.report.json")))
    assert rep["summary"]["n_completed"] == 10
    assert rep["spec"]["workload"]["rate"] == 20
    assert cli_main(["sweep", str(spec_path), "--axis",
                     "topology.n_replicas=1,2", "--jobs", "2",
                     "-o", out]) == 0
    lines = [json.loads(l) for l in
             open(os.path.join(out, "cli-test.sweep.jsonl"))]
    assert len(lines) == 2
    assert cli_main(["list"]) == 0
    assert "models" in capsys.readouterr().out
    assert cli_main(["run", str(tmp_path / "missing.yaml")]) == 2


def test_cli_rejects_bad_spec(tmp_path, capsys):
    p = tmp_path / "bad.yaml"
    p.write_text("model:\n  name: not-a-model\n")
    assert cli_main(["run", str(p)]) == 2
    assert "unknown model" in capsys.readouterr().err
