"""Serving metrics: TTFT / TPOT / throughput / goodput / Pareto frontier."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Request


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


@dataclass
class MetricsCollector:
    completed: List[Request] = field(default_factory=list)
    token_times: List[float] = field(default_factory=list)
    start: float = 0.0
    end: float = 0.0

    def on_token(self, r: Request, replica, t: float) -> None:
        self.token_times.append(t)
        self.end = max(self.end, t)

    def on_complete(self, r: Request, replica) -> None:
        self.completed.append(r)
        self.end = max(self.end, r.finish_time or 0.0)

    # ------------------------------------------------------------- report --
    def report(self, *, n_devices: int = 1,
               slo_ttft: Optional[float] = None,
               slo_tpot: Optional[float] = None) -> Dict[str, float]:
        dur = max(self.end - self.start, 1e-9)
        ttfts = [r.ttft() for r in self.completed if r.ttft() is not None]
        tpots = [r.tpot() for r in self.completed if r.tpot() is not None]
        out_tokens = sum(r.generated for r in self.completed)
        rep = {
            "n_completed": len(self.completed),
            "duration_s": dur,
            "throughput_tok_s": out_tokens / dur,
            "throughput_tok_s_per_device": out_tokens / dur / max(n_devices, 1),
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else float("nan"),
            "ttft_p50_s": _pct(ttfts, 50), "ttft_p99_s": _pct(ttfts, 99),
            "tpot_mean_s": float(np.mean(tpots)) if tpots else float("nan"),
            "tpot_p50_s": _pct(tpots, 50), "tpot_p99_s": _pct(tpots, 99),
        }
        if slo_ttft is not None and slo_tpot is not None and self.completed:
            good = [r for r in self.completed
                    if (r.ttft() or 9e9) <= slo_ttft
                    and (r.tpot() or 9e9) <= slo_tpot]
            rep["goodput_tok_s"] = sum(r.generated for r in good) / dur
            rep["slo_attainment"] = len(good) / len(self.completed)
        return rep


def pareto_frontier(points: Sequence[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """(throughput, interactivity=1/tpot) maximization frontier."""
    pts = sorted(points, key=lambda p: (-p[0], -p[1]))
    front, best = [], -np.inf
    for x, y in pts:
        if y > best:
            front.append((x, y))
            best = y
    return front
