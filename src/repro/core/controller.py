"""GlobalController: the stateful orchestrator of inter-stage workflows.

Implements the paper's §3.3 PD-disaggregation workflow verbatim:
(1) prefill stage as producer — requests routed to the prefill cluster,
    PREFILL_COMPLETE transitions tracked, KV held in the prefill buffer;
(2) decode stage as consumer with finite KV memory — its ClusterScheduler
    signals MEMORY_AVAILABLE on evictions;
(3) the controller respects backpressure: it keeps a PREFILL_COMPLETE queue
    and initiates KV_CACHE_TRANSFER only when a decode replica has space.
Colocated mode degenerates to routing + tracking.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core.cluster import ClusterWorker, Hooks, ReplicaWorker
from repro.core.engine import SimEngine
from repro.core.events import EV
from repro.core.hardware import LinkSpec
from repro.core.metrics import MetricsCollector
from repro.core.policies.memory import KVTransferPlan
from repro.core.request import Request, RState


class GlobalController:
    def __init__(self, engine: SimEngine, *,
                 mode: str = "colocated",
                 clusters: Dict[str, ClusterWorker],
                 kv_bytes_per_token: float = 0.0,
                 transfer_bw: float = 25e9,
                 metrics: Optional[MetricsCollector] = None,
                 links: Optional[Dict[Tuple[str, str], LinkSpec]] = None,
                 entry: Optional[List[str]] = None,
                 kv_layers: int = 1,
                 transfer_overlap: float = 0.0,
                 fabric=None):
        self.engine = engine
        self.mode = mode
        self.clusters = clusters
        self.kv_bytes_per_token = kv_bytes_per_token
        self.transfer_bw = transfer_bw
        self.metrics = metrics or MetricsCollector()
        # inter-cluster link table (asymmetric: keyed on (src, dst)); a
        # missing entry falls back to the flat transfer_bw
        self.links = links or {}
        # entry cluster names for arrivals; None -> legacy mode-based lookup
        self.entry = entry
        # layer-wise streamed KV transfer: per-layer chunks pipeline behind
        # remaining prefill compute; overlap=0 keeps the legacy lump-sum
        # pricing bit-for-bit
        self.kv_layers = max(kv_layers, 1)
        self.transfer_overlap = transfer_overlap
        # shared-fabric contention model (core.fabric.Fabric); None keeps
        # the legacy isolated point-to-point transfer pricing
        self.fabric = fabric
        self.transfer_stats = {"transfers": 0, "bytes": 0.0,
                               "serial_s": 0.0, "exposed_s": 0.0}
        self.pending_transfer: List[Request] = []   # PREFILL_COMPLETE queue
        self.prefill_home: Dict[int, ReplicaWorker] = {}
        self.requests: Dict[int, Request] = {}
        self._transfers_in_flight = 0
        self._closed_queue: Deque[Request] = deque()  # closed-loop backlog
        # instance-ification hooks: a fleet control plane treats this
        # controller as ONE serving instance among many.  ``observer`` is
        # called on every request completion (drain tracking / fleet
        # metrics); ``completed_count`` backs the outstanding() load signal
        # global routers read.
        self.observer: Optional[Callable[[Request, ReplicaWorker], None]] = None
        self.completed_count = 0
        # observability recorder (repro.obs.Telemetry); None = fully off
        self.telemetry = None
        self.tel_instance = ""      # fleet instance label for span identity

    # ------------------------------------------------------------- wiring --
    def hooks(self) -> Hooks:
        return Hooks(
            prefill_complete=self.on_prefill_complete,
            token_generated=self.metrics.on_token,
            request_complete=self.on_request_complete,
            memory_available=self.on_memory_available,
            preempted=self.on_preempted,
        )

    # ------------------------------------------------------------ arrivals --
    def _submit_one(self, r: Request, at: float) -> None:
        r.arrival = at
        self.requests[r.rid] = r
        self.engine.at(at, EV.REQUEST_ARRIVAL,
                       lambda ev, r=r: self._arrive(r), rid=r.rid)

    def submit_all(self, requests: List[Request]) -> None:
        arr = [r.arrival for r in requests]
        if any(a > b for a, b in zip(arr, arr[1:])):
            for r in requests:            # unsorted: per-event heap path
                self._submit_one(r, r.arrival)
            return
        # sorted arrival streams (every open-loop generator) go through the
        # engine's bulk timeline: no heap traffic, no per-arrival closure,
        # and Event objects materialize lazily at dispatch.  Sequence
        # numbers are assigned here in request order, so tie-breaking is
        # bit-identical to the per-event path.
        for r in requests:
            self.requests[r.rid] = r
        self.engine.schedule_timeline(
            (r.arrival, EV.REQUEST_ARRIVAL, self._arrive_ev, r)
            for r in requests)

    def _arrive_ev(self, ev) -> None:
        self._arrive(ev.data)

    def submit_closed(self, requests: List[Request], concurrency: int) -> None:
        """Closed-loop injection: keep at most ``concurrency`` requests in
        flight; a new request arrives the moment a slot frees (its arrival
        timestamp is re-stamped to the completion time that freed it)."""
        if concurrency < 1:
            raise ValueError(f"closed-loop concurrency must be >= 1, "
                             f"got {concurrency}")
        self._closed_queue.extend(requests)
        for _ in range(min(concurrency, len(self._closed_queue))):
            self._submit_one(self._closed_queue.popleft(), at=self.engine.now)

    def _entry_clusters(self) -> List[ClusterWorker]:
        if self.entry:
            return [self.clusters[n] for n in self.entry]
        return [self.clusters["prefill" if self.mode == "pd" else "colocated"]]

    def _decode_clusters(self) -> List[ClusterWorker]:
        return [c for c in self.clusters.values() if c.role == "decode"]

    def _arrive(self, r: Request) -> None:
        # anchor the measurement window to the first actual arrival (a late
        # first request must not inflate the measured duration)
        if self.metrics.start is None:
            self.metrics.start = self.engine.now
        # least-loaded healthy replica across all entry clusters
        candidates = []
        for cluster in self._entry_clusters():
            try:
                candidates.append(cluster.route(r))
            except RuntimeError:
                continue
        if not candidates:
            raise RuntimeError("no healthy entry replicas")
        replica = min(candidates, key=lambda w: (w.load(), w.name))
        replica.enqueue_prefill(r)

    # -------------------------------------------------- PD stage handoffs --
    def on_prefill_complete(self, r: Request, replica: ReplicaWorker) -> None:
        if self.mode != "pd":
            return
        # KV stays in the prefill replica's buffer until transferred.
        self.prefill_home[r.rid] = replica
        self.pending_transfer.append(r)
        self._try_transfers()

    def on_memory_available(self, cluster: Optional[ClusterWorker],
                            replica: ReplicaWorker) -> None:
        if self.mode == "pd" and cluster is not None and cluster.role == "decode":
            self._try_transfers()

    def _transfer_time(self, src: Optional[str], dst: str,
                       nbytes: float) -> float:
        link = self.links.get((src, dst)) if src is not None else None
        if link is not None:
            return link.transfer_time(nbytes)
        return nbytes / self.transfer_bw if self.transfer_bw else 0.0

    def _transfer_exposed(self, src: Optional[str], dst: str,
                          nbytes: float, r: Request) -> Tuple[float, float]:
        """Price one KV transfer: (exposed_time, serial_time).

        With ``transfer_overlap > 0`` the KV streams layer-by-layer over
        the link during the producing prefill's residency window, so only
        the un-hidden tail is exposed; overlap=0 takes the legacy lump-sum
        path verbatim (identical event timing, serial == exposed).
        """
        if self.transfer_overlap <= 0.0 or self.kv_layers <= 1:
            dt = self._transfer_time(src, dst, nbytes)
            return dt, dt
        link = self.links.get((src, dst)) if src is not None else None
        bw = link.bandwidth if link is not None else self.transfer_bw
        lat = link.latency if link is not None else 0.0
        plan = KVTransferPlan(
            n_layers=self.kv_layers,
            bytes_per_layer=nbytes / self.kv_layers,
            bandwidth=bw, latency=lat, overlap=self.transfer_overlap)
        # the streaming window is the CURRENT prefill pass's compute span
        # only: first schedule -> prefill completion.  Neither a recompute-
        # restored request's earlier lifetime nor time spent backpressured
        # in pending_transfer can hide bytes — no decode target held memory
        # for the chunks to stream into during the wait.
        done = r.timestamps.get("prefill_complete", self.engine.now)
        start = r.prefill_started if r.prefill_started is not None else done
        return plan.exposed_time(done - start), plan.serial_time

    def _try_transfers(self) -> None:
        """Initiate KV transfers for as many queued requests as decode
        memory allows (system-level backpressure).  With multiple decode
        pools, the least-loaded pool with free memory wins; the transfer is
        priced on the (prefill cluster -> decode cluster) link when one is
        declared, else the flat transfer_bw."""
        if self.mode != "pd":
            return
        decode_pools = self._decode_clusters()
        remaining: List[Request] = []
        for r in self.pending_transfer:
            target, target_cluster = None, None
            best_load = None
            for pool in decode_pools:
                w = pool.replica_with_memory(r)
                if w is None:
                    continue
                l = w.load()
                if best_load is None or l < best_load:
                    target, target_cluster, best_load = w, pool, l
            if target is None:
                remaining.append(r)        # backpressured
                continue
            admitted = target.memory.admit(
                r.rid, r.context_len,
                max_tokens=r.prompt_len + r.output_len)
            assert admitted
            r.to(RState.KV_TRANSFER, self.engine.now)
            # everything the prefill pass (re)built crosses the link: the
            # prompt's KV, or the full restored context after a recompute
            # preemption (prefill_total == prompt_len for fresh requests)
            nbytes = self.kv_bytes_per_token * r.prefill_total
            src = self.prefill_home.get(r.rid)
            src_name = src.cluster.name if src is not None and src.cluster \
                else None
            dt, serial = self._transfer_exposed(
                src_name, target_cluster.name, nbytes, r)
            self.transfer_stats["transfers"] += 1
            self.transfer_stats["bytes"] += nbytes
            self.transfer_stats["serial_s"] += serial
            self._transfers_in_flight += 1
            if self.fabric is not None:
                # contention-priced path: the point-to-point time above is
                # only the uncontended floor (serial_s); actual completion
                # and exposed_s come from the fabric's processor-sharing
                # re-pricing
                link = self.links.get((src_name, target_cluster.name)) \
                    if src_name is not None else None
                cap = link.bandwidth if link is not None \
                    else (self.transfer_bw or None)
                lat = link.latency if link is not None else 0.0
                t0 = self.engine.now
                self.fabric.start_transfer(
                    src_name, target_cluster.name, nbytes, cap=cap,
                    latency=lat,
                    done=lambda r=r, tgt=target, t0=t0, serial=serial,
                    nb=nbytes: self._fabric_transfer_done(r, tgt, t0,
                                                          serial, nb))
            else:
                self.transfer_stats["exposed_s"] += dt
                if self.telemetry is not None:
                    now = self.engine.now
                    self.telemetry.span(
                        "kv_transfer", r.rid, now, now + dt,
                        replica=target.tel_name, bytes=nbytes,
                        exposed_s=dt, serial_s=serial,
                        hidden_s=max(serial - dt, 0.0))
                self.engine.after(
                    dt, EV.KV_TRANSFER_DONE,
                    lambda ev, r=r, tgt=target: self._transfer_done(r, tgt),
                    rid=r.rid, bytes=nbytes)
        self.pending_transfer = remaining

    def _fabric_transfer_done(self, r: Request, target: ReplicaWorker,
                              t0: float, serial: float = 0.0,
                              nbytes: float = 0.0) -> None:
        self.transfer_stats["exposed_s"] += self.engine.now - t0
        if self.telemetry is not None:
            # under contention the uncontended point-to-point time is the
            # floor (serial_s); the span's extent is actual occupancy
            self.telemetry.span(
                "kv_transfer", r.rid, t0, self.engine.now,
                replica=target.tel_name, bytes=nbytes,
                exposed_s=self.engine.now - t0, serial_s=serial,
                contended=True)
        self._transfer_done(r, target)

    def _transfer_done(self, r: Request, target: ReplicaWorker) -> None:
        self._transfers_in_flight -= 1
        src = self.prefill_home.pop(r.rid, None)
        if src is not None and src.memory is not None:
            src.memory.free(r.rid)
            src.kick()                      # prefill can admit more work
        target.start_decode(r)

    # ---------------------------------------------------------- preemption --
    def on_preempted(self, r: Request, replica: ReplicaWorker) -> None:
        """Recompute restore: the request re-enters prefill at the least
        loaded entry cluster (its KV is gone; swap restores stay local to
        the replica and never reach this hook)."""
        if self.telemetry is not None:
            self.telemetry.span("recompute_requeue", r.rid,
                                self.engine.now, self.engine.now,
                                replica=replica.tel_name)
        self._arrive(r)

    # ------------------------------------------------------------- endings --
    def on_request_complete(self, r: Request, replica: ReplicaWorker) -> None:
        self.metrics.on_complete(r, replica)
        self.completed_count += 1
        if self.telemetry is not None:
            self.telemetry.end_request(r, instance=self.tel_instance)
        if self.observer is not None:
            self.observer(r, replica)
        if self._closed_queue:      # closed loop: a slot just freed
            self._submit_one(self._closed_queue.popleft(), at=self.engine.now)

    # --------------------------------------------------- instance surface --
    def outstanding(self) -> int:
        """Requests submitted to this instance and not yet complete — the
        load signal global (fleet-level) routers balance on."""
        return len(self.requests) - self.completed_count

    def pool_depths(self) -> Dict[str, int]:
        """Per-role outstanding work (P:D pressure signal for rebalancing)."""
        depths: Dict[str, int] = {}
        for c in self.clusters.values():
            depths[c.role] = depths.get(c.role, 0) + c.queue_depth()
        return depths

    def prefix_probe(self, r: Request) -> int:
        """Best cached-prefix hit (tokens) any entry replica would give this
        request right now — the affinity signal for cache-aware routing."""
        best = 0
        for cluster in self._entry_clusters():
            for w in cluster.replicas:
                # inactive replicas' caches are unreachable for new work
                # (drained donor / standby pools) — never advertise them
                if w.failed or not w.active or w.memory is None:
                    continue
                best = max(best, w.memory.prefix_hit(r))
        return best

    # ------------------------------------------------------------ failures --
    def inject_failure(self, cluster_name: str, replica_idx: int,
                       at: float, downtime: float) -> None:
        cluster = self.clusters[cluster_name]
        replica = cluster.replicas[replica_idx]

        def do_fail(ev):
            lost = replica.fail(downtime)
            # re-route lost work to healthy replicas (restart from scratch:
            # conservative fault model — KV is gone)
            for r in lost:
                if r.state in (RState.QUEUED_PREFILL, RState.PREFILL_RUNNING):
                    r.state = RState.QUEUED_PREFILL
                    cluster.route(r).enqueue_prefill(r)
                elif r.state in (RState.DECODING, RState.QUEUED_DECODE,
                                 RState.PREEMPTED):
                    r.state = RState.QUEUED_PREFILL
                    r.prefill_progress = 0
                    r.generated = 0
                    r.prefill_len = None
                    r.restore_pending = False
                    r.prefill_started = None
                    self._arrive(r)
        self.engine.at(at, EV.REPLICA_FAILURE, do_fail,
                       cluster=cluster_name, replica=replica_idx)

    # ------------------------------------------------------------- invariant --
    def conservation_check(self) -> Dict[str, int]:
        """Every submitted request is exactly in one place (property test)."""
        states = {}
        for r in self.requests.values():
            states[r.state.value] = states.get(r.state.value, 0) + 1
        return states
