"""AF (Attention/FFN) disaggregation — MegaScale-Infer / Step-3 style.

One decode step is simulated as an *event dependency graph*: the global
batch is partitioned into m micro-batches; ATTN_COMPUTE(i,k) runs on the
attention cluster, A2F_TRANSFER(i,k) ships activations, the FFN stage runs
on the FFN cluster, F2A_TRANSFER(i,k) returns.  The event engine schedules
each node as soon as its dependencies are met, capturing the ping-pong
latency hiding: while A2F(i,k) is in flight the attention cluster computes
ATTN(i+1,k).  The step time is the timestamp of the final event — the
critical path.

Expert parallelism is first-class: an MoE FFN stage is not a scalar max()
but an explicit per-EP-rank sub-graph per micro-batch —

    gate -> EXPERT_DISPATCH(r) [all-to-all, per rank]
         -> EXPERT_RANK(r)     [heterogeneous GroupedGEMM per rank]
         -> barrier            [straggler: last rank gates the combine]
         -> EXPERT_COMBINE     [all-to-all + shared experts]

Ranks listed in ``remote_ranks`` host their expert shards on a *different
cluster*: their dispatch/combine legs traverse an inter-cluster LinkSpec
(lower bandwidth, extra latency) and their GroupedGEMM runs on that
cluster's operator models (heterogeneous hardware) — the cross-cluster
expert-routing regime.  Because dispatch and combine are collectives, the
EP group advances in lockstep: micro-batch i+1's experts start only after
micro-batch i's combine has completed on every rank.

The *resource model* of one step is selected by a
:class:`repro.core.pipeline.PipelineConfig` (see that module):
``af_overlap="none"`` keeps the legacy lanes (attention compute + FFN
lockstep, un-contended transfers), ``"serial"`` chains every task on one
resource (the no-latency-hiding baseline; step time = sum of durations),
and ``"two_batch"`` adds per-direction NIC lanes so transfers contend but
hide behind the other micro-batch's attention.  ``ep_overlap`` hides the
per-rank dispatch/combine legs behind GroupedGEMM compute at a configured
efficiency.  Every step also books its serial (no-overlap) makespan, so
``overlap_efficiency = 1 - makespan/serial_makespan`` and the exposed-comm
fractions are first-class observables.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig
from repro.core.engine import SimEngine
from repro.core.events import EV
from repro.core.hardware import HardwareSpec, LinkSpec, ParallelismConfig
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.pipeline import PipelineConfig
from repro.core.predictor import ExecutionPredictor, StepBreakdown
from repro.core.routing import RoutingModule, split_by_rank


@dataclass
class AFStepStats:
    makespan: float = 0.0
    attn_busy: float = 0.0
    ffn_busy: float = 0.0
    transfer_bytes: float = 0.0
    attn_bubble_frac: float = 0.0
    ffn_bubble_frac: float = 0.0
    events: int = 0
    # latency-hiding observability (pipelining layer)
    serial_makespan: float = 0.0      # sum of all task durations (no overlap)
    bubble_time: float = 0.0          # attention-lane idle within makespan
    overlap_efficiency: float = 0.0   # 1 - makespan / serial_makespan
    attn_exposed_comm: float = 0.0    # F2A time that stalled the attn lane
    ffn_exposed_comm: float = 0.0     # A2F time that stalled the FFN group
    ep_overlap_hidden: float = 0.0    # EP a2a time hidden behind GEMMs
    # expert-parallel observability (per-EP-rank event graph)
    ep_dispatch_time: float = 0.0     # sum over stages of the dispatch leg
    ep_combine_time: float = 0.0      # sum over stages of the combine leg
    ep_straggler_excess: float = 0.0  # sum of (last rank - mean rank) waits
    rank_busy: List[float] = field(default_factory=list)  # GEMM time per rank
    cross_cluster_bytes: float = 0.0  # dispatch+combine bytes on remote link


def simulate_af_decode_step(cfg: ModelConfig, hw: HardwareSpec,
                            ops: OperatorModelSet,
                            context_lens: Sequence[int], *,
                            m: int, attn_par: ParallelismConfig,
                            ffn_par: ParallelismConfig,
                            routing: Optional[RoutingModule] = None,
                            rng: Optional[np.random.Generator] = None,
                            remote_ranks: Sequence[int] = (),
                            remote_link: Optional[LinkSpec] = None,
                            remote_ops: Optional[OperatorModelSet] = None,
                            pipeline: Optional[PipelineConfig] = None,
                            ) -> AFStepStats:
    """Event-dependency-graph simulation of ONE decode step (one token)."""
    rng = rng or np.random.default_rng(0)
    eng = SimEngine()
    mode = pipeline.af_overlap if pipeline is not None else "none"
    eta = pipeline.ep_overlap if pipeline is not None else 0.0
    nic_lanes = pipeline.nic_lanes if pipeline is not None else 1
    L = cfg.num_layers
    micro = [list(c) for c in np.array_split(np.asarray(context_lens), m)]
    micro = [c for c in micro if len(c)]
    m_eff = len(micro)
    d = cfg.d_model
    ep = max(ffn_par.ep, ffn_par.tp, 1) if cfg.moe is not None else 1
    remote = frozenset(int(r) for r in remote_ranks)
    if remote and not all(0 <= r < ep for r in remote):
        raise ValueError(f"remote_ranks {sorted(remote)} out of range for "
                         f"ep={ep}")
    if remote and remote_link is None:
        raise ValueError("remote_ranks given without a remote_link — the "
                         "cross-cluster legs would not be modeled")
    r_ops = remote_ops or ops

    # ---- per-(microbatch, layer) task durations --------------------------
    def t_attn(lens: List[int], kind: str) -> float:
        tp = max(attn_par.tp, 1)
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        t = ops.gemm(len(lens), (H + 2 * K) * hd // tp, d)
        t += ops.attention_decode(lens, H // tp, max(K // tp, 1), hd,
                                  window=window)
        t += ops.gemm(len(lens), d, H * hd // tp)
        t += ops.all_reduce(2.0 * len(lens) * d, tp)
        return t

    def t_ffn_dense(n_tok: int) -> float:
        n_mats = 3 if cfg.gated_mlp else 2
        tp = max(ffn_par.tp, 1)
        return (n_mats * ops.gemm(n_tok, cfg.d_ff // tp, d)
                + ops.all_reduce(2.0 * n_tok * d, tp))

    # A2F/F2A is MegaScale's M2N fan: attention ranks to FFN ranks (EP
    # group for MoE, TP group for dense).  The flat model prices it exactly
    # as p2p; FabricOps spreads the payload over the narrow side's NICs.
    n_attn = max(attn_par.devices, 1)
    n_ffn = max(ep, ffn_par.devices, 1)

    def t_xfer(n_tok: int) -> float:
        return ops.m2n(2.0 * n_tok * d, n_attn, n_ffn)

    attn_kinds = [k for k in cfg.pattern]
    stats = AFStepStats()
    stats.rank_busy = [0.0] * ep

    # ---- resources & dependency-driven scheduling -------------------------
    # "none":      attention lane + FFN lockstep lane; transfers free.
    # "serial":    ONE chain shared by everything (no-latency-hiding
    #              baseline — makespan == sum of task durations).
    # "two_batch": attention lane + FFN lane + per-direction NIC lanes
    #              (transfers contend but overlap compute — ping-pong).
    if mode == "serial":
        chain = [0.0]
        attn_free = ffn_free = chain
    else:
        attn_free = [0.0]    # attention cluster: single pipeline
        ffn_free = [0.0]     # FFN/EP group: lockstep (collectives barrier it)
    a2f_nic = [0.0] * nic_lanes
    f2a_nic = [0.0] * nic_lanes
    done_f2a = {i: 0.0 for i in range(m_eff)}  # F2A(i, k-1) completion
    f2a_dur = {i: 0.0 for i in range(m_eff)}   # its transfer duration

    def xfer_start(lanes: List[float], dur: float) -> float:
        """Transfer start time under the mode's NIC resource model."""
        if mode == "serial":
            start = max(eng.now, attn_free[0])   # the one shared chain
            attn_free[0] = start + dur
            return start
        if mode == "two_batch":
            j = min(range(len(lanes)), key=lambda n: lanes[n])
            start = max(eng.now, lanes[j])
            lanes[j] = start + dur
            return start
        return eng.now                           # legacy: un-contended NIC

    def schedule_attn(i: int, k: int, ev=None):
        kind = attn_kinds[k]
        if kind not in (ATTN_GLOBAL, ATTN_LOCAL):
            # recurrent block: runs on the attention cluster too
            dur = ops.gemm(len(micro[i]), d, d) * 3
        else:
            dur = t_attn(micro[i], kind)
        if k > 0 and mode != "serial":
            # F2A return time that the attention lane could not hide
            stats.attn_exposed_comm += max(
                0.0, min(done_f2a[i] - attn_free[0], f2a_dur[i]))
        start = max(eng.now, attn_free[0], done_f2a[i])
        attn_free[0] = start + dur
        stats.attn_busy += dur
        stats.serial_makespan += dur
        eng.at(start + dur, EV.ATTN_COMPUTE_DONE,
               lambda ev: schedule_a2f(i, k), i=i, k=k)

    def schedule_a2f(i: int, k: int):
        dur = t_xfer(len(micro[i]))
        stats.transfer_bytes += 2.0 * len(micro[i]) * d
        stats.serial_makespan += dur
        if mode == "serial":
            stats.ffn_exposed_comm += dur   # nothing hides on one chain
        start = xfer_start(a2f_nic, dur)
        eng.at(start + dur, EV.A2F_TRANSFER_DONE,
               lambda ev: schedule_ffn(i, k, dur), i=i, k=k)

    def schedule_ffn(i: int, k: int, xfer: float = 0.0):
        if mode != "serial":
            # A2F delivery time that stalled the (idle) FFN group
            stats.ffn_exposed_comm += max(
                0.0, min(eng.now - ffn_free[0], xfer))
        if cfg.moe is None:
            dur = t_ffn_dense(len(micro[i]))
            start = max(eng.now, ffn_free[0])
            ffn_free[0] = start + dur
            stats.ffn_busy += dur
            stats.serial_makespan += dur
            eng.at(start + dur, EV.FFN_COMPUTE_DONE,
                   lambda ev: schedule_f2a(i, k), i=i, k=k)
        else:
            schedule_experts(i, k)

    # ---- the per-EP-rank expert sub-graph ---------------------------------
    moe = cfg.moe

    def schedule_experts(i: int, k: int):
        n_tok = len(micro[i])
        n_mats = 3 if cfg.gated_mlp else 2
        t0 = max(eng.now, ffn_free[0])
        t_gate = ops.gemm(n_tok, moe.num_experts, d)
        counts = (routing.assign(n_tok, moe.num_experts, moe.top_k, rng)
                  if routing is not None else
                  np.full(moe.num_experts,
                          n_tok * moe.top_k // moe.num_experts))
        per_rank = split_by_rank(np.asarray(counts), ep)
        a2a_base = ops.all_to_all(2.0 * n_tok * moe.top_k * d / ep, ep)

        # per-rank leg time (one dispatch or combine collective into/out of
        # rank r) and the bytes that cross the inter-cluster link doing it
        legs: List[float] = []
        for r in range(ep):
            if r not in remote or remote_link is None:
                legs.append(a2a_base)
            else:
                nbytes = 2.0 * float(np.sum(per_rank[r])) * d
                # dispatch + combine each traverse the link once
                stats.cross_cluster_bytes += 2.0 * nbytes
                legs.append(a2a_base + remote_link.transfer_time(nbytes))

        # dispatch and combine are collectives: the group advances in
        # lockstep, so the whole stage timeline is fixed once the dispatch
        # starts — compute it, reserve the group through the combine, and
        # emit the per-rank events at their true timestamps.  With
        # ep_overlap=eta the a2a legs hide behind GroupedGEMM compute
        # (chunked dispatch): comm+compute pairs cost
        # (1-eta)*(comm+compute) + eta*max(comm, compute).
        finish: List[float] = []
        serial_finish = 0.0
        for r in range(ep):
            rops = r_ops if r in remote else ops
            dur = n_mats * rops.grouped_gemm(list(per_rank[r]), d,
                                             moe.expert_d_ff)
            stats.rank_busy[r] += dur
            serial_finish = max(serial_finish, t_gate + legs[r] + dur)
            hidden = eta * min(legs[r], dur)
            stats.ep_overlap_hidden += hidden
            t_ready = t0 + t_gate + (legs[r] - hidden)
            finish.append(t_ready + dur)
            eng.at(t_ready, EV.EXPERT_DISPATCH_DONE, None, i=i, k=k, r=r)
            eng.at(t_ready + dur, EV.EXPERT_RANK_DONE, None, i=i, k=k, r=r)
        barrier = max(finish)
        stats.ep_straggler_excess += barrier - sum(finish) / len(finish)
        stats.ep_dispatch_time += max(legs)
        t_comb = max(legs)
        t_shared = 0.0
        if moe.num_shared_experts:
            t_shared = n_mats * ops.gemm(
                n_tok, moe.expert_d_ff * moe.num_shared_experts, d)
        if eta > 0.0:
            # combine a2a overlaps the shared-expert GEMM tail at eta
            tail = ((1.0 - eta) * (t_comb + t_shared)
                    + eta * max(t_comb, t_shared))
            stats.ep_overlap_hidden += (t_comb + t_shared) - tail
        else:
            tail = t_comb + t_shared
        end = barrier + tail
        # combine leg + the serial shared-expert tail (dispatch_time covers
        # only the inbound collective, so the two fields stay distinct)
        stats.ep_combine_time += t_comb + t_shared
        # the no-overlap baseline runs EP ranks in parallel but overlaps
        # nothing else: gate + slowest (dispatch + GEMM) + combine + shared
        stats.serial_makespan += serial_finish + t_comb + t_shared
        ffn_free[0] = end
        stats.ffn_busy += end - t0
        eng.at(end, EV.EXPERT_COMBINE_DONE,
               lambda ev: schedule_f2a(i, k), i=i, k=k)

    def schedule_f2a(i: int, k: int):
        dur = t_xfer(len(micro[i]))
        stats.transfer_bytes += 2.0 * len(micro[i]) * d
        stats.serial_makespan += dur
        if mode == "serial":
            stats.attn_exposed_comm += dur
        start = xfer_start(f2a_nic, dur)

        def done(ev):
            done_f2a[i] = eng.now
            f2a_dur[i] = dur
            if k + 1 < L:
                schedule_attn(i, k + 1)
        eng.at(start + dur, EV.F2A_TRANSFER_DONE, done, i=i, k=k)

    for i in range(m_eff):
        schedule_attn(i, 0)
    eng.run()

    stats.makespan = eng.now
    stats.events = eng.processed
    if stats.makespan > 0:
        stats.attn_bubble_frac = 1.0 - stats.attn_busy / stats.makespan
        stats.ffn_bubble_frac = 1.0 - stats.ffn_busy / stats.makespan
    stats.bubble_time = max(stats.makespan - stats.attn_busy, 0.0)
    if stats.serial_makespan > 0:
        stats.overlap_efficiency = max(
            1.0 - stats.makespan / stats.serial_makespan, 0.0)
    return stats


class AFPipelinePredictor(ExecutionPredictor):
    """ExecutionPredictor whose decode step runs the AF event graph."""

    def __init__(self, *args, m: int = 2,
                 attn_par: Optional[ParallelismConfig] = None,
                 ffn_par: Optional[ParallelismConfig] = None,
                 remote_ranks: Sequence[int] = (),
                 remote_link: Optional[LinkSpec] = None,
                 remote_ops: Optional[OperatorModelSet] = None,
                 pipeline: Optional[PipelineConfig] = None, **kw):
        super().__init__(*args, **kw)
        self.m = m
        self.attn_par = attn_par or self.par
        self.ffn_par = ffn_par or self.par
        self.remote_ranks = tuple(remote_ranks)
        self.remote_link = remote_link
        self.remote_ops = remote_ops
        self.pipeline = pipeline
        self.last_stats: Optional[AFStepStats] = None
        # run-level EP observability totals (cache hits replay the cached
        # step's stats, so totals stay consistent with simulated time)
        self.af_totals = {
            "decode_steps": 0, "makespan_s": 0.0, "ep_dispatch_time_s": 0.0,
            "ep_combine_time_s": 0.0, "ep_straggler_excess_s": 0.0,
            "cross_cluster_bytes": 0.0, "transfer_bytes": 0.0,
            # latency-hiding observability (pipelining layer)
            "serial_makespan_s": 0.0, "bubble_time_s": 0.0,
            "attn_exposed_comm_s": 0.0, "ffn_exposed_comm_s": 0.0,
            "ep_overlap_hidden_s": 0.0,
        }

    def _accumulate(self, stats: AFStepStats) -> None:
        t = self.af_totals
        t["decode_steps"] += 1
        t["makespan_s"] += float(stats.makespan)
        t["ep_dispatch_time_s"] += float(stats.ep_dispatch_time)
        t["ep_combine_time_s"] += float(stats.ep_combine_time)
        t["ep_straggler_excess_s"] += float(stats.ep_straggler_excess)
        t["cross_cluster_bytes"] += float(stats.cross_cluster_bytes)
        t["transfer_bytes"] += float(stats.transfer_bytes)
        t["serial_makespan_s"] += float(stats.serial_makespan)
        t["bubble_time_s"] += float(stats.bubble_time)
        t["attn_exposed_comm_s"] += float(stats.attn_exposed_comm)
        t["ffn_exposed_comm_s"] += float(stats.ffn_exposed_comm)
        t["ep_overlap_hidden_s"] += float(stats.ep_overlap_hidden)

    def _on_cache_hit(self, bd: StepBreakdown) -> None:
        # cached prefill steps carry no AF stats; keep the last decode stats
        if hasattr(bd, "af_stats"):
            self.last_stats = bd.af_stats
            self._accumulate(bd.af_stats)

    def _step_time_impl(self, q_lens, kv_lens, *, decode: bool,
                        n_prefill=None) -> StepBreakdown:
        if not decode:
            return super()._step_time_impl(q_lens, kv_lens, decode=False,
                                           n_prefill=n_prefill)
        stats = simulate_af_decode_step(
            self.cfg, self.hw, self.ops, list(kv_lens), m=self.m,
            attn_par=self.attn_par, ffn_par=self.ffn_par,
            routing=self.routing, rng=self.rng,
            remote_ranks=self.remote_ranks, remote_link=self.remote_link,
            remote_ops=self.remote_ops, pipeline=self.pipeline)
        self.last_stats = stats
        self._accumulate(stats)
        bd = StepBreakdown()
        bd.add("af_pipeline", stats.makespan)
        bd.add("engine_overhead", self.engine_overhead)
        bd.parts["attn_bubble_frac"] = stats.attn_bubble_frac
        bd.parts["ffn_bubble_frac"] = stats.ffn_bubble_frac
        bd.parts["ep_straggler_excess"] = stats.ep_straggler_excess
        bd.af_stats = stats
        return bd


def build_af(cfg: ModelConfig, hw: HardwareSpec, *,
             n_prefill: int = 1, n_decode: int = 1, m: int = 2,
             attn_par: Optional[ParallelismConfig] = None,
             ffn_par: Optional[ParallelismConfig] = None,
             prefill_par: Optional[ParallelismConfig] = None,
             ops: Optional[OperatorModelSet] = None,
             engine=None,
             routing=None, seed: int = 0,
             expert_cluster_hw: Optional[HardwareSpec] = None,
             remote_expert_ranks: Sequence[int] = (),
             expert_link: Optional[LinkSpec] = None,
             memory=None, queue_policy=None,
             memoize: bool = True,
             pipeline=None, transfer_overlap: float = 0.0,
             kv_frac: float = 0.9, fabric=None):
    """PD front + AF-disaggregated decode (as deployed by MegaScale-Infer).

    .. deprecated::
        ``build_af`` is kept as a thin shim over the declarative experiment
        API; prefer ``repro.api.SimSpec`` with
        ``TopologySpec(preset="af", ...)`` and ``repro.api.run`` — specs
        serialize, validate, and sweep.

    Preset over :func:`repro.core.topology.build_system`.  Pass
    ``remote_expert_ranks`` (+ optionally ``expert_cluster_hw`` /
    ``expert_link``) to place some EP ranks on a separate expert cluster
    reached over an inter-cluster link (cross-cluster expert routing).
    """
    from repro.core.topology import ClusterSpec, StageGraph, build_system
    attn_par = attn_par or ParallelismConfig(tp=1)
    ffn_par = ffn_par or ParallelismConfig(tp=1, ep=1)
    prefill_par = prefill_par or ParallelismConfig(tp=1)
    graph = StageGraph(clusters=[
        ClusterSpec("prefill", "prefill", n_replicas=n_prefill,
                    par=prefill_par, seed_offset=0, memoize=memoize),
        ClusterSpec("decode", "decode", n_replicas=n_decode,
                    par=attn_par, step="af", m=m,
                    attn_par=attn_par, ffn_par=ffn_par, seed_offset=50,
                    expert_cluster_hw=expert_cluster_hw,
                    remote_expert_ranks=tuple(remote_expert_ranks),
                    expert_link=expert_link, memoize=memoize),
    ], fabric=fabric)
    return build_system(cfg, hw, graph, ops=ops, routing=routing,
                        engine=engine,
                        memory=memory, queue_policy=queue_policy, seed=seed,
                        pipeline=pipeline, transfer_overlap=transfer_overlap,
                        kv_frac=kv_frac)
