"""Random-forest regression from scratch (numpy CART ensemble).

No sklearn in this environment; the paper uses random forests [Breiman 2001]
for operator runtime prediction, so we implement one: variance-reduction
CART trees with bootstrap sampling and per-split feature subsampling,
vectorized over prefix sums.  Targets are fit in log-space by the callers
(runtimes span orders of magnitude).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class _Tree:
    feature: np.ndarray      # (nodes,) int; -1 => leaf
    threshold: np.ndarray    # (nodes,) float
    left: np.ndarray         # (nodes,) int
    right: np.ndarray        # (nodes,) int
    value: np.ndarray        # (nodes,) float

    def predict(self, X: np.ndarray) -> np.ndarray:
        out = np.empty(len(X))
        for r in range(len(X)):
            n = 0
            while self.feature[n] >= 0:
                n = (self.left[n] if X[r, self.feature[n]] <= self.threshold[n]
                     else self.right[n])
            out[r] = self.value[n]
        return out


def _best_split(X: np.ndarray, y: np.ndarray, feats: np.ndarray,
                min_leaf: int) -> Tuple[Optional[int], float, float]:
    n = len(y)
    base_sse = float(((y - y.mean()) ** 2).sum())
    best = (None, 0.0, base_sse)
    for j in feats:
        order = np.argsort(X[:, j], kind="stable")
        xs, ys = X[order, j], y[order]
        c1 = np.cumsum(ys)
        c2 = np.cumsum(ys * ys)
        ln = np.arange(1, n)
        tot1, tot2 = c1[-1], c2[-1]
        sse_l = c2[:-1] - c1[:-1] ** 2 / ln
        rn = n - ln
        sse_r = (tot2 - c2[:-1]) - (tot1 - c1[:-1]) ** 2 / rn
        sse = sse_l + sse_r
        ok = (xs[1:] != xs[:-1]) & (ln >= min_leaf) & (rn >= min_leaf)
        if not ok.any():
            continue
        sse = np.where(ok, sse, np.inf)
        i = int(np.argmin(sse))
        if sse[i] < best[2] - 1e-12:
            best = (int(j), float((xs[i] + xs[i + 1]) / 2.0), float(sse[i]))
    return best


def _grow(X: np.ndarray, y: np.ndarray, *, max_depth: int, min_leaf: int,
          max_features: int, rng: np.random.Generator) -> _Tree:
    feat, thr, left, right, val = [], [], [], [], []

    def node(idx: np.ndarray, depth: int) -> int:
        me = len(feat)
        feat.append(-1); thr.append(0.0); left.append(-1); right.append(-1)
        val.append(float(y[idx].mean()))
        if depth >= max_depth or len(idx) < 2 * min_leaf or np.ptp(y[idx]) < 1e-12:
            return me
        fs = rng.choice(X.shape[1], size=min(max_features, X.shape[1]),
                        replace=False)
        j, t, _ = _best_split(X[idx], y[idx], fs, min_leaf)
        if j is None:
            return me
        mask = X[idx, j] <= t
        if mask.all() or not mask.any():
            return me
        feat[me], thr[me] = j, t
        left[me] = node(idx[mask], depth + 1)
        right[me] = node(idx[~mask], depth + 1)
        return me

    node(np.arange(len(y)), 0)
    return _Tree(np.array(feat), np.array(thr), np.array(left),
                 np.array(right), np.array(val))


class RandomForest:
    def __init__(self, n_trees: int = 24, max_depth: int = 14,
                 min_leaf: int = 2, max_features: Optional[int] = None,
                 seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.max_features = max_features
        self.seed = seed
        self.trees: List[_Tree] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForest":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        rng = np.random.default_rng(self.seed)
        mf = self.max_features or max(1, int(np.ceil(X.shape[1] / 3)))
        self.trees = []
        for _ in range(self.n_trees):
            idx = rng.integers(0, len(y), len(y))   # bootstrap
            self.trees.append(_grow(X[idx], y[idx], max_depth=self.max_depth,
                                    min_leaf=self.min_leaf, max_features=mf,
                                    rng=rng))
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float64)
        if X.ndim == 1:
            X = X[None, :]
        return np.mean([t.predict(X) for t in self.trees], axis=0)

    # ------------------------------------------------------- serialization --
    # JSON-portable dict form: hyperparameters + flat per-tree node arrays.
    # float64 round-trips exactly through repr-based json encoding, so a
    # from_dict(to_dict(f)) forest predicts bit-identically.
    def to_dict(self) -> dict:
        return {
            "n_trees": self.n_trees,
            "max_depth": self.max_depth,
            "min_leaf": self.min_leaf,
            "max_features": self.max_features,
            "seed": self.seed,
            "trees": [{
                "feature": t.feature.tolist(),
                "threshold": t.threshold.tolist(),
                "left": t.left.tolist(),
                "right": t.right.tolist(),
                "value": t.value.tolist(),
            } for t in self.trees],
        }

    @classmethod
    def from_dict(cls, data: dict) -> "RandomForest":
        forest = cls(n_trees=int(data["n_trees"]),
                     max_depth=int(data["max_depth"]),
                     min_leaf=int(data["min_leaf"]),
                     max_features=data.get("max_features"),
                     seed=int(data.get("seed", 0)))
        forest.trees = [
            _Tree(feature=np.asarray(t["feature"], np.int64),
                  threshold=np.asarray(t["threshold"], np.float64),
                  left=np.asarray(t["left"], np.int64),
                  right=np.asarray(t["right"], np.int64),
                  value=np.asarray(t["value"], np.float64))
            for t in data["trees"]]
        return forest
