"""MoE + AF disaggregation study (MegaScale-Infer / Step-3 style).

Sweeps the attention:FFN device ratio and micro-batch count for
mixtral-8x7b decode under skewed (Zipf) expert routing, reporting the
pipeline critical path, bubbles, and the per-EP-rank straggler penalty —
the phenomena Frontier's event-graph + micro-workflow models capture.
A second sweep moves expert ranks onto a *remote* cluster to show the
cross-cluster expert-routing penalty as a function of link bandwidth.

    PYTHONPATH=src python examples/moe_af_simulation.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import A800_SXM4_80G, LinkSpec, ParallelismConfig
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.routing import resolve_router
from repro.core.workflows.af_disagg import simulate_af_decode_step


def main():
    cfg = get_config("mixtral-8x7b")
    hw = A800_SXM4_80G
    ops = OperatorModelSet(hw)
    lens = [2048] * 256          # decode batch: 256 seqs @ 2k context

    print(f"{'attn:ffn':>9s} {'m':>3s} {'routing':>9s} {'step(ms)':>9s} "
          f"{'attn idle':>9s} {'ffn idle':>9s} {'straggler':>10s}")
    for n_attn, n_ffn in ((2, 6), (4, 4), (6, 2)):
        for m in (1, 2, 4):
            for rname in ("balanced", "zipf"):
                st = simulate_af_decode_step(
                    cfg, hw, ops, lens, m=m,
                    attn_par=ParallelismConfig(tp=n_attn),
                    ffn_par=ParallelismConfig(tp=1, ep=n_ffn),
                    routing=resolve_router(rname),
                    rng=np.random.default_rng(0))
                print(f"{n_attn}:{n_ffn:>7} {m:3d} {rname:>9s} "
                      f"{st.makespan*1e3:9.2f} {st.attn_bubble_frac:9.1%} "
                      f"{st.ffn_bubble_frac:9.1%} "
                      f"{st.ep_straggler_excess*1e3:8.2f}ms")
    print("\nReading: ffn-heavy ratios waste attention GPUs (idle%); "
          "zipf routing inflates the FFN stage via the straggler barrier.")

    # ---- cross-cluster expert routing: 2 of 8 EP ranks remote --------------
    print(f"\n{'expert link':>12s} {'step(ms)':>9s} {'xc MB/step':>11s} "
          f"{'straggler':>10s}")
    base = dict(m=2, attn_par=ParallelismConfig(tp=4),
                ffn_par=ParallelismConfig(tp=1, ep=8),
                routing=resolve_router("zipf"))
    for label, link in (("local", None),
                        ("100 GB/s", LinkSpec("decode", "exp", 100e9, 5e-6)),
                        ("25 GB/s", LinkSpec("decode", "exp", 25e9, 5e-6)),
                        ("5 GB/s", LinkSpec("decode", "exp", 5e9, 20e-6))):
        st = simulate_af_decode_step(
            cfg, hw, ops, lens, rng=np.random.default_rng(0),
            remote_ranks=(6, 7) if link else (), remote_link=link, **base)
        print(f"{label:>12s} {st.makespan*1e3:9.2f} "
              f"{st.cross_cluster_bytes/1e6:11.2f} "
              f"{st.ep_straggler_excess*1e3:8.2f}ms")
    print("\nReading: remote expert shards stretch dispatch/combine; below "
          "~25 GB/s the link, not the GroupedGEMM, gates the step.")


if __name__ == "__main__":
    main()
