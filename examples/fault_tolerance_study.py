"""Case study: replica failures and stragglers in a serving cluster.

Demonstrates the large-scale-operations machinery through the experiment
API: failure injection is data — a `FaultSpec` list on the `SimSpec` — so
the three what-if cases differ only in their fault lists and could equally
be three YAML files run by `python -m repro run`.

    PYTHONPATH=src python examples/fault_tolerance_study.py
"""
from repro.api import (FaultSpec, ModelRef, SimSpec, TopologySpec,
                       WorkloadSpec, run)

BASE = SimSpec(
    model=ModelRef("qwen2-7b"),
    topology=TopologySpec(preset="colocated", n_replicas=4, tp=1),
    workload=WorkloadSpec(n_requests=300, rate=40.0, prompt_mean=512,
                          output_mean=96),
    seed=0)

CASES = {
    "healthy x4": [],
    # replica 0 dies 1s in, recovers after 10s of downtime
    "1 failure (10s)": [FaultSpec(kind="failure", cluster="colocated",
                                  replica=0, at=1.0, downtime=10.0)],
    "1 straggler (3x)": [FaultSpec(kind="straggler", cluster="colocated",
                                   replica=1, slowdown=3.0)],
}


def main():
    reports = {}
    for name, faults in CASES.items():
        spec = SimSpec.from_dict(BASE.to_dict())
        spec.faults = faults
        rep = run(spec)
        reports[name] = rep
        print(f"{name:22s} tok/s {rep['throughput_tok_s']:8.0f}   "
              f"ttft_p99 {rep['ttft_p99_s']*1e3:8.1f} ms   "
              f"tpot_p99 {rep['tpot_p99_s']*1e3:7.1f} ms   "
              f"completed {rep['n_completed']}")
        assert rep.all_complete, rep.conservation

    base, f, s = (reports[k] for k in CASES)
    print(f"\nfailure throughput cost: "
          f"{1 - f['throughput_tok_s']/base['throughput_tok_s']:.1%}; "
          f"straggler cost: "
          f"{1 - s['throughput_tok_s']/base['throughput_tok_s']:.1%} "
          f"(all requests still complete — conservation holds)")


if __name__ == "__main__":
    main()
