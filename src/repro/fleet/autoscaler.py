"""SLO-driven fleet autoscaling.

The autoscaler wakes on a fixed tick, reads two signals — mean outstanding
requests per active instance (queue depth) and, when the spec carries an
SLO, the TTFT-SLO attainment of completions since the previous tick — and
takes at most one action per tick, rate-limited by a cooldown:

- **up**: queue depth above ``up_queue_depth`` OR recent attainment below
  ``slo_attainment_floor`` → provision a clone of the template group with
  a modeled cold start (weights over ``provision_bw``);
- **down**: queue depth below ``down_queue_depth`` for two consecutive
  ticks (hysteresis) and more than ``min_instances`` active → drain the
  least-loaded instance (stop routing, finish residents, release GPUs);
- **rebalance** (``pd_rebalance``): inside disaggregated instances, when
  one pool's per-replica queue pressure exceeds ``rebalance_ratio`` times
  the other's, shift one replica of capacity between the prefill and
  decode pools via pre-provisioned standby replicas.

Ticks stop rescheduling once every arrival has fired and the fleet is
empty, so the event heap always drains and runs terminate.
"""
from __future__ import annotations

from typing import Optional

from repro.core.events import EV
from repro.core.metrics import slo_attainment
from repro.fleet.instance import ACTIVE, STARTING


class Autoscaler:
    def __init__(self, spec, fleet):
        self.spec = spec          # AutoscalerSpec
        self.fleet = fleet        # FleetController
        self._last_action = -float("inf")
        self._down_streak = 0

    # --------------------------------------------------------------- tick --
    def start(self) -> None:
        self._schedule()

    def _schedule(self) -> None:
        self.fleet.engine.after(self.spec.interval_s, EV.AUTOSCALE_TICK,
                                lambda ev: self._tick())

    def _tick(self) -> None:
        fleet, now = self.fleet, self.fleet.engine.now
        self.act(now)
        # deliberately no inst.touch() here: GPU-second integration only
        # advances on provisioning changes and completions, so an idle
        # tick after the last completion never charges phantom idle time
        fleet._track_peak()
        # keep ticking while arrivals are still due or work is in flight
        # (or a pool move / cold start is pending — its event finishes the
        # heap either way, but the tick loop must not outlive the run)
        if now < fleet.last_arrival or fleet.outstanding() > 0:
            self._schedule()

    # ------------------------------------------------------------- policy --
    def act(self, now: float) -> None:
        fleet, spec = self.fleet, self.spec
        actives = [i for i in fleet.instances.values() if i.state == ACTIVE]
        starting = [i for i in fleet.instances.values()
                    if i.state == STARTING]
        recent = fleet.recent_completed
        fleet.recent_completed = []
        if not actives:
            return
        if spec.pd_rebalance:
            self._rebalance(actives)
        depth = sum(i.outstanding() for i in actives) / len(actives)
        slo = fleet.spec.slo
        attain: Optional[float] = None
        if slo is not None and spec.slo_attainment_floor is not None:
            attain = slo_attainment(recent, ttft_s=slo.ttft_s)
        if now - self._last_action < spec.cooldown_s:
            return
        n = len(actives) + len(starting)
        want_up = (depth > spec.up_queue_depth
                   or (attain is not None
                       and attain < spec.slo_attainment_floor))
        if want_up and n < spec.max_instances:
            group = fleet.fleet.instance_by_name(spec.template)
            fleet.scale_up(group)
            self._last_action = now
            self._down_streak = 0
            return
        if depth < spec.down_queue_depth and n > spec.min_instances \
                and not starting:
            self._down_streak += 1
            if self._down_streak >= 2:      # hysteresis: two calm ticks
                victim = min(actives,
                             key=lambda i: (i.outstanding(), i.name))
                fleet.scale_down(victim)
                self._last_action = now
                self._down_streak = 0
        else:
            self._down_streak = 0

    def _rebalance(self, actives) -> None:
        spec, fleet = self.spec, self.fleet
        if fleet._moves_in_flight:
            return                      # one pool move in flight at a time
        for inst in actives:
            if not inst.has_spares:
                continue
            depths = inst.controller.pool_depths()
            n_p = max(len(inst.pool_replicas("prefill", active=True)), 1)
            n_d = max(len(inst.pool_replicas("decode", active=True)), 1)
            p = depths.get("prefill", 0) / n_p
            d = depths.get("decode", 0) / n_d
            if p > spec.rebalance_ratio * (d + 1.0):
                if fleet.rebalance_pd(inst, "decode", "prefill"):
                    return
            elif d > spec.rebalance_ratio * (p + 1.0):
                if fleet.rebalance_pd(inst, "prefill", "decode"):
                    return
