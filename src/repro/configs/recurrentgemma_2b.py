"""recurrentgemma-2b (Griffin) — RG-LRU recurrent blocks + local attention, 1:2.
[arXiv:2402.19427; hf]

Pattern: (recurrent, recurrent, local-attn) cycled over 26 layers.
10 heads x head_dim 256 = 2560.  10 is not divisible by the 16-way model axis
=> attention runs replicated on the model axis (documented in DESIGN.md);
the recurrent blocks and MLP shard on channels.
Sub-quadratic (RG-LRU state + 2048-token local window) => runs long_500k.
"""
from repro.configs.base import ModelConfig, RECURRENT, ATTN_LOCAL

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=(RECURRENT, RECURRENT, ATTN_LOCAL),
    sliding_window=2048,
    mlp_act="gelu",
    tie_embeddings=True,
    conv1d_width=4,
)
