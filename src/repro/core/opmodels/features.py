"""Feature extraction for the refined operator models (paper §3.2).

Attention: aggregate AND distributional statistics of batch sequence
lengths (Vidur collapses these to a single sqrt proxy — exactly what loses
the heterogeneity information).  GroupedGEMM: token counts, expert counts,
model dims, selection ratio, and load-balance metrics (max/mean, CV,
entropy) per the paper.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

ATTN_FEATURE_NAMES = [
    "batch", "sum_len", "sum_len_sq", "max_len", "min_len", "mean_len",
    "std_len", "p50", "p90", "p99", "cv", "heads", "kv_heads", "head_dim",
    "causal", "window",
]


def attention_features(q_lens: Sequence[int], kv_lens: Sequence[int],
                       n_heads: int, n_kv_heads: int, head_dim: int, *,
                       causal: bool, window: int) -> np.ndarray:
    kv = np.asarray(kv_lens, np.float64)
    if window:
        kv = np.minimum(kv, window)
    q = np.asarray(q_lens, np.float64)
    work = q * kv  # per-request attention work proxy
    return np.array([
        len(kv),
        q.sum(),
        float((work).sum()),
        kv.max(initial=0.0),
        kv.min(initial=0.0),
        kv.mean() if len(kv) else 0.0,
        kv.std() if len(kv) else 0.0,
        float(np.percentile(kv, 50)) if len(kv) else 0.0,
        float(np.percentile(kv, 90)) if len(kv) else 0.0,
        float(np.percentile(kv, 99)) if len(kv) else 0.0,
        float(kv.std() / kv.mean()) if len(kv) and kv.mean() > 0 else 0.0,
        n_heads, n_kv_heads, head_dim,
        1.0 if causal else 0.0,
        float(window),
    ])


GG_FEATURE_NAMES = [
    "total_tokens", "n_experts", "n_active", "d_in", "d_out",
    "selection_ratio", "max_load", "mean_load", "load_cv", "load_entropy",
    "max_over_mean",
]


def grouped_gemm_features(tokens_per_expert: Sequence[int], d_in: int,
                          d_out: int) -> np.ndarray:
    c = np.asarray(tokens_per_expert, np.float64)
    total = c.sum()
    active = (c > 0).sum()
    mean = c.mean() if len(c) else 0.0
    p = c / total if total > 0 else np.full_like(c, 1.0 / max(len(c), 1))
    with np.errstate(divide="ignore", invalid="ignore"):
        ent = float(-(p[p > 0] * np.log(p[p > 0])).sum())
    return np.array([
        total, len(c), active, d_in, d_out,
        active / max(len(c), 1),
        c.max(initial=0.0), mean,
        float(c.std() / mean) if mean > 0 else 0.0,
        ent,
        float(c.max(initial=0.0) / mean) if mean > 0 else 0.0,
    ])
