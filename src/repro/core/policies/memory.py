"""KV-cache memory subsystem: managers, preemption policy, transfer plans.

The decode cluster's ClusterScheduler tracks memory through a
:class:`KVCacheManager`; ``free`` events trigger MEMORY_AVAILABLE signals to
the GlobalController — the backpressure mechanism of PD disaggregation.

Three managers are registered (``MEMORY`` / :func:`resolve_memory`,
mirroring the batching/routing/scheduler registries):

- ``"paged"`` — vLLM-style paged allocator: fixed-size token blocks per
  request, watermark-guarded admission AND growth (decode growth must not
  silently drain the reserve admission keeps).
- ``"prefix"`` — radix-style prefix cache on top of the paged allocator:
  requests carrying a ``prefix_id`` share the whole blocks of their common
  prefix (ref-counted); completed prefixes stay cached cold and are evicted
  LRU under pressure.  A hit advances ``Request.prefill_progress`` so the
  batching policies skip the cached prefill compute, and the manager
  reports hit-token fractions.
- ``"monolithic"`` — TensorRT-LLM-v1-style contiguous allocation: each
  request reserves its full ``prompt_len + output_len`` bound up front
  (``max_len`` is only the fallback when no bound is known).

Every manager also carries the *preemption policy* for the replicas using
it: ``preemption="recompute"`` drops the KV and re-prefills the full
context through an entry cluster; ``preemption="swap"`` moves the KV to
host memory over ``swap_bw`` and restores it in place when blocks free.

:class:`KVTransferPlan` prices layer-wise streamed KV transfer between
clusters (DistServe/MegaScale discipline): per-layer chunks pipeline over
the link while later prefill layers still compute, so only the exposed
tail delays the decode handoff.  ``overlap=0`` reproduces the legacy
lump-sum pricing bit-for-bit.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

PREEMPTION_MODES = ("recompute", "swap")


class KVCacheManager:
    """Block-granular KV accounting shared by every manager.

    The base implementation IS the paged allocator; subclasses refine the
    reservation rule (monolithic) or add block sharing (prefix cache).
    """

    name = "base"

    def __init__(self, total_bytes: float, kv_bytes_per_token: float, *,
                 block_tokens: int = 16, watermark: float = 0.02,
                 preemption: str = "recompute", swap_bw: float = 32e9):
        if preemption not in PREEMPTION_MODES:
            raise ValueError(f"preemption must be one of {PREEMPTION_MODES}, "
                             f"got {preemption!r}")
        self.block_tokens = block_tokens
        self.kv_bytes_per_token = kv_bytes_per_token
        self.block_bytes = kv_bytes_per_token * block_tokens
        self.total_blocks = int(total_bytes // max(self.block_bytes, 1))
        self.free_blocks = self.total_blocks
        self.watermark_blocks = int(self.total_blocks * watermark)
        self.preemption = preemption
        self.swap_bw = swap_bw
        self._held: Dict[int, int] = {}   # rid -> unique blocks
        # observability
        self.peak_used_blocks = 0
        self.hit_tokens = 0
        self.prompt_tokens = 0
        self.evictions = 0
        self.evicted_blocks = 0

    # ----------------------------------------------------------- sizing --
    def blocks_for(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.block_tokens))

    def _floor(self, ignore_watermark: bool) -> int:
        return 0 if ignore_watermark else self.watermark_blocks

    def _track_peak(self) -> None:
        used = self.total_blocks - self.free_blocks
        if used > self.peak_used_blocks:
            self.peak_used_blocks = used

    # --------------------------------------------------------- admission --
    def can_admit(self, tokens: int, max_tokens: Optional[int] = None) -> bool:
        return (self.free_blocks - self.blocks_for(tokens)
                >= self.watermark_blocks)

    def admit(self, rid: int, tokens: int, *,
              max_tokens: Optional[int] = None,
              ignore_watermark: bool = False) -> bool:
        need = self.blocks_for(tokens)
        if self.free_blocks - need < self._floor(ignore_watermark):
            return False
        self.free_blocks -= need
        self._held[rid] = need
        self._track_peak()
        return True

    def admit_request(self, r) -> bool:
        """Admit a request's (possibly restored) prefill context.

        Subclasses may use the request's prefix identity here; the base
        manager reserves blocks for ``prefill_total`` tokens with the
        per-request ``prompt_len + output_len`` bound for managers that
        reserve up front.
        """
        return self.admit(r.rid, r.prefill_total,
                          max_tokens=r.prompt_len + r.output_len)

    def prefix_hit(self, r) -> int:
        """Cached-prefix tokens this request would skip (0 for non-sharing
        managers); a probe only — ``admit_request`` applies the hit."""
        return 0

    # ------------------------------------------------------------ growth --
    def grow(self, rid: int, new_tokens: int, *,
             ignore_watermark: bool = False) -> bool:
        """Ensure rid holds enough blocks for new total token count.

        Honors the same watermark reserve as ``admit`` — decode growth must
        not silently drain the headroom admission keeps; replicas may pass
        ``ignore_watermark=True`` as a last resort before preempting the
        only resident request.
        """
        need = self.blocks_for(new_tokens)
        have = self._held.get(rid, 0)
        if need <= have:
            return True
        extra = need - have
        if self.free_blocks - extra < self._floor(ignore_watermark):
            return False
        self.free_blocks -= extra
        self._held[rid] = need
        self._track_peak()
        return True

    # ----------------------------------------------------------- release --
    def free(self, rid: int, *, insert: bool = True,
             full_extent: bool = True) -> int:
        """Release rid's blocks.  ``insert=False`` (replica failure, swap)
        tells sharing managers not to cache the request's prefix;
        ``full_extent=False`` (recompute preemption) caps the cached fold
        at the declared shared prefix instead of everything computed."""
        blocks = self._held.pop(rid, 0)
        self.free_blocks += blocks
        assert self.free_blocks <= self.total_blocks
        return blocks

    def holds(self, rid: int) -> bool:
        return rid in self._held

    # -------------------------------------------------------------- swap --
    def swap_time(self, tokens: int) -> float:
        """Host<->device KV movement time for a preempt/restore swap."""
        if not self.swap_bw:
            return 0.0
        return tokens * self.kv_bytes_per_token / self.swap_bw

    # ------------------------------------------------------------- state --
    @property
    def utilization(self) -> float:
        if self.total_blocks == 0:
            return 1.0
        return 1.0 - self.free_blocks / self.total_blocks

    @property
    def peak_utilization(self) -> float:
        if self.total_blocks == 0:
            return 1.0
        return self.peak_used_blocks / self.total_blocks

    @property
    def prefix_hit_rate(self) -> float:
        if not self.prompt_tokens:
            return 0.0
        return self.hit_tokens / self.prompt_tokens

    def held_blocks(self) -> int:
        return sum(self._held.values())

    def cached_blocks(self) -> int:
        return 0


class PagedKVManager(KVCacheManager):
    """vLLM-style paged allocator: fixed-size token blocks per request."""

    name = "paged"


class _PrefixEntry:
    __slots__ = ("blocks", "refs", "lru")

    def __init__(self, blocks: int = 0, refs: int = 0, lru: int = 0):
        self.blocks = blocks
        self.refs = refs
        self.lru = lru


class PrefixCachingKVManager(KVCacheManager):
    """Radix-style prefix cache over the paged allocator.

    Requests tagged with a ``prefix_id`` share the whole blocks of their
    common prefix: on admission the cached portion counts as already
    prefilled (``Request.prefill_progress`` advances past it, capped one
    token short so the first output token is still computed), and only the
    unique suffix allocates fresh blocks.  When a request frees, its prefix
    blocks are folded into the cache (cold, ref-count 0) instead of
    returning to the free pool; cold prefixes are evicted LRU whenever an
    allocation needs the space.
    """

    name = "prefix"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._prefix: Dict[int, _PrefixEntry] = {}
        self._refs: Dict[int, Tuple[int, int]] = {}    # rid -> (pid, blocks)
        self._insert: Dict[int, Tuple[int, int]] = {}  # rid -> (pid, declared)
        self._extent: Dict[int, int] = {}              # rid -> computed toks
        self._clock = itertools.count(1)

    # ---------------------------------------------------------- eviction --
    def _cold_blocks(self) -> int:
        return sum(e.blocks for e in self._prefix.values() if e.refs == 0)

    def _evict_one(self, protect: Optional[int]) -> bool:
        victim, best = None, None
        for pid, e in self._prefix.items():
            if e.refs or pid == protect or not e.blocks:
                continue
            if best is None or e.lru < best:
                victim, best = pid, e.lru
        if victim is None:
            return False
        entry = self._prefix.pop(victim)
        self.free_blocks += entry.blocks
        self.evictions += 1
        self.evicted_blocks += entry.blocks
        return True

    def _reserve(self, n: int, *, protect: Optional[int] = None,
                 ignore_watermark: bool = False) -> bool:
        floor = self._floor(ignore_watermark)
        while self.free_blocks - n < floor:
            if not self._evict_one(protect):
                break
        return self.free_blocks - n >= floor

    # --------------------------------------------------------- admission --
    def _hit_blocks(self, r) -> Tuple[Optional[int], int]:
        pid = r.prefix_id
        if pid is None:
            return None, 0
        # cap one token short of the prefill target: the last prompt token
        # must be computed to emit the first output token
        plen = min(r.prefix_len, max(r.prefill_total - 1, 0))
        entry = self._prefix.get(pid)
        hit = min(entry.blocks, plen // self.block_tokens) \
            if entry is not None else 0
        return pid, hit

    def prefix_hit(self, r) -> int:
        return self._hit_blocks(r)[1] * self.block_tokens

    def can_admit(self, tokens: int, max_tokens: Optional[int] = None) -> bool:
        # cold cached prefixes are reclaimable on demand
        return (self.free_blocks + self._cold_blocks()
                - self.blocks_for(tokens) >= self.watermark_blocks)

    def admit(self, rid: int, tokens: int, *,
              max_tokens: Optional[int] = None,
              ignore_watermark: bool = False) -> bool:
        need = self.blocks_for(tokens)
        if not self._reserve(need, ignore_watermark=ignore_watermark):
            return False
        self.free_blocks -= need
        self._held[rid] = need
        self._track_peak()
        return True

    def admit_request(self, r) -> bool:
        pid, hit = self._hit_blocks(r)
        if pid is None:
            ok = self.admit(r.rid, r.prefill_total,
                            max_tokens=r.prompt_len + r.output_len)
            if ok and not r.restore_pending:
                self.prompt_tokens += r.prefill_total
            return ok
        unique = max(self.blocks_for(r.prefill_total) - hit, 0)
        if not self._reserve(unique, protect=pid):
            return False
        self.free_blocks -= unique
        self._held[r.rid] = unique
        self._track_peak()
        if hit:
            entry = self._prefix[pid]
            entry.refs += 1
            entry.lru = next(self._clock)
            self._refs[r.rid] = (pid, hit)
            hit_toks = hit * self.block_tokens
            if hit_toks > r.prefill_progress:
                r.prefill_progress = hit_toks
            if not r.restore_pending:
                self.hit_tokens += hit_toks
        self._insert[r.rid] = (pid, min(r.prefix_len, r.prefill_total))
        self._extent[r.rid] = r.prefill_total
        if not r.restore_pending:
            # recompute-restore re-admissions still *use* their own cached
            # prefix (the compute saving is real) but are excluded from the
            # hit-rate stat: prefix_hit_token_frac measures cross-request
            # sharing, not preemption churn
            self.prompt_tokens += r.prefill_total
        return True

    # ------------------------------------------------------------ growth --
    def grow(self, rid: int, new_tokens: int, *,
             ignore_watermark: bool = False) -> bool:
        ref = self._refs.get(rid, (None, 0))[1]
        need = max(self.blocks_for(new_tokens) - ref, 0)
        have = self._held.get(rid, 0)
        if need <= have:
            return True
        extra = need - have
        if not self._reserve(extra, ignore_watermark=ignore_watermark):
            return False
        self.free_blocks -= extra
        self._held[rid] = need
        if rid in self._extent and new_tokens > self._extent[rid]:
            self._extent[rid] = new_tokens
        self._track_peak()
        return True

    # ----------------------------------------------------------- release --
    def free(self, rid: int, *, insert: bool = True,
             full_extent: bool = True) -> int:
        blocks = self._held.pop(rid, 0)
        self.free_blocks += blocks
        target = self._insert.pop(rid, None)
        extent = self._extent.pop(rid, 0)
        if target is not None and insert:
            pid, declared = target
            # radix semantics: everything this request computed is a valid
            # prefix for its successors (a conversation's next turn extends
            # the whole prior context, not just the declared prefix_len);
            # consumers' hits stay capped by THEIR declared prefix_len.
            # A recompute preemption (full_extent=False) folds only the
            # provably shared declared prefix — folding the whole context
            # into a ref-pinned entry would leave un-evictable blocks no
            # consumer can hit, during the very OOM preemption relieves
            if not full_extent:
                extent = min(extent, declared)
            pblocks = extent // self.block_tokens
            entry = self._prefix.get(pid)
            if entry is None:
                entry = self._prefix[pid] = _PrefixEntry()
            growth = min(pblocks - entry.blocks, self.free_blocks)
            if growth > 0:
                # the request's prefix blocks stay resident as cold cache
                self.free_blocks -= growth
                entry.blocks += growth
            entry.lru = next(self._clock)
        ref = self._refs.pop(rid, None)
        if ref is not None:
            entry = self._prefix.get(ref[0])
            if entry is not None and entry.refs > 0:
                entry.refs -= 1
        assert self.free_blocks <= self.total_blocks
        return blocks

    def cached_blocks(self) -> int:
        return sum(e.blocks for e in self._prefix.values())


class MonolithicKVManager(KVCacheManager):
    """Contiguous per-request allocation (TensorRT-LLM v1 style static
    memory): each request reserves its full ``prompt_len + output_len``
    bound at admission; ``max_len`` is only the fallback when a raw admit
    carries no per-request bound."""

    name = "monolithic"

    def __init__(self, total_bytes: float, kv_bytes_per_token: float,
                 max_len: int = 8192, **kw):
        kw.setdefault("block_tokens", 1)
        super().__init__(total_bytes, kv_bytes_per_token, **kw)
        self.max_len = max_len

    def _bound(self, tokens: int, max_tokens: Optional[int]) -> int:
        return max(max_tokens if max_tokens is not None else self.max_len,
                   tokens)

    def can_admit(self, tokens: int, max_tokens: Optional[int] = None) -> bool:
        return (self.free_blocks - self._bound(tokens, max_tokens)
                >= self.watermark_blocks)

    def admit(self, rid: int, tokens: int, *,
              max_tokens: Optional[int] = None,
              ignore_watermark: bool = False) -> bool:
        need = self._bound(tokens, max_tokens)
        if self.free_blocks - need < self._floor(ignore_watermark):
            return False
        self.free_blocks -= need
        self._held[rid] = need
        self._track_peak()
        return True
    # grow() is inherited: block_tokens == 1, and the reservation already
    # covers every context length up to the per-request bound, so growth
    # within the reserve is free and growth beyond it allocates the excess.


MEMORY = {c.name: c for c in (PagedKVManager, PrefixCachingKVManager,
                              MonolithicKVManager)}


def resolve_memory(spec) -> Tuple[type, dict]:
    """Resolve a memory-manager spec to ``(cls, constructor_kwargs)``.

    Unlike batching/routing, KV managers need build-time arguments (the
    per-replica byte budget), so resolution returns the class plus any
    extra kwargs; the system builder supplies budget/kv_bytes_per_token.
    Accepts None (paged defaults), a registered name, or a mapping
    ``{"name": ..., **kwargs}`` (e.g. block_tokens, watermark, preemption,
    swap_bw).
    """
    if spec is None:
        return PagedKVManager, {}
    if isinstance(spec, str):
        spec = {"name": spec}
    if isinstance(spec, dict):
        kw = dict(spec)
        name = kw.pop("name", None)
        if name not in MEMORY:
            raise KeyError(f"unknown memory manager {name!r}; "
                           f"registered: {sorted(MEMORY)}")
        if kw.get("preemption") is not None \
                and kw["preemption"] not in PREEMPTION_MODES:
            raise KeyError(f"unknown preemption mode {kw['preemption']!r}; "
                           f"modes: {PREEMPTION_MODES}")
        return MEMORY[name], kw
    raise TypeError(f"memory must be None, a name, or a mapping; "
                    f"got {type(spec).__name__}")


# ---------------------------------------------------- streamed KV transfer --
@dataclass(frozen=True)
class KVTransferPlan:
    """Layer-wise streamed KV transfer over one inter-cluster link.

    A prefill's KV is moved as ``n_layers`` per-layer chunks.  Layer *i*'s
    chunk can start streaming while layers *i+1..L* still prefill, so by
    the time prefill completes only the un-hidden tail is exposed on the
    critical path.  ``overlap`` in [0, 1] scales how much of that
    opportunity the transport realizes: 0 is the legacy lump-sum transfer
    (``exposed_time == serial_time`` exactly), 1 hides everything the
    compute window allows — never less than the last layer's chunk plus
    the link latency.
    """
    n_layers: int
    bytes_per_layer: float
    bandwidth: float
    latency: float = 0.0
    overlap: float = 0.0

    @property
    def total_bytes(self) -> float:
        return self.n_layers * self.bytes_per_layer

    @property
    def layer_time(self) -> float:
        return self.bytes_per_layer / self.bandwidth if self.bandwidth else 0.0

    @property
    def serial_time(self) -> float:
        """The lump-sum (no-streaming) price of the whole transfer."""
        return self.latency + (self.total_bytes / self.bandwidth
                               if self.bandwidth else 0.0)

    def exposed_time(self, compute_window: float = 0.0) -> float:
        """Transfer time left on the critical path after prefill completes.

        ``compute_window`` is the wall-clock span the producing prefill
        occupied (first schedule -> transfer start): the window in which
        the first L-1 chunks could stream behind remaining layers.
        """
        serial = self.serial_time
        if self.overlap <= 0.0 or self.n_layers <= 1:
            return serial
        hideable = (self.n_layers - 1) * self.layer_time
        # layer i's chunk only overlaps compute of layers AFTER i: in a
        # balanced pipeline (L-1)/L of the window is usable
        window = max(compute_window, 0.0) * (self.n_layers - 1) / self.n_layers
        hidden = self.overlap * min(hideable, window)
        return max(serial - hidden, self.latency + self.layer_time)
