"""Property tests for memory/batching policies (system invariants)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.policies.batching import ChunkedPrefill, ContinuousBatching
from repro.core.policies.memory import PagedKVManager
from repro.core.request import Request, RState


@given(st.lists(st.tuples(st.sampled_from(["admit", "grow", "free"]),
                          st.integers(0, 19), st.integers(1, 4096)),
                min_size=1, max_size=300))
@settings(max_examples=60, deadline=None)
def test_paged_kv_block_conservation(ops):
    mgr = PagedKVManager(total_bytes=1_000_000, kv_bytes_per_token=10,
                         block_tokens=16, watermark=0.0)
    total = mgr.total_blocks
    live = {}
    for kind, rid, toks in ops:
        if kind == "admit" and rid not in live:
            if mgr.admit(rid, toks):
                live[rid] = toks
        elif kind == "grow" and rid in live:
            if mgr.grow(rid, live[rid] + toks):
                live[rid] += toks
        elif kind == "free" and rid in live:
            mgr.free(rid)
            del live[rid]
        # invariant: free + held == total, never negative
        assert 0 <= mgr.free_blocks <= total
        assert mgr.free_blocks + mgr.held_blocks() == total
    for rid in list(live):
        mgr.free(rid)
    assert mgr.free_blocks == total


def _reqs(lens):
    return [Request(rid=i, arrival=0.0, prompt_len=l, output_len=8)
            for i, l in enumerate(lens)]


@given(st.lists(st.integers(1, 4096), min_size=1, max_size=40),
       st.integers(64, 2048))
@settings(max_examples=50, deadline=None)
def test_chunked_prefill_respects_token_budget(lens, budget):
    pol = ChunkedPrefill(chunk=256, max_batched_tokens=budget)
    plan = pol.plan(_reqs(lens), [], None, 0.0)
    assert sum(c for _, c in plan.prefill) <= budget
    for r, c in plan.prefill:
        assert 0 < c <= min(256, r.prompt_len)


def test_continuous_batching_backpressure():
    mgr = PagedKVManager(total_bytes=100 * 10 * 16, kv_bytes_per_token=10,
                         block_tokens=16, watermark=0.0)  # 100 blocks
    pol = ContinuousBatching(max_batched_tokens=1 << 20)
    reqs = _reqs([800, 800, 800])       # 50 blocks each
    plan = pol.plan(reqs, [], mgr, 0.0)
    assert len(plan.prefill) == 2       # third is backpressured
    assert mgr.free_blocks == 0


def test_request_state_machine_rejects_illegal():
    r = Request(rid=0, arrival=0.0, prompt_len=4, output_len=4)
    try:
        r.to(RState.COMPLETE, 0.0)
        assert False, "expected ValueError"
    except ValueError:
        pass
