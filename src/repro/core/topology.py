"""Declarative StageGraph topology layer.

A serving system is described as a *StageGraph*: a set of ClusterSpecs
(role, replica count, per-cluster hardware and parallelism, step model) plus
directed LinkSpecs between clusters (asymmetric bandwidths, base latency).
``build_system`` compiles the graph into the event-driven runtime objects
(GlobalController, ClusterWorkers, ReplicaWorkers) — the single place where
replicas are constructed.  ``build_colocated`` / ``build_pd`` / ``build_af``
are thin presets over this layer, and new combinations — PD front + AF
decode with heterogeneous hardware per cluster, multiple decode pools,
cross-cluster expert placement — are one-liner graph edits.

Example (heterogeneous PD + AF decode with cross-cluster EP)::

    graph = StageGraph(
        clusters=[
            ClusterSpec("prefill", "prefill", n_replicas=2,
                        par=ParallelismConfig(tp=2)),
            ClusterSpec("decode", "decode", step="af", m=2,
                        hardware=H100_SXM,
                        attn_par=ParallelismConfig(tp=2),
                        ffn_par=ParallelismConfig(ep=8),
                        remote_expert_ranks=(6, 7),
                        expert_cluster_hw=A800_SXM4_80G),
        ],
        links=[LinkSpec("prefill", "decode", bandwidth=50e9),
               LinkSpec("decode", "prefill", bandwidth=25e9)])
    handle = build_system(cfg, A800_SXM4_80G, graph)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from repro.configs.base import ModelConfig
from repro.core.cluster import ClusterWorker, ReplicaWorker
from repro.core.controller import GlobalController
from repro.core.engine import SimEngine
from repro.core.fabric import Fabric, FabricConfig, FabricOps
from repro.core.hardware import HardwareSpec, LinkSpec, ParallelismConfig
from repro.core.metrics import MetricsCollector
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.pipeline import PipelineConfig, resolve_pipeline
from repro.core.policies.batching import (
    BatchingPolicy, ChunkedPrefill, ContinuousBatching,
)
from repro.core.predictor import ExecutionPredictor
from repro.core.request import Request
from repro.core.routing import resolve_router

ROLES = ("prefill", "decode", "colocated")


@dataclass
class SystemHandle:
    engine: SimEngine
    controller: GlobalController
    clusters: dict
    n_devices: int
    fabric: Optional[Fabric] = None

    def run(self, requests: List[Request], until: float = float("inf"), *,
            closed_concurrency: Optional[int] = None,
            slo_ttft: Optional[float] = None,
            slo_tpot: Optional[float] = None):
        """Replay ``requests`` through the event engine and report metrics.

        ``closed_concurrency`` switches to closed-loop injection: at most
        that many requests in flight, the next one arriving when a slot
        frees.  The metrics window starts at the first actual arrival.
        """
        if closed_concurrency is not None:
            self.controller.submit_closed(requests, closed_concurrency)
        else:
            self.controller.submit_all(requests)
        self.engine.run(until)
        rep = self.controller.metrics.report(
            n_devices=self.n_devices, slo_ttft=slo_ttft, slo_tpot=slo_tpot)
        rep["preemptions"] = sum(w.stats.get("preemptions", 0)
                                 for c in self.clusters.values()
                                 for w in c.replicas)
        return rep


def _kv_budget(cfg: ModelConfig, hw: HardwareSpec, par: ParallelismConfig,
               pred: ExecutionPredictor, frac: float = 0.9) -> float:
    """KV memory per replica = devices*(HBM - weights) * frac.

    ``frac`` is the cache-size knob (``MemorySpec.capacity_frac``): the
    fraction of post-weight HBM given to the KV cache — sweeping it down
    simulates memory pressure without changing the hardware.
    """
    total = hw.hbm_capacity * par.devices
    weights = 2.0 * cfg.param_count()
    # the floor scales with frac too (frac=0.9 keeps the legacy 5% floor),
    # so capacity_frac sweeps stay monotone even when weights dominate
    return max((total - weights) * frac, hw.hbm_capacity * frac / 18.0)


@dataclass
class ClusterSpec:
    """One specialized hardware pool in the topology."""
    name: str
    role: str                                  # "prefill"|"decode"|"colocated"
    n_replicas: int = 1
    par: ParallelismConfig = field(default_factory=ParallelismConfig)
    hardware: Optional[HardwareSpec] = None    # None -> topology default hw
    policy: Optional[BatchingPolicy] = None    # None -> role default
    step: str = "dense"                        # "dense" | "af" (event graph)
    # AF step parameters (step == "af")
    m: int = 2
    attn_par: Optional[ParallelismConfig] = None
    ffn_par: Optional[ParallelismConfig] = None
    # cross-cluster expert placement: these EP ranks live on a remote expert
    # cluster (its hardware / link given below), reached per dispatch/combine
    remote_expert_ranks: Tuple[int, ...] = ()
    expert_cluster_hw: Optional[HardwareSpec] = None
    expert_link: Optional[LinkSpec] = None
    seed_offset: int = 0
    replica_prefix: Optional[str] = None       # default: cluster name
    # step-time memo cache (see ExecutionPredictor); False -> exact
    # per-step operator-graph walks and routing draws
    memoize: bool = True
    # latency-hiding strategy (repro.core.pipeline.PipelineConfig); None
    # falls back to build_system's topology-wide default (also None ->
    # the legacy serial-per-micro-batch model, bit-for-bit)
    pipeline: Optional["PipelineConfig"] = None

    def devices_per_replica(self) -> int:
        if self.step == "af":
            ap = self.attn_par or self.par
            fp = self.ffn_par or self.par
            return ap.devices + fp.devices
        return self.par.devices


@dataclass
class StageGraph:
    """The full topology: clusters + directed inter-cluster links."""
    clusters: List[ClusterSpec]
    links: List[LinkSpec] = field(default_factory=list)
    # shared-fabric contention model; None or mode="none" keeps the legacy
    # isolated point-to-point pricing bit-identically
    fabric: Optional[FabricConfig] = None

    def validate(self) -> None:
        names = [c.name for c in self.clusters]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate cluster names: {names}")
        for c in self.clusters:
            if c.role not in ROLES:
                raise ValueError(f"cluster {c.name}: unknown role {c.role!r}")
            if c.step not in ("dense", "af"):
                raise ValueError(f"cluster {c.name}: unknown step {c.step!r}")
            if c.remote_expert_ranks:
                fp = c.ffn_par or c.par
                ep = max(fp.ep, fp.tp, 1)
                bad = [r for r in c.remote_expert_ranks if not 0 <= r < ep]
                if bad:
                    raise ValueError(f"cluster {c.name}: remote_expert_ranks "
                                     f"{bad} out of range for ep={ep}")
            elif c.expert_cluster_hw is not None or c.expert_link is not None:
                raise ValueError(
                    f"cluster {c.name}: expert_cluster_hw/expert_link have "
                    f"no effect without remote_expert_ranks")
        for l in self.links:
            for end in (l.src, l.dst):
                if end not in names:
                    raise ValueError(f"link {l.src}->{l.dst}: unknown "
                                     f"cluster {end!r}")
        roles = {c.role for c in self.clusters}
        if "colocated" in roles and roles != {"colocated"}:
            raise ValueError(
                "colocated clusters cannot be mixed with prefill/decode "
                f"roles (got {sorted(roles)})")
        if roles != {"colocated"} and roles != {"prefill", "decode"}:
            raise ValueError(
                f"a topology is either all-colocated or prefill+decode; "
                f"got roles {sorted(roles)}")

    @property
    def mode(self) -> str:
        roles = {c.role for c in self.clusters}
        return "pd" if "prefill" in roles and "decode" in roles else "colocated"

    @property
    def entry_clusters(self) -> List[str]:
        want = "prefill" if self.mode == "pd" else "colocated"
        return [c.name for c in self.clusters if c.role == want]

    def link_table(self) -> Dict[Tuple[str, str], LinkSpec]:
        return {(l.src, l.dst): l for l in self.links}


def _default_policy(role: str) -> BatchingPolicy:
    if role == "prefill":
        return ContinuousBatching(max_batched_tokens=16384)
    if role == "decode":
        return ContinuousBatching(max_num_seqs=512)
    return ContinuousBatching()


def build_system(cfg: ModelConfig, hw: HardwareSpec, graph: StageGraph, *,
                 ops: Optional[OperatorModelSet] = None,
                 routing: Union[None, str, dict, "RoutingModule"] = None,
                 engine: Optional[SimEngine] = None,
                 transfer_bw: Optional[float] = None,
                 memory: Union[None, str, dict] = None,
                 queue_policy: Union[None, str, dict, "QueuePolicy"] = None,
                 seed: int = 0,
                 pipeline: Union[None, str, dict, PipelineConfig] = None,
                 transfer_overlap: float = 0.0,
                 kv_frac: float = 0.9,
                 ) -> SystemHandle:
    """Compile a StageGraph into a runnable SystemHandle.

    ``hw``/``ops`` are the topology defaults; a ClusterSpec with its own
    ``hardware`` gets a fresh analytical OperatorModelSet for it (pass a
    custom ``ops`` only for homogeneous-hardware clusters).  ``memory``
    ("paged"/"prefix"/"monolithic" + kwargs incl. preemption/swap_bw) and
    ``queue_policy`` ("fcfs"/"sjf"/"priority") select registered KV-manager
    and queue-ordering policies for every replica.  ``pipeline`` (name /
    mapping / PipelineConfig) selects the latency-hiding strategy for every
    cluster that does not carry its own ``ClusterSpec.pipeline``; None
    keeps the legacy serial model bit-for-bit.  ``transfer_overlap`` in
    (0, 1] switches PD KV handoffs to layer-wise streamed transfer
    (0 keeps the legacy lump-sum pricing bit-for-bit); ``kv_frac`` sets
    the fraction of post-weight HBM given to the KV cache.
    """
    from repro.core.policies.memory import resolve_memory
    from repro.core.policies.scheduling import resolve_scheduler
    from repro.core.workflows.af_disagg import AFPipelinePredictor
    graph.validate()
    for spec in graph.clusters:
        if spec.remote_expert_ranks and cfg.moe is None:
            raise ValueError(
                f"cluster {spec.name}: remote_expert_ranks requires an MoE "
                f"model config ({cfg.name} is dense)")
    engine = engine or SimEngine()
    ops = ops or OperatorModelSet(hw)
    fabric = None
    if graph.fabric is not None and graph.fabric.mode != "none":
        if transfer_overlap > 0.0:
            raise ValueError(
                "fabric contention and layer-streamed KV transfer "
                "(transfer_overlap > 0) cannot be combined: streamed "
                "chunks are priced against a dedicated link, not the "
                "shared fabric")
        fabric = Fabric(engine, graph.fabric)
    routing = resolve_router(routing)
    mem_cls, mem_kw = resolve_memory(memory)
    qpolicy = resolve_scheduler(queue_policy)
    default_pipe = resolve_pipeline(pipeline)
    metrics = MetricsCollector()
    mode = graph.mode

    pred0 = ExecutionPredictor(cfg, graph.clusters[0].par, hw, ops)
    controller = GlobalController(
        engine, mode=mode, clusters={},
        kv_bytes_per_token=pred0.kv_bytes_per_token(),
        transfer_bw=transfer_bw if transfer_bw is not None
        else hw.inter_node_bw,
        metrics=metrics, links=graph.link_table(),
        entry=graph.entry_clusters,
        kv_layers=pred0.kv_layer_count(),
        transfer_overlap=transfer_overlap,
        fabric=fabric)
    hooks = controller.hooks()

    clusters: Dict[str, ClusterWorker] = {}
    n_devices = 0
    for spec in graph.clusters:
        hw_c = spec.hardware or hw
        ops_c = ops if spec.hardware is None else OperatorModelSet(hw_c)
        if fabric is not None:
            # the cluster's NIC uplink joins the shared fabric, and its
            # inter-node collective terms are re-priced fabric-aware
            fabric.attach(spec.name, hw_c.inter_node_bw)
            ops_c = FabricOps(ops_c, fabric.config, fabric)
        prefix = spec.replica_prefix or spec.name
        pipe = spec.pipeline if spec.pipeline is not None else default_pipe
        policy = spec.policy
        if (policy is None and pipe is not None and pipe.chunked_prefill
                and spec.role in ("prefill", "colocated")):
            # chunked-prefill strategy: the role-default batching policy
            # becomes Sarathi-style chunking at the configured budget
            policy = ChunkedPrefill(chunk=pipe.prefill_chunk)
        replicas = []
        for i in range(spec.n_replicas):
            rseed = seed + spec.seed_offset + i
            if spec.step == "af":
                remote_ops = (OperatorModelSet(spec.expert_cluster_hw)
                              if spec.expert_cluster_hw is not None else None)
                link = spec.expert_link
                if link is None and spec.remote_expert_ranks:
                    link = LinkSpec(spec.name, f"{spec.name}-experts",
                                    bandwidth=hw_c.inter_node_bw)
                pred = AFPipelinePredictor(
                    cfg, spec.par, hw_c, ops_c, routing=routing, seed=rseed,
                    memoize=spec.memoize,
                    m=spec.m, attn_par=spec.attn_par or spec.par,
                    ffn_par=spec.ffn_par or spec.par,
                    remote_ranks=spec.remote_expert_ranks,
                    remote_link=link, remote_ops=remote_ops,
                    pipeline=pipe)
            else:
                pred = ExecutionPredictor(cfg, spec.par, hw_c, ops_c,
                                          routing=routing, seed=rseed,
                                          memoize=spec.memoize)
            mem = mem_cls(_kv_budget(cfg, hw_c, spec.par, pred, frac=kv_frac),
                          pred.kv_bytes_per_token(), **mem_kw)
            replicas.append(ReplicaWorker(
                engine, f"{prefix}{i}", pred,
                policy or _default_policy(spec.role),
                mem, hooks, role=spec.role, queue_policy=qpolicy,
                pipeline=pipe))
        cluster = ClusterWorker(spec.name, spec.role, replicas)
        cluster.spec = spec
        cluster.hw = hw_c
        clusters[spec.name] = cluster
        n_devices += spec.n_replicas * spec.devices_per_replica()

    controller.clusters.update(clusters)
    return SystemHandle(engine, controller, clusters, n_devices,
                        fabric=fabric)
