"""Vectorized batch evaluation of the analytical step-time model.

The scalar :meth:`ExecutionPredictor.step_time` walks the layer pattern
per call, looping over per-request shapes in Python — fine for one step,
ruinous for thousands of candidate batches (sweeps, router cache probes,
bench cells).  This module evaluates the SAME closed-form roofline math
over whole arrays of ``(q_lens, kv_lens)`` batch shapes at once:

- every roofline operator (GEMM / attention / grouped-GEMM / membound)
  contributes one ``(flops, bytes)`` row per layer term, vectorized
  across the B steps;
- per-request attention reductions use one concatenation plus
  ``np.add.reduceat`` instead of B Python loops;
- MoE layers are first-class: routing draws are made through
  ``routing.assign`` per ``(step, layer)`` in the *identical call order*
  as the scalar walk (same ``pred.rng`` sequence), capacity clipping and
  the per-EP-rank GroupedGEMM straggler ``max()`` are array reductions,
  and the dispatch/combine all-to-alls are linear terms;
- the ``numpy`` backend replays the scalar walk's exact term-by-term
  accumulation order, so per-step totals are **bit-identical** to the
  Python path (every flop/byte tally is an exact small integer in
  float64); the ``jit`` backend stacks the roof rows — grouped-GEMM and
  dense alike — into one cached ``jax.jit`` fused
  ``sum_t mult_t * max(F_t/peak, B_t/bw)`` kernel (float32 on CPU jax;
  looser tolerance).

Only base analytical operator models vectorize: refined/subclassed model
sets may override arbitrary operators, and predictor subclasses (the AF
event graph) replace the step walk entirely.  :func:`supports_vectorized`
gates those cases; the predictor falls back to the scalar walk per step.
Any :class:`~repro.core.routing.RoutingModule` is supported — stochastic
routers vectorize via pre-drawn count arrays with the draw sequence
preserved.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV
from repro.core.opmodels.analytical import OperatorModelSet

#: methods whose analytical closed form the vectorizer replicates; any
#: override on the installed OperatorModelSet disables vectorization
_ANALYTICAL_METHODS = ("gemm", "attention_prefill", "attention_decode",
                       "grouped_gemm", "all_reduce", "all_to_all", "p2p",
                       "membound", "_roof")


def supports_vectorized(pred) -> bool:
    """True when ``batch_step_totals`` reproduces ``pred.step_time``.

    MoE models vectorize for every routing module: the batch path draws
    ``routing.assign`` per ``(step, layer)`` in the scalar call order, so
    the ``pred.rng`` sequence — and therefore every count array — is
    identical to the per-step walk.
    """
    from repro.core.predictor import ExecutionPredictor
    if type(pred)._step_time_impl is not ExecutionPredictor._step_time_impl:
        return False                      # subclassed step walk (AF events)
    ops_t = type(pred.ops)
    return all(getattr(ops_t, m, None) is getattr(OperatorModelSet, m)
               for m in _ANALYTICAL_METHODS)


def expert_rank_map(n_experts: int, ep: int) -> np.ndarray:
    """Expert-index -> EP-rank map matching ``routing.split_by_rank``
    (contiguous shards; remainder experts spread over the first ranks)."""
    ep = max(int(ep), 1)
    base, rem = divmod(int(n_experts), ep)
    sizes = np.full(ep, base, np.int64)
    sizes[:rem] += 1
    return np.repeat(np.arange(ep), sizes)


def grouped_gemm_rank_times(ops, rank_sums, rank_groups, d_in: int,
                            d_out: int, n_mats: int,
                            dtype_bytes: int = 2) -> np.ndarray:
    """``[n_mats * ops.grouped_gemm(counts_r, d_in, d_out) for r]`` as one
    array expression over EP ranks.

    ``rank_sums[r]`` is the token total routed to rank ``r`` and
    ``rank_groups[r]`` its expert-group count.  Bit-identical to the
    scalar loop for the base analytical model because every flop/byte
    tally is an exact integer in float64 (products and sums below 2^53
    round nowhere).  ``ops`` may also be an array-like of per-rank
    ``(peak_flops, hbm_bw, op_overhead)`` triples via
    :func:`rank_hw_arrays` for heterogeneous expert clusters.
    """
    s = np.asarray(rank_sums, float)
    g = np.asarray(rank_groups, float)
    if isinstance(ops, tuple):
        peak, hbm, oh = ops
    else:
        hw = ops.hw
        peak, hbm, oh = hw.peak_flops, hw.hbm_bw, hw.op_overhead
    flops = 2.0 * d_in * d_out * s
    bytes_ = dtype_bytes * (d_in + d_out) * s + dtype_bytes * d_in * d_out * g
    return n_mats * (np.maximum(flops / peak, bytes_ / hbm) + oh)


def analytic_roofline_hw(ops) -> Optional[Tuple[float, float, float]]:
    """``(peak_flops, hbm_bw, op_overhead)`` when ``ops`` prices
    grouped-GEMMs with the base analytical roofline — unwrapping
    pure-delegating :class:`FabricOps` layers — else None (an overridden
    grouped_gemm/_roof must be called per rank)."""
    from repro.core.fabric import FabricOps
    o = ops
    while isinstance(o, FabricOps):
        o = o.inner
    t = type(o)
    if (t.grouped_gemm is OperatorModelSet.grouped_gemm
            and t._roof is OperatorModelSet._roof):
        return o.hw.peak_flops, o.hw.hbm_bw, o.hw.op_overhead
    return None


class _Terms:
    """Ordered term accumulator translating the scalar ``bd.add`` sequence
    into vectorized rows.

    The ``numpy`` evaluation replays the terms in emission order —
    ``total += mult * (max(F/peak, B/bw) + oh)`` per roof row, linear
    terms verbatim — which reproduces the scalar walk's accumulation
    order exactly.  The ``jit`` evaluation stacks the roof rows into the
    cached fused kernel (order-free sum; float32 tolerance).
    """

    def __init__(self, B: int, hw):
        self._seq: List[tuple] = []       # ("roof", F, Bt, mult) | ("lin", a)
        self.hw = hw
        self._b = B

    def roof(self, flops, bytes_, mult: float = 1.0) -> None:
        self._seq.append((
            "roof",
            np.broadcast_to(np.asarray(flops, float), (self._b,)),
            np.broadcast_to(np.asarray(bytes_, float), (self._b,)),
            mult))

    def lin(self, arr) -> None:
        self._seq.append(("lin",
                          np.broadcast_to(np.asarray(arr, float),
                                          (self._b,))))

    def gemm(self, m, n: int, k: int, mult: float = 1.0,
             dtype_bytes: int = 2) -> None:
        m = np.asarray(m, float)
        self.roof(2.0 * m * n * k,
                  dtype_bytes * (m * k + k * n + m * n), mult)

    def membound(self, nbytes, mult: float = 1.0) -> None:
        # max(0/peak, b/hbm) + oh == b/hbm + oh: bitwise the scalar path
        self.roof(0.0, nbytes, mult)

    def all_reduce(self, nbytes, n: int) -> None:
        if n <= 1:
            return
        bw = self.hw.intra_node_bw
        self.lin(2.0 * np.asarray(nbytes, float) * (n - 1) / n / bw
                 + self.hw.op_overhead)

    def all_to_all(self, nbytes, n: int) -> None:
        if n <= 1:
            return
        bw = self.hw.intra_node_bw
        self.lin(np.asarray(nbytes, float) * (n - 1) / n / bw
                 + self.hw.op_overhead)

    def evaluate(self, backend: str) -> np.ndarray:
        hw = self.hw
        if backend == "jit":
            F = [t[1] for t in self._seq if t[0] == "roof"]
            if F:
                fn = _fused_kernel(hw.peak_flops, hw.hbm_bw)
                if fn is not None:
                    Bt = np.stack([t[2] for t in self._seq
                                   if t[0] == "roof"])
                    mult = np.asarray([t[3] for t in self._seq
                                       if t[0] == "roof"], float)
                    out = np.asarray(fn(np.stack(F), Bt, mult), float)
                    out = out + mult.sum() * hw.op_overhead
                    for t in self._seq:
                        if t[0] == "lin":
                            out = out + t[1]
                    return out
        total = np.zeros(self._b)
        for t in self._seq:
            if t[0] == "roof":
                _, F, Bt, mult = t
                row = np.maximum(F / hw.peak_flops, Bt / hw.hbm_bw) \
                    + hw.op_overhead
                total = total + (row if mult == 1.0 else mult * row)
            else:
                total = total + t[1]
        return total


_KERNELS = {}


def _fused_kernel(peak: float, hbm: float):
    """One jit-compiled fused roofline evaluation per hardware point.
    Returns None when jax is unavailable (callers fall back to numpy)."""
    key = (peak, hbm)
    if key in _KERNELS:
        return _KERNELS[key]
    try:
        import jax
        import jax.numpy as jnp
    except ImportError:                   # gated dep: numpy fallback
        _KERNELS[key] = None
        return None

    @jax.jit
    def fused(F, Bt, mult):
        return (mult[:, None]
                * jnp.maximum(F / peak, Bt / hbm)).sum(axis=0)

    _KERNELS[key] = fused
    return fused


def _predraw_moe_rows(pred, toks_int: List[int], n_moe_layers: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-(layer, step) straggler-rank (max flops, max bytes) rows for the
    MoE GroupedGEMM barrier, with routing draws consumed from ``pred.rng``
    in the exact scalar order: step-major, layer-minor.

    The reduction exploits ``max_r max(F_r/p, B_r/b) ==
    max(max_r F_r / p, max_r B_r / b)`` (p, b positive constants), so the
    per-layer term stays one roofline row.
    """
    cfg, par = pred.cfg, pred.par
    moe = cfg.moe
    E, top_k = moe.num_experts, moe.top_k
    ep = max(par.ep, 1)
    tp_in_expert = max(par.tp // ep, 1)
    d_in, d_out = cfg.d_model, moe.expert_d_ff // tp_in_expert
    rank_of = expert_rank_map(E, ep)
    groups = np.bincount(rank_of, minlength=ep).astype(float)
    B = len(toks_int)
    maxF = np.empty((n_moe_layers, B))
    maxB = np.empty((n_moe_layers, B))
    stochastic = pred.routing.stochastic

    def rank_rows(toks: int) -> Tuple[float, float]:
        counts = pred.routing.assign(toks, E, top_k, pred.rng)
        cap = math.ceil(moe.capacity_factor_eval * toks * top_k / E)
        kept = np.minimum(counts, cap)
        s = np.bincount(rank_of, weights=kept, minlength=ep)
        flops = 2.0 * d_in * d_out * s
        bytes_ = 2 * (d_in + d_out) * s + 2 * d_in * d_out * groups
        return float(flops.max()), float(bytes_.max())

    for bi, toks in enumerate(toks_int):
        if stochastic:
            for li in range(n_moe_layers):
                maxF[li, bi], maxB[li, bi] = rank_rows(toks)
        else:
            # deterministic routing consumes no draws and depends only on
            # the token total: one evaluation covers every layer
            f, b = rank_rows(toks)
            maxF[:, bi] = f
            maxB[:, bi] = b
    return maxF, maxB


def batch_step_totals(pred, steps: Sequence[Tuple[Sequence[int],
                                                  Sequence[int]]],
                      *, decode: bool,
                      backend: str = "numpy") -> np.ndarray:
    """Vectorized ``[pred.step_time(q, kv, decode=...).total for q, kv in
    steps]`` for analytical-model predictors (see module doc).

    ``steps`` is a sequence of ``(q_lens, kv_lens)`` pairs; returns a
    float64 array of per-step totals in seconds.  Requires
    ``supports_vectorized(pred)``.  MoE predictors consume routing draws
    from ``pred.rng`` exactly as the scalar walk would (one ``assign``
    per attention layer per non-empty step, step-major order).
    """
    cfg, par, hw = pred.cfg, pred.par, pred.ops.hw
    B = len(steps)
    if B == 0:
        return np.zeros(0)
    tp = max(par.tp, 1)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    moe = cfg.moe

    lens = np.array([len(q) for q, _ in steps])
    live = lens > 0                       # zero-token steps price to 0.0
    idx = np.flatnonzero(live)
    if len(idx) == 0:
        return np.zeros(B)
    Q = np.concatenate([np.asarray(steps[i][0], float) for i in idx])
    KV = np.concatenate([np.asarray(steps[i][1], float) for i in idx])
    offs = np.concatenate(([0], np.cumsum(lens[idx])))[:-1]
    n_req = lens[idx].astype(float)
    toks = np.add.reduceat(Q, offs)

    if moe is not None:
        n_moe_layers = sum(1 for kind in cfg.pattern
                           if kind in (ATTN_GLOBAL, ATTN_LOCAL))
        toks_int = [int(sum(steps[i][0])) for i in idx]
        gg_maxF, gg_maxB = _predraw_moe_rows(pred, toks_int, n_moe_layers)
        ep = max(par.ep, 1)
        tp_in_expert = max(par.tp // ep, 1)
        moe_n_mats = 3 if cfg.gated_mlp else 2
        a2a_bytes = 2.0 * toks * moe.top_k * d / ep

    # per-window attention reductions, computed once and reused per layer
    attn_cache = {}

    def attn_sums(window: int):
        if window in attn_cache:
            return attn_cache[window]
        eff = np.minimum(KV, window) if window else KV
        if decode:
            pairs_sum = None
        else:
            factor = (np.where(Q == KV, 0.5, 1.0)
                      if not window else np.ones_like(Q))
            pairs_sum = np.add.reduceat(Q * eff * factor, offs)
        sums = (pairs_sum, np.add.reduceat(eff, offs),
                np.add.reduceat(Q, offs))
        attn_cache[window] = sums
        return sums

    t = _Terms(len(idx), hw)
    t.membound(2.0 * toks * d)                                    # embed
    moe_li = 0
    for kind in cfg.pattern:
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            window = cfg.sliding_window if kind == ATTN_LOCAL else 0
            t.gemm(toks, (H + 2 * K) * hd // tp, d)               # qkv
            pairs_sum, eff_sum, q_sum = attn_sums(window)
            if decode:
                t.roof(4.0 * (H // tp) * hd * eff_sum,
                       4.0 * eff_sum * max(K // tp, 1) * hd)
            else:
                t.roof(4.0 * (H // tp) * hd * pairs_sum,
                       2.0 * (q_sum * (H // tp)
                              + 2.0 * eff_sum * max(K // tp, 1)) * hd)
            t.gemm(toks, d, H * hd // tp)                         # o_gemm
            t.all_reduce(2.0 * toks * d, tp)
            if moe is not None:                                   # MoE ffn
                t.gemm(toks, moe.num_experts, d)                  # gate
                t.all_to_all(a2a_bytes, ep)                       # dispatch
                t.roof(gg_maxF[moe_li], gg_maxB[moe_li],
                       mult=moe_n_mats)                           # straggler
                t.all_to_all(a2a_bytes, ep)                       # combine
                if moe.num_shared_experts:
                    ff = moe.expert_d_ff * moe.num_shared_experts
                    t.gemm(toks, ff // tp, d, mult=moe_n_mats)
                if tp_in_expert > 1:
                    t.all_reduce(2.0 * toks * d, tp_in_expert)
                moe_li += 1
            else:
                n_mats = 3 if cfg.gated_mlp else 2                # dense ffn
                t.gemm(toks, cfg.d_ff // tp, d, mult=n_mats)
                t.all_reduce(2.0 * toks * d, tp)
        elif kind == RWKV:
            t.gemm(toks, d // tp, d, mult=5)
            Hh, hs = d // cfg.rwkv_head_size, cfg.rwkv_head_size
            t.membound(4.0 * toks * Hh * hs * hs / tp)
            t.gemm(toks, d, d // tp)
            t.all_reduce(2.0 * toks * d, tp)
            t.gemm(toks, cfg.d_ff // tp, d, mult=2)               # chan-mix
        else:                                                     # RG-LRU
            t.gemm(toks, d // tp, d, mult=2)
            t.gemm(toks, d // tp, d // tp, mult=2)
            t.membound(4.0 * toks * d / tp)
            t.gemm(toks, d, d // tp)
            t.all_reduce(2.0 * toks * d, tp)
            if kind == RECURRENT:
                n_mats = 3 if cfg.gated_mlp else 2
                t.gemm(toks, cfg.d_ff // tp, d, mult=n_mats)
                t.all_reduce(2.0 * toks * d, tp)
    n_logits = toks if decode else n_req
    t.gemm(n_logits, cfg.padded_vocab // tp, d)                   # head

    totals = t.evaluate(backend)
    pp = max(par.pp, 1)
    if pp > 1:
        m = np.maximum(n_req, 1.0)
        totals = totals * (pp + m - 1) / (m * pp) * pp
        totals = totals + ((2.0 * toks * d) / hw.inter_node_bw
                           + hw.op_overhead) * (pp - 1)
    totals = totals + pred.engine_overhead

    out = np.zeros(B)
    out[idx] = totals
    return out
