"""FlashDecode Pallas kernel: one query token vs a long KV cache.

Grid: (B*K, nk) — per (batch, kv-head) the kernel streams (bk, hd) KV tiles
sequentially with online-softmax state in VMEM; all G = H/K query heads of
the group are processed together as a (G, hd) q tile (so the KV tile is
read once per group — the GQA arithmetic-intensity win).  Per-row `lengths`
masks ring-buffer slots beyond the valid prefix.

Decode is KV-bandwidth bound; the roofline win vs the XLA path is reading
the KV cache exactly once at bf16 instead of materializing f32 scores.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e38


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, bk: int, nk: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid_len = len_ref[0]
    need = (ik * bk) < valid_len

    @pl.when(need)
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # (G, hd)
        k = k_ref[...].astype(jnp.float32)            # (bk, hd)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < valid_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where((m_new == NEG_INF)[:, None], 0.0, p)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, bk: int = 256,
                     interpret: bool = True) -> jax.Array:
    """q (B,H,hd); k/v (B,T,K,hd); lengths (B,) int32.  -> (B,H,hd)."""
    B, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = hd ** -0.5
    bk = min(bk, max(T, 8))
    Tp = math.ceil(T / bk) * bk
    nk = Tp // bk

    qr = q.reshape(B, K, g, hd).reshape(B * K, g, hd)
    kr = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vr = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kr = kr.transpose(0, 2, 1, 3).reshape(B * K, Tp, hd)
    vr = vr.transpose(0, 2, 1, 3).reshape(B * K, Tp, hd)
    lens = jnp.repeat(lengths.astype(jnp.int32), K).reshape(B * K, 1)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * K, nk),
        in_specs=[
            pl.BlockSpec((None, 1), lambda bh, ik: (bh, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, g, hd), lambda bh, ik: (bh, 0, 0)),
            pl.BlockSpec((None, bk, hd), lambda bh, ik: (bh, ik, 0)),
            pl.BlockSpec((None, bk, hd), lambda bh, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, g, hd), lambda bh, ik: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(B, K, g, hd).reshape(B, H, hd)
