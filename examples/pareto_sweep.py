"""Design-space exploration: throughput/interactivity Pareto frontier.

The motivating use-case of the paper — finding the optimal serving config
without burning 18,000 GPU-hours.  Sweeps (topology x parallelism x
batching policy) for qwen2-7b on a 16-GPU budget and prints the frontier.

    PYTHONPATH=src python examples/pareto_sweep.py
"""
from repro.configs import get_config
from repro.core import A800_SXM4_80G, ParallelismConfig, pareto_frontier
from repro.core.policies.batching import ChunkedPrefill, ContinuousBatching
from repro.core.workflows.colocated import build_colocated
from repro.core.workflows.pd_disagg import build_pd
from repro.workload.generator import WorkloadConfig, generate


def main():
    cfg = get_config("qwen2-7b")
    hw = A800_SXM4_80G
    wl = WorkloadConfig(n_requests=150, rate=25.0, prompt_mean=1024,
                        output_mean=128, seed=0)
    budget = 16
    candidates = []

    for tp in (1, 2, 4):
        n = budget // tp
        candidates.append((f"colo x{n} tp{tp} cont",
                           lambda tp=tp, n=n: build_colocated(
                               cfg, hw, n_replicas=n,
                               par=ParallelismConfig(tp=tp),
                               policy=ContinuousBatching())))
        candidates.append((f"colo x{n} tp{tp} chunked",
                           lambda tp=tp, n=n: build_colocated(
                               cfg, hw, n_replicas=n,
                               par=ParallelismConfig(tp=tp),
                               policy=ChunkedPrefill(chunk=512))))
    for n_p in (4, 8, 12):
        n_d = budget - n_p
        candidates.append((f"pd {n_p}P:{n_d}D",
                           lambda n_p=n_p, n_d=n_d: build_pd(
                               cfg, hw, n_prefill=n_p, n_decode=n_d)))

    points = []
    print(f"{'config':24s} {'tok/s/dev':>10s} {'tpot_p50(ms)':>13s} "
          f"{'ttft_p99(ms)':>13s}")
    for name, builder in candidates:
        rep = builder().run(generate(wl))
        thr = rep["throughput_tok_s_per_device"]
        inter = 1.0 / max(rep["tpot_p50_s"], 1e-9)
        points.append(((thr, inter), name, rep))
        print(f"{name:24s} {thr:10.1f} {rep['tpot_p50_s']*1e3:13.2f} "
              f"{rep['ttft_p99_s']*1e3:13.1f}")

    front = pareto_frontier([p for p, _, _ in points])
    names = [n for (p, n, _) in points if p in front]
    print("\nPareto frontier (throughput x interactivity):")
    for n in names:
        print("  *", n)


if __name__ == "__main__":
    main()
