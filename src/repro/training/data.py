"""Deterministic synthetic token pipeline (sharded, restartable).

Generates a Zipf-distributed token stream with short-range structure (a
seeded Markov chain over a small transition table) so next-token prediction
is learnable — the loss should drop visibly over a few hundred steps, which
the end-to-end train driver and tests assert.

Determinism contract: batch(step, dp_rank) is a pure function of
(seed, step, dp_rank) — restart-safe and order-independent, the property a
fault-tolerant data loader must provide at scale.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64          # markov states


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, S = cfg.vocab_size, cfg.n_states
        # sharply peaked markov transitions; states emit zipf tokens — low
        # conditional entropy so next-token prediction is clearly learnable
        self.trans = rng.dirichlet(np.full(S, 0.05), size=S)
        ranks = np.arange(1, V + 1, dtype=np.float64)
        base = ranks ** -2.0
        self.emit = np.stack([
            np.roll(base, rng.integers(0, V)) for _ in range(S)])
        self.emit /= self.emit.sum(1, keepdims=True)
        self.trans_cum = np.cumsum(self.trans, axis=1)
        self.emit_cum = np.cumsum(self.emit, axis=1)

    def batch(self, step: int, dp_rank: int = 0, dp_size: int = 1
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b_local = cfg.global_batch // dp_size
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + dp_rank)
        T = cfg.seq_len + 1
        toks = np.empty((b_local, T), np.int32)
        s = rng.integers(0, cfg.n_states, b_local)
        for t in range(T):   # vectorized over batch
            u_tok = rng.random((b_local, 1))
            toks[:, t] = (self.emit_cum[s] < u_tok).sum(axis=1)
            u_s = rng.random((b_local, 1))
            s = (self.trans_cum[s] < u_s).sum(axis=1)
        np.clip(toks, 0, cfg.vocab_size - 1, out=toks)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
