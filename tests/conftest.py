import os
import sys
from pathlib import Path

# src-layout import without installation
ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

# Keep tests on the single real CPU device (the 512-device override is
# reserved for dryrun.py, which tests exercise via subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
