"""Fleet control-plane tests: spec plumbing, routing policies, autoscaling
lifecycle, P:D rebalancing, fleet-wide conservation (hypothesis), and
byte-identical determinism of FleetReport."""
import json

import pytest

from repro.api import SimSpec, SpecError, run
from repro.api.run import Report
from repro.fleet import FLEET_ROUTERS, FleetReport, resolve_fleet_router
from repro.fleet.router import PrefixAffinityRouter

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property test skips; the rest still runs
    HAVE_HYPOTHESIS = False

SMOKE = {"name": "qwen2-7b", "smoke": True}


def _fleet_spec(n_requests=60, router="least_outstanding", instances=None,
                autoscaler=None, tenants=None, faults=None, **workload):
    wl = {"n_requests": n_requests, "rate": 40.0, "prompt_mean": 128,
          "output_mean": 16, "seed": 9}
    wl.update(workload)
    d = {
        "name": "fleet-test",
        "model": SMOKE,
        "topology": {"preset": "colocated"},
        "workload": wl,
        "fleet": {
            "instances": instances or [{"name": "colo", "count": 2}],
            "router": router,
        },
        "seed": 9,
    }
    if autoscaler is not None:
        d["fleet"]["autoscaler"] = autoscaler
    if tenants is not None:
        d["fleet"]["tenants"] = tenants
    if faults is not None:
        d["faults"] = faults
    return SimSpec.from_dict(d)


# ------------------------------------------------------------------ spec --
def test_fleet_spec_round_trip():
    spec = _fleet_spec(
        instances=[{"name": "a", "count": 2},
                   {"name": "b", "count": 1,
                    "topology": {"preset": "pd", "n_decode": 2},
                    "memory": {"manager": "prefix"}}],
        autoscaler={"max_instances": 4, "template": "a"},
        tenants=[{"name": "paid", "weight": 1.0, "ttft_s": 0.5}])
    assert SimSpec.from_yaml(spec.to_yaml()) == spec
    assert SimSpec.from_dict(spec.to_dict()) == spec
    assert SimSpec.from_yaml(spec.to_yaml()).spec_hash() == spec.spec_hash()


def test_fleet_spec_validation_errors():
    with pytest.raises(SpecError, match="fleet.instances"):
        SimSpec.from_dict({"model": SMOKE,
                           "fleet": {"instances": []}}).validate()
    with pytest.raises(KeyError, match="unknown fleet router"):
        resolve_fleet_router("nope")
    with pytest.raises(SpecError, match="fleet.router"):
        _fleet_spec(router="nope").validate()
    with pytest.raises(SpecError, match="duplicate group"):
        _fleet_spec(instances=[{"name": "a"}, {"name": "a"}]).validate()
    with pytest.raises(SpecError, match="closed-loop"):
        _fleet_spec(arrival="closed", concurrency=4).validate()
    with pytest.raises(SpecError, match="min_instances"):
        _fleet_spec(autoscaler={"min_instances": 3,
                                "max_instances": 1}).validate()
    with pytest.raises(SpecError, match="unknown instance group"):
        _fleet_spec(autoscaler={"template": "nope"}).validate()
    with pytest.raises(SpecError, match="weight"):
        _fleet_spec(tenants=[{"name": "t", "weight": 0}]).validate()
    with pytest.raises(SpecError, match="named instances"):
        spec = SimSpec.from_dict({
            "model": SMOKE,
            "faults": [{"kind": "failure", "cluster": "colocated",
                        "instance": "colo"}]})
        spec.validate()


def test_registry_has_all_four_policies():
    assert set(FLEET_ROUTERS) == {"round_robin", "least_outstanding",
                                  "power_of_two", "prefix_affinity"}
    r = resolve_fleet_router({"name": "prefix_affinity",
                              "overload_factor": 3.0})
    assert isinstance(r, PrefixAffinityRouter)
    assert r.overload_factor == 3.0


def test_single_instance_specs_unchanged():
    """No fleet section -> the legacy Report path, bit-for-bit."""
    d = {"model": SMOKE,
         "workload": {"n_requests": 20, "rate": 20.0, "seed": 1},
         "seed": 1}
    rep = run(SimSpec.from_dict(d))
    assert isinstance(rep, Report) and not isinstance(rep, FleetReport)
    assert rep.all_complete


# --------------------------------------------------------------- routing --
def test_every_router_conserves_and_completes():
    for router in sorted(FLEET_ROUTERS):
        rep = run(_fleet_spec(router=router))
        assert isinstance(rep, FleetReport)
        assert rep.all_complete, (router, rep.conservation)
        assert sum(i["routed"] for i in rep.instances.values()) == 60


def test_round_robin_is_even():
    rep = run(_fleet_spec(router="round_robin", n_requests=64))
    counts = [i["routed"] for i in rep.instances.values()]
    assert counts == [32, 32]
    assert rep.summary["routing_imbalance"] == 0.0


def test_prefix_affinity_beats_round_robin_on_hit_rate():
    """Acceptance: cache-aware routing exploits the PR-4 prefix cache —
    one cold miss per group instead of one per (group, instance)."""
    base = {
        "model": SMOKE,
        "topology": {"preset": "colocated"},
        "workload": {"n_requests": 200, "rate": 40.0, "prompt_mean": 128,
                     "output_mean": 16, "prefix_groups": 8,
                     "prefix_len": 512, "seed": 5},
        "memory": {"manager": "prefix"},
        "fleet": {"instances": [{"name": "colo", "count": 4}]},
        "seed": 5,
    }
    hits = {}
    for router in ("round_robin", "prefix_affinity"):
        d = json.loads(json.dumps(base))
        d["fleet"]["router"] = router
        rep = run(SimSpec.from_dict(d))
        assert rep.all_complete
        hits[router] = rep.summary["prefix_hit_token_frac"]
    assert hits["prefix_affinity"] > hits["round_robin"]


# ----------------------------------------------------------- autoscaling --
def test_scale_up_has_cold_start_and_scale_down_drains():
    rep = run(_fleet_spec(
        n_requests=800, rate=120.0, prompt_mean=512, output_mean=64,
        instances=[{"name": "colo", "count": 1}],
        autoscaler={"min_instances": 1, "max_instances": 4,
                    "interval_s": 1.0, "cooldown_s": 2.0,
                    "up_queue_depth": 6.0, "down_queue_depth": 1.0,
                    "provision_bw": 64e9, "startup_base_s": 0.5}))
    assert rep.all_complete
    assert rep.summary["scale_up_events"] >= 1
    ups = {e["instance"]: e for e in rep.scale_events
           if e["kind"] == "scale_up"}
    readies = {e["instance"]: e for e in rep.scale_events
               if e["kind"] == "ready"}
    for name, up in ups.items():
        assert up["cold_start_s"] > 0.5          # weight load is modeled
        assert readies[name]["t"] == pytest.approx(
            up["t"] + up["cold_start_s"])
    # a drained instance released its GPUs and kept its completed work
    for e in rep.scale_events:
        if e["kind"] == "drained":
            blk = rep.instances[e["instance"]]
            assert blk["state"] == "stopped"
            assert blk["outstanding"] == 0
    assert rep.summary["provisioned_gpu_seconds"] > 0
    assert rep.summary["idle_gpu_seconds"] >= 0


def test_pd_rebalance_moves_capacity():
    rep = run(_fleet_spec(
        n_requests=300, arrival="burst", burst_size=100, burst_period=2.0,
        prompt="fixed", prompt_mean=2048, output="fixed", output_mean=8,
        instances=[{"name": "pd", "count": 1,
                    "topology": {"preset": "pd", "n_prefill": 1,
                                 "n_decode": 2}}],
        autoscaler={"min_instances": 1, "max_instances": 1,
                    "interval_s": 0.25, "cooldown_s": 0.5,
                    "up_queue_depth": 1e9,
                    "pd_rebalance": True, "pd_spares": 1,
                    "rebalance_ratio": 2.0, "reconfigure_s": 0.2}))
    assert rep.all_complete
    assert rep.summary["rebalance_events"] >= 1
    moves = [e for e in rep.scale_events if e["kind"] == "rebalance"]
    assert all(e["moved"] in ("decode->prefill", "prefill->decode")
               for e in moves)


def test_build_rejects_fleet_specs():
    """build() compiles one deployment; silently dropping the fleet
    section would yield plausible-but-wrong single-instance results."""
    from repro.api import build
    with pytest.raises(SpecError, match="fleet"):
        build(_fleet_spec())


def test_cluster_keyed_batching_must_exist_in_every_group():
    """The policy section is shared by every instance: a batching key
    naming one group's inline cluster fails at validate(), not mid-build
    of another group."""
    spec = _fleet_spec(
        instances=[{"name": "inline", "count": 1,
                    "topology": {"preset": None, "clusters": [
                        {"name": "pre", "role": "prefill"},
                        {"name": "dec", "role": "decode"}]}},
                   {"name": "colo", "count": 1}])
    spec.policy.batching = {"pre": {"name": "continuous"}}
    with pytest.raises(SpecError, match="policy.batching"):
        spec.validate()


def test_spares_excluded_from_device_accounting():
    """Parked P:D standbys hold no GPUs: the instance's device count and
    GPU-second integral cover only the serving replicas."""
    rep = run(_fleet_spec(
        n_requests=40,
        instances=[{"name": "pd", "count": 1,
                    "topology": {"preset": "pd", "n_prefill": 1,
                                 "n_decode": 1}}],
        autoscaler={"min_instances": 1, "max_instances": 1,
                    "interval_s": 0.5, "up_queue_depth": 1e9,
                    "pd_rebalance": True, "pd_spares": 1}))
    assert rep.all_complete
    blk = next(iter(rep.instances.values()))
    if not rep.summary["rebalance_events"]:
        assert blk["devices"] == 2       # 1 prefill + 1 decode, no spares
        assert blk["gpu_seconds"] <= 2 * rep.summary["duration_s"] * 1.5


def test_idle_autoscaler_does_not_inflate_gpu_seconds():
    """Regression: trailing AUTOSCALE_TICK events past the last completion
    must not be charged as provisioned/idle capacity — an autoscaler that
    never acts reports the same GPU-seconds as no autoscaler at all."""
    plain = run(_fleet_spec(n_requests=60))
    lazy = run(_fleet_spec(
        n_requests=60,
        autoscaler={"min_instances": 2, "max_instances": 2,
                    "interval_s": 0.5, "up_queue_depth": 1e9,
                    "down_queue_depth": -1.0}))
    assert lazy.summary["scale_up_events"] == 0
    assert lazy.summary["scale_down_events"] == 0
    assert lazy.summary["provisioned_gpu_seconds"] == pytest.approx(
        plain.summary["provisioned_gpu_seconds"])
    assert lazy.summary["idle_gpu_seconds"] == pytest.approx(
        plain.summary["idle_gpu_seconds"])


def test_fault_cluster_checked_against_target_group_at_validate():
    """Regression: a fault naming a cluster from a DIFFERENT group than
    its instance target must fail at validate(), not mid-build."""
    spec = _fleet_spec(
        instances=[{"name": "colo", "count": 1},
                   {"name": "pd", "count": 1,
                    "topology": {"preset": "pd"}}],
        faults=[{"kind": "failure", "cluster": "prefill", "replica": 0,
                 "instance": "colo"}])
    with pytest.raises(SpecError, match="faults\\[0\\].cluster"):
        spec.validate()


def test_spill_during_total_instance_outage_conserves():
    """Regression: an instance whose ONLY replica is down must reject
    arrivals without registering them — a phantom entry would pin its
    outstanding() above zero forever (hanging autoscaler ticks and
    drains) and break fleet conservation."""
    rep = run(_fleet_spec(
        n_requests=40, rate=40.0,
        instances=[{"name": "a", "count": 1,
                    "topology": {"preset": "colocated", "n_replicas": 1}},
                   {"name": "b", "count": 1}],
        autoscaler={"min_instances": 1, "max_instances": 2,
                    "interval_s": 0.5},
        faults=[{"kind": "failure", "cluster": "colocated", "replica": 0,
                 "at": 0.0, "downtime": 1.0, "instance": "a"}]))
    assert rep.all_complete
    assert rep.conservation == {"complete": 40}
    # every registered request completed where it was routed
    for blk in rep.instances.values():
        assert blk["outstanding"] == 0
        assert blk["routed"] == blk["conservation"].get("complete", 0)


def test_pd_rebalance_leaves_inline_topologies_untouched():
    """Regression: spares are only bumped into pd-PRESET pools; an inline
    PD graph must keep every declared replica serving (parking its only
    prefill replica would deadlock arrivals)."""
    rep = run(_fleet_spec(
        n_requests=30,
        instances=[{"name": "inline", "count": 1,
                    "topology": {"preset": None, "clusters": [
                        {"name": "pre", "role": "prefill",
                         "n_replicas": 1},
                        {"name": "dec", "role": "decode",
                         "n_replicas": 1}]}}],
        autoscaler={"min_instances": 1, "max_instances": 1,
                    "interval_s": 0.5, "pd_rebalance": True,
                    "pd_spares": 1}))
    assert rep.all_complete
    assert rep.summary["rebalance_events"] == 0


# -------------------------------------------------------------- tenants --
def test_tenant_classes_and_slos():
    rep = run(_fleet_spec(
        n_requests=200,
        tenants=[{"name": "paid", "weight": 1, "ttft_s": 0.5},
                 {"name": "free", "weight": 3, "ttft_s": 2.0,
                  "priority": 1}]))
    assert rep.all_complete
    assert set(rep.tenants) == {"paid", "free"}
    n_paid = rep.tenants["paid"]["n_completed"]
    n_free = rep.tenants["free"]["n_completed"]
    assert n_paid + n_free == 200
    assert n_free > n_paid                      # 3:1 weighted draw
    for t in rep.tenants.values():
        assert t["slo_attainment"] is not None
    assert rep.summary["tenant_slo_attainment_min"] == min(
        t["slo_attainment"] for t in rep.tenants.values())


# --------------------------------------------------------- determinism --
def test_fleet_report_byte_identical_across_runs():
    spec = _fleet_spec(
        n_requests=120, router="power_of_two",
        instances=[{"name": "colo", "count": 2},
                   {"name": "pd", "count": 1,
                    "topology": {"preset": "pd"}}],
        autoscaler={"max_instances": 4, "interval_s": 0.5,
                    "up_queue_depth": 4.0},
        tenants=[{"name": "a", "weight": 1}, {"name": "b", "weight": 2}])

    def blob():
        d = run(SimSpec.from_dict(spec.to_dict())).to_dict()
        d.pop("wall_clock_s")
        d.pop("created_at")
        return json.dumps(d, sort_keys=True, default=float)

    assert blob() == blob()


# -------------------------------------------------------- windowed mode --
def _normalized_blob(spec_dict):
    """FleetReport JSON with the fields that legitimately differ between
    engine modes removed: provenance (spec/spec_hash embed the mode),
    mode-tagged summary keys, and sim_events (windowed mode fires one
    extra hand-off event per deferred arrival)."""
    d = run(SimSpec.from_dict(spec_dict)).to_dict()
    for k in ("wall_clock_s", "created_at", "spec", "spec_hash",
              "sim_events"):
        d.pop(k, None)
    d["summary"].pop("fleet_engine_mode", None)
    d["summary"].pop("fleet_window_s", None)
    return json.dumps(d, sort_keys=True, default=float)


def test_windowed_zero_window_matches_serial_on_golden_spec():
    import copy
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).parent))
    from test_golden import SPECS
    serial = copy.deepcopy(SPECS["fleet_pd"])
    windowed = copy.deepcopy(SPECS["fleet_pd"])
    windowed["fleet"] = dict(windowed["fleet"], engine="windowed",
                             window_s=0.0)
    assert _normalized_blob(serial) == _normalized_blob(windowed)


def test_windowed_nonzero_window_is_deterministic_and_complete():
    import copy
    spec = _fleet_spec(
        n_requests=80, router="prefix_affinity",
        instances=[{"name": "colo", "count": 3}],
        autoscaler={"max_instances": 5, "interval_s": 0.5,
                    "up_queue_depth": 4.0}).to_dict()
    spec["fleet"] = dict(spec["fleet"], engine="windowed", window_s=0.2)
    a = _normalized_blob(copy.deepcopy(spec))
    b = _normalized_blob(copy.deepcopy(spec))
    assert a == b                        # deterministic given the window
    rep = run(SimSpec.from_dict(spec))
    assert rep.all_complete
    assert rep.summary["fleet_engine_mode"] == "windowed"
    assert rep.summary["fleet_window_s"] == 0.2


def test_fleet_engine_spec_validation():
    spec = _fleet_spec().to_dict()
    spec["fleet"]["engine"] = "threads"
    with pytest.raises(SpecError, match="engine"):
        SimSpec.from_dict(spec).validate()
    spec["fleet"]["engine"] = "windowed"
    spec["fleet"]["window_s"] = -1.0
    with pytest.raises(SpecError, match="window_s"):
        SimSpec.from_dict(spec).validate()


# ------------------------------------------------- conservation property --
def _check_conservation(preset, router, counts, n_requests, fault_at, seed):
    """Shared body: every arrived request ends complete on exactly one
    instance, fleet-wide, whatever the fleet shape / router / faults."""
    topo = {"preset": preset, "n_replicas": 2} if preset == "colocated" \
        else {"preset": preset, "n_prefill": 2, "n_decode": 2}
    instances = [{"name": "a", "count": counts[0], "topology": topo}]
    if counts[1]:
        instances.append({"name": "b", "count": counts[1]})
    faults = None
    if fault_at is not None:
        cluster = "colocated" if preset == "colocated" else "prefill"
        faults = [{"kind": "failure", "cluster": cluster, "replica": 0,
                   "at": fault_at, "downtime": 0.4, "instance": "a"}]
    rep = run(_fleet_spec(n_requests=n_requests, router=router,
                          instances=instances, faults=faults, seed=seed))
    assert rep.conservation == {"complete": n_requests}
    assert rep.all_complete
    # exactly-once: per-instance conservation sums to the fleet total and
    # every instance's requests completed where they were routed
    per_inst = [i["conservation"].get("complete", 0)
                for i in rep.instances.values()]
    assert sum(per_inst) == n_requests


@pytest.mark.parametrize("preset,router,fault_at", [
    ("colocated", "round_robin", None),
    ("pd", "prefix_affinity", 0.3),
    ("colocated", "power_of_two", 0.0),
    ("pd", "least_outstanding", 0.8),
])
def test_fleet_conservation_matrix(preset, router, fault_at):
    """Deterministic slice of the property below (runs without hypothesis)."""
    _check_conservation(preset, router, (2, 1), 30, fault_at, seed=1)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(
        preset=st.sampled_from(["colocated", "pd"]),
        router=st.sampled_from(sorted(FLEET_ROUTERS)),
        counts=st.tuples(st.integers(1, 2), st.integers(0, 2)),
        n_requests=st.integers(10, 40),
        fault_at=st.one_of(st.none(), st.floats(0.0, 1.0)),
        seed=st.integers(0, 3),
    )
    def test_fleet_wide_conservation(preset, router, counts, n_requests,
                                     fault_at, seed):
        """Over random fleets, routers, and fault injections: every
        arrived request completes exactly once across all instances."""
        _check_conservation(preset, router, counts, n_requests, fault_at,
                            seed)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fleet_wide_conservation():
        pass
