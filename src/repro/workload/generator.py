"""Workload generation: request traces with configurable arrivals/lengths."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.core.request import Request


@dataclass
class WorkloadConfig:
    n_requests: int = 100
    arrival: str = "poisson"            # "poisson" | "uniform" | "burst" | "closed"
    rate: float = 4.0                   # requests/s (open-loop)
    prompt: str = "lognormal"           # "fixed" | "uniform" | "lognormal" | "bimodal"
    prompt_mean: int = 512
    prompt_max: int = 8192
    output: str = "lognormal"
    output_mean: int = 128
    output_max: int = 2048
    seed: int = 0


def _lengths(kind: str, mean: int, maxv: int, n: int,
             rng: np.random.Generator) -> np.ndarray:
    if kind == "fixed":
        return np.full(n, mean, np.int64)
    if kind == "uniform":
        return rng.integers(1, 2 * mean, n)
    if kind == "bimodal":
        short = rng.integers(max(mean // 8, 1), mean // 2, n)
        long_ = rng.integers(mean * 2, mean * 4, n)
        pick = rng.random(n) < 0.7
        return np.where(pick, short, long_)
    # lognormal with mean ~= mean (ShareGPT-ish heavy tail)
    sigma = 1.0
    mu = np.log(mean) - sigma ** 2 / 2
    v = rng.lognormal(mu, sigma, n)
    return np.clip(v.astype(np.int64), 1, maxv)


def generate(cfg: WorkloadConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_requests
    if cfg.arrival == "poisson":
        gaps = rng.exponential(1.0 / cfg.rate, n)
        arrivals = np.cumsum(gaps)
    elif cfg.arrival == "uniform":
        arrivals = np.sort(rng.uniform(0, n / cfg.rate, n))
    elif cfg.arrival == "burst":
        arrivals = np.zeros(n)
    elif cfg.arrival == "closed":
        arrivals = np.zeros(n)          # closed-loop: all queued at t=0
    else:
        raise ValueError(cfg.arrival)
    plens = _lengths(cfg.prompt, cfg.prompt_mean, cfg.prompt_max, n, rng)
    olens = _lengths(cfg.output, cfg.output_mean, cfg.output_max, n, rng)
    return [Request(rid=i, arrival=float(arrivals[i]),
                    prompt_len=int(plens[i]), output_len=max(int(olens[i]), 1))
            for i in range(n)]


def fixed_batch(n: int, prompt_len: int, output_len: int) -> List[Request]:
    """The paper's Table-2 style workload: B requests, fixed lens, t=0."""
    return [Request(rid=i, arrival=0.0, prompt_len=prompt_len,
                    output_len=output_len) for i in range(n)]
