"""Golden-report regression fixtures for the three canonical presets.

Fails with a per-metric diff when a summary drifts by more than 1e-6
(relative) without an intentional update.  To bless new numbers after an
intended simulator change:

    REPRO_UPDATE_GOLDENS=1 PYTHONPATH=src python -m pytest tests/test_golden.py
"""
import json
import os
from pathlib import Path

import pytest

from repro.api import SimSpec, run

GOLDEN_DIR = Path(__file__).parent / "golden"
RTOL = 1e-6

SPECS = {
    "colocated": {
        "name": "golden-colocated",
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "colocated", "n_replicas": 2, "tp": 1},
        "workload": {"n_requests": 60, "rate": 30.0, "prompt_mean": 512,
                     "output_mean": 64, "seed": 11},
        "slo": {"ttft_s": 1.0, "tpot_s": 0.05},
        "seed": 11,
    },
    "pd_disagg": {
        "name": "golden-pd",
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "pd", "n_prefill": 1, "n_decode": 2},
        "workload": {"n_requests": 60, "rate": 25.0, "prompt_mean": 1024,
                     "output_mean": 96, "seed": 12},
        "seed": 12,
    },
    "af_moe": {
        "name": "golden-af-moe",
        "model": {"name": "mixtral-8x7b", "smoke": True},
        "topology": {"preset": "af", "n_prefill": 1, "n_decode": 1,
                     "m": 4, "ffn_ep": 4},
        "workload": {"n_requests": 40, "rate": 20.0, "prompt_mean": 256,
                     "output_mean": 32, "seed": 13},
        "pipeline": {"preset": "two_batch", "ep_overlap": 0.5},
        "seed": 13,
    },
    # the fleet control plane end-to-end: a heterogeneous PD+colocated
    # fleet behind cache-aware routing, tenant classes with per-class
    # SLOs, and an autoscaler chasing a diurnal arrival curve
    "fleet_pd": {
        "name": "golden-fleet-pd",
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "colocated"},
        "workload": {"n_requests": 120, "rate": 40.0,
                     "rate_curve": "diurnal", "rate_period": 10.0,
                     "rate_amplitude": 0.7, "prompt_mean": 256,
                     "output_mean": 32, "prefix_groups": 4,
                     "prefix_len": 256, "seed": 15},
        "memory": {"manager": "prefix"},
        "slo": {"ttft_s": 0.5, "tpot_s": 0.05},
        "fleet": {
            "instances": [
                {"name": "colo", "count": 2},
                {"name": "pd", "count": 1,
                 "topology": {"preset": "pd", "n_prefill": 1,
                              "n_decode": 1}},
            ],
            "router": "prefix_affinity",
            "autoscaler": {"min_instances": 1, "max_instances": 4,
                           "interval_s": 0.5, "cooldown_s": 1.0,
                           "up_queue_depth": 8.0,
                           "down_queue_depth": 1.0},
            "tenants": [
                {"name": "paid", "weight": 1, "ttft_s": 0.3},
                {"name": "free", "weight": 3, "ttft_s": 1.0,
                 "priority": 1},
            ],
        },
        "seed": 15,
    },
    # the shared network fabric end-to-end: AF disagg whose M2N dispatch
    # and KV transfers are priced over an oversubscribed shared uplink —
    # exposed comm must strictly exceed the uncontended sum (contention)
    "fabric_af": {
        "name": "golden-fabric-af",
        "model": {"name": "mixtral-8x7b", "smoke": True},
        "topology": {"preset": "af", "n_prefill": 1, "n_decode": 1,
                     "m": 4, "ffn_ep": 4,
                     "fabric": {"mode": "shared",
                                "oversubscription": 2.0,
                                "latency_s": 5e-6}},
        "workload": {"n_requests": 40, "rate": 20.0, "prompt_mean": 256,
                     "output_mean": 32, "seed": 13},
        "pipeline": {"preset": "two_batch", "ep_overlap": 0.5},
        "seed": 16,
    },
    # the memory subsystem end-to-end: prefix-caching manager on a
    # shared-prefix workload, layer-wise streamed KV transfer, and a
    # capacity small enough that decode growth preempts (recompute)
    "memory_pd": {
        "name": "golden-memory-pd",
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "pd", "n_prefill": 1, "n_decode": 1},
        "workload": {"n_requests": 40, "arrival": "burst", "burst_size": 20,
                     "burst_period": 2.0, "prompt": "fixed",
                     "prompt_mean": 128, "output": "fixed",
                     "output_mean": 1024, "prefix_groups": 4,
                     "prefix_len": 512, "seed": 14},
        "memory": {"manager": "prefix", "capacity_frac": 0.0001,
                   "preemption": "recompute", "transfer_overlap": 0.8},
        "seed": 14,
    },
}


def _golden_payload(rep):
    return {"spec_hash": rep.spec_hash, "summary": rep.summary}


def _diff(expected, actual):
    """Readable per-key drift report; empty list means 'matches'."""
    lines = []
    for key in sorted(set(expected) | set(actual)):
        e, a = expected.get(key, "<missing>"), actual.get(key, "<missing>")
        if isinstance(e, float) and isinstance(a, float):
            tol = RTOL * max(abs(e), abs(a), 1e-12)
            if abs(e - a) > tol:
                lines.append(f"  {key}: golden={e!r} actual={a!r} "
                             f"(drift {a - e:+.3e})")
        elif e != a:
            lines.append(f"  {key}: golden={e!r} actual={a!r}")
    return lines


@pytest.mark.parametrize("preset", sorted(SPECS))
def test_summary_matches_golden(preset):
    rep = run(SimSpec.from_dict(SPECS[preset]))
    path = GOLDEN_DIR / f"{preset}.json"
    if os.environ.get("REPRO_UPDATE_GOLDENS"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(json.dumps(_golden_payload(rep), indent=2,
                                   sort_keys=True) + "\n")
        pytest.skip(f"golden updated: {path}")
    assert path.exists(), (
        f"missing golden fixture {path}; generate it with "
        f"REPRO_UPDATE_GOLDENS=1")
    golden = json.loads(path.read_text())
    drift = _diff(golden["summary"], rep.summary)
    if golden["spec_hash"] != rep.spec_hash:
        drift.insert(0, f"  spec_hash: golden={golden['spec_hash']} "
                        f"actual={rep.spec_hash} (the spec schema or "
                        f"defaults changed)")
    assert not drift, (
        f"golden report '{preset}' drifted (>{RTOL:g} rel):\n"
        + "\n".join(drift)
        + "\nIf intentional, re-bless with REPRO_UPDATE_GOLDENS=1")


def test_fabric_golden_shows_contention():
    """The fabric-on golden must expose strictly more comm time than the
    uncontended sum — oversubscription and overlapping flows cost real
    simulated time, or the fabric layer is not actually wired in."""
    path = GOLDEN_DIR / "fabric_af.json"
    if not path.exists():
        pytest.skip("goldens not generated yet")
    s = json.loads(path.read_text())["summary"]
    assert s["fabric_transfers"] > 0
    assert s["fabric_exposed_comm_s"] > s["fabric_uncontended_comm_s"]
    assert s["fabric_contention_delay_s"] > 0


def test_goldens_complete_and_valid_json():
    for preset in SPECS:
        path = GOLDEN_DIR / f"{preset}.json"
        if not path.exists():
            pytest.skip("goldens not generated yet")
        payload = json.loads(path.read_text())   # strict: NaN would raise
        json.loads(json.dumps(payload["summary"], allow_nan=False))
        assert payload["summary"]["n_completed"] > 0
