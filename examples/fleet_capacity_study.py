"""Fleet capacity study: router x instance-count x autoscaler under
diurnal load.

Sweeps the global routing policy, the provisioned fleet size, and
whether the SLO-driven autoscaler may resize the fleet, over a
shared-prefix diurnal workload — the capacity-planning question a fleet
operator actually asks: how few GPUs hold the SLO through the daily
peak, and how much does cache-aware routing buy?

    PYTHONPATH=src python examples/fleet_capacity_study.py
"""
import json
import os

from repro.api import SimSpec, sweep

SMOKE = bool(int(os.environ.get("SMOKE", "1")))


def instance_groups(n: int):
    """A heterogeneous fleet of n instances: 3/4 colocated, 1/4 PD."""
    return [
        {"name": "colo", "count": n - n // 4},
        {"name": "pd", "count": n // 4,
         "topology": {"preset": "pd", "n_prefill": 1, "n_decode": 1}},
    ]


def main():
    base = SimSpec.from_dict({
        "name": "fleet-capacity",
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "colocated"},
        "workload": {"n_requests": 600 if SMOKE else 5000, "rate": 200.0,
                     "rate_curve": "diurnal", "rate_period": 20.0,
                     "rate_amplitude": 0.7, "prompt_mean": 256,
                     "output_mean": 32, "prefix_groups": 12,
                     "prefix_len": 256, "seed": 0},
        "memory": {"manager": "prefix"},
        "slo": {"ttft_s": 0.5, "tpot_s": 0.05},
        "fleet": {"instances": instance_groups(4),
                  "router": "least_outstanding"},
        "seed": 0,
    })
    autoscaler = {"min_instances": 2, "max_instances": 24,
                  "interval_s": 1.0, "cooldown_s": 2.0,
                  "up_queue_depth": 8.0, "down_queue_depth": 1.0,
                  "slo_attainment_floor": 0.9, "provision_bw": 64e9,
                  "startup_base_s": 1.0}
    axes = {
        "fleet.router": ["round_robin", "least_outstanding",
                         "power_of_two", "prefix_affinity"],
        "fleet.instances": [instance_groups(4), instance_groups(8)],
        "fleet.autoscaler": [None, autoscaler],
    }
    reports = sweep(base, axes, jsonl="artifacts/fleet_capacity.jsonl")

    hdr = (f"{'router':18s} {'inst':>4s} {'auto':>5s} {'ttft_p99':>9s} "
           f"{'slo':>6s} {'hit%':>6s} {'imbal':>6s} {'idle_gpu_s':>10s} "
           f"{'scale':>6s}")
    print("\n" + hdr + "\n" + "-" * len(hdr))
    for rep in reports:
        p = rep.point
        n0 = sum(g["count"] for g in p["fleet.instances"])
        auto = p["fleet.autoscaler"] is not None
        s = rep.summary
        hit = s.get("prefix_hit_token_frac")
        print(f"{p['fleet.router']:18s} {n0:4d} {str(auto):>5s} "
              f"{s['ttft_p99_s']:9.4f} {s.get('slo_attainment', 0):6.3f} "
              f"{'' if hit is None else f'{100 * hit:6.2f}'} "
              f"{s.get('routing_imbalance') or 0:6.3f} "
              f"{s['idle_gpu_seconds']:10.1f} "
              f"{s['scale_up_events'] + s['scale_down_events']:6d}")

    # the cache-aware routing headline: fleet prefix-hit rate by router
    # (static 4-instance fleet, apples to apples)
    print("\nPrefix-cache hit rate by router (4 instances, no autoscaler):")
    for rep in reports:
        p = rep.point
        if p["fleet.autoscaler"] is None \
                and sum(g["count"] for g in p["fleet.instances"]) == 4:
            print(f"  {p['fleet.router']:18s} "
                  f"{100 * rep.summary['prefix_hit_token_frac']:.2f}%")
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/fleet_capacity_points.json", "w") as f:
        json.dump([{"point": r.point, "summary": r.summary}
                   for r in reports], f, indent=2, default=float)
    print("\nreports -> artifacts/fleet_capacity.jsonl")


if __name__ == "__main__":
    main()
