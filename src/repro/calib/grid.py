"""Shape-grid sampling for calibration: deterministic heterogeneous-batch
grids derived from a model config's operator geometry and clamped to the
oracle's measurable domain.

Reuses the regime samplers in ``core/opmodels/calibration.py`` (uniform /
lognormal / skewed / bimodal length mixes, Zipf-like expert loads) — the
batch shapes the paper shows proxy models mis-price.  Train and eval
grids are drawn from disjoint seeds so the fidelity numbers are held-out
by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.opmodels.calibration import (
    sample_attention_batch, sample_grouped_gemm,
)


@dataclass
class AttentionSample:
    q_lens: List[int]
    kv_lens: List[int]
    decode: bool          # decode batches price via attention_decode

    @property
    def causal(self) -> bool:
        return not self.decode


@dataclass
class GroupedGemmSample:
    tokens_per_expert: List[int]


@dataclass
class CalibGrid:
    """The full sampling plan for one (model, hardware, oracle) triple."""
    geometry: Dict[str, int]                 # attention geometry
    moe_geometry: Optional[Dict[str, int]]   # None for dense models
    attn_train: List[AttentionSample] = field(default_factory=list)
    attn_eval: List[AttentionSample] = field(default_factory=list)
    gg_train: List[GroupedGemmSample] = field(default_factory=list)
    gg_eval: List[GroupedGemmSample] = field(default_factory=list)


def attention_grid(n: int, *, seed: int, max_len: int, max_batch: int,
                   decode_frac: float = 0.5) -> List[AttentionSample]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        decode = bool(rng.random() < decode_frac)
        q, kv = sample_attention_batch(rng, decode=decode, max_len=max_len,
                                       max_batch=max_batch)
        out.append(AttentionSample(q, kv, decode))
    return out


def grouped_gemm_grid(n: int, *, seed: int, n_experts: int, top_k: int,
                      d_in: int, d_out: int, max_tokens: int
                      ) -> List[GroupedGemmSample]:
    rng = np.random.default_rng(seed)
    return [GroupedGemmSample(sample_grouped_gemm(
        rng, n_experts=n_experts, top_k=top_k, d_in=d_in, d_out=d_out,
        max_tokens=max_tokens)) for _ in range(n)]


def geometry_of(cfg) -> Dict[str, int]:
    """The attention geometry the predictor prices with (tp=1 base)."""
    return {"n_heads": cfg.num_heads, "n_kv_heads": cfg.num_kv_heads,
            "head_dim": cfg.resolved_head_dim}


def moe_geometry_of(cfg) -> Optional[Dict[str, int]]:
    if cfg.moe is None:
        return None
    return {"n_experts": cfg.moe.num_experts, "top_k": cfg.moe.top_k,
            "d_in": cfg.d_model, "d_out": cfg.moe.expert_d_ff}


def build_grid(cfg, *, n_train: int, n_eval: int, seed: int,
               limits: Dict[str, int],
               max_len: Optional[int] = None,
               max_batch: Optional[int] = None) -> CalibGrid:
    """Train + held-out eval grids for one model config, clamped to the
    oracle's limits.  Eval seeds are offset so no sample is shared."""
    max_len = min(max_len or limits["max_len"], limits["max_len"])
    max_batch = min(max_batch or limits["max_batch"], limits["max_batch"])
    max_len = max(32, max_len)
    max_batch = max(1, max_batch)
    grid = CalibGrid(geometry=geometry_of(cfg),
                     moe_geometry=moe_geometry_of(cfg))
    grid.attn_train = attention_grid(n_train, seed=seed, max_len=max_len,
                                     max_batch=max_batch)
    grid.attn_eval = attention_grid(n_eval, seed=seed + 10_007,
                                    max_len=max_len, max_batch=max_batch)
    if grid.moe_geometry is not None:
        max_tokens = min(limits["max_tokens"],
                         max(128, max_batch * max_len))
        grid.gg_train = grouped_gemm_grid(
            n_train, seed=seed + 1, max_tokens=max_tokens,
            **grid.moe_geometry)
        grid.gg_eval = grouped_gemm_grid(
            n_eval, seed=seed + 10_009, max_tokens=max_tokens,
            **grid.moe_geometry)
    return grid
