"""Scan-aware HLO cost extraction.

``compiled.cost_analysis()`` counts a ``while`` (lax.scan) body ONCE
(verified empirically), which would understate a scanned-transformer's
FLOPs/bytes by ~num_layers x.  This module parses the compiled HLO text
into a computation call graph, multiplies per-computation costs by the
product of ``known_trip_count`` values along the call chain, and returns
corrected totals:

- flops: dot/convolution FLOPs (dense algebra dominates; elementwise ops
  are counted at 1 flop/element which is negligible but keeps honesty),
- bytes: HBM traffic under XLA's fusion model — each *top-level* op in a
  computation reads its operands and writes its output; ops inside fusions
  are free (that is how XLA itself accounts bytes),
- collective bytes per kind (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), using output-shape bytes.

This parser feeds both EXPERIMENTS.md §Roofline and the Frontier
simulator's TPU operator cost model.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "s2": 1, "u2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# NB: parameters may be tuple-typed (nested parens) — match greedily to the
# arrow.  Instruction lines contain " = " and are excluded in parse_hlo.
_DEF_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_CALLED_BRACED_RE = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")
_CALLED_SINGLE_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _parse_shapes(s: str) -> List[Tuple[str, List[int]]]:
    """All dtype[dims] shape tokens in a string prefix (before operands)."""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        out.append((dt, [int(x) for x in dims.split(",") if x] if dims else []))
    return out


def _nbytes(dt: str, dims: List[int]) -> int:
    n = DTYPE_BYTES.get(dt, 4)
    for d in dims:
        n *= d
    return n


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclass
class Instr:
    name: str
    op: str
    out_shapes: List[Tuple[str, List[int]]]
    body: str
    called: List[str] = field(default_factory=list)
    trip_count: int = 1
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    is_fusion_body: bool = False


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if " = " not in line:
            d = _DEF_RE.match(line)
            if d and "{" in line:
                cur = Computation(d.group(1))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        is_root = bool(re.match(r"^\s*ROOT\b", line))
        name, rest = m.group(1), m.group(2)
        # op comes after shape: "f32[8,16]{1,0} dot(%a, %b), ..."
        opm = re.search(r"\}?\s*([a-z][a-z0-9\-_]*)\(", rest)
        op = opm.group(1) if opm else ""
        # output shapes: everything before the op name
        cut = opm.start() if opm else len(rest)
        out_shapes = _parse_shapes(rest[:cut])
        ins = Instr(name, op, out_shapes, rest, is_root=is_root)
        rest_wo = _CALLED_BRACED_RE.sub(" ", rest)
        for grp in _CALLED_BRACED_RE.findall(rest):
            for c in grp.split(","):
                c = c.strip().lstrip("%")
                if c:
                    ins.called.append(c)
        for c in _CALLED_SINGLE_RE.findall(rest_wo):
            ins.called.append(c)
        tm = _TRIP_RE.search(rest)
        if tm:
            ins.trip_count = int(tm.group(1))
        cur.instrs.append(ins)
    return comps


def _dot_flops(instr: Instr, comps: Dict[str, Computation],
               operand_shapes: Dict[str, List[Tuple[str, List[int]]]]) -> float:
    """2 * numel(out) * K for dot ops; K from contracting dims of lhs."""
    body = instr.body
    out_elems = sum(_numel(d) for _, d in instr.out_shapes) or 1
    km = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", body)
    ops = _OPERAND_RE.findall(body.split("(", 1)[1]) if "(" in body else []
    k = 1
    if km and ops:
        lhs_shape = operand_shapes.get(ops[0])
        if lhs_shape:
            dims = lhs_shape[0][1]
            for idx in (int(x) for x in km.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * out_elems * k


def _operands(i: Instr) -> List[str]:
    if "(" not in i.body:
        return []
    return _OPERAND_RE.findall(i.body.split("(", 1)[1].split(")")[0])


_PARAM_NUM_RE = re.compile(r"parameter\((\d+)\)")


def _instr_bytes(i: Instr, comps: Dict[str, Computation],
                 shapes: Dict[str, List[Tuple[str, List[int]]]]) -> float:
    """HBM bytes for one top-level instruction under XLA's in-place model.

    dynamic-(update-)slice and scatter touch only the slice/updates (the
    big aliased buffer is updated in place) — critical for scanned models
    whose stacked weights / KV caches / ys-accumulators would otherwise be
    charged O(L^2) traffic.  XLA routinely FUSES those slices into consumer
    fusions, so for fusion instructions we inspect the body: an operand that
    is only dynamic-sliced inside is charged at slice size; an operand that
    is the target of a dynamic-update-slice is charged at update size.
    """
    out_b = sum(_nbytes(dt, d) for dt, d in i.out_shapes)
    ops_ = _operands(i)

    def op_bytes(o: str) -> int:
        return sum(_nbytes(dt, d) for dt, d in shapes.get(o, []))

    if i.op == "scatter":
        upd_b = op_bytes(ops_[2]) if len(ops_) > 2 else out_b
        return 3.0 * upd_b
    if i.op == "gather":
        return 2.0 * out_b
    if i.op == "dynamic-slice":
        return 2.0 * out_b
    if i.op == "dynamic-update-slice":
        upd_b = op_bytes(ops_[1]) if len(ops_) > 1 else out_b
        return 3.0 * upd_b

    if i.op != "fusion":
        return float(out_b + sum(op_bytes(o) for o in ops_))

    # ---- fusion: slice-aware operand accounting ---------------------------
    body: Optional[Computation] = None
    for callee in i.called:
        body = comps.get(callee)
        if body is not None:
            break
    if body is None or not body.instrs:
        return float(out_b + sum(op_bytes(o) for o in ops_))

    param_of: Dict[str, int] = {}
    for instr in body.instrs:
        if instr.op == "parameter":
            m = _PARAM_NUM_RE.search(instr.body)
            if m:
                param_of[instr.name] = int(m.group(1))
    # alias pass-through: copy/bitcast/convert/reshape chains keep pointing
    # at the underlying parameter (these ops are layout/dtype plumbing that
    # does not exist on the TPU target for in-place scan buffers)
    for instr in body.instrs:
        if instr.op in ("copy", "bitcast", "convert", "reshape", "transpose"):
            bops = _operands(instr)
            if bops and bops[0] in param_of:
                param_of[instr.name] = param_of[bops[0]]

    charge: Dict[int, float] = {}       # param idx -> bytes override
    dus_update_b = 0.0
    has_dus = False
    for instr in body.instrs:
        bops = _operands(instr)
        if instr.op == "dynamic-slice" and bops and bops[0] in param_of:
            idx = param_of[bops[0]]
            sl = sum(_nbytes(dt, d) for dt, d in instr.out_shapes)
            charge[idx] = charge.get(idx, 0.0) + sl
        elif instr.op == "dynamic-update-slice" and bops and bops[0] in param_of:
            has_dus = True
            idx = param_of[bops[0]]
            upd = op_bytes(bops[1]) if len(bops) > 1 and bops[1] in shapes \
                else sum(_nbytes(dt, d) for dt, d in instr.out_shapes)
            if len(bops) > 1:
                # update operand may itself be a body instr with known shape
                b1 = bops[1]
                if b1 in shapes:
                    upd = op_bytes(b1)
            charge[idx] = charge.get(idx, 0.0) + 2.0 * upd
            dus_update_b += upd
        elif instr.op == "dynamic-update-slice":
            has_dus = True
            dus_update_b += sum(_nbytes(dt, d) for dt, d in instr.out_shapes[:1])

    in_b = 0.0
    for pos, o in enumerate(ops_):
        in_b += charge.get(pos, None) if pos in charge else op_bytes(o)
    if has_dus:
        # the fusion writes only the updated slices (aliased big buffer)
        out_b = max(dus_update_b, 0.0)
    return float(out_b + in_b)


def analyze(text: str, *, entry: Optional[str] = None) -> Dict[str, float]:
    """Corrected totals from compiled (SPMD, per-device) HLO text."""
    comps = parse_hlo(text)
    if not comps:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}

    # operand shape lookup per computation (instr name -> shapes)
    shapes: Dict[str, List[Tuple[str, List[int]]]] = {}
    for c in comps.values():
        for i in c.instrs:
            shapes[i.name] = i.out_shapes

    # entry = computation never called by others, preferring one named main*
    called_by = defaultdict(list)
    for c in comps.values():
        for i in c.instrs:
            for callee in i.called:
                if callee in comps:
                    called_by[callee].append(c.name)
    if entry is None:
        roots = [n for n in comps if n not in called_by]
        mains = [n for n in roots if n.startswith("main")]
        entry = mains[0] if mains else (roots[0] if roots else next(iter(comps)))

    # fusion bodies: bytes/flops of *internal* ops follow XLA's model:
    # internal elementwise are free for bytes; dots inside fusions still
    # count flops.  Identify them from fusion instrs' `calls=`.
    fusion_bodies = set()
    for c in comps.values():
        for i in c.instrs:
            if i.op == "fusion":
                for callee in i.called:
                    fusion_bodies.add(callee)

    totals = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
              "transcendentals": 0.0}
    per_coll: Dict[str, float] = defaultdict(float)

    # producer index (instr name -> Instr) for collective dtype tracing
    producer: Dict[str, Instr] = {}
    for c in comps.values():
        for i in c.instrs:
            producer[i.name] = i

    SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "while", "conditional", "call", "custom-call",
                      "after-all", "partition-id", "replica-id"}

    seen_stack = set()

    def walk(name: str, mult: float, in_fusion: bool):
        if name not in comps or name in seen_stack:
            return
        seen_stack.add(name)
        c = comps[name]
        for i in c.instrs:
            m = mult * (i.trip_count if i.op == "while" else 1)
            # recurse into called computations
            if i.op in ("while", "conditional", "call", "fusion"):
                sub_fusion = in_fusion or i.op == "fusion"
                for callee in i.called:
                    if i.op == "while":
                        # body and condition both run trip_count times
                        walk(callee, mult * i.trip_count, in_fusion)
                    elif i.op == "conditional":
                        walk(callee, mult, in_fusion)  # upper bound: all branches? take max later
                    else:
                        walk(callee, mult, sub_fusion)
            elif i.called and i.op not in ("all-reduce", "reduce", "scatter",
                                           "reduce-scatter", "reduce-window",
                                           "sort", "map", "select-and-scatter",
                                           "all-to-all"):
                for callee in i.called:
                    walk(callee, mult, in_fusion)

            if i.op in ("dot", "convolution"):
                totals["flops"] += mult * _dot_flops(i, comps, shapes)
            if i.op in COLLECTIVES:
                nb = sum(_nbytes(dt, d) for dt, d in i.out_shapes)
                # XLA:CPU hoists bf16->f32 converts above collectives; on
                # the TPU target the collective runs at the program dtype.
                # Charge at the pre-convert width when the operand is a
                # convert(-fusion) of a narrower tensor.
                ops_c = _operands(i)
                if ops_c:
                    prod = producer.get(ops_c[0])
                    if prod is not None and "convert" in prod.name:
                        pops = _operands(prod)
                        if pops and pops[0] in shapes and shapes[pops[0]]:
                            src_dt = shapes[pops[0]][0][0]
                            out_dt = i.out_shapes[0][0] if i.out_shapes else "f32"
                            sb = DTYPE_BYTES.get(src_dt, 4)
                            ob = DTYPE_BYTES.get(out_dt, 4)
                            if sb < ob:
                                nb = nb * sb / ob
                totals["collective_bytes"] += mult * nb
                per_coll[i.op] += mult * nb

            if not in_fusion and i.op not in SKIP_BYTES_OPS and i.op:
                totals["bytes"] += mult * _instr_bytes(i, comps, shapes)
        seen_stack.discard(name)

    walk(entry, 1.0, False)
    for k, v in per_coll.items():
        totals[f"coll_{k}"] = v
    return totals


def roofline_terms(costs: Dict[str, float], *, n_chips: int,
                   peak_flops: float = 197e12, hbm_bw: float = 819e9,
                   ici_bw: float = 50e9, flops_total_all_chips: bool = False,
                   ) -> Dict[str, float]:
    """Three roofline terms in seconds.  `costs` are per-device (SPMD HLO)."""
    t_compute = costs["flops"] / peak_flops
    t_memory = costs["bytes"] / hbm_bw
    t_coll = costs["collective_bytes"] / ici_bw
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom[1],
    }
