"""ClusterWorker / ClusterScheduler / ReplicaWorker.

A ClusterWorker is the abstraction for one specialized hardware pool (a
prefill cluster, a decode cluster, a colocated pool, an attention or FFN
cluster).  Its ClusterScheduler routes requests to ReplicaWorkers and
participates in inter-stage coordination (memory-availability signaling for
PD backpressure).  A ReplicaWorker simulates one model instance: it forms
batches with a pluggable BatchingPolicy, prices them with the
ExecutionPredictor, and advances request state on BATCH_DONE events.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.engine import SimEngine
from repro.core.events import EV, Event
from repro.core.policies.batching import BatchingPolicy, BatchPlan
from repro.core.policies.memory import PagedKVManager
from repro.core.policies.scheduling import FCFS, QueuePolicy
from repro.core.predictor import ExecutionPredictor
from repro.core.request import Request, RState


@dataclass
class Hooks:
    """Controller callbacks (inter-stage coordination points)."""
    prefill_complete: Callable = lambda r, replica: None
    token_generated: Callable = lambda r, replica, t: None
    request_complete: Callable = lambda r, replica: None
    memory_available: Callable = lambda cluster, replica: None


class ReplicaWorker:
    def __init__(self, engine: SimEngine, name: str,
                 predictor: ExecutionPredictor, policy: BatchingPolicy,
                 memory: Optional[PagedKVManager], hooks: Hooks, *,
                 role: str = "colocated", queue_policy: Optional[QueuePolicy] = None,
                 slowdown: float = 1.0, pipeline=None):
        self.engine = engine
        self.name = name
        self.predictor = predictor
        self.policy = policy
        self.memory = memory
        self.hooks = hooks
        self.role = role
        self.queue_policy = queue_policy or FCFS()
        self.pipeline = pipeline          # PipelineConfig (latency hiding)
        self.slowdown = slowdown          # straggler factor (1.0 = healthy)
        self.waiting: List[Request] = []
        self.running: List[Request] = []  # decoding requests resident here
        self.busy = False
        self.failed = False
        self._epoch = 0      # bumped on failure; stale BATCH_DONEs dropped
        self.cluster: Optional["ClusterWorker"] = None
        self.stats = {"batches": 0, "busy_time": 0.0, "tokens": 0,
                      "prefill_tokens": 0}

    # ------------------------------------------------------------- intake --
    def enqueue_prefill(self, r: Request) -> None:
        self.waiting.append(r)
        self.kick()

    def start_decode(self, r: Request) -> None:
        if r.state != RState.QUEUED_DECODE:
            r.to(RState.QUEUED_DECODE, self.engine.now)
        self.running.append(r)
        self.kick()

    def kick(self) -> None:
        self.engine.after(0.0, EV.SCHEDULE_TICK, lambda ev: self._schedule())

    # ---------------------------------------------------------- scheduling --
    def _schedule(self) -> None:
        if self.busy or self.failed:
            return
        ordered = self.queue_policy.order(self.waiting, self.engine.now)
        plan = self.policy.plan(ordered, self.running, self.memory,
                                self.engine.now)
        if plan.empty:
            return
        self.busy = True
        if (self.pipeline is not None and self.pipeline.chunked_prefill
                and plan.prefill and plan.decode):
            # chunked prefill with piggybacked decode: the mixed batch is
            # priced as ONE fused step — prefill attention for the chunks,
            # decode attention for the piggybacked rows, shared GEMMs.
            # Deliberately gated on the pipeline flag, NOT the batch shape:
            # a bare ChunkedPrefill batching policy (no PipelineSpec) keeps
            # the legacy all-prefill pricing bit-for-bit; fused per-class
            # pricing is opt-in via PipelineSpec(chunked_prefill=True)
            bd = self.predictor.step_time(plan.q_lens, plan.kv_lens,
                                          decode=False,
                                          n_prefill=len(plan.prefill))
            self.stats["piggyback_tokens"] = (
                self.stats.get("piggyback_tokens", 0) + len(plan.decode))
        else:
            bd = self.predictor.step_time(plan.q_lens, plan.kv_lens,
                                          decode=(not plan.prefill))
        t = bd.total * self.slowdown
        self.stats["batches"] += 1
        self.stats["busy_time"] += t
        for r, _ in plan.prefill:
            if r.state == RState.QUEUED_PREFILL:
                r.to(RState.PREFILL_RUNNING, self.engine.now)
                # queueing-delay anchor: first time any replica scheduled it
                r.timestamps.setdefault("first_scheduled", self.engine.now)
        for r in plan.decode:
            if r.state == RState.QUEUED_DECODE:
                r.to(RState.DECODING, self.engine.now)
        self.engine.after(t, EV.BATCH_DONE,
                          lambda ev, epoch=self._epoch:
                          self._batch_done(plan, epoch),
                          replica=self.name, dur=t,
                          n_prefill=len(plan.prefill), n_decode=len(plan.decode))

    def _batch_done(self, plan: BatchPlan, epoch: int = -1) -> None:
        if epoch != -1 and epoch != self._epoch:
            # the replica failed while this batch was in flight: its work is
            # lost and its requests were re-routed — drop the stale event
            return
        now = self.engine.now
        self.busy = False
        freed = False
        for r, chunk in plan.prefill:
            r.prefill_progress += chunk
            self.stats["prefill_tokens"] += chunk
            if r.prefill_progress >= r.prompt_len:
                self.waiting.remove(r)
                r.to(RState.PREFILL_COMPLETE, now)
                # prefill emits the first token
                r.generated += 1
                self.stats["tokens"] += 1
                if r.first_token_time is None:
                    r.first_token_time = now
                self.hooks.token_generated(r, self, now)
                if self.role == "colocated":
                    if self.memory is not None:
                        self.memory.grow(r.rid, r.context_len)
                    r.to(RState.QUEUED_DECODE, now)
                    self.running.append(r)
                else:
                    self.hooks.prefill_complete(r, self)
            else:
                r.to(RState.QUEUED_PREFILL, now)  # chunked: back to queue
        for r in plan.decode:
            r.generated += 1
            self.stats["tokens"] += 1
            if self.memory is not None:
                self.memory.grow(r.rid, r.context_len)
            self.hooks.token_generated(r, self, now)
            if r.done:
                self.running.remove(r)
                r.to(RState.COMPLETE, now)
                r.finish_time = now
                if self.memory is not None:
                    self.memory.free(r.rid)
                    freed = True
                self.hooks.request_complete(r, self)
        if freed:
            self.hooks.memory_available(self.cluster, self)
        self.kick()

    # ------------------------------------------------------------ failures --
    def fail(self, downtime: float) -> List[Request]:
        """Replica failure: running work is lost and must be re-routed."""
        self.failed = True
        self._epoch += 1      # invalidate any in-flight BATCH_DONE
        self.busy = False
        lost = self.waiting + self.running
        self.waiting, self.running = [], []
        if self.memory is not None:
            for r in lost:
                self.memory.free(r.rid)
        self.engine.after(downtime, EV.REPLICA_RECOVERED,
                          lambda ev: self._recover(), replica=self.name)
        return lost

    def _recover(self) -> None:
        self.failed = False
        self.kick()

    # -------------------------------------------------------------- state --
    def load(self) -> float:
        mem = self.memory.utilization if self.memory is not None else 0.0
        return len(self.waiting) + len(self.running) + mem


class ClusterWorker:
    """A pool of replicas with a cluster-level scheduler."""

    def __init__(self, name: str, role: str, replicas: List[ReplicaWorker]):
        self.name = name
        self.role = role
        self.replicas = replicas
        for r in replicas:
            r.cluster = self

    # -- ClusterScheduler duties -------------------------------------------
    def route(self, r: Request) -> ReplicaWorker:
        healthy = [w for w in self.replicas if not w.failed]
        if not healthy:
            raise RuntimeError(f"cluster {self.name}: no healthy replicas")
        w = min(healthy, key=lambda w: (w.load(), w.name))
        return w

    def replica_with_memory(self, tokens: int) -> Optional[ReplicaWorker]:
        """For pull-based KV transfer: who can host this request's KV?"""
        best, best_load = None, None
        for w in self.replicas:
            if w.failed or w.memory is None:
                continue
            if w.memory.can_admit(tokens):
                l = w.load()
                if best is None or l < best_load:
                    best, best_load = w, l
        return best

    def utilization(self, now: float) -> float:
        if not self.replicas or now <= 0:
            return 0.0
        return sum(w.stats["busy_time"] for w in self.replicas) / (
            now * len(self.replicas))
