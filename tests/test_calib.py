"""Calibration & fidelity subsystem: oracles, fitting, artifacts, loading
into run(spec), and the FIDELITY trajectory gate."""
import json
import os

import numpy as np
import pytest

from repro.api import ModelRef, SimSpec, TopologySpec, WorkloadSpec, run
from repro.api.spec import OpModelSpec, SpecError
from repro.calib import (
    CalibrationArtifact, CalibrationError, HLOCostOracle, KernelSimOracle,
    ORACLES, append_fidelity, calibrate, check_fidelity_regression,
    default_oracle_name, discover_artifacts, entry_from_result,
    load_artifact, load_calibrated_ops, load_trajectory, resolve_oracle,
)
from repro.calib.grid import build_grid
from repro.configs import get_config
from repro.core.hardware import HARDWARE
from repro.core.opmodels.forest import RandomForest
from repro.core.opmodels.kernelsim import VirtualKernels

HW = HARDWARE["A800-SXM4-80G"]
CAL_KW = dict(oracle="kernelsim", smoke=True, n_train=160, n_eval=60,
              max_len=1024, max_batch=32)


@pytest.fixture(scope="module")
def qwen_artifacts(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("calib_qwen"))
    result = calibrate(model="qwen2-7b", out_root=root, **CAL_KW)
    return root, result


@pytest.fixture(scope="module")
def mixtral_artifacts(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("calib_mixtral"))
    result = calibrate(model="mixtral-8x7b", out_root=root, **CAL_KW)
    return root, result


def _spec(calibration=None, **kw):
    opmodel = OpModelSpec(name="refined", calibration=calibration) \
        if calibration else OpModelSpec()
    base = dict(
        model=ModelRef("qwen2-7b", smoke=True),
        topology=TopologySpec(preset="colocated", n_replicas=1, tp=1),
        workload=WorkloadSpec(n_requests=12, rate=20.0, prompt_mean=96,
                              output_mean=12),
        opmodel=opmodel, seed=0)
    base.update(kw)
    return SimSpec(**base)


# ------------------------------------------------------------------ forest --
def test_forest_json_roundtrip_exact():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(80, 6))
    y = rng.normal(size=80)
    f = RandomForest(n_trees=8, seed=3).fit(X, y)
    clone = RandomForest.from_dict(
        json.loads(json.dumps(f.to_dict())))
    Xq = rng.normal(size=(40, 6))
    np.testing.assert_array_equal(f.predict(Xq), clone.predict(Xq))


# ----------------------------------------------------------------- oracles --
def test_oracle_registry_and_auto():
    assert set(ORACLES) == {"kernelsim", "pallas", "hlo"}
    # CPU test environment -> kernelsim is the auto choice
    assert default_oracle_name() == "kernelsim"
    assert isinstance(resolve_oracle("auto", HW), KernelSimOracle)
    assert isinstance(resolve_oracle(None, HW), KernelSimOracle)
    orc = resolve_oracle({"name": "hlo", "bucket": 1.5}, HW)
    assert isinstance(orc, HLOCostOracle) and orc.bucket == 1.5
    inst = KernelSimOracle(HW)
    assert resolve_oracle(inst, HW) is inst
    with pytest.raises(KeyError, match="unknown oracle"):
        resolve_oracle("nope", HW)


def test_kernelsim_oracle_matches_virtual_kernels():
    orc = KernelSimOracle(HW)
    vk = VirtualKernels(HW)
    q, kv = [64, 8, 1], [128, 512, 64]
    assert orc.attention_prefill(q, kv, 8, 2, 64) == \
        vk.attention_prefill(q, kv, 8, 2, 64)
    # the fit-facing dispatch: all-q==1 batches go through decode pricing
    assert orc.attention([1, 1], [256, 64], 8, 2, 64) == \
        vk.attention_decode([256, 64], 8, 2, 64)
    assert orc.grouped_gemm([32, 0, 96], 64, 128) == \
        vk.grouped_gemm([32, 0, 96], 64, 128)


def test_hlo_oracle_prices_and_caches():
    orc = HLOCostOracle(HW)
    t = orc.attention_prefill([16], [16], 2, 2, 16)
    assert t > 0 and np.isfinite(t)
    n = len(orc._cache)
    # same bucketed shape -> no recompile, monotone in kv length
    assert orc.attention_prefill([16], [16], 2, 2, 16) == t
    assert len(orc._cache) == n
    assert orc.grouped_gemm([8, 8], 32, 32) > 0


# -------------------------------------------------------------------- grid --
def test_grid_deterministic_and_clamped():
    cfg = get_config("qwen2-7b", smoke=True)
    limits = {"max_len": 256, "max_batch": 8, "max_tokens": 512}
    g1 = build_grid(cfg, n_train=30, n_eval=10, seed=7, limits=limits)
    g2 = build_grid(cfg, n_train=30, n_eval=10, seed=7, limits=limits)
    assert [s.q_lens for s in g1.attn_train] == \
        [s.q_lens for s in g2.attn_train]
    for s in g1.attn_train + g1.attn_eval:
        assert len(s.q_lens) <= 8
        assert max(s.kv_lens) <= 256
    # eval grid is disjoint from train (different seed stream)
    assert [s.kv_lens for s in g1.attn_train[:10]] != \
        [s.kv_lens for s in g1.attn_eval]


# --------------------------------------------------------------- calibrate --
def test_calibrate_writes_artifacts_with_provenance(qwen_artifacts):
    root, result = qwen_artifacts
    path = os.path.join(root, "A800-SXM4-80G", "attention.json")
    assert result.artifact_paths["attention"] == path
    art = load_artifact(path)
    assert art.operator == "attention"
    assert art.hardware == "A800-SXM4-80G"
    assert art.model == "qwen2-7b-smoke"
    assert art.oracle == "kernelsim"
    assert art.spec_hash == art.provenance_hash()
    assert art.geometry == {"n_heads": 4, "n_kv_heads": 2, "head_dim": 16}
    found = discover_artifacts(root)
    assert [a["operator"] for a in found] == ["attention"]
    assert found[0]["mape"] == pytest.approx(
        result.fidelity["attention"]["fitted"]["mape"])


def test_fitted_beats_analytical_and_vidur_on_heldout(qwen_artifacts):
    _, result = qwen_artifacts
    fams = result.fidelity["attention"]
    assert fams["fitted"]["mape"] < fams["analytical"]["mape"]
    assert fams["fitted"]["mape"] < fams["vidur_proxy"]["mape"]


def test_calibrate_is_deterministic(tmp_path):
    r1 = calibrate(model="qwen2-7b", out_root=str(tmp_path / "a"),
                   **CAL_KW)
    r2 = calibrate(model="qwen2-7b", out_root=str(tmp_path / "b"),
                   **CAL_KW)
    assert r1.fidelity == r2.fidelity
    a1 = load_artifact(r1.artifact_paths["attention"])
    a2 = load_artifact(r2.artifact_paths["attention"])
    assert a1.forest == a2.forest
    assert a1.spec_hash == a2.spec_hash


def test_calibrate_moe_fits_grouped_gemm(mixtral_artifacts):
    root, result = mixtral_artifacts
    assert set(result.artifacts) == {"attention", "grouped_gemm"}
    fams = result.fidelity["grouped_gemm"]
    assert fams["fitted"]["mape"] < fams["analytical"]["mape"]
    cfg = get_config("mixtral-8x7b", smoke=True)
    ops = load_calibrated_ops(root, cfg, HW)
    assert ops.attention is not None and ops.grouped is not None
    # fitted pricing is live and positive
    assert ops.grouped_gemm([8, 0, 16, 4], cfg.d_model,
                            cfg.moe.expert_d_ff) > 0


# ---------------------------------------------------- artifact error paths --
def test_load_artifact_errors(tmp_path):
    with pytest.raises(CalibrationError, match="repro calibrate"):
        load_artifact(str(tmp_path / "missing.json"))
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(CalibrationError, match="unreadable"):
        load_artifact(str(bad))
    incomplete = tmp_path / "incomplete.json"
    incomplete.write_text(json.dumps({"operator": "attention"}))
    with pytest.raises(CalibrationError, match="missing field"):
        load_artifact(str(incomplete))


def test_load_artifact_version_gate(qwen_artifacts, tmp_path):
    root, _ = qwen_artifacts
    path = os.path.join(root, "A800-SXM4-80G", "attention.json")
    with open(path) as f:
        data = json.load(f)
    data["version"] = 99
    stale = tmp_path / "attention.json"
    stale.write_text(json.dumps(data))
    with pytest.raises(CalibrationError, match="version 99"):
        load_artifact(str(stale))


# ------------------------------------------------------------- spec + run --
def test_spec_calibration_field_roundtrip_and_hash_stability():
    plain = _spec()
    assert "calibration" not in plain.to_dict()["opmodel"]
    # the field must not perturb hashes of specs that do not use it
    assert plain.spec_hash() == SimSpec.from_dict(plain.to_dict()).spec_hash()
    cal = _spec(calibration="artifacts/calib")
    d = cal.to_dict()
    assert d["opmodel"]["calibration"] == "artifacts/calib"
    assert SimSpec.from_dict(d).opmodel.calibration == "artifacts/calib"
    assert cal.spec_hash() != plain.spec_hash()


def test_calibration_requires_refined_name():
    with pytest.raises(SpecError, match="refined"):
        SimSpec(opmodel=OpModelSpec(name="analytical",
                                    calibration="x")).validate()
    with pytest.raises(SpecError, match="calibration"):
        SimSpec(opmodel=OpModelSpec(name="refined",
                                    calibration="")).validate()


def test_run_with_calibration_deterministic(qwen_artifacts):
    root, _ = qwen_artifacts
    spec = _spec(calibration=root)

    def stable(rep):
        return json.dumps({"summary": rep.summary, "hash": rep.spec_hash,
                           "clusters": rep.clusters,
                           "conservation": rep.conservation,
                           "events": rep.sim_events}, sort_keys=True)

    r1, r2 = run(spec), run(spec)
    assert stable(r1) == stable(r2)        # byte-identical on repeat
    analytical = run(_spec())
    assert r1.summary["ttft_p50_s"] != analytical.summary["ttft_p50_s"]


def test_run_missing_artifact_spec_error():
    with pytest.raises(SpecError, match="does not exist"):
        run(_spec(calibration="/nonexistent/calib"))


def test_run_hardware_mismatch_spec_error(qwen_artifacts):
    root, _ = qwen_artifacts
    spec = _spec(calibration=root,
                 topology=TopologySpec(preset="colocated", n_replicas=1,
                                       tp=1, hardware="H100-SXM"))
    with pytest.raises(SpecError, match="H100-SXM"):
        run(spec)


def test_run_geometry_mismatch_spec_error(qwen_artifacts):
    root, _ = qwen_artifacts
    spec = _spec(calibration=root, model=ModelRef("qwen2-7b", smoke=False))
    with pytest.raises(SpecError, match="geometry"):
        run(spec)


# ---------------------------------------------------------------- fidelity --
def test_fidelity_entry_and_append_dedupe(qwen_artifacts, tmp_path):
    _, result = qwen_artifacts
    entry = entry_from_result(result, "t0")
    assert entry["model"] == "qwen2-7b-smoke"
    assert entry["oracle"] == "kernelsim"
    assert "fitted" in entry["operators"]["attention"]
    path = str(tmp_path / "FIDELITY.json")
    append_fidelity(path, entry)
    append_fidelity(path, dict(entry, label="t1"))
    append_fidelity(path, dict(entry, label="t0"))   # replaces, not dups
    traj = load_trajectory(path)
    assert [e["label"] for e in traj] == ["t1", "t0"]


def test_fidelity_regression_gate(qwen_artifacts):
    _, result = qwen_artifacts
    base = entry_from_result(result, "base")
    fresh_ok = json.loads(json.dumps(base))
    fresh_ok["label"] = "fresh"
    ok, lines = check_fidelity_regression(fresh_ok, [base], tolerance=0.2)
    assert ok and any("OK" in l for l in lines)
    fresh_bad = json.loads(json.dumps(fresh_ok))
    m = fresh_bad["operators"]["attention"]["fitted"]["mape"]
    fresh_bad["operators"]["attention"]["fitted"]["mape"] = m * 1.5
    ok, lines = check_fidelity_regression(fresh_bad, [base], tolerance=0.2)
    assert not ok and any("FAIL" in l for l in lines)
    # empty trajectory passes (first-ever run)
    ok, _ = check_fidelity_regression(fresh_ok, [], tolerance=0.2)
    assert ok


def test_fidelity_gate_noncomparable_fallback(qwen_artifacts):
    _, result = qwen_artifacts
    base = entry_from_result(result, "base")
    fresh = json.loads(json.dumps(base))
    fresh["n_train"] = base["n_train"] * 2   # different fit config
    ok, lines = check_fidelity_regression(fresh, [base], tolerance=0.2)
    assert ok and any("no comparable" in l for l in lines)


# --------------------------------------------------------------------- cli --
def test_cli_calibrate_and_list(tmp_path, capsys):
    from repro.api.cli import main
    out = str(tmp_path / "calib")
    fid = str(tmp_path / "FIDELITY.json")
    entry = str(tmp_path / "entry.json")
    rc = main(["calibrate", "--oracle", "kernelsim", "--model", "qwen2-7b",
               "--smoke", "--train-samples", "60", "--eval-samples", "20",
               "--max-len", "512", "--max-batch", "16", "--out", out,
               "--fidelity", fid, "--entry-out", entry, "--label", "cli"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "fitted" in text and "vidur_proxy" in text
    assert os.path.isfile(os.path.join(out, "A800-SXM4-80G",
                                       "attention.json"))
    assert load_trajectory(fid)[0]["label"] == "cli"
    with open(entry) as f:
        assert json.load(f)["label"] == "cli"
    rc = main(["calibrate", "--oracle", "bogus"])
    assert rc == 2

    old = os.getcwd()
    os.chdir(tmp_path)   # list discovers ./artifacts/calib (none here)
    try:
        assert main(["list"]) == 0
    finally:
        os.chdir(old)
    text = capsys.readouterr().out
    assert "oracle backends" in text
    assert "kernelsim" in text
