"""Refined operator model set: RF-backed Attention + GroupedGEMM, wired
into the OperatorModelSet interface the ExecutionPredictor consumes.

This is Frontier's §3.2 model: fine-grained, feature-rich, per-(operator,
model, hardware) fitted predictors, with the analytical roofline as the
fallback for operators outside the fitted domain.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.hardware import HardwareSpec
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.opmodels.calibration import (
    FittedAttention, FittedGroupedGemm, fit_attention_model,
    fit_grouped_gemm_model,
)
from repro.core.opmodels.kernelsim import VirtualKernels


class RefinedModels(OperatorModelSet):
    def __init__(self, hw: HardwareSpec, *,
                 attention: Optional[FittedAttention] = None,
                 grouped: Optional[FittedGroupedGemm] = None,
                 kernels: Optional[VirtualKernels] = None):
        super().__init__(hw)
        self.attention = attention
        self.grouped = grouped
        self.kernels = kernels or VirtualKernels(hw)

    # GEMM: virtual-kernel model (tile/wave-aware) instead of pure roofline
    def gemm(self, m, n, k, dtype_bytes: int = 2) -> float:
        return self.kernels.gemm(m, n, k, dtype_bytes)

    def attention_prefill(self, q_lens, kv_lens, n_heads, n_kv_heads,
                          head_dim, causal=True, window=0) -> float:
        if self.attention is not None and \
                (n_heads, n_kv_heads, head_dim) == (self.attention.n_heads,
                                                    self.attention.n_kv_heads,
                                                    self.attention.head_dim):
            return self.attention.predict(q_lens, kv_lens, causal=causal,
                                          window=window)
        return self.kernels.attention_prefill(q_lens, kv_lens, n_heads,
                                              n_kv_heads, head_dim,
                                              causal=causal, window=window)

    def attention_decode(self, context_lens, n_heads, n_kv_heads, head_dim,
                         window=0) -> float:
        if self.attention is not None and \
                (n_heads, n_kv_heads, head_dim) == (self.attention.n_heads,
                                                    self.attention.n_kv_heads,
                                                    self.attention.head_dim):
            return self.attention.predict([1] * len(context_lens),
                                          context_lens, causal=False,
                                          window=window)
        return self.kernels.attention_decode(context_lens, n_heads,
                                             n_kv_heads, head_dim,
                                             window=window)

    def grouped_gemm(self, tokens_per_group, d_in, d_out,
                     dtype_bytes: int = 2) -> float:
        if self.grouped is not None and (d_in, d_out) == (self.grouped.d_in,
                                                          self.grouped.d_out):
            return self.grouped.predict(tokens_per_group)
        return self.kernels.grouped_gemm(tokens_per_group, d_in, d_out,
                                         dtype_bytes)


def calibrate_refined(hw: HardwareSpec, *, n_heads: int, n_kv_heads: int,
                      head_dim: int, moe_dims=None, n_samples: int = 500,
                      seed: int = 0) -> RefinedModels:
    """Fit RF models against the virtual-kernel ground truth for one model
    config on one hardware profile (the paper's per-model profiling flow)."""
    vk = VirtualKernels(hw)
    attn, _ = fit_attention_model(
        lambda q, kv, H, K, hd, causal, window: (
            vk.attention_prefill(q, kv, H, K, hd, causal=causal, window=window)
            if any(x > 1 for x in q) else
            vk.attention_decode(kv, H, K, hd, window=window)),
        n_heads=n_heads, n_kv_heads=n_kv_heads, head_dim=head_dim,
        n_samples=n_samples, seed=seed)
    grouped = None
    if moe_dims is not None:
        n_experts, top_k, d_in, d_out = moe_dims
        grouped, _ = fit_grouped_gemm_model(
            lambda c, di, do: vk.grouped_gemm(c, di, do),
            n_experts=n_experts, top_k=top_k, d_in=d_in, d_out=d_out,
            n_samples=n_samples, seed=seed)
    return RefinedModels(hw, attention=attn, grouped=grouped, kernels=vk)
