"""Parallel experiment sweeps: ``sweep(base_spec, axes) -> [Report]``.

Axes are dotted spec paths mapped to value lists; grid mode takes the
cartesian product, zip mode pairs them positionally.  Points fan out over
a ``ProcessPoolExecutor`` (each point re-builds its own simulator from the
pickled spec dict, so no RNG or cache state leaks between points), stream
to JSONL as they complete, and come back in deterministic point order.
Capacity-planning studies are ~10 lines::

    base = SimSpec.load("examples/specs/quickstart.yaml")
    reports = sweep(base, {"topology.tp": [1, 2, 4],
                           "workload.rate": [5, 10, 20]},
                    jobs=8, jsonl="artifacts/capacity.jsonl")
    print(best_under_slo(reports, ttft_p99=0.5, tpot_p99=0.05).point)
"""
from __future__ import annotations

import itertools
import json
import os
from concurrent.futures import ProcessPoolExecutor, as_completed
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.api.run import Report, run
from repro.api.spec import SimSpec, SpecError, set_path
from repro.core.metrics import pareto_frontier


# ------------------------------------------------------------- expansion --
def expand(base: SimSpec, axes: Mapping[str, Sequence[Any]],
           mode: str = "grid",
           seeds: Optional[Sequence[int]] = None,
           ) -> List[Tuple[SimSpec, Dict[str, Any]]]:
    """Expand ``axes`` over ``base`` into ``(spec, point)`` pairs.

    ``point`` records the axis assignment of each spec.  ``seeds``
    replicates every point once per seed (deterministic per-point seeds —
    results are independent of execution order and parallelism).
    """
    if mode not in ("grid", "zip"):
        raise SpecError(f"sweep mode must be 'grid' or 'zip', got {mode!r}")
    names = list(axes)
    values = [list(axes[n]) for n in names]
    for n, v in zip(names, values):
        if not v:
            raise SpecError(f"axis {n!r}: empty value list")
    if mode == "grid":
        combos = list(itertools.product(*values)) if names else [()]
    else:
        lens = {len(v) for v in values}
        if len(lens) > 1:
            raise SpecError(
                f"zip mode needs equal-length axes; got "
                f"{ {n: len(v) for n, v in zip(names, values)} }")
        combos = list(zip(*values)) if names else [()]
    seed_list: List[Optional[int]] = list(seeds) if seeds else [None]
    points: List[Tuple[SimSpec, Dict[str, Any]]] = []
    base_dict = base.to_dict()
    for combo in combos:
        for s in seed_list:
            d = json.loads(json.dumps(base_dict))   # deep copy
            point: Dict[str, Any] = {}
            for n, v in zip(names, combo):
                set_path(d, n, v)
                point[n] = v
            if s is not None:
                d["seed"] = s
                point["seed"] = s
            points.append((SimSpec.from_dict(d).validate(), point))
    return points


# --------------------------------------------------------------- workers --
def _sweep_worker(args: Tuple[int, Dict[str, Any], Dict[str, Any]]
                  ) -> Tuple[int, Dict[str, Any]]:
    i, spec_dict, point = args
    rep = run(SimSpec.from_dict(spec_dict))
    rep.point = point
    return i, rep.to_dict()


def _stream(jsonl: Optional[str], rep: Report) -> None:
    if jsonl is None:
        return
    os.makedirs(os.path.dirname(jsonl) or ".", exist_ok=True)
    with open(jsonl, "a") as f:
        f.write(rep.to_json())
        f.write("\n")


# ----------------------------------------------------------------- sweep --
def sweep(base: SimSpec, axes: Mapping[str, Sequence[Any]], *,
          mode: str = "grid",
          jobs: int = 1,
          seeds: Optional[Sequence[int]] = None,
          jsonl: Optional[str] = None,
          progress=None) -> List[Report]:
    """Run the expanded grid; return Reports in deterministic point order.

    ``jobs > 1`` fans points out over a process pool.  ``jsonl`` streams
    each finished Report as one JSON line (append; written as points
    complete, so partial sweeps leave usable artifacts).  ``progress`` is
    an optional ``fn(done, total, report)`` callback.
    """
    points = expand(base, axes, mode=mode, seeds=seeds)
    total = len(points)
    results: List[Optional[Report]] = [None] * total
    if jobs <= 1 or total <= 1:
        for i, (spec, point) in enumerate(points):
            rep = run(spec)
            rep.point = point
            results[i] = rep
            _stream(jsonl, rep)
            if progress:
                progress(i + 1, total, rep)
        return results  # type: ignore[return-value]
    args = [(i, spec.to_dict(), point)
            for i, (spec, point) in enumerate(points)]
    done = 0
    with ProcessPoolExecutor(max_workers=min(jobs, total)) as pool:
        futures = [pool.submit(_sweep_worker, a) for a in args]
        for fut in as_completed(futures):
            i, rep_dict = fut.result()
            if "instances" in rep_dict:      # fleet point
                from repro.fleet import FleetReport
                rep = FleetReport.from_dict(rep_dict)
            else:
                rep = Report.from_dict(rep_dict)
            results[i] = rep
            _stream(jsonl, rep)
            done += 1
            if progress:
                progress(done, total, rep)
    return results  # type: ignore[return-value]


# --------------------------------------------------------------- helpers --
def pareto(reports: Sequence[Report],
           x: str = "throughput_tok_s_per_device",
           y: str = "tpot_p50_s",
           invert_y: bool = True) -> List[Report]:
    """Reports on the (x, interactivity) maximization frontier.

    By default y is TPOT p50 inverted to interactivity (1/latency), the
    paper's throughput-interactivity trade-off plot.
    """
    kept, pts = [], []
    for r in reports:
        xv = r.summary.get(x)
        yv = r.summary.get(y)
        if xv is None or yv is None:
            continue
        kept.append(r)
        pts.append((float(xv),
                    1.0 / max(float(yv), 1e-12) if invert_y else float(yv)))
    front = set(pareto_frontier(pts))
    return [r for r, p in zip(kept, pts) if p in front]


def best_under_slo(reports: Sequence[Report], *,
                   ttft_p99: Optional[float] = None,
                   tpot_p99: Optional[float] = None,
                   key: str = "throughput_tok_s_per_device",
                   require_complete: bool = True) -> Optional[Report]:
    """The highest-``key`` report whose p99 latencies meet the SLOs."""
    ok = []
    for r in reports:
        if require_complete and not r.all_complete:
            continue
        ttft = r.summary.get("ttft_p99_s")
        tpot = r.summary.get("tpot_p99_s")
        if ttft_p99 is not None and not (ttft is not None
                                         and ttft <= ttft_p99):
            continue
        if tpot_p99 is not None and not (tpot is not None
                                         and tpot <= tpot_p99):
            continue
        ok.append(r)

    def _key(r: Report) -> float:
        v = r.summary.get(key)
        return float("-inf") if v is None else v
    return max(ok, key=_key, default=None)
