"""Paper Fig. 2: CDF of relative error in simulated operator runtime.

Frontier's RF models vs the Vidur sqrt-proxy vs the analytical roofline,
evaluated on held-out heterogeneous batches against the virtual-kernel
ground truth (A800 profile, the paper's hardware).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.hardware import A800_SXM4_80G
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.opmodels.calibration import (
    fit_attention_model, fit_grouped_gemm_model, sample_attention_batch,
    sample_grouped_gemm,
)
from repro.core.opmodels.kernelsim import VirtualKernels
from repro.core.opmodels.vidur_proxy import VidurProxyModel

# qwen2-7b operator geometry (the paper's eval model)
H, K, HD = 28, 4, 128
E, TOPK, D_IN, D_OUT = 64, 8, 3584, 2560


def _cdf_stats(err: np.ndarray) -> Dict[str, float]:
    return {
        "mean": float(err.mean()),
        "p50": float(np.percentile(err, 50)),
        "p90": float(np.percentile(err, 90)),
        "p99": float(np.percentile(err, 99)),
        "frac_lt_6pct": float(np.mean(err < 0.06)),
        "frac_lt_10pct": float(np.mean(err < 0.10)),
    }


def run(n_fit: int = 900, n_eval: int = 150, seed: int = 0) -> List[str]:
    hw = A800_SXM4_80G
    vk = VirtualKernels(hw)
    analytical = OperatorModelSet(hw)
    proxy = VidurProxyModel(vk)
    lines = []

    def attn_oracle(q, kv, h, k, hd, causal, window):
        if any(x > 1 for x in q):
            return vk.attention_prefill(q, kv, h, k, hd, causal=causal,
                                        window=window)
        return vk.attention_decode(kv, h, k, hd, window=window)

    t0 = time.perf_counter()
    rf, _ = fit_attention_model(attn_oracle, n_heads=H, n_kv_heads=K,
                                head_dim=HD, n_samples=n_fit, seed=seed)
    fit_us = (time.perf_counter() - t0) * 1e6

    rng = np.random.default_rng(seed + 1)
    errs = {"frontier_rf": [], "vidur_proxy": [], "analytical": []}
    for _ in range(n_eval):
        decode = rng.random() < 0.5
        q, kv = sample_attention_batch(rng, decode=decode)
        t = attn_oracle(q, kv, H, K, HD, not decode, 0)
        preds = {
            "frontier_rf": rf.predict(q, kv, causal=not decode, window=0),
            "vidur_proxy": (proxy.attention_decode(kv, H, K, HD) if decode
                            else proxy.attention_prefill(q, kv, H, K, HD)),
            "analytical": (analytical.attention_decode(kv, H, K, HD) if decode
                           else analytical.attention_prefill(q, kv, H, K, HD)),
        }
        for name, p in preds.items():
            errs[name].append(abs(p - t) / max(t, 1e-12))

    for name, e in errs.items():
        s = _cdf_stats(np.asarray(e))
        lines.append(
            f"fig2_attention_{name},{fit_us if name=='frontier_rf' else 0:.0f},"
            f"mean_rel_err={s['mean']:.4f};p50={s['p50']:.4f};p90={s['p90']:.4f};"
            f"frac_lt_10pct={s['frac_lt_10pct']:.3f}")

    # GroupedGEMM (Vidur: unsupported -> homogenized fallback shown for scale)
    t0 = time.perf_counter()
    gg, _ = fit_grouped_gemm_model(lambda c, di, do: vk.grouped_gemm(c, di, do),
                                   n_experts=E, top_k=TOPK, d_in=D_IN,
                                   d_out=D_OUT, n_samples=n_fit // 2, seed=seed)
    gg_fit_us = (time.perf_counter() - t0) * 1e6
    gerrs = {"frontier_rf": [], "vidur_homog": [], "analytical": []}
    for _ in range(n_eval):
        c = sample_grouped_gemm(rng, n_experts=E, top_k=TOPK, d_in=D_IN,
                                d_out=D_OUT)
        t = vk.grouped_gemm(c, D_IN, D_OUT)
        gerrs["frontier_rf"].append(abs(gg.predict(c) - t) / t)
        gerrs["vidur_homog"].append(
            abs(proxy.grouped_gemm(c, D_IN, D_OUT) - t) / t)
        gerrs["analytical"].append(
            abs(analytical.grouped_gemm(c, D_IN, D_OUT) - t) / t)
    for name, e in gerrs.items():
        s = _cdf_stats(np.asarray(e))
        lines.append(
            f"fig2_groupedgemm_{name},{gg_fit_us if name=='frontier_rf' else 0:.0f},"
            f"mean_rel_err={s['mean']:.4f};frac_lt_6pct={s['frac_lt_6pct']:.3f}")
    return lines


if __name__ == "__main__":
    for l in run():
        print(l)
