"""Block assembly + scan-over-layer-groups decoder stack.

The per-layer pattern (cfg.block_pattern) is cycled into *groups* of one
period each; ``lax.scan`` runs over the groups with stacked parameters
(compact HLO, compile time independent of depth — essential for the
512-device dry-run).  A non-divisible tail (recurrentgemma's 26 = 3*8 + 2)
is applied unrolled.

Block kinds: "global"/"local" (attention + dense-or-MoE FFN), "rwkv"
(time-mix + channel-mix), "recurrent" (RG-LRU + MLP).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV, ModelConfig,
)
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import PD, AxisRules, rms_norm

AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac", "moe_load_cv")


def _zeros_aux() -> Dict[str, jax.Array]:
    return {k: jnp.float32(0.0) for k in AUX_KEYS}


# ---------------------------------------------------------------------------
# Param descriptors
# ---------------------------------------------------------------------------
def block_pds(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    p: Dict[str, Any] = {
        "ln1": PD((d,), ("embed",), "zeros"),
        "ln2": PD((d,), ("embed",), "zeros"),
    }
    if cfg.post_block_norm:
        p["ln1_post"] = PD((d,), ("embed",), "zeros")
        p["ln2_post"] = PD((d,), ("embed",), "zeros")
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        p["attn"] = attn.attn_pds(cfg)
        if cfg.cross_attention:
            p["xattn"] = attn.attn_pds(cfg, cross=True)
            p["ln_x"] = PD((d,), ("embed",), "zeros")
        if cfg.moe is not None:
            p["moe"] = moe_mod.moe_pds(cfg)
            if cfg.moe.num_shared_experts:
                p["shared_mlp"] = mlp_mod.mlp_pds(
                    cfg, cfg.moe.expert_d_ff * cfg.moe.num_shared_experts)
        else:
            p["mlp"] = mlp_mod.mlp_pds(cfg)
    elif kind == RWKV:
        p["tm"] = rwkv_mod.timemix_pds(cfg)
        p["cm"] = rwkv_mod.channelmix_pds(cfg)
    elif kind == RECURRENT:
        p["rec"] = rglru_mod.rglru_pds(cfg)
        p["mlp"] = mlp_mod.mlp_pds(cfg)
    else:
        raise ValueError(kind)
    return p


def block_cache_pds(cfg: ModelConfig, kind: str, batch: int, seq: int,
                    memory_len: int = 0) -> Dict[str, Any]:
    d = cfg.d_model
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        clen = cfg.kv_cache_len(seq, kind)
        c = attn.cache_pds(cfg, batch, clen)
        if cfg.cross_attention and memory_len:
            K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            c["xk"] = PD((batch, memory_len, K, hd), ("batch", None, None, None), "zeros")
            c["xv"] = PD((batch, memory_len, K, hd), ("batch", None, None, None), "zeros")
        return c
    if kind == RWKV:
        H, hs = d // cfg.rwkv_head_size, cfg.rwkv_head_size
        return {
            "tm_shift": PD((batch, d), ("batch", "embed"), "zeros"),
            "cm_shift": PD((batch, d), ("batch", "embed"), "zeros"),
            "state": PD((batch, H, hs, hs), ("batch", "heads", None, None),
                        "zeros", jnp.float32),
        }
    if kind == RECURRENT:
        W = cfg.conv1d_width
        return {
            "conv_tail": PD((batch, W - 1, d), ("batch", None, "mlp"), "zeros"),
            "h": PD((batch, d), ("batch", "mlp"), "zeros", jnp.float32),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _ffn_train(cfg, p, h, ax, *, train: bool):
    if cfg.moe is not None:
        y, aux = moe_mod.moe_apply(cfg, p["moe"], h, ax, train=train)
        if cfg.moe.num_shared_experts:
            y = y + mlp_mod.mlp_apply(cfg, p["shared_mlp"], h, ax)
        return y, aux
    return mlp_mod.mlp_apply(cfg, p["mlp"], h, ax), _zeros_aux()


def _post(cfg, p, name, y):
    if cfg.post_block_norm:
        return rms_norm(y, p[name], cfg.rms_eps, zero_centered=True)
    return y


def block_train(cfg: ModelConfig, kind: str, p, x, ax: AxisRules, *,
                causal: bool = True, train: bool = True,
                memory: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence block forward (no cache)."""
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        h = rms_norm(x, p["ln1"], cfg.rms_eps, zero_centered=True)
        a = attn.attention_train(cfg, p["attn"], h, ax, window=window, causal=causal)
        x = x + _post(cfg, p, "ln1_post", a)
        if memory is not None:
            hx = rms_norm(x, p["ln_x"], cfg.rms_eps, zero_centered=True)
            x = x + attn.attention_train(cfg, p["xattn"], hx, ax, memory=memory)
        h = rms_norm(x, p["ln2"], cfg.rms_eps, zero_centered=True)
        f, aux = _ffn_train(cfg, p, h, ax, train=train)
        return x + _post(cfg, p, "ln2_post", f), aux
    if kind == RWKV:
        h = rms_norm(x, p["ln1"], cfg.rms_eps, zero_centered=True)
        B, _, d = x.shape
        H, hs = d // cfg.rwkv_head_size, cfg.rwkv_head_size
        y, _, _ = rwkv_mod.timemix_apply(
            cfg, p["tm"], h, ax,
            prev_shift=jnp.zeros((B, d), x.dtype),
            prev_state=jnp.zeros((B, H, hs, hs), jnp.float32))
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.rms_eps, zero_centered=True)
        y, _ = rwkv_mod.channelmix_apply(cfg, p["cm"], h, ax,
                                         prev_shift=jnp.zeros((B, d), x.dtype))
        return x + y, _zeros_aux()
    if kind == RECURRENT:
        B, _, d = x.shape
        h = rms_norm(x, p["ln1"], cfg.rms_eps, zero_centered=True)
        y, _, _ = rglru_mod.rglru_apply(
            cfg, p["rec"], h, ax,
            conv_tail=jnp.zeros((B, cfg.conv1d_width - 1, d), x.dtype),
            h0=jnp.zeros((B, d), jnp.float32))
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.rms_eps, zero_centered=True)
        return x + mlp_mod.mlp_apply(cfg, p["mlp"], h, ax), _zeros_aux()
    raise ValueError(kind)


def block_prefill(cfg: ModelConfig, kind: str, p, x, ax: AxisRules, *,
                  memory: Optional[jax.Array] = None, cache_len: int = 0,
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Forward that also produces the decode cache entry for this block."""
    B, S, d = x.shape
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        h = rms_norm(x, p["ln1"], cfg.rms_eps, zero_centered=True)
        # recompute k/v for the cache (cheap relative to attention)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        _, k, v = attn._project_qkv(cfg, p["attn"], h, pos, ax)
        a = attn.attention_train(cfg, p["attn"], h, ax, window=window, causal=True)
        x = x + _post(cfg, p, "ln1_post", a)
        cache = _kv_to_cache(cfg, k, v, cache_len or S, window, ax)
        if memory is not None:
            hx = rms_norm(x, p["ln_x"], cfg.rms_eps, zero_centered=True)
            x = x + attn.attention_train(cfg, p["xattn"], hx, ax, memory=memory)
            cache["xk"] = jnp.einsum("bsd,dhk->bshk", memory, p["xattn"]["wk"])
            cache["xv"] = jnp.einsum("bsd,dhk->bshk", memory, p["xattn"]["wv"])
        h = rms_norm(x, p["ln2"], cfg.rms_eps, zero_centered=True)
        f, _ = _ffn_train(cfg, p, h, ax, train=False)
        return x + _post(cfg, p, "ln2_post", f), cache
    if kind == RWKV:
        h = rms_norm(x, p["ln1"], cfg.rms_eps, zero_centered=True)
        H, hs = d // cfg.rwkv_head_size, cfg.rwkv_head_size
        y, tm_shift, state = rwkv_mod.timemix_apply(
            cfg, p["tm"], h, ax,
            prev_shift=jnp.zeros((B, d), x.dtype),
            prev_state=jnp.zeros((B, H, hs, hs), jnp.float32))
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.rms_eps, zero_centered=True)
        y, cm_shift = rwkv_mod.channelmix_apply(
            cfg, p["cm"], h, ax, prev_shift=jnp.zeros((B, d), x.dtype))
        return x + y, {"tm_shift": tm_shift, "cm_shift": cm_shift, "state": state}
    if kind == RECURRENT:
        h = rms_norm(x, p["ln1"], cfg.rms_eps, zero_centered=True)
        y, tail, hlast = rglru_mod.rglru_apply(
            cfg, p["rec"], h, ax,
            conv_tail=jnp.zeros((B, cfg.conv1d_width - 1, d), x.dtype),
            h0=jnp.zeros((B, d), jnp.float32))
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.rms_eps, zero_centered=True)
        return x + mlp_mod.mlp_apply(cfg, p["mlp"], h, ax), \
            {"conv_tail": tail, "h": hlast}
    raise ValueError(kind)


def _kv_to_cache(cfg, k, v, cache_len, window, ax: AxisRules):
    """Store prefill K/V into a (possibly ring) cache of length cache_len."""
    S = k.shape[1]
    eff = min(window, cache_len) if window else cache_len
    if S >= eff:
        ck, cv = k[:, S - eff:], v[:, S - eff:]
        if window and eff == cache_len:
            # ring semantics: absolute position p lives at slot p % cache_len
            # (decode writes at pos % cache_len), so rotate the stored window.
            ck = jnp.roll(ck, S % cache_len, axis=1)
            cv = jnp.roll(cv, S % cache_len, axis=1)
        if eff < cache_len:
            pad = [(0, 0), (0, cache_len - eff), (0, 0), (0, 0)]
            ck, cv = jnp.pad(ck, pad), jnp.pad(cv, pad)
    else:
        pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
        ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
    ck = ax.constrain(ck, "batch", "kv_seq", None, None)
    cv = ax.constrain(cv, "batch", "kv_seq", None, None)
    return {"k": ck, "v": cv}


def block_decode(cfg: ModelConfig, kind: str, p, x, cache, pos, ax: AxisRules,
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token step.  x (B,1,D); pos scalar int32."""
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        h = rms_norm(x, p["ln1"], cfg.rms_eps, zero_centered=True)
        kv_cache = {"k": cache["k"], "v": cache["v"]}
        a, kv_cache = attn.attention_decode(cfg, p["attn"], h, kv_cache, pos, ax,
                                            window=window)
        x = x + _post(cfg, p, "ln1_post", a)
        new_cache = dict(cache)
        new_cache.update(kv_cache)
        if cfg.cross_attention and "xk" in cache:
            hx = rms_norm(x, p["ln_x"], cfg.rms_eps, zero_centered=True)
            a, _ = attn.attention_decode(cfg, p["xattn"], hx, {}, pos, ax,
                                         memory_kv=(cache["xk"], cache["xv"]))
            x = x + a
        h = rms_norm(x, p["ln2"], cfg.rms_eps, zero_centered=True)
        f, _ = _ffn_train(cfg, p, h, ax, train=False)
        return x + _post(cfg, p, "ln2_post", f), new_cache
    if kind == RWKV:
        h = rms_norm(x, p["ln1"], cfg.rms_eps, zero_centered=True)
        y, tm_shift, state = rwkv_mod.timemix_decode(
            cfg, p["tm"], h, ax, prev_shift=cache["tm_shift"],
            prev_state=cache["state"])
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.rms_eps, zero_centered=True)
        y, cm_shift = rwkv_mod.channelmix_apply(
            cfg, p["cm"], h, ax, prev_shift=cache["cm_shift"])
        x = x + y
        return x, {"tm_shift": tm_shift, "cm_shift": cm_shift, "state": state}
    if kind == RECURRENT:
        h = rms_norm(x, p["ln1"], cfg.rms_eps, zero_centered=True)
        y, tail, hlast = rglru_mod.rglru_decode(
            cfg, p["rec"], h, ax, conv_tail=cache["conv_tail"], h0=cache["h"])
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.rms_eps, zero_centered=True)
        x = x + mlp_mod.mlp_apply(cfg, p["mlp"], h, ax)
        return x, {"conv_tail": tail, "h": hlast}
    raise ValueError(kind)
