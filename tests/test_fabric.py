"""Shared network fabric + $ accounting test suite (PR tentpole pin).

Three layers of coverage:

1. A hand-computed golden fixture for the processor-sharing contention
   math, pinned to 1e-9 — two overlapping transfers into one uplink on a
   tiny 3-cluster topology, completion times worked out by hand.
2. Deterministic end-to-end checks: ``fabric: none`` is bit-identical to
   the point-to-point path, fabric-on exposes strictly more comm time
   than the uncontended sum, and the $ metrics satisfy their defining
   identities (rate = devices x price, fleet $ = sum of instance $,
   tok/s/$ inversely proportional to price).
3. A hypothesis property suite (runs where hypothesis is installed, like
   tests/test_properties.py): bytes conservation over random
   topologies/flow sets, contention monotonicity (an extra flow never
   speeds anything up), and oversubscription monotonicity.
"""
import math

import pytest

from repro.api import SimSpec, run
from repro.api.spec import FabricSpec, SpecError, TopologySpec
from repro.core.engine import SimEngine
from repro.core.events import EV
from repro.core.fabric import Fabric, FabricConfig, FabricOps
from repro.core.hardware import A800_SXM4_80G, H100_SXM, HARDWARE, LinkSpec
from repro.core.opmodels.analytical import OperatorModelSet


# ------------------------------------------------------------- harness --
def run_fabric(uplinks, transfers, *, oversubscription=1.0, latency_s=0.0):
    """Drive a bare Fabric: ``transfers`` is [(t_submit, src, dst, nbytes)];
    returns (fabric, {index: completion_time})."""
    eng = SimEngine()
    fab = Fabric(eng, FabricConfig(mode="shared",
                                   oversubscription=oversubscription,
                                   latency_s=latency_s))
    for name, bw in uplinks.items():
        fab.attach(name, bw)
    done = {}
    for i, (t0, src, dst, nb) in enumerate(transfers):
        def submit(ev, i=i, src=src, dst=dst, nb=nb):
            fab.start_transfer(
                src, dst, nb,
                done=lambda i=i: done.__setitem__(i, eng.now))
        eng.at(t0, EV.KV_TRANSFER_START, submit)
    eng.run()
    return fab, done


def pd_spec(**overrides):
    body = {
        "name": "fabric-pd",
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "pd", "n_prefill": 1, "n_decode": 2},
        "workload": {"n_requests": 30, "rate": 25.0, "prompt_mean": 512,
                     "output_mean": 32, "seed": 7},
        "seed": 7,
    }
    body.update(overrides)
    return SimSpec.from_dict(body)


# ------------------------------------- satellite 1: zero-bandwidth link --
def test_link_zero_bandwidth_raises():
    """Regression: bandwidth=0 used to price every transfer as FREE
    (``latency + nbytes/bw`` with a silent division guard upstream)."""
    with pytest.raises(ValueError, match="bandwidth must be > 0"):
        LinkSpec("a", "b", bandwidth=0.0).transfer_time(1e6)
    with pytest.raises(ValueError, match="bandwidth must be > 0"):
        LinkSpec("a", "b", bandwidth=-1.0).transfer_time(1e6)
    # sane links still price normally
    assert LinkSpec("a", "b", bandwidth=1e9,
                    latency=1e-3).transfer_time(1e9) == \
        pytest.approx(1.001)


def test_spec_rejects_zero_bandwidth_link():
    spec = pd_spec(topology={
        "preset": None,
        "clusters": [{"name": "p", "role": "prefill"},
                     {"name": "d", "role": "decode"}],
        "links": [{"src": "p", "dst": "d", "bandwidth": 0.0}]})
    with pytest.raises(SpecError, match="must be > 0 bytes/s"):
        spec.validate()


def test_spec_rejects_nonpositive_transfer_bw():
    spec = pd_spec(topology={"preset": "pd", "transfer_bw": 0.0})
    with pytest.raises(SpecError, match="transfer_bw"):
        spec.validate()


# --------------------------- satellite 2: hand-computed fixture (1e-9) --
def test_hand_computed_contention_fixture():
    """3 clusters (A, B, C), every uplink 100 B/s, oversubscription 1:

    - T1: A->C, 600 B, submitted t=0.  Solo rate 100 B/s.
    - T2: B->C, 300 B, submitted t=2.  C's rx uplink now carries two
      flows, so each gets 100/2 = 50 B/s (A's and B's tx sides are solo).

    Timeline: T1 moves 200 B by t=2 (400 left).  Both run at 50 B/s;
    T2 finishes its 300 B at t = 2 + 300/50 = 8.  T1 moved another
    300 B by then (100 left), is re-priced back to 100 B/s, and
    finishes at t = 8 + 100/100 = 9.  Uncontended: 6 s + 3 s.
    """
    fab, done = run_fabric(
        {"A": 100.0, "B": 100.0, "C": 100.0},
        [(0.0, "A", "C", 600.0),
         (2.0, "B", "C", 300.0)])
    assert done[0] == pytest.approx(9.0, abs=1e-9)
    assert done[1] == pytest.approx(8.0, abs=1e-9)
    assert fab.stats["bytes"] == pytest.approx(900.0, abs=1e-9)
    assert fab.stats["transfers"] == 2
    # exposed spans: (9 - 0) + (8 - 2) = 15; uncontended 600/100 + 300/100
    assert fab.exposed_comm_s() == pytest.approx(15.0, abs=1e-9)
    assert fab.uncontended_comm_s() == pytest.approx(9.0, abs=1e-9)
    assert fab.in_flight() == 0


def test_fixture_without_overlap_is_uncontended():
    """The same two transfers spaced out never contend: each completes in
    its solo time and exposed == uncontended exactly."""
    fab, done = run_fabric(
        {"A": 100.0, "B": 100.0, "C": 100.0},
        [(0.0, "A", "C", 600.0),
         (50.0, "B", "C", 300.0)])
    assert done[0] == pytest.approx(6.0, abs=1e-9)
    assert done[1] == pytest.approx(53.0, abs=1e-9)
    assert fab.exposed_comm_s() == pytest.approx(
        fab.uncontended_comm_s(), abs=1e-9)


def test_oversubscription_divides_uplinks():
    """oversubscription k divides every uplink's effective capacity by k
    — a solo 600 B transfer over a 100 B/s uplink takes 6k seconds."""
    for k in (1.0, 2.0, 4.0):
        _, done = run_fabric({"A": 100.0, "C": 100.0},
                             [(0.0, "A", "C", 600.0)],
                             oversubscription=k)
        assert done[0] == pytest.approx(6.0 * k, abs=1e-9)


def test_latency_phase_precedes_bandwidth_phase():
    fab, done = run_fabric({"A": 100.0, "C": 100.0},
                           [(0.0, "A", "C", 600.0)], latency_s=0.5)
    assert done[0] == pytest.approx(6.5, abs=1e-9)
    assert fab.uncontended_comm_s() == pytest.approx(6.5, abs=1e-9)


def test_unattached_endpoints_are_unconstrained():
    """A flow whose endpoints never attached an uplink (e.g. an external
    KV source) completes immediately — the fabric only prices what it
    models."""
    fab, done = run_fabric({}, [(1.0, "X", "Y", 1e12)])
    assert done[0] == pytest.approx(1.0, abs=1e-9)
    assert fab.in_flight() == 0


# ------------------------------------- deterministic monotonicity pins --
def test_added_flow_never_speeds_up_existing():
    base = [(0.0, "A", "C", 600.0)]
    _, solo = run_fabric({"A": 100.0, "B": 100.0, "C": 100.0}, base)
    _, shared = run_fabric({"A": 100.0, "B": 100.0, "C": 100.0},
                           base + [(2.0, "B", "C", 300.0)])
    assert shared[0] >= solo[0] - 1e-12


def test_raising_oversubscription_never_lowers_completions():
    ups = {"A": 100.0, "B": 100.0, "C": 100.0}
    flows = [(0.0, "A", "C", 600.0), (2.0, "B", "C", 300.0),
             (3.0, "A", "B", 250.0)]
    prev = None
    for k in (1.0, 1.5, 2.0, 4.0):
        _, done = run_fabric(ups, flows, oversubscription=k)
        if prev is not None:
            for i in done:
                assert done[i] >= prev[i] - 1e-12
        prev = done


# ------------------------------------------------- FabricOps collectives --
def test_base_m2n_is_exactly_p2p():
    """The base model set's m2n must price exactly as p2p so workflows
    that switched from p2p to m2n stay bit-identical without a fabric."""
    ops = OperatorModelSet(A800_SXM4_80G)
    for nbytes in (1e3, 1e6, 1e9):
        assert ops.m2n(nbytes, 4, 8) == ops.p2p(nbytes, inter_node=True)
        assert ops.m2n(nbytes, 4, 8, inter_node=False) == \
            ops.p2p(nbytes, inter_node=False)


def test_fabric_ops_collectives_slower_when_oversubscribed():
    inner = OperatorModelSet(A800_SXM4_80G)
    fops = FabricOps(inner, FabricConfig(mode="shared",
                                         oversubscription=2.0,
                                         latency_s=5e-6))
    nbytes = 64e6
    for n in (2, 4, 8):
        assert fops.all_reduce(nbytes, n, inter_node=True) > \
            inner.all_reduce(nbytes, n, inter_node=True)
        assert fops.all_to_all(nbytes, n, inter_node=True) > \
            inner.all_to_all(nbytes, n, inter_node=True)
        assert fops.p2p(nbytes) > inner.p2p(nbytes)
        assert fops.m2n(nbytes, n, 2 * n) > 0.0
        # intra-node falls through to the wrapped models untouched
        assert fops.all_reduce(nbytes, n, inter_node=False) == \
            inner.all_reduce(nbytes, n, inter_node=False)
    # compute delegates exactly
    assert fops.gemm(512, 512, 512) == inner.gemm(512, 512, 512)


def test_fabric_ops_tree_vs_ring():
    cfg = dict(mode="shared", oversubscription=1.0, latency_s=1e-5)
    inner = OperatorModelSet(A800_SXM4_80G)
    ring = FabricOps(inner, FabricConfig(collective="ring", **cfg))
    tree = FabricOps(inner, FabricConfig(collective="tree", **cfg))
    # both algorithms price positive and differently at n=8
    r = ring.all_reduce(64e6, 8, inter_node=True)
    t = tree.all_reduce(64e6, 8, inter_node=True)
    assert r > 0 and t > 0 and r != t


def test_m2n_narrow_side_bottlenecks():
    fops = FabricOps(OperatorModelSet(A800_SXM4_80G),
                     FabricConfig(mode="shared"))
    # widening the narrow side adds lanes -> strictly faster
    assert fops.m2n(1e9, 2, 8) > fops.m2n(1e9, 4, 8)
    # widening only the wide side does nothing
    assert fops.m2n(1e9, 2, 8) == fops.m2n(1e9, 2, 16)


# ---------------------------------------- end-to-end: none == baseline --
def test_fabric_none_bit_identical_to_baseline():
    base = run(pd_spec())
    none_str = run(pd_spec(topology={"preset": "pd", "n_prefill": 1,
                                     "n_decode": 2, "fabric": "none"}))
    none_map = run(pd_spec(topology={"preset": "pd", "n_prefill": 1,
                                     "n_decode": 2,
                                     "fabric": {"mode": "none"}}))
    assert none_str.summary == base.summary
    assert none_map.summary == base.summary


def test_fabric_shared_exposes_contention_end_to_end():
    # a burst of arrivals over a slow shared uplink forces KV transfers
    # to overlap on the decode rx side — that's the contention under test
    rep = run(pd_spec(
        topology={"preset": "pd", "n_prefill": 2, "n_decode": 1,
                  "fabric": {"mode": "shared", "oversubscription": 2.0,
                             "uplink_bw": 2e7}},
        workload={"n_requests": 30, "arrival": "burst", "burst_size": 15,
                  "burst_period": 2.0, "prompt_mean": 512,
                  "output_mean": 32, "seed": 7}))
    s = rep.summary
    assert rep.all_complete
    assert s["fabric_transfers"] > 0
    assert s["fabric_exposed_comm_s"] > s["fabric_uncontended_comm_s"]
    assert s["fabric_contention_delay_s"] > 0
    # the legacy serial accounting still runs alongside
    assert s["kv_transfer_count"] == s["fabric_transfers"]


def test_fabric_excludes_layer_streamed_transfer():
    spec = pd_spec(topology={"preset": "pd",
                             "fabric": {"mode": "shared"}},
                   memory={"manager": "paged", "transfer_overlap": 0.5})
    with pytest.raises(SpecError, match="transfer_overlap"):
        spec.validate()


def test_fabric_spec_validation_and_roundtrip():
    with pytest.raises(SpecError, match="fabric mode"):
        pd_spec(topology={"preset": "pd",
                          "fabric": {"mode": "warp"}}).validate()
    with pytest.raises(SpecError, match="oversubscription"):
        pd_spec(topology={"preset": "pd",
                          "fabric": {"mode": "shared",
                                     "oversubscription": 0}}).validate()
    with pytest.raises(SpecError, match="collective"):
        pd_spec(topology={"preset": "pd",
                          "fabric": {"mode": "shared",
                                     "collective": "mesh"}}).validate()
    spec = pd_spec(topology={"preset": "pd", "n_prefill": 1, "n_decode": 2,
                             "fabric": {"mode": "shared",
                                        "oversubscription": 1.5,
                                        "latency_s": 1e-5,
                                        "collective": "tree"}})
    spec.validate()
    assert SimSpec.from_yaml(spec.to_yaml()) == spec
    assert SimSpec.from_dict(spec.to_dict()) == spec
    # unset fabric stays out of the serialized form (hash stability)
    assert "fabric" not in pd_spec().to_dict()["topology"]


# ------------------------------------------- satellite 4: $ accounting --
def test_cost_identities_mixed_hardware():
    """Hand-computed: H100 prefill (1 dev x $3.90/hr) + A800 decode
    (1 dev x $1.90/hr) burn $5.80/hr; every derived metric follows."""
    spec = pd_spec(topology={
        "preset": None,
        "clusters": [
            {"name": "prefill", "role": "prefill",
             "hardware": "H100-SXM"},
            {"name": "decode", "role": "decode",
             "hardware": "A800-SXM4-80G"},
        ],
        "links": [{"src": "prefill", "dst": "decode",
                   "bandwidth": 5e10}]})
    rep = run(spec)
    s = rep.summary
    rate = (H100_SXM.dollars_per_hour + A800_SXM4_80G.dollars_per_hour)
    assert rate == pytest.approx(5.80)
    assert s["dollars_per_hour"] == pytest.approx(rate)
    assert s["provisioned_dollars"] == pytest.approx(
        rate * s["duration_s"] / 3600.0)
    assert s["tok_per_s_per_dollar"] == pytest.approx(
        s["throughput_tok_s"] / rate)
    assert rep.clusters["prefill"]["cost"]["dollars_per_hour"] == \
        pytest.approx(H100_SXM.dollars_per_hour)
    assert rep.clusters["decode"]["cost"]["dollars_per_hour"] == \
        pytest.approx(A800_SXM4_80G.dollars_per_hour)


def test_dollar_override_scales_cost_not_simulation():
    """topology.dollars_per_hour re-prices hardware without touching the
    simulation: throughput identical, tok/s/$ exactly inverse in price."""
    base = run(pd_spec())
    k = 2.0
    name = "A800-SXM4-80G"
    priced = pd_spec(topology={
        "preset": "pd", "n_prefill": 1, "n_decode": 2,
        "dollars_per_hour": {name: HARDWARE[name].dollars_per_hour * k}})
    rep = run(priced)
    assert rep.summary["throughput_tok_s"] == \
        base.summary["throughput_tok_s"]
    assert rep.summary["dollars_per_hour"] == pytest.approx(
        base.summary["dollars_per_hour"] * k)
    assert rep.summary["tok_per_s_per_dollar"] == pytest.approx(
        base.summary["tok_per_s_per_dollar"] / k)
    # round-trips through YAML with the override intact
    assert SimSpec.from_yaml(priced.to_yaml()) == priced


def test_dollar_override_validation():
    with pytest.raises(SpecError, match="unknown hardware"):
        pd_spec(topology={"preset": "pd",
                          "dollars_per_hour": {"B200": 9.0}}).validate()
    with pytest.raises(SpecError, match="dollars_per_hour"):
        pd_spec(topology={
            "preset": "pd",
            "dollars_per_hour": {"H100-SXM": -1.0}}).validate()


def test_unpriced_hardware_reports_none():
    spec = pd_spec(topology={"preset": "pd", "n_prefill": 1,
                             "n_decode": 2,
                             "dollars_per_hour": {"A800-SXM4-80G": 0.0}})
    s = run(spec).summary
    assert s["dollars_per_hour"] == 0.0
    assert s["provisioned_dollars"] == 0.0
    assert s["tok_per_s_per_dollar"] is None


# --------------------------------------------------- fleet $ accounting --
FLEET_BODY = {
    "name": "fabric-fleet",
    "model": {"name": "qwen2-7b", "smoke": True},
    "topology": {"preset": "colocated"},
    "workload": {"n_requests": 80, "rate": 40.0, "rate_curve": "diurnal",
                 "rate_period": 8.0, "rate_amplitude": 0.7,
                 "prompt_mean": 256, "output_mean": 32, "seed": 21},
    "slo": {"ttft_s": 0.5, "tpot_s": 0.05},
    "fleet": {
        "instances": [
            {"name": "colo", "count": 2},
            {"name": "pd", "count": 1,
             "topology": {"preset": "pd", "n_prefill": 1,
                          "n_decode": 1,
                          "dollars_per_hour": {"A800-SXM4-80G": 3.0}}},
        ],
        "autoscaler": {"min_instances": 1, "max_instances": 4,
                       "interval_s": 0.5, "cooldown_s": 1.0,
                       "up_queue_depth": 6.0, "down_queue_depth": 1.0},
    },
    "seed": 21,
}


def test_fleet_dollars_is_sum_of_instances():
    from repro.fleet.report import run_fleet
    rep = run_fleet(SimSpec.from_dict(FLEET_BODY))
    total = sum(b["provisioned_dollars"] for b in rep.instances.values())
    assert rep.summary["provisioned_dollars"] == pytest.approx(
        total, rel=1e-12)
    assert 0.0 <= rep.summary["idle_dollars"] <= \
        rep.summary["provisioned_dollars"] + 1e-12
    assert rep.summary["tok_per_s_per_dollar"] > 0
    # the pd group's decode/prefill run on re-priced ($3/hr) hardware
    pd_rates = [b["summary"] for n, b in rep.instances.items()
                if n.startswith("pd")]
    assert pd_rates  # the heterogeneous group was actually built


def test_scale_events_carry_dollar_deltas():
    from repro.fleet.report import run_fleet
    rep = run_fleet(SimSpec.from_dict(FLEET_BODY))
    ups = [e for e in rep.scale_events if e["kind"] == "scale_up"]
    downs = [e for e in rep.scale_events if e["kind"] == "scale_down"]
    assert ups or downs, "autoscaler never acted; retune FLEET_BODY"
    for e in ups:
        assert e["dollars_per_hour_delta"] > 0
    for e in downs:
        assert e["dollars_per_hour_delta"] < 0


# --------------------------------------- satellite 3: hypothesis suite --
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _SETTINGS = dict(max_examples=25, deadline=None)
    CLUSTERS = ("A", "B", "C", "D")

    uplink_sets = st.fixed_dictionaries(
        {c: st.floats(min_value=1.0, max_value=1e4, allow_nan=False)
         for c in CLUSTERS})

    def _flows(min_size=1, max_size=8):
        one = st.tuples(
            st.floats(min_value=0.0, max_value=10.0, allow_nan=False),
            st.sampled_from(CLUSTERS), st.sampled_from(CLUSTERS),
            st.floats(min_value=1.0, max_value=1e5, allow_nan=False))
        return st.lists(one, min_size=min_size, max_size=max_size)

    @given(ups=uplink_sets, flows=_flows(),
           oversub=st.floats(min_value=1.0, max_value=8.0,
                             allow_nan=False))
    @settings(**_SETTINGS)
    def test_bytes_conserved_and_all_complete(ups, flows, oversub):
        fab, done = run_fabric(ups, flows, oversubscription=oversub)
        # every flow completed exactly once, none left in flight
        assert sorted(done) == list(range(len(flows)))
        assert fab.in_flight() == 0
        assert fab.stats["transfers"] == len(flows)
        assert fab.stats["bytes"] == pytest.approx(
            sum(f[3] for f in flows), rel=1e-12)
        # no flow beats its solo (uncontended) time, and exposed time
        # sums to at least the uncontended total
        for i, (t0, src, dst, nb) in enumerate(flows):
            solo = min(ups[src], ups[dst]) / oversub
            assert done[i] >= t0 + nb / solo - 1e-6
        assert fab.exposed_comm_s() >= fab.uncontended_comm_s() - 1e-6

    @given(ups=uplink_sets, flows=_flows(min_size=2))
    @settings(**_SETTINGS)
    def test_extra_flow_is_monotone(ups, flows):
        """Removing the last flow never delays the survivors."""
        _, full = run_fabric(ups, flows)
        _, trimmed = run_fabric(ups, flows[:-1])
        for i in trimmed:
            assert full[i] >= trimmed[i] - 1e-6

    @given(ups=uplink_sets, flows=_flows(),
           k1=st.floats(min_value=1.0, max_value=4.0, allow_nan=False),
           k2=st.floats(min_value=1.0, max_value=4.0, allow_nan=False))
    @settings(**_SETTINGS)
    def test_oversubscription_is_monotone(ups, flows, k1, k2):
        lo, hi = min(k1, k2), max(k1, k2)
        _, fast = run_fabric(ups, flows, oversubscription=lo)
        _, slow = run_fabric(ups, flows, oversubscription=hi)
        for i in fast:
            assert slow[i] >= fast[i] - 1e-6
