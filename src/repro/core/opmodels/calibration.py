"""Calibration: sample operator workloads, fit RF models against a ground
truth (virtual kernels, or measured CPU wall-clock of the JAX oracles), and
evaluate relative-error CDFs — the paper's Fig. 2 protocol.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import HardwareSpec
from repro.core.opmodels.features import (
    attention_features, grouped_gemm_features,
)
from repro.core.opmodels.forest import RandomForest
from repro.core.opmodels.kernelsim import VirtualKernels


# ---------------------------------------------------------------------------
# Workload samplers (heterogeneous batches, incl. the skewed regimes that
# break proxy models)
# ---------------------------------------------------------------------------
def sample_attention_batch(rng: np.random.Generator, *, decode: bool,
                           max_len: int = 8192, max_batch: int = 128,
                           ) -> Tuple[List[int], List[int]]:
    b = int(rng.integers(1, max_batch + 1))
    regime = rng.choice(["uniform", "lognormal", "skewed", "bimodal"])
    if regime == "uniform":
        lens = rng.integers(16, max_len, b)
    elif regime == "lognormal":
        lens = np.clip(rng.lognormal(np.log(512), 1.0, b).astype(int), 16, max_len)
    elif regime == "bimodal":
        lens = np.where(rng.random(b) < 0.8,
                        rng.integers(16, 256, b),
                        rng.integers(max_len // 2, max_len, b))
    else:  # skewed: one giant + many small (the paper's 72-request example)
        lens = rng.integers(16, 128, b)
        lens[0] = int(rng.integers(max_len // 2, max_len))
    # clamp covers the skewed regime's fixed 16..128 draws when an oracle
    # caps max_len below 128 (CPU interpret-mode Pallas timing)
    lens = [min(int(x), max_len) for x in lens]
    if decode:
        return [1] * b, lens
    return lens, lens


def sample_grouped_gemm(rng: np.random.Generator, *, n_experts: int,
                        top_k: int, d_in: int, d_out: int,
                        max_tokens: int = 16384) -> List[int]:
    toks = int(rng.integers(min(64, max_tokens), max_tokens))
    alpha = float(rng.uniform(0.0, 2.0))
    ranks = np.arange(1, n_experts + 1, dtype=np.float64)
    p = ranks ** -alpha
    rng.shuffle(p)
    p /= p.sum()
    return [int(x) for x in rng.multinomial(toks * top_k, p)]


# ---------------------------------------------------------------------------
# Fitted models
# ---------------------------------------------------------------------------
@dataclass
class FittedAttention:
    forest: RandomForest
    n_heads: int
    n_kv_heads: int
    head_dim: int

    def predict(self, q_lens, kv_lens, *, causal: bool, window: int) -> float:
        x = attention_features(q_lens, kv_lens, self.n_heads,
                               self.n_kv_heads, self.head_dim,
                               causal=causal, window=window)
        return float(np.exp(self.forest.predict(x[None])[0]))


@dataclass
class FittedGroupedGemm:
    forest: RandomForest
    d_in: int
    d_out: int

    def predict(self, tokens_per_expert) -> float:
        x = grouped_gemm_features(tokens_per_expert, self.d_in, self.d_out)
        return float(np.exp(self.forest.predict(x[None])[0]))


def fit_attention_model(oracle: Callable, *, n_heads: int, n_kv_heads: int,
                        head_dim: int, n_samples: int = 600,
                        decode_frac: float = 0.5, max_len: int = 8192,
                        seed: int = 0, window: int = 0,
                        ) -> Tuple[FittedAttention, Dict[str, np.ndarray]]:
    """oracle(q_lens, kv_lens, heads, kv, hd, causal, window) -> seconds."""
    rng = np.random.default_rng(seed)
    X, y, held = [], [], []
    for i in range(n_samples):
        decode = rng.random() < decode_frac
        q, kv = sample_attention_batch(rng, decode=decode, max_len=max_len)
        t = oracle(q, kv, n_heads, n_kv_heads, head_dim,
                   causal=not decode, window=window)
        X.append(attention_features(q, kv, n_heads, n_kv_heads, head_dim,
                                    causal=not decode, window=window))
        y.append(math.log(max(t, 1e-9)))
        held.append((q, kv, decode, t))
    X, y = np.asarray(X), np.asarray(y)
    n_tr = int(0.8 * len(y))
    forest = RandomForest(seed=seed).fit(X[:n_tr], y[:n_tr])
    model = FittedAttention(forest, n_heads, n_kv_heads, head_dim)
    # held-out eval
    rel = []
    for (q, kv, decode, t) in held[n_tr:]:
        p = model.predict(q, kv, causal=not decode, window=window)
        rel.append(abs(p - t) / max(t, 1e-12))
    return model, {"rel_err": np.asarray(rel)}


def fit_grouped_gemm_model(oracle: Callable, *, n_experts: int, top_k: int,
                           d_in: int, d_out: int, n_samples: int = 500,
                           seed: int = 0,
                           ) -> Tuple[FittedGroupedGemm, Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    X, y, held = [], [], []
    for _ in range(n_samples):
        counts = sample_grouped_gemm(rng, n_experts=n_experts, top_k=top_k,
                                     d_in=d_in, d_out=d_out)
        t = oracle(counts, d_in, d_out)
        X.append(grouped_gemm_features(counts, d_in, d_out))
        y.append(math.log(max(t, 1e-9)))
        held.append((counts, t))
    X, y = np.asarray(X), np.asarray(y)
    n_tr = int(0.8 * len(y))
    forest = RandomForest(seed=seed).fit(X[:n_tr], y[:n_tr])
    model = FittedGroupedGemm(forest, d_in, d_out)
    rel = []
    for counts, t in held[n_tr:]:
        p = model.predict(counts)
        rel.append(abs(p - t) / max(t, 1e-12))
    return model, {"rel_err": np.asarray(rel)}


# ---------------------------------------------------------------------------
# Measured-on-CPU oracle (real wall-clock of the jnp reference ops) and
# micro-benchmarked CPU hardware profile — used for the end-to-end
# validation against the real mini serving engine (Table 2 protocol).
# ---------------------------------------------------------------------------
def measure_cpu_hardware(seed: int = 0) -> HardwareSpec:
    import jax
    import jax.numpy as jnp
    n = 1024
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(6):
        f(a).block_until_ready()
    dt = (time.perf_counter() - t0) / 6
    peak = 2 * n ** 3 / dt
    big = jnp.ones((64 * 1024 * 1024 // 4,), jnp.float32)
    g = jax.jit(lambda x: x * 1.0001)
    g(big).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(6):
        g(big).block_until_ready()
    bw = 2 * big.size * 4 / ((time.perf_counter() - t0) / 6)
    return HardwareSpec(name="cpu-host", peak_flops=peak, hbm_bw=bw,
                        hbm_capacity=8e9, intra_node_bw=bw, inter_node_bw=bw,
                        devices_per_node=1, n_cores=1, op_overhead=3e-5)


def cpu_attention_oracle(reps: int = 3) -> Callable:
    """Wall-clock oracle running the jnp reference attention on CPU."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref

    def oracle(q_lens, kv_lens, H, K, hd, causal=True, window=0):
        # pack the ragged batch as one padded tensor (measurement device is
        # CPU; shapes kept small by the caller)
        total = 0.0
        for q_len, kv_len in zip(q_lens, kv_lens):
            q = jnp.ones((1, int(q_len), H, hd), jnp.float32)
            k = jnp.ones((1, int(kv_len), K, hd), jnp.float32)
            v = k
            fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(
                q, k, v, causal=causal, window=window))
            fn(q, k, v).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(q, k, v).block_until_ready()
            total += (time.perf_counter() - t0) / reps
        return total
    return oracle
