"""Multi-device tests (subprocess with forced host devices): sharded train
step equivalence, MoE shard_map path, checkpoint elastic resharding, and a
small-scale dry-run including hlo_cost sanity."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow   # tier-2: subprocess multi-device runs

ROOT = Path(__file__).resolve().parent.parent


def _run(code: str, devices: int = 8) -> str:
    prog = ("import os\n"
            f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(code))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=540,
                       env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       cwd=str(ROOT))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.models.common import AxisRules, init_tree
    from repro.models.model import build_model
    from repro.training.optimizer import AdamW, AdamWConfig, make_train_step
    from repro.training.data import DataConfig, SyntheticLM

    cfg = get_config("qwen3-8b", smoke=True)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4, seed=0))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    def losses(mesh):
        ax = AxisRules(mesh)
        model = build_model(cfg, ax)
        params = init_tree(jax.random.PRNGKey(0), model.pds(), jnp.float32)
        opt = AdamW(AdamWConfig(lr=1e-3, zero1=True), ax)
        state = opt.init(params)
        step = make_train_step(model, opt)
        ls = []
        if mesh is None:
            jstep = jax.jit(step)
            for _ in range(3):
                params, state, m = jstep(params, state, batch)
                ls.append(float(m["loss"]))
        else:
            with jax.set_mesh(mesh):
                jstep = jax.jit(step)
                for _ in range(3):
                    params, state, m = jstep(params, state, batch)
                    ls.append(float(m["loss"]))
        return ls

    l1 = losses(None)
    l2 = losses(make_mesh((2, 4), ("data", "model")))
    np.testing.assert_allclose(l1, l2, rtol=5e-3, atol=5e-3)
    print("OK", l1, l2)
    """)
    assert "OK" in out


def test_moe_shard_map_matches_single_device():
    out = _run("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.configs.base import MoEConfig
    from repro.launch.mesh import make_mesh
    from repro.models.common import AxisRules, NO_RULES, init_tree
    from repro.models.moe import moe_apply, moe_pds

    cfg = dataclasses.replace(
        get_config("mixtral-8x7b", smoke=True),
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                      capacity_factor_train=8.0))  # dropless on both paths
    p = init_tree(jax.random.PRNGKey(0), moe_pds(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

    y0, aux0 = jax.jit(lambda p, x: moe_apply(cfg, p, x, NO_RULES, train=True))(p, x)

    mesh = make_mesh((2, 4), ("data", "model"))   # EP: 8 experts / 4 = 2
    ax = AxisRules(mesh)
    with jax.set_mesh(mesh):
        y1, aux1 = jax.jit(lambda p, x: moe_apply(cfg, p, x, ax, train=True))(p, x)
    # NB: capacity is per token-shard under data parallelism, so dispatch
    # can differ only when drops occur; this workload has no drops:
    assert float(aux0["moe_drop_frac"]) == 0.0, aux0
    assert float(aux1["moe_drop_frac"]) == 0.0, aux1
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=2e-5, rtol=2e-5)
    print("OK")
    """)
    assert "OK" in out


def test_checkpoint_elastic_reshard():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.models.common import AxisRules, init_tree, shape_tree
    from repro.models.model import build_model
    from repro.training import checkpoint as ckpt
    from jax.sharding import NamedSharding

    cfg = get_config("yi-9b", smoke=True)
    mesh_a = make_mesh((8,), ("model",))
    ax_a = AxisRules(mesh_a)
    model_a = build_model(cfg, ax_a)
    params = init_tree(jax.random.PRNGKey(0), model_a.pds(), jnp.float32)
    shard_a = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh_a, s), ax_a.spec_tree(model_a.pds()))
    params = jax.device_put(params, shard_a)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, params=params, step=5)
        # restore onto a DIFFERENT mesh shape (elastic rescale 8 -> 2x4)
        mesh_b = make_mesh((2, 4), ("data", "model"))
        ax_b = AxisRules(mesh_b)
        shard_b = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh_b, s), ax_b.spec_tree(model_a.pds()))
        like = shape_tree(model_a.pds(), jnp.float32)
        p2, _, step, _ = ckpt.restore(d, params_like=like, shardings=shard_b)
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK")
    """)
    assert "OK" in out


def test_small_scale_dryrun_and_roofline_terms():
    out = _run("""
    import jax, json
    from repro.configs import get_config, SHAPES
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.lowering import build_step, lower_step
    from repro.launch import hlo_cost

    cfg = get_config("yi-9b", smoke=True)
    mesh = make_mesh((2, 4), ("data", "model"))
    shape = ShapeConfig("mini_train", 32, 4, "train")
    b = build_step(cfg, mesh, shape)
    comp = lower_step(b, mesh).compile()
    costs = hlo_cost.analyze(comp.as_text())
    assert costs["flops"] > 0
    terms = hlo_cost.roofline_terms(costs, n_chips=8)
    assert terms["bottleneck"] in ("compute", "memory", "collective")
    shape_d = ShapeConfig("mini_dec", 64, 4, "decode")
    b2 = build_step(cfg, mesh, shape_d)
    comp2 = lower_step(b2, mesh).compile()
    print("OK", json.dumps(terms))
    """)
    assert "OK" in out
