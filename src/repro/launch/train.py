"""End-to-end training driver.

Runs a real training loop on the local devices (CPU in this container; the
same code path jit-lowers on the production meshes — see dryrun.py).
Supports any --arch (reduced via --smoke for laptop scale or a custom small
config), checkpoint/restart (--resume), and deterministic data.

examples/train_lm.py drives this for the ~100M-class run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_mesh
from repro.models.common import AxisRules, init_tree
from repro.models.model import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamW, AdamWConfig, make_train_step


def small_config(base: ModelConfig, *, layers: int, d_model: int,
                 d_ff: int, vocab: int, heads: int) -> ModelConfig:
    return dataclasses.replace(
        base, num_layers=layers, d_model=d_model, d_ff=d_ff,
        vocab_size=vocab, num_heads=heads,
        num_kv_heads=min(base.num_kv_heads, heads), head_dim=d_model // heads)


def run(arch: str = "yi-9b", *, smoke: bool = True, steps: int = 50,
        seq_len: int = 128, global_batch: int = 8, lr: float = 1e-3,
        ckpt_dir: str = "", ckpt_every: int = 25, resume: bool = False,
        mesh_shape=None, log_every: int = 10, size: str = "smoke",
        dtype=jnp.float32, seed: int = 0, remat: str = "none"):
    if size == "100m":
        cfg = small_config(get_config(arch), layers=8, d_model=512,
                           d_ff=2048, vocab=8192, heads=8)
    else:
        cfg = get_config(arch, smoke=smoke)

    if mesh_shape:
        mesh = make_mesh(mesh_shape, ("data", "model")[: len(mesh_shape)])
        ax = AxisRules(mesh)
    else:
        mesh, ax = None, AxisRules(None)

    model = build_model(cfg, ax, remat=remat)
    opt = AdamW(AdamWConfig(lr=lr, zero1=mesh is not None), ax)
    params = init_tree(jax.random.PRNGKey(seed), model.pds(), dtype)
    opt_state = opt.init(params)
    start_step = 0
    if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        params, opt_state, start_step, _ = ckpt.restore(
            ckpt_dir, params_like=params, opt_like=opt_state)
        print(f"resumed from step {start_step}")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                  global_batch=global_batch, seed=seed))
    step_fn = make_train_step(model, opt)
    if mesh is not None:
        with jax.set_mesh(mesh):
            step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for step in range(start_step, start_step + steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if mesh is not None:
            with jax.set_mesh(mesh):
                params, opt_state, metrics = step_fn(params, opt_state, batch)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == start_step + steps - 1:
            dt = time.time() - t0
            tok_s = (step - start_step + 1) * global_batch * seq_len / dt
            print(f"step {step:5d} loss {loss:.4f} ({tok_s:,.0f} tok/s)")
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            path = ckpt.save(ckpt_dir, params=params, opt_state=opt_state,
                             step=step + 1,
                             extra={"arch": cfg.name, "loss": loss})
            print(f"checkpoint -> {path}")
    return {"first_loss": losses[0], "last_loss": losses[-1],
            "losses": losses, "params": params, "opt_state": opt_state}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--size", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    a = ap.parse_args()
    out = run(a.arch, smoke=True, steps=a.steps, seq_len=a.seq_len,
              global_batch=a.global_batch, lr=a.lr, ckpt_dir=a.ckpt_dir,
              ckpt_every=a.ckpt_every, resume=a.resume, size=a.size,
              log_every=a.log_every)
    print(f"loss: {out['first_loss']:.4f} -> {out['last_loss']:.4f}")


if __name__ == "__main__":
    main()
