"""Request lifecycle state machine."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Optional


class RState(enum.Enum):
    QUEUED_PREFILL = "queued_prefill"
    PREFILL_RUNNING = "prefill_running"
    PREFILL_COMPLETE = "prefill_complete"   # KV held in prefill buffer
    KV_TRANSFER = "kv_transfer"
    QUEUED_DECODE = "queued_decode"
    DECODING = "decoding"
    COMPLETE = "complete"


# legal transitions (property-tested)
_TRANSITIONS = {
    RState.QUEUED_PREFILL: {RState.PREFILL_RUNNING},
    RState.PREFILL_RUNNING: {RState.PREFILL_COMPLETE, RState.QUEUED_PREFILL},
    RState.PREFILL_COMPLETE: {RState.KV_TRANSFER, RState.QUEUED_DECODE},
    RState.KV_TRANSFER: {RState.QUEUED_DECODE},
    RState.QUEUED_DECODE: {RState.DECODING},
    RState.DECODING: {RState.COMPLETE, RState.QUEUED_DECODE},
}


@dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    output_len: int
    state: RState = RState.QUEUED_PREFILL
    generated: int = 0
    prefill_progress: int = 0          # chunked-prefill bookkeeping
    timestamps: Dict[str, float] = field(default_factory=dict)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    def to(self, state: RState, now: float) -> None:
        allowed = _TRANSITIONS.get(self.state, set())
        if state not in allowed:
            raise ValueError(f"illegal transition {self.state} -> {state} "
                             f"(rid={self.rid})")
        self.state = state
        self.timestamps[state.value] = now

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.output_len

    # ---- metrics -----------------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        if self.generated <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (self.generated - 1)

    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival
