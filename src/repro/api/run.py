"""``run(spec) -> Report``: execute one SimSpec and return a typed report.

The Report replaces the raw metrics dict: summary percentiles (TTFT/TPOT/
e2e/queueing/goodput), per-cluster breakdowns (utilization, replica stats,
AF expert-parallel totals incl. straggler excess and cross-cluster bytes),
the request-conservation check, and provenance (spec hash, wall clock,
event count) — everything a sweep point needs to be self-describing on
disk.
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass
from datetime import datetime, timezone
from typing import Any, Dict, Mapping, Optional

from repro.api.spec import SimSpec, SpecError, _resolve_hw
from repro.configs import get_config
from repro.core.hardware import HardwareSpec, LinkSpec, ParallelismConfig
from repro.core.opmodels import resolve_opmodels
from repro.core.policies.batching import resolve_batching
from repro.core.topology import SystemHandle, build_system
from repro.core.workflows.af_disagg import build_af
from repro.core.workflows.colocated import build_colocated
from repro.core.workflows.pd_disagg import build_pd


class ReportBase:
    """Shared serialization surface of Report and FleetReport: summary
    item access, dict/JSON round-trip, and file save — one implementation
    so the two report types cannot drift apart."""

    def __getitem__(self, key: str) -> float:
        return self.summary[key]

    def get(self, key: str, default: Any = None) -> Any:
        return self.summary.get(key, default)

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]):
        return cls(**dict(d))

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True,
                          default=float)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2))
            f.write("\n")


@dataclass
class Report(ReportBase):
    """Typed result of one simulation run (JSON-serializable)."""
    name: str
    spec: Dict[str, Any]
    spec_hash: str
    summary: Dict[str, float]
    clusters: Dict[str, Dict[str, Any]]
    conservation: Dict[str, int]
    all_complete: bool
    n_devices: int
    sim_events: int
    sim_duration_s: float
    wall_clock_s: float
    created_at: str
    point: Optional[Dict[str, Any]] = None   # sweep-axis assignment


# ----------------------------------------------------------------- build --
def build(spec: SimSpec, *,
          hardware: Optional[HardwareSpec] = None,
          ops=None,
          engine=None) -> SystemHandle:
    """Compile a validated SimSpec into a runnable SystemHandle.

    ``hardware``/``ops`` inject measured/calibrated objects (the
    benchmark-calibration flow); by default both come from the spec.
    ``engine`` injects a shared SimEngine — how the fleet layer builds
    many instances into ONE deterministic event timeline.
    """
    if spec.fleet is not None:
        raise SpecError(
            "spec.fleet: build() compiles ONE deployment — fleet specs go "
            "through run() (repro.fleet.run_fleet), which builds each "
            "instance from a fleet-stripped sub-spec")
    spec.validate()
    cfg = get_config(spec.model.name, smoke=spec.model.smoke)
    topo = spec.topology
    hw = hardware if hardware is not None \
        else _resolve_hw(topo.hardware, "topology.hardware")
    if ops is None:
        if spec.opmodel.calibration is not None:
            from repro.calib import CalibrationError, load_calibrated_ops
            try:
                ops = load_calibrated_ops(spec.opmodel.calibration, cfg, hw)
            except CalibrationError as e:
                raise SpecError(f"opmodel.calibration: {e}") from e
        else:
            ops = resolve_opmodels(spec.opmodel.name, hw)
    pol = spec.policy
    pipeline = spec.pipeline.to_config() if spec.pipeline is not None \
        else None
    common = dict(ops=ops, routing=pol.router, seed=spec.seed,
                  engine=engine,
                  memory=pol.memory, queue_policy=pol.scheduler,
                  memoize=topo.memoize, pipeline=pipeline,
                  fabric=topo.fabric_config())
    if spec.memory is not None:
        # no memory section -> omit the kwargs so build_system's own
        # defaults apply (one source of truth for the legacy values)
        common.update(memory=spec.memory.manager_mapping(),
                      transfer_overlap=spec.memory.transfer_overlap,
                      kv_frac=spec.memory.capacity_frac)

    def batching(role: str, name: str = ""):
        try:
            return resolve_batching(pol.batching_for(role, name))
        except (KeyError, TypeError) as e:
            raise SpecError(f"policy.batching: {e}") from e

    if topo.preset == "colocated":
        handle = build_colocated(
            cfg, hw, n_replicas=topo.n_replicas,
            par=ParallelismConfig(tp=topo.tp, pp=topo.pp, ep=topo.ep),
            policy=batching("colocated", "colocated"), **common)
    elif topo.preset == "pd":
        handle = build_pd(
            cfg, hw, n_prefill=topo.n_prefill, n_decode=topo.n_decode,
            prefill_par=ParallelismConfig(tp=topo.prefill_tp),
            decode_par=ParallelismConfig(tp=topo.decode_tp),
            prefill_policy=batching("prefill", "prefill"),
            decode_policy=batching("decode", "decode"),
            transfer_bw=topo.transfer_bw, **common)
    elif topo.preset == "af":
        common.pop("memoize")
        link = None
        if topo.expert_link_bw is not None:
            link = LinkSpec("decode", "decode-experts",
                            bandwidth=topo.expert_link_bw,
                            latency=topo.expert_link_latency)
        handle = build_af(
            cfg, hw, n_prefill=topo.n_prefill, n_decode=topo.n_decode,
            m=topo.m, attn_par=ParallelismConfig(tp=topo.attn_tp),
            ffn_par=ParallelismConfig(tp=topo.ffn_tp, ep=topo.ffn_ep),
            prefill_par=ParallelismConfig(tp=topo.prefill_tp),
            remote_expert_ranks=tuple(topo.remote_expert_ranks),
            expert_cluster_hw=(_resolve_hw(topo.expert_cluster_hw,
                                           "topology.expert_cluster_hw")
                               if topo.expert_cluster_hw else None),
            expert_link=link, memoize=topo.memoize, **common)
    else:
        # inline StageGraph (the graph itself carries the fabric config)
        graph = topo.inline_graph(batching=lambda role, name:
                                  pol.batching_for(role, name))
        handle = build_system(cfg, hw, graph, transfer_bw=topo.transfer_bw,
                              **{k: v for k, v in common.items()
                                 if k not in ("memoize", "fabric")})
    if topo.dollars_per_hour:
        # spec-level $/GPU-hr overrides reprice each cluster's hardware;
        # downstream cost accounting reads cluster.hw
        for cluster in handle.clusters.values():
            cluster.hw = topo.hw_pricing(cluster.hw)
    if spec.opmodel.backend != "python":
        for cluster in handle.clusters.values():
            for w in cluster.replicas:
                w.predictor.backend = spec.opmodel.backend
    return handle


def _apply_faults(spec: SimSpec, handle: SystemHandle) -> None:
    for i, f in enumerate(spec.faults):
        cluster = handle.clusters[f.cluster]
        if f.replica >= len(cluster.replicas):
            raise SpecError(
                f"faults[{i}].replica: index {f.replica} out of range — "
                f"cluster {f.cluster!r} has {len(cluster.replicas)} "
                f"replicas")
        if f.kind == "failure":
            handle.controller.inject_failure(f.cluster, f.replica,
                                             at=f.at, downtime=f.downtime)
        else:   # straggler
            cluster.replicas[f.replica].slowdown = f.slowdown


def _cluster_breakdown(handle: SystemHandle) -> Dict[str, Dict[str, Any]]:
    now = handle.engine.now
    out: Dict[str, Dict[str, Any]] = {}
    for name, cluster in handle.clusters.items():
        cspec = getattr(cluster, "spec", None)
        info: Dict[str, Any] = {
            "role": cluster.role,
            "n_replicas": len(cluster.replicas),
            "devices": (cspec.n_replicas * cspec.devices_per_replica()
                        if cspec is not None else len(cluster.replicas)),
            "hardware": getattr(getattr(cluster, "hw", None), "name", None),
            "utilization": cluster.utilization(now),
            "replicas": {w.name: dict(w.stats) for w in cluster.replicas},
        }
        # provisioning cost: the cluster's device-count x $/GPU-hr rate
        # (run()/run_fleet fill in the time-integrated $ figures)
        info["cost"] = {
            "dollars_per_hour": info["devices"] * getattr(
                getattr(cluster, "hw", None), "dollars_per_hour", 0.0),
        }
        # memory-subsystem observability: per-cluster KV manager aggregates
        mems = [w.memory for w in cluster.replicas if w.memory is not None]
        if mems:
            hit = sum(m.hit_tokens for m in mems)
            prompt = sum(m.prompt_tokens for m in mems)
            info["memory"] = {
                "manager": type(mems[0]).name,
                "total_blocks": sum(m.total_blocks for m in mems),
                "utilization": (sum(m.utilization for m in mems)
                                / len(mems)),
                "peak_utilization": max(m.peak_utilization for m in mems),
                "cached_blocks": sum(m.cached_blocks() for m in mems),
                "preemptions": sum(w.stats.get("preemptions", 0)
                                   for w in cluster.replicas),
                "swap_outs": sum(w.stats.get("swap_outs", 0)
                                 for w in cluster.replicas),
                "swap_ins": sum(w.stats.get("swap_ins", 0)
                                for w in cluster.replicas),
                "evictions": sum(m.evictions for m in mems),
                "evicted_blocks": sum(m.evicted_blocks for m in mems),
                "prefix_hit_tokens": hit,
                "prefix_prompt_tokens": prompt,
                "prefix_hit_rate": (hit / prompt) if prompt else None,
            }
        # AF expert-parallel observability: aggregate per-replica totals
        af: Dict[str, float] = {}
        for w in cluster.replicas:
            totals = getattr(w.predictor, "af_totals", None)
            if totals:
                for k, v in totals.items():
                    af[k] = af.get(k, 0) + v
        if af:
            makespan = af.get("makespan_s", 0.0)
            serial = af.get("serial_makespan_s", 0.0)
            # latency-hiding derived observables: how much of the serial
            # chain was hidden, and the comm time each stage had exposed
            if serial > 0:
                af["overlap_efficiency"] = max(1.0 - makespan / serial, 0.0)
            if makespan > 0:
                af["attn_exposed_comm_frac"] = \
                    af.get("attn_exposed_comm_s", 0.0) / makespan
                af["ffn_exposed_comm_frac"] = \
                    af.get("ffn_exposed_comm_s", 0.0) / makespan
            info["af"] = af
        out[name] = info
    return out


def predictor_cache_stats(handle: SystemHandle) -> Dict[str, Any]:
    """Memo-cache effectiveness across every replica predictor: how much
    simulated work the shape-bucketed step cache absorbed (the dominant
    hot-path shortcut, so a collapsed hit rate explains a slow run)."""
    hits = misses = 0
    for cluster in handle.clusters.values():
        for w in cluster.replicas:
            hits += w.predictor.cache_hits
            misses += w.predictor.cache_misses
    total = hits + misses
    return {
        "predictor_cache_hits": hits,
        "predictor_cache_misses": misses,
        "predictor_cache_hit_rate": (hits / total) if total else None,
    }


# ------------------------------------------------------------------- run --
def run(spec: SimSpec, *,
        hardware: Optional[HardwareSpec] = None,
        ops=None,
        engine_overhead: Optional[float] = None,
        telemetry=None) -> Report:
    """Validate, build, and run one experiment; return its Report.

    Same spec + same seed is bit-deterministic: the event engine orders
    simultaneous events by schedule sequence and every RNG is seeded from
    ``spec.seed``.

    A spec with a ``fleet`` section dispatches to the fleet control plane
    and returns a :class:`repro.fleet.FleetReport` (same surface:
    ``summary`` / ``spec_hash`` / ``save`` / item access).

    ``telemetry`` injects an externally owned :class:`repro.obs.Telemetry`
    recorder (how ``run_traced`` keeps the spans after the run); with the
    default ``None``, a recorder is created internally iff ``spec.obs``
    is enabled.  Obs-off runs never touch the recorder paths.
    """
    if spec.fleet is not None:
        from repro.fleet import run_fleet
        return run_fleet(spec, hardware=hardware, ops=ops,
                         engine_overhead=engine_overhead,
                         telemetry=telemetry)
    if telemetry is None and spec.obs is not None and spec.obs.enabled:
        from repro.obs import Telemetry
        telemetry = Telemetry.from_spec(spec.obs)
    t0 = time.perf_counter()
    handle = build(spec, hardware=hardware, ops=ops)
    if telemetry is not None:
        from repro.obs import attach_telemetry
        attach_telemetry(handle, telemetry)
    if engine_overhead is not None:
        for cluster in handle.clusters.values():
            for w in cluster.replicas:
                w.predictor.engine_overhead = engine_overhead
    _apply_faults(spec, handle)
    requests = spec.workload.build_requests(spec.seed)
    closed = (spec.workload.concurrency
              if spec.workload.arrival == "closed" else None)
    summary = handle.run(
        requests,
        until=spec.until if spec.until is not None else float("inf"),
        closed_concurrency=closed,
        slo_ttft=spec.slo.ttft_s if spec.slo else None,
        slo_tpot=spec.slo.tpot_s if spec.slo else None)
    wall = time.perf_counter() - t0
    conservation = handle.controller.conservation_check()
    clusters = _cluster_breakdown(handle)
    # lift aggregate latency-hiding observables into the summary (AF
    # event-graph clusters book both actual and serial makespans)
    makespan = sum(c["af"].get("makespan_s", 0.0)
                   for c in clusters.values() if "af" in c)
    serial = sum(c["af"].get("serial_makespan_s", 0.0)
                 for c in clusters.values() if "af" in c)
    if serial > 0:
        summary["bubble_time_s"] = sum(c["af"].get("bubble_time_s", 0.0)
                                       for c in clusters.values()
                                       if "af" in c)
        summary["overlap_efficiency"] = max(1.0 - makespan / serial, 0.0)
    # memory-subsystem observables: prefix-cache hits and exposed vs
    # lump-sum KV-transfer time (PD layer-wise streaming); "preemptions"
    # is already in the summary via SystemHandle.run
    prompt_toks = sum(c["memory"]["prefix_prompt_tokens"]
                      for c in clusters.values() if "memory" in c)
    if prompt_toks:
        hit_toks = sum(c["memory"]["prefix_hit_tokens"]
                       for c in clusters.values() if "memory" in c)
        summary["prefix_hit_token_frac"] = hit_toks / prompt_toks
    summary.update(predictor_cache_stats(handle))
    ts = handle.controller.transfer_stats
    if ts["transfers"]:
        summary["kv_transfer_count"] = ts["transfers"]
        summary["kv_transfer_serial_s"] = ts["serial_s"]
        summary["kv_transfer_exposed_s"] = ts["exposed_s"]
        summary["kv_transfer_exposed_frac"] = (
            ts["exposed_s"] / ts["serial_s"] if ts["serial_s"] > 0 else 1.0)
    # first-class $ accounting: provisioned rate from each cluster's
    # hardware pricing, integrated over the measured duration
    duration = float(summary.get("duration_s") or 0.0)
    rate = 0.0
    for c in clusters.values():
        crate = c["cost"]["dollars_per_hour"]
        c["cost"]["provisioned_dollars"] = crate * duration / 3600.0
        toks = sum(r.get("tokens", 0) for r in c["replicas"].values())
        c["cost"]["tok_per_s_per_dollar"] = (
            float(toks / duration / crate) if crate > 0 and duration > 0
            else None)
        rate += crate
    summary["dollars_per_hour"] = rate
    summary["provisioned_dollars"] = rate * duration / 3600.0
    tput = float(summary.get("throughput_tok_s") or 0.0)
    summary["tok_per_s_per_dollar"] = tput / rate if rate > 0 else None
    if handle.fabric is not None:
        fs = handle.fabric.stats
        exposed = handle.fabric.exposed_comm_s()
        uncontended = handle.fabric.uncontended_comm_s()
        summary["fabric_transfers"] = fs["transfers"]
        summary["fabric_exposed_comm_s"] = exposed
        summary["fabric_uncontended_comm_s"] = uncontended
        summary["fabric_contention_delay_s"] = exposed - uncontended
    if telemetry is not None:
        summary.update(telemetry.summary_fields())
    return Report(
        name=spec.name,
        spec=spec.to_dict(),
        spec_hash=spec.spec_hash(),
        summary=summary,
        clusters=clusters,
        conservation=conservation,
        all_complete=(conservation == {"complete": len(requests)}),
        n_devices=handle.n_devices,
        sim_events=handle.engine.processed,
        sim_duration_s=summary.get("duration_s", 0.0),
        wall_clock_s=wall,
        created_at=datetime.now(timezone.utc).isoformat(timespec="seconds"),
    )
