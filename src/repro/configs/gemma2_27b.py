"""gemma2-27b — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig, ATTN_LOCAL, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    block_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norm=True,
    mlp_act="gelu",            # GeGLU
    tie_embeddings=True,
)
