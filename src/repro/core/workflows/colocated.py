"""Colocated serving system (the traditional deployment baseline).

A thin preset over the StageGraph topology layer: one cluster, role
"colocated".  SystemHandle/_kv_budget live in repro.core.topology and are
re-exported here for backward compatibility.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.engine import SimEngine
from repro.core.hardware import HardwareSpec, ParallelismConfig
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.policies.batching import BatchingPolicy
from repro.core.topology import (  # noqa: F401  (re-exports)
    ClusterSpec, StageGraph, SystemHandle, _kv_budget, build_system,
)


def build_colocated(cfg: ModelConfig, hw: HardwareSpec, *,
                    n_replicas: int = 1,
                    par: Optional[ParallelismConfig] = None,
                    policy: Optional[BatchingPolicy] = None,
                    ops: Optional[OperatorModelSet] = None,
                    engine: Optional[SimEngine] = None,
                    routing=None, seed: int = 0,
                    memory=None, queue_policy=None,
                    memoize: bool = True,
                    pipeline=None, transfer_overlap: float = 0.0,
                    kv_frac: float = 0.9, fabric=None) -> SystemHandle:
    """Colocated preset.

    .. deprecated::
        ``build_colocated`` is kept as a thin shim over the declarative
        experiment API; prefer ``repro.api.SimSpec`` with
        ``TopologySpec(preset="colocated", ...)`` and ``repro.api.run`` —
        specs serialize, validate, and sweep.
    """
    graph = StageGraph(clusters=[
        ClusterSpec("colocated", "colocated", n_replicas=n_replicas,
                    par=par or ParallelismConfig(tp=1), policy=policy,
                    replica_prefix="colo", memoize=memoize),
    ], fabric=fabric)
    return build_system(cfg, hw, graph, ops=ops, routing=routing,
                        engine=engine, memory=memory,
                        queue_policy=queue_policy, seed=seed,
                        pipeline=pipeline, transfer_overlap=transfer_overlap,
                        kv_frac=kv_frac)
