"""MiniEngine correctness: continuous batching must not change tokens."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import NO_RULES, build_model, init_tree
from repro.serving.engine import MiniEngine


def _reference_greedy(cfg, params, prompt, n_new, max_seq):
    model = build_model(cfg, NO_RULES)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = model.prefill(params, {"tokens": toks},
                                  cache_len=max_seq, all_logits=True)
    out = [int(np.argmax(np.asarray(logits)[0, len(prompt) - 1]))]
    pos = len(prompt)
    cur = jnp.asarray([[out[-1]]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = model.decode(params, cache, cur, jnp.int32(pos))
        out.append(int(np.argmax(np.asarray(logits)[0, 0])))
        cur = jnp.asarray([[out[-1]]], jnp.int32)
        pos += 1
    return out


@pytest.mark.slow   # tier-2: real-model greedy decode (~13 s on CPU)
def test_engine_matches_reference_greedy_decode():
    cfg = get_config("qwen2-7b", smoke=True)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12),
               rng.integers(0, cfg.vocab_size, 20),
               rng.integers(0, cfg.vocab_size, 7)]
    eng = MiniEngine(cfg, max_slots=3, max_seq=64, seed=0)
    reqs = eng.submit(prompts, 10)
    eng.run()
    for req in reqs:
        want = _reference_greedy(cfg, eng.params, req.prompt, 10, 64)
        assert req.tokens == want, (req.rid, req.tokens, want)


def test_engine_more_requests_than_slots():
    cfg = get_config("qwen2-7b", smoke=True)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 8) for _ in range(5)]
    eng = MiniEngine(cfg, max_slots=2, max_seq=48, seed=1)
    reqs = eng.submit(prompts, 6)
    rep = eng.run()
    assert rep["n_requests"] == 5
    assert all(len(r.tokens) == 6 for r in reqs)
    assert all(r.finished is not None for r in reqs)
