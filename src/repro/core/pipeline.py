"""Latency-hiding pipelining strategies (paper: "advanced pipelining
strategies for latency hiding").

A ``PipelineConfig`` selects, per workflow, how much of the serving
micro-workflow is allowed to overlap:

- **AF decode-step overlap** (``af_overlap``): how the attention/transfer/
  FFN event graph of one AF-disaggregated decode step shares resources.

  * ``"none"``   — the legacy model: the attention cluster is one compute
    lane, the FFN/EP group advances in lockstep, and A2F/F2A transfers are
    un-contended (an infinitely wide NIC).  This is the default and is
    bit-for-bit identical to the simulator before pipelining existed.
  * ``"serial"`` — the no-latency-hiding baseline: every task (attention,
    transfers, FFN/expert stages) is chained on ONE resource, so the step
    time is the sum of all task durations.  This is the denominator of
    ``overlap_efficiency``.
  * ``"two_batch"`` — MegaScale-Infer-style ping-pong: attention compute,
    FFN compute, and per-direction NIC lanes (``nic_lanes`` each way) are
    separate resources, so micro-batch *i*'s A2F/F2A transfers and
    FFN/expert compute hide behind micro-batch *i+1*'s attention — but
    transfers now *contend* for finite NIC lanes instead of being free.

- **Chunked prefill with piggybacked decode** (``chunked_prefill``): the
  Sarathi-Serve strategy for colocated pools and PD prefill clusters.
  Prefills are split into ``prefill_chunk``-token chunks and mixed batches
  (prefill chunk + decode tokens) are priced as one fused step: prefill
  attention for the chunk, decode attention for the piggybacked tokens,
  shared GEMMs over the combined token count (see
  ``ExecutionPredictor.step_time(..., n_prefill=...)``).

- **EP dispatch/combine comm-compute overlap** (``ep_overlap``): the
  efficiency eta in [0, 1] with which the per-rank expert sub-graph hides
  its all-to-all legs behind GroupedGEMM compute (chunked dispatch a la
  DeepEP).  A leg+compute pair costs ``(1-eta)*(comm+compute) +
  eta*max(comm, compute)`` — eta=0 is the serial legacy behavior, eta=1 is
  perfect overlap.

Configs resolve uniformly (instance | registered name | ``{"name": ...,
**overrides}`` mapping | ``None``) through :func:`resolve_pipeline`,
mirroring the batching/routing/scheduler registries.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, replace
from typing import Optional, Union

AF_OVERLAP_MODES = ("none", "serial", "two_batch")


@dataclass(frozen=True)
class PipelineConfig:
    """Per-workflow latency-hiding strategy selection (see module docs)."""
    af_overlap: str = "none"       # "none" | "serial" | "two_batch"
    nic_lanes: int = 1             # parallel transfer lanes per direction
    chunked_prefill: bool = False  # Sarathi chunked prefill + piggyback
    prefill_chunk: int = 512       # tokens per prefill chunk
    ep_overlap: float = 0.0        # EP comm/compute overlap efficiency eta

    def validate(self) -> "PipelineConfig":
        if self.af_overlap not in AF_OVERLAP_MODES:
            raise ValueError(f"af_overlap must be one of {AF_OVERLAP_MODES}, "
                             f"got {self.af_overlap!r}")
        if self.nic_lanes < 1:
            raise ValueError(f"nic_lanes must be >= 1, got {self.nic_lanes}")
        if self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {self.prefill_chunk}")
        if not 0.0 <= self.ep_overlap <= 1.0:
            raise ValueError(f"ep_overlap must be in [0, 1], "
                             f"got {self.ep_overlap}")
        return self

    @property
    def enabled(self) -> bool:
        """True when any strategy deviates from the legacy serial model."""
        return (self.af_overlap != "none" or self.chunked_prefill
                or self.ep_overlap > 0.0)

    def to_dict(self) -> dict:
        return asdict(self)


# Named strategy presets, selectable from specs/YAML like any other policy.
PIPELINES = {
    "serial": PipelineConfig(af_overlap="serial"),
    "two_batch": PipelineConfig(af_overlap="two_batch"),
    "chunked_prefill": PipelineConfig(chunked_prefill=True),
    "ep_overlap": PipelineConfig(ep_overlap=0.8),
    "full_overlap": PipelineConfig(af_overlap="two_batch",
                                   chunked_prefill=True, ep_overlap=0.8),
}


def resolve_pipeline(spec: Union[None, str, dict, PipelineConfig]
                     ) -> Optional[PipelineConfig]:
    """Uniform pipeline-config argument handling (mirrors resolve_router).

    Accepts an instance (validated and returned), a registered preset name
    ("serial", "two_batch", "chunked_prefill", "ep_overlap",
    "full_overlap"), a mapping — either ``{"name": preset, **overrides}``
    or plain ``PipelineConfig`` fields — or None (pipelining disabled).
    """
    if spec is None:
        return None
    if isinstance(spec, PipelineConfig):
        return spec.validate()
    if isinstance(spec, str):
        spec = {"name": spec}
    if isinstance(spec, dict):
        kw = dict(spec)
        name = kw.pop("name", None)
        if name is not None:
            if name not in PIPELINES:
                raise KeyError(f"unknown pipeline preset {name!r}; "
                               f"registered: {sorted(PIPELINES)}")
            return replace(PIPELINES[name], **kw).validate()
        return PipelineConfig(**kw).validate()
    raise TypeError(f"pipeline must be None, a name, a mapping, or a "
                    f"PipelineConfig; got {type(spec).__name__}")
