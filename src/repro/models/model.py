"""Model assembly: decoder-only LM and encoder-decoder, with scan-over-groups.

Public API (used by launch/, serving/, training/, tests/):

    model = build_model(cfg, ax, remat="none")
    pds    = model.pds()                  # param descriptors
    params = common.init_tree(key, pds, dtype)
    loss   = model.loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode(params, cache, tokens, pos)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    ATTN_GLOBAL, ModelConfig, ShapeConfig,
)
from repro.models import transformer as tfm
from repro.models.common import (
    PD, AxisRules, cross_entropy_loss, rms_norm, softcap, stack_pds,
)
from repro.models.transformer import AUX_KEYS

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


def _tree_sum(trees):
    out = {k: jnp.float32(0.0) for k in AUX_KEYS}
    for t in trees:
        for k in AUX_KEYS:
            v = t[k]
            out[k] = out[k] + (jnp.sum(v) if getattr(v, "ndim", 0) else v)
    return out


class LM:
    """Decoder-only LM covering dense / moe / ssm / hybrid / vlm families."""

    def __init__(self, cfg: ModelConfig, ax: AxisRules, *, remat: str = "none"):
        self.cfg = cfg
        self.ax = ax
        self.remat = remat
        pat = cfg.pattern
        period = len(cfg.block_pattern)
        self.n_groups = cfg.num_layers // period
        self.period_kinds = tuple(pat[:period])
        self.tail_kinds = tuple(pat[self.n_groups * period:])

    # ------------------------------------------------------------ params --
    def pds(self) -> Dict[str, Any]:
        cfg = self.cfg
        tree: Dict[str, Any] = {
            "embed": PD((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), 0.02),
            "final_norm": PD((cfg.d_model,), ("embed",), "zeros"),
            "groups": tuple(
                stack_pds(tfm.block_pds(cfg, kind), self.n_groups)
                for kind in self.period_kinds),
            "tail": tuple(tfm.block_pds(cfg, kind) for kind in self.tail_kinds),
        }
        if not cfg.tie_embeddings:
            tree["head"] = PD((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"), 0.02)
        return tree

    # --------------------------------------------------------- embeddings --
    def _embed(self, params, ids: jax.Array) -> jax.Array:
        """Megatron-style vocab-sharded lookup (local gather + psum)."""
        cfg, ax = self.cfg, self.ax
        emb = params["embed"]
        tp = ax.model_size()
        if ax.mesh is None or tp <= 1 or cfg.padded_vocab % tp != 0:
            x = emb[ids]
        else:
            Vl = cfg.padded_vocab // tp
            bspec = ax.batch(ids.shape[0])

            def body(e_l, ids_l):
                j = jax.lax.axis_index("model")
                loc = ids_l - j * Vl
                ok = (loc >= 0) & (loc < Vl)
                g = e_l[jnp.clip(loc, 0, Vl - 1)]
                g = jnp.where(ok[..., None], g, 0)
                return jax.lax.psum(g, "model")

            x = shard_map(
                body, mesh=ax.mesh,
                in_specs=(P("model", None), P(bspec, None)),
                out_specs=P(bspec, None, None), check_vma=False,
            )(emb, ids)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        return self.ax.constrain(x, "batch", None, "embed")

    def _inputs_to_x(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        if cfg.frontend == "patch" and "embeds" in batch:
            pe = batch["embeds"].astype(x.dtype)
            pe = self.ax.constrain(pe, "batch", None, "embed")
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def _logits(self, params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.rms_eps, zero_centered=True)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
        logits = softcap(logits, cfg.final_logit_softcap)
        return self.ax.constrain(logits, "batch", None, "vocab")

    # ------------------------------------------------------------- stacks --
    def _scan_train(self, params, x, *, causal=True, train=True, memory=None):
        cfg, ax = self.cfg, self.ax
        kinds = self.period_kinds

        def group_fn(x, gp):
            auxes = []
            for s, kind in enumerate(kinds):
                x, aux = tfm.block_train(cfg, kind, gp[s], x, ax,
                                         causal=causal, train=train,
                                         memory=memory)
                auxes.append(aux)
            return x, _tree_sum(auxes)

        fn = group_fn
        if self.remat != "none" and train:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if self.remat == "dots" else None)
            fn = jax.checkpoint(group_fn, policy=policy)
        x, auxs = jax.lax.scan(fn, x, params["groups"])
        tails = []
        for kind, tp_ in zip(self.tail_kinds, params["tail"]):
            x, aux = tfm.block_train(cfg, kind, tp_, x, ax, causal=causal,
                                     train=train, memory=memory)
            tails.append(aux)
        aux = _tree_sum([jax.tree_util.tree_map(jnp.sum, auxs)] + tails)
        n = max(cfg.num_layers, 1)
        aux = {k: v / n for k, v in aux.items()}
        return x, aux

    def _scan_prefill(self, params, x, *, cache_len: int, memory=None):
        cfg, ax = self.cfg, self.ax
        kinds = self.period_kinds

        def group_fn(x, gp):
            caches = []
            for s, kind in enumerate(kinds):
                x, c = tfm.block_prefill(cfg, kind, gp[s], x, ax,
                                         memory=memory,
                                         cache_len=cfg.kv_cache_len(cache_len, kind))
                caches.append(c)
            return x, tuple(caches)

        x, gcaches = jax.lax.scan(group_fn, x, params["groups"])
        tcaches = []
        for kind, tp_ in zip(self.tail_kinds, params["tail"]):
            x, c = tfm.block_prefill(cfg, kind, tp_, x, ax, memory=memory,
                                     cache_len=cfg.kv_cache_len(cache_len, kind))
            tcaches.append(c)
        return x, {"groups": gcaches, "tail": tuple(tcaches)}

    def _scan_decode(self, params, cache, x, pos):
        cfg, ax = self.cfg, self.ax
        kinds = self.period_kinds

        def group_fn(x, scanned):
            gp, gc = scanned
            newc = []
            for s, kind in enumerate(kinds):
                x, c = tfm.block_decode(cfg, kind, gp[s], x, gc[s], pos, ax)
                newc.append(c)
            return x, tuple(newc)

        x, gcaches = jax.lax.scan(group_fn, x, (params["groups"], cache["groups"]))
        tcaches = []
        for kind, tp_, tc in zip(self.tail_kinds, params["tail"], cache["tail"]):
            x, c = tfm.block_decode(cfg, kind, tp_, x, tc, pos, ax)
            tcaches.append(c)
        return x, {"groups": gcaches, "tail": tuple(tcaches)}

    # -------------------------------------------------------------- steps --
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x = self._inputs_to_x(params, batch)
        x, aux = self._scan_train(params, x, train=True)
        logits = self._logits(params, x)
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:  # vlm: loss on text tail only
            logits = logits[:, logits.shape[1] - labels.shape[1]:]
        mask = labels >= 0
        loss = self._sharded_ce(logits, jnp.maximum(labels, 0), mask)
        moe_loss = 0.01 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]
        metrics = dict(aux)
        metrics["ce_loss"] = loss
        return loss + moe_loss, metrics

    def _sharded_ce(self, logits, labels, mask) -> jax.Array:
        """CE over a vocab-sharded logits tensor without big gathers."""
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, len(lf.shape) - 1)
        picked = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
        nll = lse - picked
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)

    def prefill(self, params, batch, *, cache_len: Optional[int] = None,
                all_logits: bool = False):
        x = self._inputs_to_x(params, batch)
        S_total = x.shape[1]
        x, cache = self._scan_prefill(params, x, cache_len=cache_len or S_total)
        logits = self._logits(params, x if all_logits else x[:, -1:, :])
        return logits, cache

    def decode(self, params, cache, tokens, pos):
        x = self._embed(params, tokens)
        x, cache = self._scan_decode(params, cache, x, pos)
        logits = self._logits(params, x)
        return logits, cache

    # ------------------------------------------------------------- shapes --
    def cache_pds(self, batch: int, seq: int, memory_len: int = 0):
        cfg = self.cfg
        g = tuple(
            stack_pds(tfm.block_cache_pds(cfg, kind, batch, seq, memory_len),
                      self.n_groups)
            for kind in self.period_kinds)
        t = tuple(tfm.block_cache_pds(cfg, kind, batch, seq, memory_len)
                  for kind in self.tail_kinds)
        return {"groups": g, "tail": t}

    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            if cfg.frontend == "patch":
                Sp = int(S * cfg.frontend_fraction)
                return {
                    "tokens": jax.ShapeDtypeStruct((B, S - Sp), i32),
                    "embeds": jax.ShapeDtypeStruct((B, Sp, cfg.d_model), jnp.bfloat16),
                    "labels": jax.ShapeDtypeStruct((B, S), i32),
                }
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            if cfg.frontend == "patch":
                Sp = int(S * cfg.frontend_fraction)
                return {
                    "tokens": jax.ShapeDtypeStruct((B, S - Sp), i32),
                    "embeds": jax.ShapeDtypeStruct((B, Sp, cfg.d_model), jnp.bfloat16),
                }
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        # decode
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


class EncDec:
    """Encoder-decoder (seamless).  Same step API as LM."""

    def __init__(self, cfg: ModelConfig, ax: AxisRules, *, remat: str = "none"):
        self.cfg = cfg
        self.ax = ax
        enc_cfg = dataclasses.replace(cfg, cross_attention=False,
                                      num_layers=cfg.encoder_layers)
        self.encoder = LM(enc_cfg, ax, remat=remat)
        self.decoder = LM(cfg, ax, remat=remat)

    def pds(self):
        enc = self.encoder.pds()
        enc.pop("embed"), enc.pop("final_norm")
        enc.pop("head", None)
        dec = self.decoder.pds()
        d = self.cfg.d_model
        return {
            "enc": {"groups": enc["groups"], "tail": enc["tail"],
                    "norm": PD((d,), ("embed",), "zeros")},
            "dec": dec,
        }

    def encode(self, params, frames: jax.Array) -> jax.Array:
        dt = jax.tree_util.tree_leaves(params["dec"])[0].dtype
        x = self.ax.constrain(frames.astype(dt), "batch", None, "embed")
        ep = {"groups": params["enc"]["groups"], "tail": params["enc"]["tail"]}
        x, _ = self.encoder._scan_train(ep, x, causal=False, train=False)
        return rms_norm(x, params["enc"]["norm"], self.cfg.rms_eps,
                        zero_centered=True)

    def loss(self, params, batch):
        memory = self.encode(params, batch["frames"])
        x = self.decoder._embed(params["dec"], batch["tokens"])
        x, aux = self.decoder._scan_train(params["dec"], x, train=True,
                                          memory=memory)
        logits = self.decoder._logits(params["dec"], x)
        mask = batch["labels"] >= 0
        loss = self.decoder._sharded_ce(logits, jnp.maximum(batch["labels"], 0), mask)
        metrics = dict(aux)
        metrics["ce_loss"] = loss
        return loss, metrics

    def prefill(self, params, batch, *, cache_len: Optional[int] = None,
                all_logits: bool = False):
        memory = self.encode(params, batch["frames"])
        x = self.decoder._embed(params["dec"], batch["tokens"])
        S = x.shape[1]
        x, cache = self.decoder._scan_prefill(params["dec"], x,
                                              cache_len=cache_len or S,
                                              memory=memory)
        logits = self.decoder._logits(params["dec"],
                                      x if all_logits else x[:, -1:, :])
        return logits, cache

    def decode(self, params, cache, tokens, pos):
        x = self.decoder._embed(params["dec"], tokens)
        x, cache = self.decoder._scan_decode(params["dec"], cache, x, pos)
        logits = self.decoder._logits(params["dec"], x)
        return logits, cache

    def cache_pds(self, batch: int, seq: int, memory_len: int = 0):
        return self.decoder.cache_pds(batch, seq, memory_len or 4096)

    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        f = jnp.float32
        if shape.kind == "train":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), f),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if shape.kind == "prefill":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), f),
                "tokens": jax.ShapeDtypeStruct((B, 1024), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def build_model(cfg: ModelConfig, ax: AxisRules, *, remat: str = "none"):
    if cfg.encoder_layers:
        return EncDec(cfg, ax, remat=remat)
    return LM(cfg, ax, remat=remat)
