"""Checkpoint/restore with elastic resharding.

Fault-tolerance substrate: step-atomic writes (tmp dir + rename), full
round-trip of params/opt-state/step/data-state, and restore onto a
DIFFERENT mesh (elastic scaling) — the restore path device_puts each tensor
with the NamedSharding derived from the *target* mesh's axis rules, so a
checkpoint taken on (16,16) loads onto (2,16,16) or a single host.

In a real multi-host deployment each process writes its local shards
(tensorstore/OCDBT); in this single-host container the store is one .npz
per checkpoint plus a JSON manifest — the resharding logic is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(path: str, *, params, opt_state=None, step: int = 0,
         extra: Optional[Dict] = None) -> str:
    """Atomic checkpoint write; returns the final directory."""
    path = Path(path)
    final = path / f"step_{step:08d}"
    tmp = path / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    blobs = {}
    for prefix, tree in (("params", params), ("opt", opt_state or {})):
        for k, v in _flatten(tree).items():
            blobs[f"{prefix}/{k}"] = np.asarray(jax.device_get(v))
    np.savez(tmp / "tensors.npz", **blobs)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(blobs),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune stale tmp dirs from crashed writers
    for stale in path.glob(".tmp_step_*"):
        shutil.rmtree(stale, ignore_errors=True)
    return str(final)


def latest_step(path: str) -> Optional[int]:
    p = Path(path)
    if not p.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in p.glob("step_*")]
    return max(steps) if steps else None


def restore(path: str, *, params_like, opt_like=None,
            shardings=None, opt_shardings=None,
            step: Optional[int] = None) -> Tuple[Any, Any, int, Dict]:
    """Load a checkpoint onto (possibly different) target shardings.

    params_like/opt_like: pytrees of arrays or ShapeDtypeStructs defining
    the target structure; shardings: matching NamedSharding pytrees (None =>
    default placement).
    """
    p = Path(path)
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = p / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    blobs = np.load(d / "tensors.npz")

    def rebuild(tree, prefix, shard_tree):
        flat_keys = _flatten(tree)
        shards = _flatten(shard_tree) if shard_tree is not None else {}
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        out = []
        for (k, like) in flat_keys.items():
            arr = blobs[f"{prefix}/{k}"]
            tgt_dtype = getattr(like, "dtype", arr.dtype)
            arr = arr.astype(tgt_dtype)
            sh = shards.get(k)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    params = rebuild(params_like, "params", shardings)
    opt = rebuild(opt_like, "opt", opt_shardings) if opt_like is not None else None
    return params, opt, step, manifest.get("extra", {})
