"""The telemetry recorder and its attachment seam.

One :class:`Telemetry` instance records an entire run — for fleets it is
shared by every instance's sub-engine (windowed sub-engines keep
absolute sim time, so spans from all instances merge on the global clock
with no translation).  Core components (``ReplicaWorker``,
``GlobalController``, ``Fabric``, ``FleetController``) each carry a
``telemetry`` attribute that defaults to ``None``; every instrumentation
site guards on it, so runs without observability execute the exact
pre-observability code path.

:func:`attach_telemetry` is the one wiring point: given a built
``SystemHandle`` it registers replica identity (cluster + instance),
sets the ``telemetry`` attributes, and — when EP spans are requested —
arms ``AFPipelinePredictor.af_trace`` so the per-EP-rank marker events
of cache-miss decode steps are recorded (the traced inner engine is
bit-identical to the fast virtual path; cache-hit steps replay memoized
results and carry no markers).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.events import EV
from repro.obs.attribution import (
    ATTRIBUTION_KEYS, aggregate_fractions, attribution_for,
)
from repro.obs.counters import CounterBoard
from repro.obs.spans import Span


@dataclass
class RequestRecord:
    """Per-request outcome: identity, latency, and its attribution."""
    rid: int
    arrival: float
    finish: float
    e2e: float
    ttft: Optional[float]
    instance: str = ""
    tenant: Optional[str] = None
    attribution: Dict[str, float] = field(default_factory=dict)
    n_spans: int = 0

    def to_dict(self) -> dict:
        return {"rid": self.rid, "arrival": self.arrival,
                "finish": self.finish, "e2e": self.e2e, "ttft": self.ttft,
                "instance": self.instance, "tenant": self.tenant,
                "attribution": dict(self.attribution),
                "n_spans": self.n_spans}


class Telemetry:
    """Span + counter recorder for one run (single-instance or fleet)."""

    def __init__(self, *, spans: bool = True, counters: bool = True,
                 ep_spans: bool = False, max_spans: int = 500_000,
                 max_counter_points: int = 4096):
        self.spans_enabled = spans
        self.counters_enabled = counters
        self.ep_spans = ep_spans
        self.max_spans = int(max_spans)
        self.spans: List[Span] = []
        self.dropped_spans = 0
        self.counters = CounterBoard(max_counter_points)
        self.records: List[RequestRecord] = []
        # replica name -> (cluster, instance) identity for export
        self._replicas: Dict[str, Tuple[str, str]] = {}
        # open coalesced decode spans keyed by (rid, replica)
        self._open_decode: Dict[Tuple[int, str], Span] = {}
        # per-request span index, dropped after the request finishes
        self._by_rid: Dict[int, List[Span]] = {}
        # AF inner-engine recording state (set per batch by the replica)
        self._af_base = 0.0
        self._af_replica = ""
        self._af_pending: Dict[Tuple[int, int, int], float] = {}

    @classmethod
    def from_spec(cls, obs) -> "Telemetry":
        """Build from an :class:`repro.api.spec.ObsSpec`."""
        return cls(spans=obs.spans, counters=obs.counters,
                   ep_spans=obs.ep_spans, max_spans=obs.max_spans,
                   max_counter_points=obs.max_counter_points)

    # ---- identity registry -------------------------------------------------

    def register_replica(self, replica: str, *, cluster: str = "",
                         instance: str = "") -> None:
        self._replicas[replica] = (cluster, instance)

    def replica_info(self, replica: str) -> Tuple[str, str]:
        return self._replicas.get(replica, ("", ""))

    # ---- spans -------------------------------------------------------------

    def span(self, kind: str, rid: int, start: float, end: float, *,
             replica: str = "", **meta) -> None:
        if not self.spans_enabled:
            return
        if len(self.spans) >= self.max_spans:
            self.dropped_spans += 1
            return
        s = Span(kind, rid, start, end, replica, meta)
        self.spans.append(s)
        if rid >= 0:
            self._by_rid.setdefault(rid, []).append(s)

    def compute_span(self, kind: str, rid: int, start: float, end: float,
                     replica: str, **meta) -> None:
        """Record a compute interval; contiguous decode epochs on the
        same replica coalesce into one growing span (continuous batching
        emits one batch per token — thousands of 1-token spans per
        request would swamp both memory and the trace viewer)."""
        if not self.spans_enabled:
            return
        if kind == "decode":
            key = (rid, replica)
            open_ = self._open_decode.get(key)
            if open_ is not None:
                if start <= open_.end + 1e-12:
                    open_.end = end
                    open_.meta["epochs"] = open_.meta.get("epochs", 1) + 1
                    return
                self._flush_decode(key)
            if len(self.spans) >= self.max_spans:
                self.dropped_spans += 1
                return
            s = Span(kind, rid, start, end, replica, dict(meta, epochs=1))
            self._open_decode[key] = s
            self.spans.append(s)
            if rid >= 0:
                self._by_rid.setdefault(rid, []).append(s)
            return
        self.span(kind, rid, start, end, replica=replica, **meta)

    def _flush_decode(self, key) -> None:
        self._open_decode.pop(key, None)

    # ---- counters ----------------------------------------------------------

    def counter(self, name: str, t: float, value: float, *,
                replica: str = "", instance: str = "") -> None:
        if not self.counters_enabled:
            return
        if not instance and replica:
            instance = self._replicas.get(replica, ("", ""))[1]
        if instance:
            # replica names repeat across fleet instances (every pd
            # instance has a "prefill0") — namespace per-instance series
            # so they never merge
            name = f"{instance}/{name}"
        self.counters.sample(name, t, value, replica=replica,
                             instance=instance)

    # ---- AF inner-engine (per-EP-rank) recording ---------------------------

    def begin_batch(self, replica: str, now: float) -> None:
        """Anchor for inner-engine AF traces: events of the traced decode
        step are step-relative, so the recorder adds the batch start."""
        self._af_base = now
        self._af_replica = replica
        self._af_pending.clear()

    def af_event(self, ev) -> None:
        """``AFPipelinePredictor.af_trace`` callback (cache-miss decode
        steps only — cache hits replay memoized stats with no markers)."""
        kind = ev.kind
        if kind is EV.EXPERT_DISPATCH_DONE:
            d = ev.data
            key = (d["i"], d["k"], d["r"])
            self._af_pending[key] = ev.time
            self.span("ep_dispatch", -1, self._af_base + ev.time,
                      self._af_base + ev.time, replica=self._af_replica,
                      rank=d["r"], layer=d["k"], micro=d["i"])
        elif kind is EV.EXPERT_RANK_DONE:
            d = ev.data
            t0 = self._af_pending.pop((d["i"], d["k"], d["r"]), ev.time)
            self.span("ep_rank", -1, self._af_base + t0,
                      self._af_base + ev.time, replica=self._af_replica,
                      rank=d["r"], layer=d["k"], micro=d["i"])
        elif kind is EV.EXPERT_COMBINE_DONE:
            d = ev.data
            self.span("ep_combine", -1, self._af_base + ev.time,
                      self._af_base + ev.time, replica=self._af_replica,
                      layer=d["k"], micro=d["i"])

    # ---- request lifecycle -------------------------------------------------

    def end_request(self, r, *, instance: str = "") -> None:
        """Close out one finished request: emit its queue-wait span,
        flush any open decode span, and derive latency attribution."""
        rid = r.rid
        for key in [k for k in self._open_decode if k[0] == rid]:
            self._flush_decode(key)
        first = r.timestamps.get("first_scheduled")
        if first is not None and first > r.arrival:
            self.span("queue_wait", rid, r.arrival, first,
                      instance=instance)
        finish = r.finish_time if r.finish_time is not None else r.arrival
        spans = self._by_rid.pop(rid, ())
        attr = attribution_for(spans, r.arrival, finish)
        ttft = r.ttft() if callable(getattr(r, "ttft", None)) else None
        self.records.append(RequestRecord(
            rid=rid, arrival=r.arrival, finish=finish,
            e2e=max(finish - r.arrival, 0.0), ttft=ttft,
            instance=instance, tenant=getattr(r, "tenant", None),
            attribution=attr, n_spans=len(spans)))

    # ---- aggregates --------------------------------------------------------

    def attribution_fractions(self) -> Dict[str, float]:
        return aggregate_fractions(self.records)

    def summary_fields(self) -> Dict[str, float]:
        """The obs block merged into Report/FleetReport summaries (only
        when observability is enabled, so pre-obs goldens are
        untouched)."""
        out = {f"attribution_{k}": v
               for k, v in self.attribution_fractions().items()}
        out["obs_spans"] = len(self.spans)
        out["obs_dropped_spans"] = self.dropped_spans
        out["obs_counter_series"] = len(self.counters)
        return out

    def slowest(self, n: int = 5) -> List[RequestRecord]:
        return sorted(self.records, key=lambda rec: -rec.e2e)[:n]


def attach_telemetry(handle, tel: Optional[Telemetry], *,
                     instance: str = "") -> None:
    """Wire a recorder into a built ``SystemHandle`` (no-op on None)."""
    if tel is None:
        return
    handle.controller.telemetry = tel
    handle.controller.tel_instance = instance
    if handle.fabric is not None:
        handle.fabric.telemetry = tel
    for cname, cluster in handle.clusters.items():
        for w in cluster.replicas:
            w.telemetry = tel
            # replica names repeat across fleet instances — qualify the
            # telemetry identity so the shared recorder never conflates
            # two instances' replicas
            w.tel_name = f"{instance}/{w.name}" if instance else w.name
            tel.register_replica(w.tel_name, cluster=cname,
                                 instance=instance)
            if tel.ep_spans and hasattr(w.predictor, "af_trace"):
                w.predictor.af_trace = tel.af_event
