"""Event-engine determinism + causality properties."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.engine import SimEngine
from repro.core.events import EV


@given(st.lists(st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
                min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_events_processed_in_time_order(times):
    eng = SimEngine()
    seen = []
    for t in times:
        eng.at(t, EV.SCHEDULE_TICK, lambda ev: seen.append(ev.time))
    eng.run()
    assert seen == sorted(seen)
    assert len(seen) == len(times)


def test_ties_break_in_schedule_order():
    eng = SimEngine()
    seen = []
    for i in range(50):
        eng.at(1.0, EV.SCHEDULE_TICK, lambda ev, i=i: seen.append(i))
    eng.run()
    assert seen == list(range(50))


def test_nested_scheduling_is_causal():
    eng = SimEngine()
    log = []

    def spawn(ev):
        log.append(eng.now)
        if eng.now < 5:
            eng.after(1.0, EV.SCHEDULE_TICK, spawn)

    eng.at(0.0, EV.SCHEDULE_TICK, spawn)
    eng.run()
    assert log == [float(i) for i in range(6)]


def test_run_until_pauses_clock():
    eng = SimEngine()
    eng.at(10.0, EV.SCHEDULE_TICK, lambda ev: None)
    eng.run(until=5.0)
    assert eng.now == 5.0
    assert eng.pending == 1
    eng.run()
    assert eng.now == 10.0
