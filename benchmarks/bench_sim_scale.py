"""Simulator performance & feature coverage.

- events/second and simulated-vs-wall time for large serving simulations
  (the practicality argument: exploring an 18k-GPU-hour config space needs
  a fast simulator);
- Table-1 feature matrix exercised programmatically (PD, AF, PP/TP/DP/EP,
  cross-cluster EP, pluggable scheduling, prefix caching, preemption) —
  each cell is an actual simulation run through the declarative
  ``SimSpec -> run`` API.

``--smoke`` shrinks the workloads for CI (same code paths, seconds not
minutes); ``--json PATH`` writes a machine-readable result file
(events/s, wall time, per-cell status) — the benchmark artifact CI
uploads to seed the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Tuple

from repro.api import SimSpec, run


def _spec(name: str, body: dict) -> SimSpec:
    d = dict(body)
    d["name"] = name
    return SimSpec.from_dict(d)


def _bench_engine_core(n_events: int, burst: int = 64) -> dict:
    """Raw event-core throughput in the shape of the simulator hot loop:
    arrivals land in bursts of ``burst`` on the bulk timeline and drain
    through the same-timestamp batch handler, with one self-rescheduling
    scheduler tick per burst — no simulation logic on top."""
    from repro.core.engine import SimEngine
    from repro.core.events import EV
    n_bursts = max(n_events // (burst + 1), 1)
    eng = SimEngine(max_events=n_events + 10)
    seen = [0]
    eng.register_batch_handler(
        EV.REQUEST_ARRIVAL,
        lambda evs: seen.__setitem__(0, seen[0] + len(evs)))
    eng.schedule_timeline(
        ((i // burst) * 1e-3, EV.REQUEST_ARRIVAL, None, None)
        for i in range(n_bursts * burst))
    left = [n_bursts]

    def tick(ev):
        left[0] -= 1
        if left[0] > 0:
            eng.after(1e-3, EV.SCHEDULE_TICK, tick)

    eng.at(0.0, EV.SCHEDULE_TICK, tick)
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    assert seen[0] == n_bursts * burst
    return {"events": eng.processed, "wall_s": wall,
            "events_per_s": eng.processed / wall, "burst": burst}


def _fleet_1m_body(n_requests: int, n_inst: int) -> dict:
    """Million-request fleet cell: ``n_inst`` single-replica instances in
    windowed mode with the numpy predictor backend and O(1) round-robin
    routing — the configuration the PR6 tentpole targets (1M requests
    across 100+ instances in minutes)."""
    return {
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "colocated"},
        "opmodel": {"backend": "numpy"},
        "workload": {"n_requests": n_requests,
                     "rate": 4.0 * n_inst,
                     "prompt_mean": 128, "output_mean": 8, "seed": 0},
        "fleet": {
            "instances": [{"name": "colo", "count": n_inst}],
            "router": "round_robin",
            "engine": "windowed",
            "window_s": 0.25,
        },
    }


def _cells(n_cell: int) -> Dict[str, dict]:
    wl = {"n_requests": n_cell, "rate": 20.0, "seed": 1}
    moe = {"name": "mixtral-8x7b"}
    return {
        "pd": {
            "topology": {"preset": "pd", "n_prefill": 2, "n_decode": 2,
                         "prefill_tp": 2, "decode_tp": 2},
            "workload": wl},
        "af": {
            "model": moe,
            "topology": {"preset": "af", "m": 2, "attn_tp": 2, "ffn_ep": 8},
            "policy": {"router": {"name": "zipf", "alpha": 1.1}},
            "workload": wl},
        # the AF cell again with full observability on (spans + counters +
        # per-EP-rank spans): measures the *enabled*-mode cost; the
        # obs-off hot path is gated separately (cells.af vs trajectory)
        "af_traced": {
            "model": moe,
            "topology": {"preset": "af", "m": 2, "attn_tp": 2, "ffn_ep": 8},
            "policy": {"router": {"name": "zipf", "alpha": 1.1}},
            "obs": {"enabled": True, "ep_spans": True},
            "workload": wl},
        "af_cross_cluster_ep": {
            "model": moe,
            "topology": {"preset": "af", "m": 2, "attn_tp": 2, "ffn_ep": 8,
                         "remote_expert_ranks": [6, 7],
                         "expert_link_bw": 25e9,
                         "expert_link_latency": 5e-6},
            "policy": {"router": {"name": "zipf", "alpha": 1.1}},
            "workload": wl},
        "tp_pp": {
            "topology": {"preset": "colocated", "tp": 4, "pp": 2},
            "workload": wl},
        "dp": {
            "topology": {"preset": "colocated", "n_replicas": 4},
            "workload": wl},
        "ep": {
            "model": moe,
            "topology": {"preset": "colocated", "tp": 8, "ep": 8},
            "policy": {"router": "zipf"},
            "workload": wl},
        "sched_chunked_prefill": {
            "topology": {"preset": "colocated"},
            "policy": {"batching": {"name": "chunked_prefill",
                                    "chunk": 256}},
            "workload": wl},
        "sched_continuous": {
            "topology": {"preset": "colocated"},
            "policy": {"batching": "continuous"},
            "workload": wl},
        "mem_prefix_cache": {
            "topology": {"preset": "pd"},
            "memory": {"manager": "prefix", "transfer_overlap": 0.8},
            "workload": dict(wl, prefix_groups=4, prefix_len=512)},
        "mem_preemption": {
            "topology": {"preset": "pd"},
            "memory": {"manager": "paged", "capacity_frac": 0.005,
                       "preemption": "recompute"},
            "workload": dict(wl, arrival="burst",
                             burst_size=max(n_cell // 2, 1),
                             prompt="fixed", prompt_mean=64,
                             output="fixed", output_mean=1024)},
    }


def _routing_tag(body: dict) -> str:
    """Human-readable routing-module tag for a cell body."""
    r = (body.get("policy") or {}).get("router")
    if r is None:
        return "none"
    if isinstance(r, str):
        return r
    if isinstance(r, dict):
        name = r.get("name", "?")
        kw = {k: v for k, v in r.items() if k != "name"}
        if kw:
            args = ",".join(f"{k}={v}" for k, v in sorted(kw.items()))
            return f"{name}({args})"
        return name
    return type(r).__name__


def run_bench(smoke: bool = False, fleet_1m: bool = False,
              profiles: Optional[Dict[str, str]] = None,
              ) -> Tuple[List[str], dict]:
    """Run every bench section.  When ``profiles`` is a dict, each Table-1
    cell additionally runs under cProfile and the top-25 cumulative report
    is stored there keyed by cell name."""
    lines: List[str] = []
    results: dict = {"smoke": smoke, "cells": {}}

    # ---- raw event core ---------------------------------------------------
    n_core = 200_000 if smoke else 2_000_000
    core = _bench_engine_core(n_core)
    core.update(engine_mode="serial", predictor_backend="n/a")
    results["engine_core"] = core
    lines.append(
        f"engine_core_{n_core // 1000}k,"
        f"{core['wall_s'] * 1e6 / max(core['events'], 1):.2f},"
        f"events={core['events']};"
        f"events_per_s={core['events_per_s']:,.0f}")

    # ---- scale: 16-replica cluster ----------------------------------------
    n_scale = 200 if smoke else 2000
    rep = run(_spec("sim-scale", {
        "topology": {"preset": "colocated", "n_replicas": 16, "tp": 4},
        "workload": {"n_requests": n_scale, "rate": 200.0,
                     "prompt_mean": 512, "output_mean": 128, "seed": 0},
    }))
    ev, wall = rep.sim_events, rep.wall_clock_s
    results["scale"] = {
        "n_requests": n_scale, "events": ev, "wall_s": wall,
        "events_per_s": ev / wall,
        "sim_speedup": rep.sim_duration_s / wall,
        "completed": rep.summary["n_completed"],
        "engine_mode": "serial", "predictor_backend": "python",
    }
    lines.append(
        f"sim_scale_16replica_{n_scale}req,{wall * 1e6 / max(ev, 1):.2f},"
        f"events={ev};events_per_s={ev / wall:,.0f};"
        f"sim_speedup={rep.sim_duration_s / wall:.1f}x;"
        f"completed={rep.summary['n_completed']}")

    # ---- fleet: the multi-instance control plane at scale -----------------
    n_fleet = 600 if smoke else 5000
    n_inst = 8 if smoke else 16
    rep = run(_spec("fleet-scale", {
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "colocated"},
        "workload": {"n_requests": n_fleet, "rate": 120.0,
                     "rate_curve": "diurnal", "rate_period": 30.0,
                     "rate_amplitude": 0.6, "prompt_mean": 256,
                     "output_mean": 32, "prefix_groups": 16,
                     "prefix_len": 256, "seed": 0},
        "memory": {"manager": "prefix"},
        "slo": {"ttft_s": 0.5, "tpot_s": 0.05},
        "fleet": {
            "instances": [
                {"name": "colo", "count": n_inst - n_inst // 4},
                {"name": "pd", "count": n_inst // 4,
                 "topology": {"preset": "pd", "n_prefill": 1,
                              "n_decode": 1}},
            ],
            "router": "prefix_affinity",
            "autoscaler": {"min_instances": 2,
                           "max_instances": n_inst + 4,
                           "interval_s": 1.0, "up_queue_depth": 8.0,
                           "down_queue_depth": 1.0},
        },
    }))
    ev, wall = rep.sim_events, rep.wall_clock_s
    results["fleet"] = {
        "n_requests": n_fleet, "instances": n_inst, "events": ev,
        "wall_s": wall, "events_per_s": ev / wall,
        "sim_speedup": rep.sim_duration_s / wall,
        "completed": rep.summary["n_completed"],
        "scale_up_events": rep.summary["scale_up_events"],
        "scale_down_events": rep.summary["scale_down_events"],
        "prefix_hit_token_frac":
            rep.summary.get("prefix_hit_token_frac"),
        "routing_imbalance": rep.summary.get("routing_imbalance"),
        "engine_mode": "serial", "predictor_backend": "python",
    }
    lines.append(
        f"fleet_{n_inst}inst_{n_fleet}req,{wall * 1e6 / max(ev, 1):.2f},"
        f"events={ev};events_per_s={ev / wall:,.0f};"
        f"completed={rep.summary['n_completed']};"
        f"scale_events={rep.summary['scale_up_events']}"
        f"+{rep.summary['scale_down_events']}")

    # ---- fleet_1m: million-request windowed fleet -------------------------
    # full size only behind --fleet-1m (minutes of wall clock); the smoke
    # variant runs the same code path at CI-friendly scale
    n_1m = 1_000_000 if fleet_1m else (10_000 if smoke else 50_000)
    n_1m_inst = 100 if fleet_1m else (16 if smoke else 32)
    rep = run(_spec("fleet-1m", _fleet_1m_body(n_1m, n_1m_inst)))
    ev, wall = rep.sim_events, rep.wall_clock_s
    results["fleet_1m"] = {
        "n_requests": n_1m, "instances": n_1m_inst, "events": ev,
        "wall_s": wall, "events_per_s": ev / wall,
        "sim_speedup": rep.sim_duration_s / wall,
        "completed": rep.summary["n_completed"],
        "engine_mode": "windowed", "predictor_backend": "numpy",
        "window_s": rep.summary.get("fleet_window_s"),
    }
    lines.append(
        f"fleet_1m_{n_1m_inst}inst_{n_1m}req,"
        f"{wall * 1e6 / max(ev, 1):.2f},"
        f"events={ev};events_per_s={ev / wall:,.0f};"
        f"completed={rep.summary['n_completed']};mode=windowed+numpy")

    # ---- fabric: shared-uplink contention pricing on vs off ---------------
    # bursty PD traffic over a slow shared uplink, priced twice: with the
    # contention-modeling fabric and with the legacy point-to-point path.
    # Gated on the fabric-on events/s (the new repricing code path).
    n_fab = 300 if smoke else 3000
    fab_wl = {"n_requests": n_fab, "arrival": "burst", "burst_size": 50,
              "burst_period": 0.5, "prompt_mean": 512, "output_mean": 32,
              "seed": 0}
    topo = {"preset": "pd", "n_prefill": 2, "n_decode": 2}
    rep_on = run(_spec("fabric-on", {
        "topology": dict(topo, fabric={"mode": "shared",
                                       "oversubscription": 2.0,
                                       "uplink_bw": 5e9}),
        "workload": fab_wl}))
    rep_off = run(_spec("fabric-off", {"topology": topo,
                                       "workload": fab_wl}))
    ev, wall = rep_on.sim_events, rep_on.wall_clock_s
    results["fabric"] = {
        "n_requests": n_fab, "events": ev, "wall_s": wall,
        "events_per_s": ev / wall,
        "sim_speedup": rep_on.sim_duration_s / wall,
        "completed": rep_on.summary["n_completed"],
        "contention_off_wall_s": rep_off.wall_clock_s,
        "contention_off_events_per_s":
            rep_off.sim_events / rep_off.wall_clock_s,
        "fabric_transfers": rep_on.summary["fabric_transfers"],
        "fabric_contention_delay_s":
            rep_on.summary["fabric_contention_delay_s"],
        "engine_mode": "serial", "predictor_backend": "python",
    }
    lines.append(
        f"fabric_pd_{n_fab}req,{wall * 1e6 / max(ev, 1):.2f},"
        f"events={ev};events_per_s={ev / wall:,.0f};"
        f"off_events_per_s="
        f"{rep_off.sim_events / rep_off.wall_clock_s:,.0f};"
        f"contention_delay="
        f"{rep_on.summary['fabric_contention_delay_s'] * 1e3:.2f}ms")

    # ---- Table-1 feature matrix -------------------------------------------
    n_cell = 20 if smoke else 100
    for name, body in _cells(n_cell).items():
        if profiles is not None:
            import cProfile
            import io
            import pstats
            pr = cProfile.Profile()
            pr.enable()
            rep = run(_spec(f"table1-{name}", body))
            pr.disable()
            buf = io.StringIO()
            pstats.Stats(pr, stream=buf).sort_stats(
                "cumulative").print_stats(25)
            profiles[name] = buf.getvalue()
        else:
            rep = run(_spec(f"table1-{name}", body))
        ok = rep.summary["n_completed"] == n_cell
        results["cells"][name] = {
            "supported": ok, "wall_s": rep.wall_clock_s,
            "events": rep.sim_events,
            "events_per_s": rep.sim_events / rep.wall_clock_s,
            "tok_s_per_device": rep.summary["throughput_tok_s_per_device"],
            "ttft_p50_s": rep.summary["ttft_p50_s"],
            "preemptions": rep.summary.get("preemptions", 0),
            "prefix_hit_token_frac":
                rep.summary.get("prefix_hit_token_frac"),
            "routing": _routing_tag(body),
            "engine_mode": "serial", "predictor_backend": "python",
        }
        ttft = rep.summary["ttft_p50_s"]
        lines.append(
            f"table1_{name},{rep.wall_clock_s * 1e6:.0f},"
            f"supported={'yes' if ok else 'NO'};"
            f"events_per_s={rep.sim_events / rep.wall_clock_s:,.0f};"
            f"tok_s_dev={rep.summary['throughput_tok_s_per_device']:.1f};"
            f"ttft_p50={'n/a' if ttft is None else f'{ttft * 1e3:.1f}ms'};"
            f"routing={_routing_tag(body)}")
    return lines, results


def append_trajectory(path: str, label: str, results: dict) -> None:
    """Append one labeled result set to a trajectory file (the repo-root
    ``BENCH_sim_scale.json``), so events/s regressions across PRs are a
    one-file diff."""
    import os
    traj = {"trajectory": []}
    if os.path.exists(path):
        with open(path) as f:
            traj = json.load(f)
    traj["trajectory"] = [e for e in traj.get("trajectory", [])
                          if e.get("label") != label] + \
        [{"label": label, **results}]
    with open(path, "w") as f:
        json.dump(traj, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workloads for CI")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable results (events/s, wall "
                         "time, per-cell status) to PATH")
    ap.add_argument("--trajectory", metavar="PATH", default=None,
                    help="append results to a cross-PR trajectory file "
                         "(e.g. the repo-root BENCH_sim_scale.json)")
    ap.add_argument("--label", default="dev",
                    help="trajectory entry label (e.g. PR5)")
    ap.add_argument("--fleet-1m", action="store_true",
                    help="run the full fleet_1m cell (1M requests across "
                         "100 windowed instances; minutes of wall clock)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each Table-1 cell (top-25 cumulative "
                         "per cell) and write the report next to the "
                         "--json output")
    args = ap.parse_args()
    profiles: Optional[Dict[str, str]] = {} if args.profile else None
    out_lines, out_results = run_bench(smoke=args.smoke,
                                       fleet_1m=args.fleet_1m,
                                       profiles=profiles)
    for l in out_lines:
        print(l)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out_results, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    if profiles is not None:
        prof_path = ((args.json + ".profile.txt") if args.json
                     else "bench_sim_scale.profile.txt")
        with open(prof_path, "w") as f:
            for name, text in profiles.items():
                f.write(f"==== table1_{name} ====\n{text}\n")
        print(f"wrote {prof_path}")
    if args.trajectory:
        append_trajectory(args.trajectory, args.label, out_results)
        print(f"appended '{args.label}' -> {args.trajectory}")
