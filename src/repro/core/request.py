"""Request lifecycle state machine."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Optional


class RState(enum.Enum):
    QUEUED_PREFILL = "queued_prefill"
    PREFILL_RUNNING = "prefill_running"
    PREFILL_COMPLETE = "prefill_complete"   # KV held in prefill buffer
    KV_TRANSFER = "kv_transfer"
    QUEUED_DECODE = "queued_decode"
    DECODING = "decoding"
    PREEMPTED = "preempted"                 # KV evicted under memory pressure
    COMPLETE = "complete"


# legal transitions (property-tested)
_TRANSITIONS = {
    RState.QUEUED_PREFILL: {RState.PREFILL_RUNNING},
    RState.PREFILL_RUNNING: {RState.PREFILL_COMPLETE, RState.QUEUED_PREFILL},
    RState.PREFILL_COMPLETE: {RState.KV_TRANSFER, RState.QUEUED_DECODE,
                              RState.PREEMPTED},
    RState.KV_TRANSFER: {RState.QUEUED_DECODE},
    RState.QUEUED_DECODE: {RState.DECODING, RState.PREEMPTED},
    RState.DECODING: {RState.COMPLETE, RState.QUEUED_DECODE,
                      RState.PREEMPTED},
    # restore paths: recompute re-prefills the full context; swap-in
    # returns the request straight to the decode queue
    RState.PREEMPTED: {RState.QUEUED_PREFILL, RState.QUEUED_DECODE},
}


@dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    output_len: int
    state: RState = RState.QUEUED_PREFILL
    generated: int = 0
    prefill_progress: int = 0          # chunked-prefill bookkeeping
    # prefix sharing (set by the workload generator): requests with the
    # same prefix_id share their first prefix_len prompt tokens
    prefix_id: Optional[int] = None
    prefix_len: int = 0
    # fleet tenancy: the tenant class this request belongs to (set by the
    # fleet control plane at submission; None for single-instance runs)
    tenant: Optional[str] = None
    # preemption/restore bookkeeping
    prefill_len: Optional[int] = None  # recompute target; None -> prompt_len
    restore_pending: bool = False      # next prefill completion is a restore
    preemptions: int = 0
    # when the CURRENT prefill pass was first scheduled (reset on recompute
    # restore) — the residency anchor for streamed-KV-transfer windows;
    # "first_scheduled" in timestamps keeps the lifetime queue-delay anchor
    prefill_started: Optional[float] = None
    timestamps: Dict[str, float] = field(default_factory=dict)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    def to(self, state: RState, now: float) -> None:
        allowed = _TRANSITIONS.get(self.state, set())
        if state not in allowed:
            raise ValueError(f"illegal transition {self.state} -> {state} "
                             f"(rid={self.rid})")
        self.state = state
        self.timestamps[state.value] = now

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.generated

    @property
    def prefill_total(self) -> int:
        """Tokens this request's (next) prefill must process: the prompt,
        or the full context when restoring after a recompute preemption."""
        return self.prefill_len if self.prefill_len is not None \
            else self.prompt_len

    @property
    def done(self) -> bool:
        return self.generated >= self.output_len

    def begin_recompute(self, now: float) -> None:
        """Recompute-restore a PREEMPTED request: the KV is gone, so the
        whole current context (prompt + generated tokens) re-prefills; no
        token is re-emitted when that prefill completes."""
        self.prefill_len = self.context_len
        self.prefill_progress = 0
        self.restore_pending = True
        self.prefill_started = None
        self.to(RState.QUEUED_PREFILL, now)

    # ---- metrics -----------------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        if self.generated <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (self.generated - 1)

    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival
