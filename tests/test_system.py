"""End-to-end behaviour tests for the Frontier simulator."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    A800_SXM4_80G, ParallelismConfig, SimEngine, build_af, build_colocated,
    build_pd, simulate_af_decode_step,
)
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.request import RState
from repro.core.routing import BalancedRouting, ZipfRouting
from repro.workload.generator import WorkloadConfig, fixed_batch, generate

CFG = get_config("qwen2-7b")
HW = A800_SXM4_80G


def test_colocated_completes_all_and_conserves():
    sys = build_colocated(CFG, HW, n_replicas=2)
    reqs = generate(WorkloadConfig(n_requests=40, rate=20.0, seed=0))
    rep = sys.run(reqs)
    assert rep["n_completed"] == 40
    states = sys.controller.conservation_check()
    assert states == {"complete": 40}
    assert rep["throughput_tok_s"] > 0


def test_pd_all_requests_flow_through_transfer():
    sys = build_pd(CFG, HW, n_prefill=1, n_decode=1)
    reqs = generate(WorkloadConfig(n_requests=30, rate=10.0, seed=1))
    rep = sys.run(reqs)
    assert rep["n_completed"] == 30
    # every request passed through the KV transfer stage
    for r in sys.controller.requests.values():
        assert "kv_transfer" in r.timestamps
        assert r.state == RState.COMPLETE


def test_pd_backpressure_under_tiny_decode_memory():
    sys = build_pd(CFG, HW, n_prefill=1, n_decode=1)
    # shrink decode memory to force the PREFILL_COMPLETE queue to back up
    dec = sys.clusters["decode"].replicas[0]
    dec.memory.free_blocks = dec.memory.blocks_for(1200)  # fits ONE request
    dec.memory.total_blocks = dec.memory.free_blocks
    dec.memory.watermark_blocks = 0
    reqs = fixed_batch(8, 1024, 64)
    sys.controller.metrics.start = 0.0
    sys.controller.submit_all(reqs)
    sys.engine.run(until=0.3)
    # with one request's worth of decode memory, prefill-complete requests
    # must be queuing behind the backpressure signal
    assert (len(sys.controller.pending_transfer) > 0
            or any(r.state != RState.COMPLETE for r in reqs))
    sys.engine.run()
    assert all(r.state == RState.COMPLETE for r in reqs)


def test_ttft_pd_beats_colocated_under_load():
    """The PD pitch: decode is not blocked by long prefills."""
    wl = WorkloadConfig(n_requests=50, rate=6.0, prompt_mean=2048,
                        output_mean=64, seed=3)
    colo = build_colocated(CFG, HW, n_replicas=2).run(generate(wl))
    pd = build_pd(CFG, HW, n_prefill=1, n_decode=1).run(generate(wl))
    assert pd["tpot_p99_s"] <= colo["tpot_p99_s"] * 1.5


def test_af_step_critical_path_bounds():
    mcfg = get_config("mixtral-8x7b")
    ops = OperatorModelSet(HW)
    st = simulate_af_decode_step(mcfg, HW, ops, [512] * 32, m=2,
                                 attn_par=ParallelismConfig(tp=2),
                                 ffn_par=ParallelismConfig(tp=1, ep=4),
                                 routing=BalancedRouting())
    # makespan at least the busiest cluster, at most the serial sum
    assert st.makespan >= max(st.attn_busy, st.ffn_busy) - 1e-9
    serial = st.attn_busy + st.ffn_busy + 2 * 1e-9
    assert st.makespan <= st.attn_busy + st.ffn_busy + st.transfer_bytes / HW.inter_node_bw + 1e-6 + 64 * 2 * HW.op_overhead


def test_af_pingpong_hides_latency_when_compute_bound():
    """Dense model, large decode batch: the m=2 ping-pong pipeline overlaps
    ATTN(i+1,k) with A2F/FFN(i,k) and beats the serial m=1 schedule."""
    dcfg = get_config("yi-9b")
    ops = OperatorModelSet(HW)
    lens = [1024] * 2048
    kw = dict(attn_par=ParallelismConfig(tp=8),
              ffn_par=ParallelismConfig(tp=8), routing=BalancedRouting())
    t1 = simulate_af_decode_step(dcfg, HW, ops, lens, m=1, **kw).makespan
    t2 = simulate_af_decode_step(dcfg, HW, ops, lens, m=2, **kw).makespan
    assert t2 < t1

def test_af_microbatching_weight_bound_moe_rereads_weights():
    """MegaScale insight, inverted case: with a SMALL decode batch the MoE
    FFN is weight-read bound, so m micro-batches re-stream expert weights
    m times — the simulator must charge that cost (m=4 slower than m=1)."""
    mcfg = get_config("mixtral-8x7b")
    ops = OperatorModelSet(HW)
    lens = [1024] * 64
    kw = dict(attn_par=ParallelismConfig(tp=2),
              ffn_par=ParallelismConfig(tp=1, ep=4),
              routing=BalancedRouting())
    t1 = simulate_af_decode_step(mcfg, HW, ops, lens, m=1, **kw).makespan
    t4 = simulate_af_decode_step(mcfg, HW, ops, lens, m=4, **kw).makespan
    assert t4 > t1


def test_moe_straggler_zipf_slower_than_balanced():
    mcfg = get_config("mixtral-8x7b")
    bal = build_colocated(mcfg, HW, routing=BalancedRouting(),
                          par=ParallelismConfig(tp=8, ep=8))
    zip_ = build_colocated(mcfg, HW, routing=ZipfRouting(1.5),
                           par=ParallelismConfig(tp=8, ep=8))
    reqs = fixed_batch(16, 256, 64)
    t_bal = bal.run(list(reqs))["throughput_tok_s"]
    t_zip = zip_.run(fixed_batch(16, 256, 64))["throughput_tok_s"]
    assert t_zip < t_bal  # imbalance must cost throughput


def test_replica_failure_recovers_and_completes():
    sys = build_colocated(CFG, HW, n_replicas=2)
    reqs = generate(WorkloadConfig(n_requests=30, rate=30.0, seed=5))
    sys.controller.inject_failure("colocated", 0, at=0.05, downtime=0.5)
    rep = sys.run(reqs)
    assert rep["n_completed"] == 30
    assert sys.controller.conservation_check() == {"complete": 30}
