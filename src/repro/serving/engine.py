"""MiniEngine: a real (executing) continuous-batching serving engine in JAX.

This is the measured system for the paper's Table-2 protocol: the simulator
predicts its throughput; bench_e2e_accuracy compares.  CPU-runnable at
smoke scale; the same engine drives examples/serve_real_model.py.

Design (vLLM-like, slot-based):
- a fixed pool of `max_slots` sequence slots with a shared stacked KV cache
  (the JAX analogue of a paged KV pool with page == slot);
- prefill runs per-request (padded to length buckets to bound compiles) and
  its KV is scattered into the slot cache;
- decode steps run the whole active slot set with per-slot positions;
- slots free on completion; waiting requests admit immediately (continuous
  batching).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import AxisRules, init_tree, shape_tree
from repro.models.model import build_model


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    submitted: float = 0.0
    first_token: Optional[float] = None
    finished: Optional[float] = None
    tokens: List[int] = field(default_factory=list)


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


class MiniEngine:
    def __init__(self, cfg: ModelConfig, *, max_slots: int = 8,
                 max_seq: int = 256, seed: int = 0,
                 params=None, dtype=jnp.float32):
        self.cfg = cfg
        self.ax = AxisRules(None)
        self.model = build_model(cfg, self.ax)
        self.max_slots = max_slots
        self.max_seq = max_seq
        if params is None:
            params = init_tree(jax.random.PRNGKey(seed), self.model.pds(), dtype)
        self.params = params
        cache_pds = self.model.cache_pds(max_slots, max_seq)
        self.cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            shape_tree(cache_pds, dtype))
        self.slots: List[Optional[ServeRequest]] = [None] * max_slots
        self.slot_pos = np.zeros(max_slots, np.int32)   # next write position
        self.slot_tok = np.zeros(max_slots, np.int32)   # last emitted token
        self.waiting: List[ServeRequest] = []
        self.step_log: List[Dict] = []

        self._prefill_jit: Dict[int, object] = {}
        self._decode_jit = jax.jit(self.model.decode)
        self._insert_jit = None

    # ------------------------------------------------------------- intake --
    def submit(self, prompts: List[np.ndarray], max_new_tokens: int) -> List[ServeRequest]:
        now = time.perf_counter()
        reqs = [ServeRequest(rid=i, prompt=np.asarray(p, np.int32),
                             max_new_tokens=max_new_tokens, submitted=now)
                for i, p in enumerate(prompts)]
        self.waiting.extend(reqs)
        return reqs

    # ----------------------------------------------------------- internals --
    def _prefill(self, req: ServeRequest, slot: int) -> None:
        S = len(req.prompt)
        bucket = min(_bucket(S), self.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :S] = req.prompt
        if bucket not in self._prefill_jit:
            self._prefill_jit[bucket] = jax.jit(
                lambda p, b: self.model.prefill(p, b, cache_len=self.max_seq,
                                                all_logits=True))
        t0 = time.perf_counter()
        logits, cache1 = self._prefill_jit[bucket](self.params,
                                                   {"tokens": jnp.asarray(toks)})
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.step_log.append({"kind": "prefill", "tokens": int(S), "dur": dt})

        # scatter request cache into the slot cache (per-leaf batch axis)
        def ins_group(c_all, c_one):
            return jax.lax.dynamic_update_slice_in_dim(
                c_all, c_one.astype(c_all.dtype), slot, axis=1)

        def ins_tail(c_all, c_one):
            return jax.lax.dynamic_update_slice_in_dim(
                c_all, c_one.astype(c_all.dtype), slot, axis=0)

        self.cache = {
            "groups": jax.tree_util.tree_map(ins_group, self.cache["groups"],
                                             cache1["groups"]),
            "tail": jax.tree_util.tree_map(ins_tail, self.cache["tail"],
                                           cache1["tail"]),
        }
        # pad KV beyond S is never visible: decode masks t <= pos and each
        # step overwrites slot pos before it becomes attendable.  The first
        # token comes from the TRUE last prompt position S-1 (causal masking
        # makes it independent of the padding).
        first = int(np.argmax(np.asarray(jax.device_get(logits))[0, S - 1]))
        now = time.perf_counter()
        req.first_token = now
        req.tokens.append(first)
        self.slots[slot] = req
        self.slot_pos[slot] = S
        self.slot_tok[slot] = first

    def _admit(self) -> None:
        for i in range(self.max_slots):
            if self.slots[i] is None and self.waiting:
                req = self.waiting.pop(0)
                self._prefill(req, i)

    def _decode_step(self) -> None:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        toks = jnp.asarray(self.slot_tok.reshape(-1, 1))
        pos = jnp.asarray(self.slot_pos)
        t0 = time.perf_counter()
        logits, self.cache = self._decode_jit(self.params, self.cache,
                                              toks, pos)
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.step_log.append({"kind": "decode", "batch": len(active), "dur": dt})
        nxt = np.asarray(jax.device_get(jnp.argmax(logits[:, 0], -1)))
        now = time.perf_counter()
        for i in active:
            req = self.slots[i]
            req.tokens.append(int(nxt[i]))
            self.slot_pos[i] += 1
            self.slot_tok[i] = int(nxt[i])
            if (len(req.tokens) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.max_seq - 1):
                req.finished = now
                self.slots[i] = None

    # ---------------------------------------------------------------- run --
    def run(self) -> Dict[str, float]:
        t0 = time.perf_counter()
        served: List[ServeRequest] = list(self.waiting)
        while self.waiting or any(s is not None for s in self.slots):
            self._admit()
            self._decode_step()
        dur = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in served)
        ttfts = [r.first_token - r.submitted for r in served if r.first_token]
        tpots = [(r.finished - r.first_token) / max(len(r.tokens) - 1, 1)
                 for r in served if r.finished and r.first_token]
        return {
            "n_requests": len(served),
            "output_tokens": toks,
            "duration_s": dur,
            "throughput_tok_s": toks / dur,
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else float("nan"),
            "tpot_mean_s": float(np.mean(tpots)) if tpots else float("nan"),
            "decode_steps": sum(1 for s in self.step_log if s["kind"] == "decode"),
        }
