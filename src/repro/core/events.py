"""Event definitions for the stage-centric simulation.

Events are the *native primitives* of Frontier's abstraction: requests flow
through a distributed system as a graph of timed events (arrival, batch
execution, KV transfer, memory signals, micro-batch pipeline stages), not as
monolithic replica-level steps.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional


class EV(enum.Enum):
    # request lifecycle
    REQUEST_ARRIVAL = "request_arrival"
    PREFILL_ENQUEUE = "prefill_enqueue"
    PREFILL_COMPLETE = "prefill_complete"
    KV_TRANSFER_START = "kv_transfer_start"
    KV_TRANSFER_DONE = "kv_transfer_done"
    DECODE_ENQUEUE = "decode_enqueue"
    TOKEN_GENERATED = "token_generated"
    REQUEST_COMPLETE = "request_complete"
    # cluster-level
    BATCH_START = "batch_start"
    BATCH_DONE = "batch_done"
    MEMORY_AVAILABLE = "memory_available"
    # preemption/restore (KV swapped to host memory and back)
    SWAP_OUT_DONE = "swap_out_done"
    SWAP_IN_DONE = "swap_in_done"
    SCHEDULE_TICK = "schedule_tick"
    REPLICA_FAILURE = "replica_failure"
    REPLICA_RECOVERED = "replica_recovered"
    # AF-disaggregation micro-pipeline
    ATTN_COMPUTE_DONE = "attn_compute_done"
    A2F_TRANSFER_DONE = "a2f_transfer_done"
    FFN_COMPUTE_DONE = "ffn_compute_done"
    F2A_TRANSFER_DONE = "f2a_transfer_done"
    # expert-parallel micro-workflow (per-EP-rank dispatch/compute/combine)
    EXPERT_DISPATCH_DONE = "expert_dispatch_done"
    EXPERT_RANK_DONE = "expert_rank_done"
    EXPERT_COMBINE_DONE = "expert_combine_done"
    # fleet control plane (multi-instance serving)
    AUTOSCALE_TICK = "autoscale_tick"
    INSTANCE_READY = "instance_ready"          # cold start finished
    POOL_RECONFIGURED = "pool_reconfigured"    # P:D rebalance weight load


_seq = itertools.count()


@dataclass(order=True)
class Event:
    time: float
    seq: int = field(default_factory=lambda: next(_seq))
    kind: EV = field(compare=False, default=EV.SCHEDULE_TICK)
    fn: Optional[Callable[["Event"], None]] = field(compare=False, default=None)
    data: Dict[str, Any] = field(compare=False, default_factory=dict)

    def __repr__(self) -> str:  # compact trace line
        return f"Event(t={self.time:.6f}, {self.kind.value}, {self.data})"
