"""Conservative time-window execution of a fleet simulation.

Serial fleet mode interleaves every instance on ONE event heap — exact,
but each of a million events pays global heap discipline.  Windowed mode
gives each instance its own sub-engine and advances the whole fleet in
conservative time windows:

1. pick the next barrier ``T`` = earliest pending event across the fleet
   engine and every instance engine;
2. run the FLEET engine through ``[T, T + window_s]`` — arrivals routed
   in this window register eagerly but fire on the target instance's
   engine at their true arrival time (see ``FleetController._accept``);
3. run every instance engine through the same window, in instance
   creation order.

The schedule is deterministic given ``window_s``: the same spec + seed +
window replays the same event order.  ``window_s == 0`` degenerates to
one barrier per distinct timestamp — the same event times and handler
arguments as serial mode, so results reproduce serial output exactly
unless distinct engines collide at an identical float timestamp (the
equivalence the fleet test suite locks on a golden spec).  Larger windows
amortize barrier overhead; cross-instance signals (router load counts,
autoscaler queue depths) are then stale by at most ``window_s`` simulated
seconds — the classic conservative-DES trade.
"""
from __future__ import annotations

from typing import List


def run_windowed(fc, until: float, window_s: float) -> None:
    """Drive a windowed FleetController to completion (or ``until``)."""
    fleet = fc.engine
    while True:
        engines: List = []
        seen = {id(fleet)}
        for inst in fc.instances.values():     # insertion order: stable
            e = inst.handle.engine
            if id(e) not in seen:
                seen.add(id(e))
                engines.append(e)
        times = [t for t in (e.peek_time() for e in [fleet] + engines)
                 if t is not None]
        if not times:
            # drained: align every clock to the global end time, so
            # duration-normalized observables (utilization, GPU-seconds)
            # read the same denominator as serial mode's shared clock
            end = max(e.now for e in [fleet] + engines)
            for e in [fleet] + engines:
                e.advance_to(end)
            return
        barrier = min(times)
        if barrier > until:
            # horizon cut: clamp every clock to the horizon and stop
            for e in [fleet] + engines:
                e.run(until)
            return
        hi = min(barrier + window_s, until)
        # control plane first: instances are at or behind the barrier, so
        # every arrival routed here defers onto an instance engine at a
        # time that engine has not yet reached (conservative-safe)
        fleet.run(hi)
        for e in engines:
            e.run(hi)
        # instances built by fleet events in this window (scale-up) enter
        # at the next barrier; their engines start at the fleet clock
