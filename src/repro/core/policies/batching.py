"""Batch-formation policies (the serving engine's scheduler inner loop).

Modeled on vLLM/SGLang/TensorRT-LLM behaviors (paper §1 challenge 3):
- ContinuousBatching: token-budget continuous batching; prefills admitted
  whole (vLLM default).
- ChunkedPrefill: Sarathi-Serve style — prefills are split into chunks and
  piggybacked onto decode batches to bound inter-token latency.
- StaticBatching: fixed batch, run to completion (classic batching).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.request import Request, RState


@dataclass
class BatchPlan:
    prefill: List[Tuple[Request, int]]   # (request, chunk_len)
    decode: List[Request]

    @property
    def empty(self) -> bool:
        return not self.prefill and not self.decode

    @property
    def q_lens(self) -> List[int]:
        return [c for _, c in self.prefill] + [1] * len(self.decode)

    @property
    def kv_lens(self) -> List[int]:
        kv = [r.prefill_progress + c for r, c in self.prefill]
        kv += [r.context_len for r in self.decode]
        return kv


class BatchingPolicy:
    name = "base"

    def plan(self, waiting: Sequence[Request], running: Sequence[Request],
             memory, now: float) -> BatchPlan:
        raise NotImplementedError


class ContinuousBatching(BatchingPolicy):
    name = "continuous"

    def __init__(self, *, max_num_seqs: int = 256,
                 max_batched_tokens: int = 8192):
        self.max_num_seqs = max_num_seqs
        self.max_batched_tokens = max_batched_tokens

    def plan(self, waiting, running, memory, now) -> BatchPlan:
        decode = [r for r in running if r.state in (RState.DECODING,
                                                    RState.QUEUED_DECODE)]
        budget = self.max_batched_tokens - len(decode)
        seqs = len(decode)
        prefill: List[Tuple[Request, int]] = []
        for r in waiting:
            if r.prefill_total - r.prefill_progress <= 0:
                continue
            # probe the prefix cache first: a hit shrinks the tokens this
            # prefill actually computes (admit_request applies it)
            hit = memory.prefix_hit(r) if memory is not None else 0
            remaining = r.prefill_total - max(r.prefill_progress, hit)
            if seqs >= self.max_num_seqs or remaining > budget:
                break  # FCFS head-of-line: vLLM admits in order
            if memory is not None and not memory.admit_request(r):
                break  # backpressure: no KV space
            remaining = r.prefill_total - r.prefill_progress
            prefill.append((r, remaining))
            budget -= remaining
            seqs += 1
        return BatchPlan(prefill, decode)


class ChunkedPrefill(BatchingPolicy):
    name = "chunked_prefill"

    def __init__(self, *, max_num_seqs: int = 256, chunk: int = 512,
                 max_batched_tokens: int = 2048):
        self.max_num_seqs = max_num_seqs
        self.chunk = chunk
        self.max_batched_tokens = max_batched_tokens

    def plan(self, waiting, running, memory, now) -> BatchPlan:
        decode = [r for r in running if r.state in (RState.DECODING,
                                                    RState.QUEUED_DECODE)]
        budget = self.max_batched_tokens - len(decode)
        seqs = len(decode)
        prefill: List[Tuple[Request, int]] = []
        # continue partially-prefilled requests first (Sarathi)
        in_flight = [r for r in waiting
                     if 0 < r.prefill_progress < r.prefill_total]
        fresh = [r for r in waiting if r.prefill_progress == 0]
        for r in in_flight + fresh:
            if budget <= 0 or seqs >= self.max_num_seqs:
                break
            if r.prefill_progress == 0 and memory is not None \
                    and not memory.admit_request(r):
                break
            # admit_request advances prefill_progress past any prefix hit
            take = min(self.chunk, r.prefill_total - r.prefill_progress,
                       budget)
            if take <= 0:
                break
            prefill.append((r, take))
            budget -= take
            seqs += 1
        return BatchPlan(prefill, decode)


class StaticBatching(BatchingPolicy):
    name = "static"

    def __init__(self, *, batch_size: int = 8):
        self.batch_size = batch_size

    def plan(self, waiting, running, memory, now) -> BatchPlan:
        decode = [r for r in running if r.state in (RState.DECODING,
                                                    RState.QUEUED_DECODE)]
        if decode:   # run the current batch to completion
            return BatchPlan([], decode)
        prefill = []
        for r in list(waiting)[: self.batch_size]:
            if memory is not None and not memory.admit_request(r):
                break
            prefill.append((r, r.prefill_total - r.prefill_progress))
        return BatchPlan(prefill, [])


BATCHING = {c.name: c for c in (ContinuousBatching, ChunkedPrefill,
                                StaticBatching)}


def resolve_batching(spec) -> Optional[BatchingPolicy]:
    """Uniform batching-policy argument handling (mirrors resolve_router).

    Accepts an instance (returned as-is), a registered name ("continuous",
    "chunked_prefill", "static"), a mapping ``{"name": ..., **kwargs}``
    whose kwargs go to the policy constructor, or None.
    """
    if spec is None or isinstance(spec, BatchingPolicy):
        return spec
    if isinstance(spec, str):
        spec = {"name": spec}
    if isinstance(spec, dict):
        kw = dict(spec)
        name = kw.pop("name", None)
        if name not in BATCHING:
            raise KeyError(f"unknown batching policy {name!r}; "
                           f"registered: {sorted(BATCHING)}")
        return BATCHING[name](**kw)
    raise TypeError(f"batching must be None, a name, a mapping, or a "
                    f"BatchingPolicy; got {type(spec).__name__}")
