"""FIDELITY.json: the repo-root simulator-accuracy trajectory.

One entry per calibration run, appended like ``BENCH_sim_scale.json``:
per-operator MAPE / p50 / p99 relative error for the fitted model and
both baselines (analytical roofline, vidur sqrt-proxy) on the held-out
heterogeneous-batch grid.  CI re-calibrates on a small grid and fails if
the fitted MAPE regresses more than the tolerance vs the last comparable
trajectory entry — accuracy is gated exactly like events/s.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

# an entry is comparable to a baseline entry when the fit problem matches
COMPARABLE_KEYS = ("model", "hardware", "oracle", "smoke", "n_train",
                   "n_eval")


def entry_from_result(result, label: str) -> Dict:
    """Build a trajectory entry from a ``CalibrationResult``."""
    return {
        "label": label,
        "model": result.model,
        "hardware": result.hardware,
        "oracle": result.oracle,
        "smoke": result.smoke,
        "seed": result.seed,
        "n_train": result.n_train,
        "n_eval": result.n_eval,
        "operators": {op: {fam: dict(stats)
                           for fam, stats in fams.items()}
                      for op, fams in result.fidelity.items()},
        "created_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


def load_trajectory(path: str) -> List[Dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f).get("trajectory", [])


def append_fidelity(path: str, entry: Dict) -> None:
    """Append (or replace, by label) an entry — same contract as
    ``bench_sim_scale.append_trajectory``."""
    traj = [e for e in load_trajectory(path)
            if e.get("label") != entry.get("label")]
    traj.append(entry)
    with open(path, "w") as f:
        json.dump({"trajectory": traj}, f, indent=2, sort_keys=True)
        f.write("\n")


def _cfg(entry: Dict) -> Dict:
    return {k: entry.get(k) for k in COMPARABLE_KEYS}


def pick_baseline(trajectory: List[Dict], fresh: Dict
                  ) -> Tuple[Optional[Dict], bool]:
    """Most recent comparable entry, else most recent entry at all."""
    if not trajectory:
        return None, False
    want = _cfg(fresh)
    for e in reversed(trajectory):
        if _cfg(e) == want:
            return e, True
    return trajectory[-1], False


def check_fidelity_regression(fresh: Dict, trajectory: List[Dict],
                              tolerance: float = 0.2
                              ) -> Tuple[bool, List[str]]:
    """Gate: fitted MAPE must not grow more than ``tolerance`` (relative)
    vs the baseline entry, per operator.  Returns (ok, report lines)."""
    base, comparable = pick_baseline(trajectory, fresh)
    if base is None:
        return True, ["fidelity gate: empty trajectory — pass "
                      "(nothing to compare against)"]
    lines = []
    if not comparable:
        lines.append(f"fidelity gate: no comparable entry "
                     f"(want {_cfg(fresh)}); using most recent "
                     f"{base.get('label', '?')!r}")
    ok = True
    for op, fams in (fresh.get("operators") or {}).items():
        fresh_mape = (fams.get("fitted") or {}).get("mape")
        base_mape = (((base.get("operators") or {}).get(op) or {})
                     .get("fitted") or {}).get("mape")
        if fresh_mape is None or base_mape is None:
            lines.append(f"fidelity gate: {op}: no fitted mape on both "
                         f"sides — skipped")
            continue
        ceiling = base_mape * (1.0 + tolerance)
        verdict = "OK" if fresh_mape <= ceiling else "FAIL"
        lines.append(
            f"fidelity gate: {op}: baseline={base.get('label', '?')} "
            f"mape {base_mape:.3%} -> fresh {fresh_mape:.3%} "
            f"(ceiling {ceiling:.3%}, tolerance {tolerance:.0%}) "
            f"{verdict}")
        if fresh_mape > ceiling:
            ok = False
    if not lines:
        lines.append("fidelity gate: fresh entry has no operators — "
                     "nothing to gate")
        ok = False
    return ok, lines
