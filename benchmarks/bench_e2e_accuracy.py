"""Paper Table 2: end-to-end predicted vs measured throughput.

The measured system is the real MiniEngine (JAX, CPU) serving the reduced
qwen2-7b; the simulator is calibrated the way the paper calibrates against
A800s — operator models fitted to profiled operator timings on the SAME
hardware (here: measured CPU wall-clock), then the end-to-end system is
predicted without ever running it.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.api import ModelRef, SimSpec, TopologySpec, WorkloadSpec
from repro.api.run import run as run_spec
from repro.configs import get_config
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.opmodels.calibration import measure_cpu_hardware
from repro.serving.engine import MiniEngine

# Table-2 grid (scaled to CPU/smoke sizes; same structure as the paper's)
GRID = [
    # batch, prompt, output
    (2, 16, 32),
    (4, 32, 16),
    (8, 16, 16),
    (4, 8, 24),
]


def run(seed: int = 0) -> List[str]:
    cfg = get_config("qwen2-7b", smoke=True)
    hw = measure_cpu_hardware()
    rng = np.random.default_rng(seed)
    lines = []

    # per-step dispatch overhead: profile a trivial jitted op
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x + 1)
    x = jnp.zeros((8,))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        f(x).block_until_ready()
    dispatch = (time.perf_counter() - t0) / 50

    for batch, p_len, o_len in GRID:
        eng = MiniEngine(cfg, max_slots=batch, max_seq=128, seed=seed)
        prompts = [rng.integers(0, cfg.vocab_size, p_len) for _ in range(batch)]
        eng.submit(list(prompts), o_len)
        eng.run()                     # warm pass: jit compilation
        eng.step_log.clear()
        eng.submit(list(prompts), o_len)
        measured = eng.run()          # steady state

        # the simulated system as a declarative spec; the measured-CPU
        # hardware/operator models are injected (calibration flow), and
        # memoize=False because this benchmark measures predictor accuracy
        # — the ~5%-bucket step-time cache must not quantize predictions
        spec = SimSpec(
            name=f"table2_b{batch}_in{p_len}_out{o_len}",
            model=ModelRef("qwen2-7b", smoke=True),
            topology=TopologySpec(preset="colocated", n_replicas=1, tp=1,
                                  memoize=False),
            workload=WorkloadSpec(n_requests=batch, arrival="burst",
                                  burst_size=batch, prompt="fixed",
                                  prompt_mean=p_len, output="fixed",
                                  output_mean=o_len),
            seed=seed)
        # calibrated per-step floor: the steady-state decode step measured
        # on this host (paper flow: operator/engine profiles from the same
        # hardware feed the predictor)
        floor = min(s["dur"] for s in eng.step_log if s["kind"] == "decode")
        predicted = run_spec(spec, hardware=hw, ops=OperatorModelSet(hw),
                             engine_overhead=max(floor, dispatch * 8))

        m, p = measured["throughput_tok_s"], predicted["throughput_tok_s"]
        err = abs(p - m) / m
        lines.append(
            f"table2_b{batch}_in{p_len}_out{o_len},"
            f"{measured['duration_s'] * 1e6:.0f},"
            f"measured={m:.1f};predicted={p:.1f};rel_err={err:.3f}")
    return lines


if __name__ == "__main__":
    for l in run():
        print(l)
