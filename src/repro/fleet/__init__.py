"""Fleet control plane: multi-instance serving, global routing, autoscaling.

The layer above a single deployment: N serving *instances* (each a full
``GlobalController`` deployment — colocated, PD- or AF-disaggregated,
heterogeneous mixes allowed) share one deterministic ``SimEngine`` behind
a pluggable global router, optionally scaled by an SLO-driven autoscaler.

Declaratively, a fleet is the ``fleet:`` section of a SimSpec::

    fleet:
      instances:
        - {name: big, count: 2, topology: {preset: pd, n_decode: 2}}
        - {name: small, count: 2}            # inherits spec.topology
      router: prefix_affinity
      autoscaler: {max_instances: 8, up_queue_depth: 12}
      tenants:
        - {name: paid, weight: 1, ttft_s: 0.5, priority: 0}
        - {name: free, weight: 3, ttft_s: 2.0, priority: 1}

and ``repro.api.run(spec)`` returns a :class:`FleetReport`.

- :mod:`repro.fleet.router` — ``FleetRouter`` protocol + ``FLEET_ROUTERS``
  registry (round_robin | least_outstanding | power_of_two |
  prefix_affinity);
- :mod:`repro.fleet.instance` — instance lifecycle (cold start, drain)
  and GPU-second accounting;
- :mod:`repro.fleet.autoscaler` — queue-depth / SLO-attainment scaling and
  P:D pool rebalancing;
- :mod:`repro.fleet.controller` — the fleet control plane itself;
- :mod:`repro.fleet.report` — ``run_fleet(spec) -> FleetReport``.
"""
from repro.fleet.autoscaler import Autoscaler  # noqa: F401
from repro.fleet.controller import FleetController  # noqa: F401
from repro.fleet.instance import Instance  # noqa: F401
from repro.fleet.report import FleetReport, run_fleet  # noqa: F401
from repro.fleet.router import (  # noqa: F401
    FLEET_ROUTERS, FleetRouter, resolve_fleet_router,
)

__all__ = [
    "FLEET_ROUTERS", "FleetRouter", "resolve_fleet_router",
    "Instance", "Autoscaler", "FleetController",
    "FleetReport", "run_fleet",
]
