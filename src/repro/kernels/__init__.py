"""Pallas TPU kernels for the perf-critical operators the paper models:
FlashAttention (prefill), FlashDecode (KV-cache decode), GroupedGEMM (MoE).
ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles."""
from repro.kernels import ops, ref  # noqa: F401
