"""Serving metrics: TTFT / TPOT / throughput / goodput / Pareto frontier."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Request


def _pct(xs: Sequence[float], q: float) -> Optional[float]:
    # None (JSON null), NOT nan: a bare NaN literal makes the report an
    # invalid JSON document, silently breaking CLI/sweep artifacts
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else None


def _mean(xs: Sequence[float]) -> Optional[float]:
    return float(np.mean(xs)) if len(xs) else None


@dataclass
class MetricsCollector:
    completed: List[Request] = field(default_factory=list)
    # token events are counted, not stored: a per-token timestamp list is
    # O(total output tokens) memory (hundreds of MB at million-request
    # scale) and nothing consumed the individual times
    token_count: int = 0
    # measurement-window start: anchored to the FIRST request arrival by the
    # controller (None until then) — measuring from t=0 silently inflates
    # the duration whenever the first arrival is late
    start: Optional[float] = None
    end: float = 0.0

    def on_token(self, r: Request, replica, t: float) -> None:
        self.token_count += 1
        if t > self.end:
            self.end = t

    def on_complete(self, r: Request, replica) -> None:
        self.completed.append(r)
        self.end = max(self.end, r.finish_time or 0.0)

    # ------------------------------------------------------------- report --
    def report(self, *, n_devices: int = 1,
               slo_ttft: Optional[float] = None,
               slo_tpot: Optional[float] = None
               ) -> Dict[str, Optional[float]]:
        """Summary metrics; empty-sample statistics are ``None`` (JSON
        null), never NaN — reports must stay valid JSON."""
        start = self.start
        if start is None:       # no arrival was ever observed
            start = min((r.arrival for r in self.completed), default=0.0)
        dur = max(self.end - start, 1e-9)
        ttfts = [r.ttft() for r in self.completed if r.ttft() is not None]
        tpots = [r.tpot() for r in self.completed if r.tpot() is not None]
        e2es = [r.e2e() for r in self.completed if r.e2e() is not None]
        # every completed request contributes a queue delay: one that was
        # never stamped ``first_scheduled`` (scheduled the instant it
        # arrived, before any stamping seam ran) waited 0.0 — dropping it
        # would bias the percentiles upward over exactly the fastest
        # requests
        queues = [r.timestamps.get("first_scheduled", r.arrival) - r.arrival
                  for r in self.completed]
        out_tokens = sum(r.generated for r in self.completed)
        rep = {
            "n_completed": len(self.completed),
            "duration_s": dur,
            "throughput_tok_s": out_tokens / dur,
            "throughput_tok_s_per_device": out_tokens / dur / max(n_devices, 1),
            "ttft_mean_s": _mean(ttfts),
            "ttft_p50_s": _pct(ttfts, 50), "ttft_p99_s": _pct(ttfts, 99),
            "tpot_mean_s": _mean(tpots),
            "tpot_p50_s": _pct(tpots, 50), "tpot_p99_s": _pct(tpots, 99),
            "e2e_mean_s": _mean(e2es),
            "e2e_p50_s": _pct(e2es, 50), "e2e_p99_s": _pct(e2es, 99),
            "queue_mean_s": _mean(queues),
            "queue_p50_s": _pct(queues, 50), "queue_p99_s": _pct(queues, 99),
            # preemption/restore observability (memory-pressure dynamics)
            "preempted_requests": sum(1 for r in self.completed
                                      if r.preemptions > 0),
            "request_preemptions": sum(r.preemptions for r in self.completed),
        }
        if slo_ttft is not None and slo_tpot is not None and self.completed:
            good = [r for r in self.completed
                    if _meets_slo(r, slo_ttft, slo_tpot)]
            rep["goodput_tok_s"] = sum(r.generated for r in good) / dur
            rep["slo_attainment"] = len(good) / len(self.completed)
        return rep

    # --------------------------------------------------------- fleet views --
    @classmethod
    def merged(cls, collectors: Sequence["MetricsCollector"]
               ) -> "MetricsCollector":
        """Fleet-wide view over per-instance collectors: one measurement
        window anchored at the earliest instance start, all completions and
        token events pooled (each request completes on exactly one
        instance, so pooling never double-counts)."""
        out = cls()
        starts = [c.start for c in collectors if c.start is not None]
        out.start = min(starts) if starts else None
        out.end = max((c.end for c in collectors), default=0.0)
        for c in collectors:
            out.completed.extend(c.completed)
            out.token_count += c.token_count
        return out


def _meets_slo(r: Request, ttft_s: Optional[float],
               tpot_s: Optional[float]) -> bool:
    """One SLO predicate for goodput and attainment (a request with no
    measured TTFT/TPOT never meets a bound)."""
    if ttft_s is not None and (r.ttft() or 9e9) > ttft_s:
        return False
    if tpot_s is not None and (r.tpot() or 9e9) > tpot_s:
        return False
    return True


def slo_attainment(requests: Sequence[Request],
                   ttft_s: Optional[float] = None,
                   tpot_s: Optional[float] = None) -> Optional[float]:
    """Fraction of ``requests`` meeting the given SLO bounds (None bound =
    don't check it); None when there are no requests or no bounds."""
    if not requests or (ttft_s is None and tpot_s is None):
        return None
    return sum(1 for r in requests
               if _meets_slo(r, ttft_s, tpot_s)) / len(requests)


def pareto_frontier(points: Sequence[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """(throughput, interactivity=1/tpot) maximization frontier."""
    pts = sorted(points, key=lambda p: (-p[0], -p[1]))
    front, best = [], -np.inf
    for x, y in pts:
        if y > best:
            front.append((x, y))
            best = y
    return front
