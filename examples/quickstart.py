"""Quickstart: simulate colocated vs PD-disaggregated serving of qwen2-7b.

Runs in seconds on CPU.  Shows the core Frontier workflow: build a system
topology, replay a workload through the event engine, read the metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config
from repro.core import A800_SXM4_80G, ParallelismConfig
from repro.core.workflows.colocated import build_colocated
from repro.core.workflows.pd_disagg import build_pd
from repro.workload.generator import WorkloadConfig, generate


def main():
    cfg = get_config("qwen2-7b")
    hw = A800_SXM4_80G
    wl = WorkloadConfig(n_requests=200, rate=12.0, prompt_mean=1024,
                        output_mean=128, seed=0)

    colo = build_colocated(cfg, hw, n_replicas=2,
                           par=ParallelismConfig(tp=1))
    rep_c = colo.run(generate(wl))

    pd = build_pd(cfg, hw, n_prefill=1, n_decode=1)
    rep_p = pd.run(generate(wl))

    print(f"{'metric':28s} {'colocated(2xTP1)':>18s} {'PD(1P+1D)':>14s}")
    for k in ("throughput_tok_s_per_device", "ttft_p50_s", "ttft_p99_s",
              "tpot_p50_s", "tpot_p99_s"):
        print(f"{k:28s} {rep_c[k]:18.4f} {rep_p[k]:14.4f}")
    print("\nPD decouples decode interactivity from long prefills "
          "(compare tpot_p99).")


if __name__ == "__main__":
    main()
