"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8.
[arXiv:2501.kimi2 paper-table; unverified]

Per the assignment table: GQA kv=8 (the real model uses MLA; the assigned
spec is authoritative here), per-expert d_ff=2048.
head_dim = 7168/64 = 112.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,                 # per-expert width
    vocab_size=163840,
    head_dim=112,
    rope_theta=50_000.0,
    moe=MoEConfig(num_experts=384, top_k=8, expert_d_ff=2048,
                  num_shared_experts=1),
)
