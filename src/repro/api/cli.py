"""``python -m repro``: the command-line front door.

    python -m repro run examples/specs/quickstart.yaml
    python -m repro sweep examples/specs/quickstart.yaml \
        --axis topology.tp=1,2,4 --axis workload.rate=5,10 --jobs 8
    python -m repro list

Reports land under ``artifacts/`` (JSON per run, JSONL per sweep),
self-describing: each carries its full spec, spec hash, and provenance.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Sequence

from repro.api.run import Report, run
from repro.api.spec import SimSpec, SpecError
from repro.api.sweep import pareto, sweep

SUMMARY_KEYS = (
    "n_completed", "duration_s", "throughput_tok_s",
    "throughput_tok_s_per_device", "ttft_p50_s", "ttft_p99_s",
    "tpot_p50_s", "tpot_p99_s", "e2e_p50_s", "e2e_p99_s",
    "queue_p50_s", "queue_p99_s", "goodput_tok_s", "slo_attainment",
    "bubble_time_s", "overlap_efficiency",
    # fleet control plane
    "fleet_instances_built", "fleet_instances_active_end",
    "scale_up_events", "scale_down_events", "rebalance_events",
    "routing_imbalance", "provisioned_gpu_seconds", "idle_gpu_seconds",
    "prefix_hit_token_frac", "tenant_slo_attainment_min",
    # $ accounting + shared-fabric contention
    "dollars_per_hour", "provisioned_dollars", "idle_dollars",
    "tok_per_s_per_dollar",
    "fabric_transfers", "fabric_exposed_comm_s",
    "fabric_contention_delay_s",
)


def _parse_value(tok: str) -> Any:
    try:
        return json.loads(tok)
    except (json.JSONDecodeError, ValueError):
        return tok


def _parse_values(text: str) -> List[Any]:
    """Parse an axis value list: JSON array semantics first (handles
    objects containing commas), else comma-split scalars."""
    try:
        v = json.loads(f"[{text}]")
        if isinstance(v, list):
            return v
    except (json.JSONDecodeError, ValueError):
        pass
    return [_parse_value(t) for t in text.split(",")]


def _split_kv(item: str, flag: str) -> tuple:
    if "=" not in item:
        raise SpecError(f"{flag} expects PATH=VALUE, got {item!r}")
    k, v = item.split("=", 1)
    return k.strip(), v


def _load_spec(path: str, sets: Sequence[str]) -> SimSpec:
    spec = SimSpec.load(path)
    updates = {}
    for item in sets or ():
        k, v = _split_kv(item, "--set")
        updates[k] = _parse_value(v)
    if updates:
        spec = spec.with_(**updates)
    return spec


def _print_summary(rep: Report, file=sys.stdout) -> None:
    label = rep.name or rep.spec_hash
    print(f"# {label}  (devices={rep.n_devices}, events={rep.sim_events}, "
          f"wall={rep.wall_clock_s:.2f}s)", file=file)
    for k in SUMMARY_KEYS:
        if rep.summary.get(k) is not None:   # empty-sample stats are None
            print(f"  {k:30s} {rep.summary[k]:14.6g}", file=file)
    if not rep.all_complete:
        print(f"  WARNING: incomplete — conservation: {rep.conservation}",
              file=file)


def _out_base(spec: SimSpec, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    label = spec.name or f"spec-{spec.spec_hash()}"
    return os.path.join(out_dir, label)


# -------------------------------------------------------------- commands --
def _cmd_run(args: argparse.Namespace) -> int:
    spec = _load_spec(args.spec, args.set)
    rep = run(spec)
    path = _out_base(spec, args.out) + ".report.json"
    rep.save(path)
    _print_summary(rep)
    print(f"report -> {path}")
    return 0 if rep.all_complete or args.until_ok else 1


def _cmd_trace(args: argparse.Namespace) -> int:
    from repro.obs import (
        run_traced, write_chrome_trace, write_spans_jsonl, write_summary,
        render_summary,
    )
    spec = _load_spec(args.spec, args.set)
    # force observability on (keeping any obs options the spec sets)
    from dataclasses import asdict
    obs = asdict(spec.obs) if spec.obs is not None else {}
    obs["enabled"] = True
    if args.ep_spans:
        obs["ep_spans"] = True
    spec = spec.with_(obs=obs)
    rep, tel = run_traced(spec)
    if args.base:
        # a bare name lands inside --out; a path is taken literally
        base = (args.base if os.path.isabs(args.base)
                or os.sep in args.base
                else os.path.join(args.out, args.base))
        os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    else:
        base = _out_base(spec, args.out)
    top_n = args.top or (spec.obs.top_n if spec.obs else 5)
    outs = {
        "chrome": base + ".trace.json",
        "jsonl": base + ".spans.jsonl",
        "summary": base + ".summary.txt",
    }
    write_chrome_trace(tel, outs["chrome"])
    write_spans_jsonl(tel, outs["jsonl"])
    write_summary(tel, outs["summary"], top_n)
    rep.save(base + ".report.json")
    print(render_summary(tel, top_n))
    for kind, path in outs.items():
        print(f"{kind:8s} -> {path}")
    print(f"report   -> {base}.report.json")
    print("open the chrome trace at https://ui.perfetto.dev "
          "(or chrome://tracing)")
    return 0 if rep.all_complete or args.until_ok else 1


def _cmd_sweep(args: argparse.Namespace) -> int:
    spec = _load_spec(args.spec, args.set)
    axes: Dict[str, List[Any]] = {}
    for item in args.axis or ():
        k, v = _split_kv(item, "--axis")
        axes[k] = _parse_values(v)
    if not axes and not args.seeds:
        raise SpecError("sweep needs at least one --axis PATH=V1,V2,... "
                        "(or --seeds)")
    seeds = ([int(s) for s in args.seeds.split(",")]
             if args.seeds else None)
    jsonl = args.jsonl or (_out_base(spec, args.out) + ".sweep.jsonl")
    if os.path.exists(jsonl):
        os.remove(jsonl)      # streaming appends; start fresh per sweep

    def progress(done: int, total: int, rep: Report) -> None:
        tag = json.dumps(rep.point) if rep.point else rep.spec_hash
        thr = rep.summary.get("throughput_tok_s_per_device")
        tpot = rep.summary.get("tpot_p50_s")
        thr = float("nan") if thr is None else thr
        tpot = float("nan") if tpot is None else tpot * 1e3
        print(f"[{done}/{total}] {tag}  tok/s/dev={thr:.1f}  "
              f"tpot_p50={tpot:.2f}ms", flush=True)

    reports = sweep(spec, axes, mode="zip" if args.zip else "grid",
                    jobs=args.jobs, seeds=seeds, jsonl=jsonl,
                    progress=progress)
    front = pareto(reports)
    if front:
        print("\nPareto frontier (throughput x interactivity):")
        for r in front:
            print(f"  * {json.dumps(r.point) if r.point else r.spec_hash}")
    print(f"\n{len(reports)} reports -> {jsonl}")
    return 0


def _cmd_calibrate(args: argparse.Namespace) -> int:
    from repro.calib import (
        CalibrationError, append_fidelity, calibrate, entry_from_result,
    )
    try:
        result = calibrate(
            model=args.model, hardware=args.hardware, oracle=args.oracle,
            smoke=args.smoke, n_train=args.train_samples,
            n_eval=args.eval_samples, seed=args.seed,
            max_len=args.max_len, max_batch=args.max_batch,
            out_root=args.out)
    except (CalibrationError, KeyError) as e:
        print(f"calibrate error: {e}", file=sys.stderr)
        return 2
    print(f"calibrated {result.model} on {result.hardware} "
          f"(oracle={result.oracle}, n_train={result.n_train}, "
          f"n_eval={result.n_eval}, wall={result.wall_s:.1f}s)")
    for op, fams in result.fidelity.items():
        print(f"  {op}:")
        for fam in ("fitted", "analytical", "vidur_proxy"):
            s = fams[fam]
            print(f"    {fam:12s} mape={s['mape']:8.3%}  "
                  f"p50={s['p50']:8.3%}  p99={s['p99']:8.3%}")
    for op, path in result.artifact_paths.items():
        print(f"  artifact -> {path}")
    entry = entry_from_result(result, args.label)
    if args.entry_out:
        with open(args.entry_out, "w") as f:
            json.dump(entry, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"  fidelity entry -> {args.entry_out}")
    if args.fidelity:
        append_fidelity(args.fidelity, entry)
        print(f"  fidelity trajectory -> {args.fidelity}")
    return 0


def _cmd_list(args: argparse.Namespace) -> int:
    from repro.calib import ORACLES, discover_artifacts
    from repro.configs import REGISTRY
    from repro.core.hardware import HARDWARE
    from repro.core.opmodels import OPMODELS
    from repro.core.pipeline import PIPELINES
    from repro.core.policies.batching import BATCHING
    from repro.core.policies.memory import MEMORY
    from repro.core.policies.scheduling import SCHEDULERS
    from repro.core.routing import ROUTERS
    from repro.fleet.router import FLEET_ROUTERS
    from repro.api.spec import ARRIVALS, PRESETS
    from repro.core.fabric import COLLECTIVES, FABRIC_MODES
    from repro.workload.generator import RATE_CURVES
    arts = [
        f"{a['hardware']}/{a['operator']} (model={a['model']} "
        f"oracle={a['oracle']}"
        + (f" mape={a['mape']:.2%}" if a.get("mape") is not None else "")
        + ")"
        for a in discover_artifacts()]
    hw_rows = []
    for n in sorted(HARDWARE):
        dph = HARDWARE[n].dollars_per_hour
        hw_rows.append(f"{n} (${dph:.2f}/GPU-hr)" if dph > 0
                       else f"{n} (unpriced)")
    sections = {
        "models": sorted(REGISTRY),
        "hardware": hw_rows,
        "fabric modes": [f"{m} (collectives: {', '.join(COLLECTIVES)})"
                         if m == "shared" else m for m in FABRIC_MODES],
        "topology presets": list(PRESETS) + ["(or inline clusters/links)"],
        "arrival processes": list(ARRIVALS),
        "rate curves": list(RATE_CURVES),
        "routers": sorted(ROUTERS),
        "fleet routers": sorted(FLEET_ROUTERS),
        "batching policies": sorted(BATCHING),
        "queue policies": sorted(SCHEDULERS),
        "memory managers": sorted(MEMORY),
        "operator models": sorted(OPMODELS),
        "oracle backends": sorted(ORACLES) + ["auto"],
        "calibration artifacts (artifacts/calib)": arts or ["(none found)"],
        "pipeline presets": sorted(PIPELINES),
    }
    want = getattr(args, "what", None)
    for title, names in sections.items():
        if want and want not in title:
            continue
        print(f"{title}:")
        for n in names:
            print(f"  {n}")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Frontier simulator: declarative experiment runner")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("run", help="run one spec, write a JSON report")
    p.add_argument("spec", help="path to a SimSpec .yaml/.json file")
    p.add_argument("-o", "--out", default="artifacts",
                   help="output directory (default: artifacts/)")
    p.add_argument("--set", action="append", metavar="PATH=VALUE",
                   help="override a spec field, e.g. --set workload.rate=20")
    p.add_argument("--until-ok", action="store_true",
                   help="exit 0 even if the run left incomplete requests "
                        "(time-bounded runs)")
    p.set_defaults(fn=_cmd_run)

    p = sub.add_parser(
        "trace",
        help="run one spec with observability on; export a Perfetto-"
             "loadable chrome trace, a JSONL span log, and a text summary")
    p.add_argument("spec", help="path to a SimSpec .yaml/.json file")
    p.add_argument("-o", "--out", default="artifacts",
                   help="output directory (default: artifacts/)")
    p.add_argument("--base", default=None,
                   help="explicit output basename (writes BASE.trace.json, "
                        "BASE.spans.jsonl, BASE.summary.txt, "
                        "BASE.report.json); a bare name lands inside "
                        "--out, a path is taken literally")
    p.add_argument("--top", type=int, default=None,
                   help="top-N slowest requests in the summary "
                        "(default: spec obs.top_n, else 5)")
    p.add_argument("--ep-spans", action="store_true",
                   help="also record per-EP-rank dispatch/compute/combine "
                        "spans (AF MoE clusters; traces the inner event "
                        "graph on cache-miss steps)")
    p.add_argument("--set", action="append", metavar="PATH=VALUE")
    p.add_argument("--until-ok", action="store_true",
                   help="exit 0 even if the run left incomplete requests")
    p.set_defaults(fn=_cmd_trace)

    p = sub.add_parser("sweep",
                       help="expand axes over a base spec, stream JSONL")
    p.add_argument("spec")
    p.add_argument("--axis", action="append", metavar="PATH=V1,V2,...",
                   help="sweep axis (repeatable); values parse as JSON "
                        "when possible")
    p.add_argument("--zip", action="store_true",
                   help="pair axes positionally instead of the cartesian "
                        "product")
    p.add_argument("--jobs", type=int, default=1,
                   help="parallel worker processes (default 1 = serial)")
    p.add_argument("--seeds", default=None, metavar="S1,S2,...",
                   help="replicate every point with these seeds")
    p.add_argument("-o", "--out", default="artifacts")
    p.add_argument("--jsonl", default=None,
                   help="explicit JSONL output path")
    p.add_argument("--set", action="append", metavar="PATH=VALUE")
    p.set_defaults(fn=_cmd_sweep)

    p = sub.add_parser(
        "calibrate",
        help="fit operator models against an oracle, write artifacts + "
             "FIDELITY.json")
    p.add_argument("--model", default="qwen2-7b",
                   help="model config whose operator geometry to fit "
                        "(default qwen2-7b)")
    p.add_argument("--smoke", action="store_true",
                   help="fit the reduced smoke geometry (matches specs "
                        "with model.smoke: true)")
    p.add_argument("--hardware", default="A800-SXM4-80G",
                   help="hardware preset to calibrate for")
    p.add_argument("--oracle", default="auto",
                   help="ground-truth backend: kernelsim | pallas | hlo | "
                        "auto (pallas on accelerators, else kernelsim)")
    p.add_argument("--train-samples", type=int, default=600,
                   help="training grid size (default 600)")
    p.add_argument("--eval-samples", type=int, default=150,
                   help="held-out eval grid size (default 150)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-len", type=int, default=None,
                   help="cap sampled sequence lengths (default: oracle "
                        "limit)")
    p.add_argument("--max-batch", type=int, default=None,
                   help="cap sampled batch sizes (default: oracle limit)")
    p.add_argument("-o", "--out", default=os.path.join("artifacts", "calib"),
                   help="artifact root (default artifacts/calib/); "
                        "artifacts land under <out>/<hardware>/")
    p.add_argument("--fidelity", default="FIDELITY.json",
                   help="fidelity trajectory to append to "
                        "(default FIDELITY.json)")
    p.add_argument("--no-fidelity", dest="fidelity", action="store_const",
                   const=None, help="do not touch the trajectory file")
    p.add_argument("--label", default="dev",
                   help="trajectory entry label (entries dedupe by label)")
    p.add_argument("--entry-out", default=None,
                   help="also write the fresh fidelity entry to this path "
                        "(CI gating input)")
    p.set_defaults(fn=_cmd_calibrate)

    p = sub.add_parser("list", help="show registries a spec can reference")
    p.add_argument("what", nargs="?", default=None,
                   help="filter sections by substring")
    p.set_defaults(fn=_cmd_list)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except SpecError as e:
        print(f"spec error: {e}", file=sys.stderr)
        return 2
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    except BrokenPipeError:      # e.g. `python -m repro list | head`
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
