"""Latency-hiding pipelining study (the paper's "advanced pipelining
strategies" tradeoff curves).

Two sweeps over the pipelining layer:

1. **AF decode overlap** — micro-batch count x overlap strategy for the
   af_moe preset: the serial (no-latency-hiding) baseline vs the legacy
   free-NIC model vs MegaScale-style two-batch ping-pong with NIC-lane
   contention and EP comm/compute overlap.  Reports step makespan,
   bubble_time, overlap_efficiency, and the per-cluster exposed-comm
   fractions — the quantities that decide whether AF disaggregation pays.

2. **Chunked prefill** — chunk size x strategy for a colocated pool:
   piggybacked decode bounds inter-token latency at the cost of prefill
   chunk turnaround (the Sarathi-Serve tradeoff).

    PYTHONPATH=src python examples/pipelining_study.py
"""
from repro.api import (
    ModelRef, PipelineSpec, SimSpec, TopologySpec, WorkloadSpec, run,
)


def af_overlap_study():
    base = SimSpec(
        model=ModelRef("mixtral-8x7b", smoke=True),
        topology=TopologySpec(preset="af", n_prefill=1, n_decode=1,
                              ffn_ep=4),
        workload=WorkloadSpec(n_requests=60, rate=25.0, prompt_mean=512,
                              output_mean=48, seed=0),
        name="af-overlap")

    print("== AF decode-step overlap: micro-batches x strategy ==")
    print(f"{'m':>3s} {'strategy':>12s} {'tpot_p50(ms)':>13s} "
          f"{'makespan(s)':>12s} {'bubble(s)':>10s} {'overlap_eff':>12s} "
          f"{'attn xcomm':>11s} {'ffn xcomm':>10s}")
    serial_makespans = {}
    for m in (1, 2, 4, 8):
        for strat in ("serial", None, "two_batch", "full_overlap"):
            spec = base.with_(**{"topology.m": m})
            if strat is not None:
                spec.pipeline = PipelineSpec(preset=strat)
            rep = run(spec)
            af = rep.clusters["decode"]["af"]
            label = strat or "off(legacy)"
            if strat == "serial":
                serial_makespans[m] = af["makespan_s"]
            print(f"{m:3d} {label:>12s} "
                  f"{rep['tpot_p50_s'] * 1e3:13.2f} "
                  f"{af['makespan_s']:12.4f} "
                  f"{rep.summary['bubble_time_s']:10.4f} "
                  f"{rep.summary['overlap_efficiency']:12.1%} "
                  f"{af['attn_exposed_comm_frac']:11.1%} "
                  f"{af['ffn_exposed_comm_frac']:10.1%}")
            if strat == "two_batch" and m > 1:
                assert af["makespan_s"] < serial_makespans[m], \
                    "two-batch overlap must beat the serial baseline"
    print("Reading: more micro-batches shrink bubbles until NIC-lane "
          "contention bites; ep_overlap (full_overlap) hides the a2a legs "
          "behind expert GEMMs.\n")


def chunked_prefill_study():
    base = SimSpec(
        model=ModelRef("qwen2-7b", smoke=True),
        topology=TopologySpec(preset="colocated", n_replicas=1),
        workload=WorkloadSpec(n_requests=80, rate=40.0, prompt_mean=2048,
                              output_mean=64, seed=0),
        name="chunked-prefill")

    print("== Chunked prefill with piggybacked decode: chunk size ==")
    print(f"{'chunk':>6s} {'ttft_p50(ms)':>13s} {'tpot_p99(ms)':>13s} "
          f"{'e2e_p50(s)':>11s} {'piggyback':>10s}")
    rep = run(base)
    print(f"{'off':>6s} {rep['ttft_p50_s'] * 1e3:13.1f} "
          f"{rep['tpot_p99_s'] * 1e3:13.2f} {rep['e2e_p50_s']:11.3f} "
          f"{'-':>10s}")
    for chunk in (128, 256, 512, 1024):
        spec = base.with_()
        spec.pipeline = PipelineSpec(chunked_prefill=True,
                                     prefill_chunk=chunk)
        rep = run(spec)
        piggy = sum(r.get("piggyback_tokens", 0)
                    for r in rep.clusters["colocated"]["replicas"].values())
        print(f"{chunk:6d} {rep['ttft_p50_s'] * 1e3:13.1f} "
              f"{rep['tpot_p99_s'] * 1e3:13.2f} {rep['e2e_p50_s']:11.3f} "
              f"{piggy:10d}")
    print("Reading: small chunks trade prefill turnaround (TTFT) for "
          "bounded inter-token latency under load.")


def main():
    af_overlap_study()
    chunked_prefill_study()


if __name__ == "__main__":
    main()
