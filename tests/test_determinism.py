"""Determinism regression: identical SimSpec + seed => byte-identical
Report summaries, in-process and across sweep() process-pool workers."""
import json

from repro.api import (
    ModelRef, PipelineSpec, SimSpec, TopologySpec, WorkloadSpec, run, sweep,
)


def _specs():
    yield SimSpec(
        name="det-colocated",
        model=ModelRef("qwen2-7b", smoke=True),
        topology=TopologySpec(preset="colocated", n_replicas=2),
        workload=WorkloadSpec(n_requests=30, rate=25.0, seed=5), seed=5)
    yield SimSpec(
        name="det-af-pipelined",
        model=ModelRef("mixtral-8x7b", smoke=True),
        topology=TopologySpec(preset="af", m=4, ffn_ep=4),
        workload=WorkloadSpec(n_requests=20, rate=20.0, prompt_mean=256,
                              output_mean=24, seed=5),
        pipeline=PipelineSpec(preset="full_overlap"), seed=5)


def _stable_view(rep):
    """Everything that must be reproducible (wall clock excluded)."""
    return json.dumps({"summary": rep.summary, "hash": rep.spec_hash,
                       "clusters": rep.clusters,
                       "conservation": rep.conservation,
                       "events": rep.sim_events}, sort_keys=True)


def test_same_spec_same_seed_is_byte_identical_in_process():
    for spec in _specs():
        a, b = run(spec), run(spec)
        assert _stable_view(a) == _stable_view(b)


def test_reports_identical_across_process_pool_workers():
    """sweep() fans points out over a ProcessPoolExecutor; every worker
    must reproduce exactly what an in-process run produces."""
    base = next(_specs())
    axes = {"workload.rate": [15.0, 25.0], "seed": [1, 2]}
    serial = sweep(base, axes, jobs=1)
    pooled = sweep(base, axes, jobs=2)
    assert len(serial) == len(pooled) == 4
    for a, b in zip(serial, pooled):
        assert a.point == b.point
        assert _stable_view(a) == _stable_view(b)


def test_seed_actually_matters():
    """Different seeds must not collapse to the same trajectory (guards
    against an accidentally shared/global RNG)."""
    spec = next(_specs())
    a = run(spec)
    b = run(spec.with_(**{"workload.seed": 99, "seed": 99}))
    assert a.summary["ttft_p50_s"] != b.summary["ttft_p50_s"]
