"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines:
- fig2_*      operator-accuracy CDFs (Frontier RF vs Vidur proxy)  [Fig. 2]
- table2_*    end-to-end predicted vs measured throughput          [Table 2]
- table1_*    feature-matrix cells exercised as real simulations   [Table 1]
- sim_scale_* simulator events/s + speedup vs simulated time
- roofline_*  40-cell dry-run roofline terms (reads artifacts/dryrun)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    sections = []
    from benchmarks import (bench_operator_accuracy, bench_e2e_accuracy,
                            bench_sim_scale, roofline)
    sections = [
        ("operator_accuracy", bench_operator_accuracy.run),
        ("e2e_accuracy", bench_e2e_accuracy.run),
        ("sim_scale", bench_sim_scale.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in sections:
        try:
            for line in fn():
                print(line)
        except Exception as e:  # report and continue; fail at the end
            traceback.print_exc()
            failed.append((name, repr(e)))
    if failed:
        print(f"bench_failures,0,{failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
