"""``python -m repro`` — declarative experiment CLI (see repro.api.cli)."""
from repro.api.cli import main

if __name__ == "__main__":
    raise SystemExit(main())
