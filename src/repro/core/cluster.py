"""ClusterWorker / ClusterScheduler / ReplicaWorker.

A ClusterWorker is the abstraction for one specialized hardware pool (a
prefill cluster, a decode cluster, a colocated pool, an attention or FFN
cluster).  Its ClusterScheduler routes requests to ReplicaWorkers and
participates in inter-stage coordination (memory-availability signaling for
PD backpressure).  A ReplicaWorker simulates one model instance: it forms
batches with a pluggable BatchingPolicy, prices them with the
ExecutionPredictor, advances request state on BATCH_DONE events, and — when
its KVCacheManager runs out of blocks mid-decode — preempts the
lowest-priority resident requests (recompute or swap restore) instead of
silently over-committing memory.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.engine import SimEngine
from repro.core.events import EV, Event
from repro.core.policies.batching import BatchingPolicy, BatchPlan
from repro.core.policies.memory import KVCacheManager
from repro.core.policies.scheduling import FCFS, QueuePolicy
from repro.core.predictor import ExecutionPredictor
from repro.core.request import Request, RState


@dataclass
class Hooks:
    """Controller callbacks (inter-stage coordination points)."""
    prefill_complete: Callable = lambda r, replica: None
    token_generated: Callable = lambda r, replica, t: None
    request_complete: Callable = lambda r, replica: None
    memory_available: Callable = lambda cluster, replica: None
    preempted: Callable = lambda r, replica: None   # recompute re-routing


class ReplicaWorker:
    def __init__(self, engine: SimEngine, name: str,
                 predictor: ExecutionPredictor, policy: BatchingPolicy,
                 memory: Optional[KVCacheManager], hooks: Hooks, *,
                 role: str = "colocated", queue_policy: Optional[QueuePolicy] = None,
                 slowdown: float = 1.0, pipeline=None):
        self.engine = engine
        self.name = name
        self.predictor = predictor
        self.policy = policy
        self.memory = memory
        self.hooks = hooks
        self.role = role
        self.queue_policy = queue_policy or FCFS()
        self.pipeline = pipeline          # PipelineConfig (latency hiding)
        self.slowdown = slowdown          # straggler factor (1.0 = healthy)
        # routing eligibility: an inactive replica takes no NEW work but
        # finishes what it holds (fleet drain / P:D-rebalance standby pools);
        # distinct from `failed`, which loses in-flight work
        self.active = True
        self.waiting: List[Request] = []
        self.running: List[Request] = []  # decoding requests resident here
        self.swapped: List[Request] = []  # preempted, KV on host, awaiting room
        self._swapping_out: List[Request] = []  # swap-out transfer in flight
        self._swapping_in: List[Request] = []   # admitted, swap-in in flight
        self.busy = False
        self.failed = False
        self._epoch = 0      # bumped on failure; stale BATCH_DONEs dropped
        self.cluster: Optional["ClusterWorker"] = None
        self.stats = {"batches": 0, "busy_time": 0.0, "tokens": 0,
                      "prefill_tokens": 0}
        # observability recorder (repro.obs.Telemetry); None = fully off —
        # every instrumentation site below guards on it, so untraced runs
        # execute the exact pre-observability path.  tel_name is the
        # fleet-unique identity ("<instance>/<name>") attach_telemetry
        # assigns — plain replica names repeat across fleet instances
        self.telemetry = None
        self.tel_name = name

    # ------------------------------------------------------------- intake --
    def enqueue_prefill(self, r: Request) -> None:
        self.waiting.append(r)
        self.kick()

    def start_decode(self, r: Request) -> None:
        if r.state != RState.QUEUED_DECODE:
            r.to(RState.QUEUED_DECODE, self.engine.now)
        self.running.append(r)
        self.kick()

    def kick(self) -> None:
        self.engine.after(0.0, EV.SCHEDULE_TICK, self._schedule_ev)

    def _schedule_ev(self, ev) -> None:
        self._schedule()

    # ---------------------------------------------------------- scheduling --
    def _schedule(self) -> None:
        if self.busy or self.failed:
            return
        self._try_swap_in()
        ordered = self.queue_policy.order(self.waiting, self.engine.now)
        plan = self.policy.plan(ordered, self.running, self.memory,
                                self.engine.now)
        if plan.empty:
            return
        self.busy = True
        tel = self.telemetry
        if tel is not None:
            # anchor for traced AF decode steps: inner-engine marker
            # events are step-relative, the recorder adds this base
            tel.begin_batch(self.tel_name, self.engine.now)
        piggyback = False
        if (self.pipeline is not None and self.pipeline.chunked_prefill
                and plan.prefill and plan.decode):
            # chunked prefill with piggybacked decode: the mixed batch is
            # priced as ONE fused step — prefill attention for the chunks,
            # decode attention for the piggybacked rows, shared GEMMs.
            # Deliberately gated on the pipeline flag, NOT the batch shape:
            # a bare ChunkedPrefill batching policy (no PipelineSpec) keeps
            # the legacy all-prefill pricing bit-for-bit; fused per-class
            # pricing is opt-in via PipelineSpec(chunked_prefill=True)
            bd = self.predictor.step_time(plan.q_lens, plan.kv_lens,
                                          decode=False,
                                          n_prefill=len(plan.prefill))
            self.stats["piggyback_tokens"] = (
                self.stats.get("piggyback_tokens", 0) + len(plan.decode))
            piggyback = True
        else:
            bd = self.predictor.step_time(plan.q_lens, plan.kv_lens,
                                          decode=(not plan.prefill))
        t = bd.total * self.slowdown
        self.stats["batches"] += 1
        self.stats["busy_time"] += t
        for r, _ in plan.prefill:
            if r.state == RState.QUEUED_PREFILL:
                r.to(RState.PREFILL_RUNNING, self.engine.now)
                # queueing-delay anchor: first time any replica scheduled it
                r.timestamps.setdefault("first_scheduled", self.engine.now)
                if r.prefill_started is None:   # current pass's residency
                    r.prefill_started = self.engine.now
        for r in plan.decode:
            if r.state == RState.QUEUED_DECODE:
                r.to(RState.DECODING, self.engine.now)
        if tel is not None:
            now = self.engine.now
            for r, chunk in plan.prefill:
                # progress is pre-chunk: a cache hit shows up as a
                # nonzero first-chunk progress (prefix tokens skipped)
                tel.span("prefill_chunk", r.rid, now, now + t,
                         replica=self.tel_name, chunk=chunk,
                         progress=r.prefill_progress,
                         total=r.prefill_total, piggyback=piggyback)
            for r in plan.decode:
                tel.compute_span("decode", r.rid, now, now + t,
                                 self.tel_name)
            tel.counter(f"batch_occupancy/{self.name}", now,
                        len(plan.prefill) + len(plan.decode),
                        replica=self.tel_name)
            if self.memory is not None:
                tel.counter(f"kv_used_blocks/{self.name}", now,
                            self.memory.total_blocks
                            - self.memory.free_blocks,
                            replica=self.tel_name)
                tel.counter(f"kv_cached_blocks/{self.name}", now,
                            self.memory.cached_blocks(),
                            replica=self.tel_name)
            straggle = bd.parts.get("ep_straggler_excess")
            if straggle is not None:
                tel.counter(f"ep_straggler_excess_s/{self.name}", now,
                            straggle, replica=self.tel_name)
        self.engine.after(t, EV.BATCH_DONE,
                          lambda ev, epoch=self._epoch:
                          self._batch_done(plan, epoch),
                          replica=self.name, dur=t,
                          n_prefill=len(plan.prefill), n_decode=len(plan.decode))

    def _batch_done(self, plan: BatchPlan, epoch: int = -1) -> None:
        if epoch != -1 and epoch != self._epoch:
            # the replica failed while this batch was in flight: its work is
            # lost and its requests were re-routed — drop the stale event
            return
        now = self.engine.now
        self.busy = False
        freed = False
        for r, chunk in plan.prefill:
            r.prefill_progress += chunk
            self.stats["prefill_tokens"] += chunk
            if r.prefill_progress >= r.prefill_total:
                self.waiting.remove(r)
                r.to(RState.PREFILL_COMPLETE, now)
                if r.restore_pending:
                    # recompute restore: the context (incl. every generated
                    # token) is rebuilt — no new token is emitted
                    r.restore_pending = False
                else:
                    # prefill emits the first token
                    r.generated += 1
                    self.stats["tokens"] += 1
                    if r.first_token_time is None:
                        r.first_token_time = now
                    self.hooks.token_generated(r, self, now)
                if self.role == "colocated":
                    if (self.memory is not None
                            and not self.memory.grow(r.rid, r.context_len)
                            and not self._resolve_oom(r)):
                        continue   # r was preempted; restore path owns it
                    r.to(RState.QUEUED_DECODE, now)
                    self.running.append(r)
                else:
                    self.hooks.prefill_complete(r, self)
            else:
                r.to(RState.QUEUED_PREFILL, now)  # chunked: back to queue
        for r in plan.decode:
            if r.state not in (RState.DECODING, RState.QUEUED_DECODE):
                continue   # evicted by an earlier OOM this step (already
                           # PREEMPTED, or re-queued for recompute); its
                           # token is discarded and recomputed on restore
            r.generated += 1
            self.stats["tokens"] += 1
            self.hooks.token_generated(r, self, now)
            if r.done:
                self.running.remove(r)
                r.to(RState.COMPLETE, now)
                r.finish_time = now
                if self.memory is not None:
                    self.memory.free(r.rid)
                    freed = True
                self.hooks.request_complete(r, self)
                continue
            if (self.memory is not None
                    and not self.memory.grow(r.rid, r.context_len)):
                self._resolve_oom(r)
        if freed:
            self._try_swap_in()
            self.hooks.memory_available(self.cluster, self)
        self.kick()

    # ----------------------------------------------------------- preemption --
    def _pick_victim(self, exclude: Request) -> Optional[Request]:
        """Lowest-priority resident: the latest-arrived decoding request
        (vLLM's preemption order), never the one we are growing."""
        candidates = [v for v in self.running
                      if v is not exclude
                      and v.state in (RState.DECODING, RState.QUEUED_DECODE)]
        if not candidates:
            return None
        return max(candidates, key=lambda v: (v.arrival, v.rid))

    def _resolve_oom(self, r: Request) -> bool:
        """Decode OOM: evict lowest-priority residents until r's KV fits.

        Returns True when r keeps its residency; False when r itself had
        to be preempted (no other victim remained and even the watermark
        reserve could not absorb the growth).
        """
        while not self.memory.grow(r.rid, r.context_len):
            victim = self._pick_victim(exclude=r)
            if victim is None:
                # r is the only resident: dip into the reserve before
                # giving up — preempting it could never make progress
                if self.memory.grow(r.rid, r.context_len,
                                    ignore_watermark=True):
                    return True
                self._preempt(r)
                return False
            self._preempt(victim)
        return True

    def _preempt(self, r: Request) -> None:
        now = self.engine.now
        if self.memory.blocks_for(r.prompt_len + r.output_len) \
                > self.memory.total_blocks:
            # restoring could never succeed: the request's maximum context
            # exceeds the whole pool — fail loudly instead of cycling
            # preempt/readmit forever or silently stranding the request
            raise RuntimeError(
                f"replica {self.name}: request {r.rid} needs "
                f"{self.memory.blocks_for(r.prompt_len + r.output_len)} KV "
                f"blocks for its full context but the pool has only "
                f"{self.memory.total_blocks}; raise memory capacity "
                f"(capacity_frac) or shorten the workload")
        swap = self.memory.preemption == "swap"
        if r in self.running:
            self.running.remove(r)
        # recompute drops the KV; only the declared shared prefix stays
        # resident (ref-counted cache, full_extent=False).  A swap moves
        # the WHOLE KV to host, so the device must not also fold it into
        # the prefix cache (that would hold the same bytes twice once
        # swap-in re-reserves them)
        self.memory.free(r.rid, insert=not swap, full_extent=False)
        r.to(RState.PREEMPTED, now)
        r.preemptions += 1
        self.stats["preemptions"] = self.stats.get("preemptions", 0) + 1
        if self.telemetry is not None:
            self.telemetry.span("preempt", r.rid, now, now,
                                replica=self.tel_name,
                                mode="swap" if swap else "recompute")
        if swap:
            dt = self.memory.swap_time(r.context_len)
            self.stats["swap_outs"] = self.stats.get("swap_outs", 0) + 1
            self.stats["swap_time_s"] = \
                self.stats.get("swap_time_s", 0.0) + dt
            self._swapping_out.append(r)
            if self.telemetry is not None:
                self.telemetry.span("swap_out", r.rid, now, now + dt,
                                    replica=self.tel_name,
                                    tokens=r.context_len)
            self.engine.after(dt, EV.SWAP_OUT_DONE,
                              lambda ev, r=r, epoch=self._epoch:
                              self._swap_out_done(r, epoch),
                              rid=r.rid, replica=self.name)
        else:  # recompute: KV is gone; re-prefill through an entry cluster
            r.begin_recompute(now)
            self.hooks.preempted(r, self)

    def _swap_out_done(self, r: Request, epoch: int) -> None:
        if epoch != self._epoch:
            return   # replica failed mid-swap; fail() re-routed the request
        self._swapping_out.remove(r)
        self.swapped.append(r)
        self._try_swap_in()

    def _try_swap_in(self) -> None:
        """Restore swapped-out requests (oldest first) as memory allows."""
        if not self.swapped or self.memory is None:
            return
        still: List[Request] = []
        for r in sorted(self.swapped, key=lambda r: (r.arrival, r.rid)):
            if self.memory.admit(r.rid, r.context_len,
                                 max_tokens=r.prompt_len + r.output_len):
                dt = self.memory.swap_time(r.context_len)
                self.stats["swap_ins"] = self.stats.get("swap_ins", 0) + 1
                self.stats["swap_time_s"] = \
                    self.stats.get("swap_time_s", 0.0) + dt
                self._swapping_in.append(r)
                if self.telemetry is not None:
                    self.telemetry.span(
                        "swap_in", r.rid, self.engine.now,
                        self.engine.now + dt, replica=self.tel_name,
                        tokens=r.context_len)
                self.engine.after(dt, EV.SWAP_IN_DONE,
                                  lambda ev, r=r, epoch=self._epoch:
                                  self._swap_in_done(r, epoch),
                                  rid=r.rid, replica=self.name)
            else:
                still.append(r)
        self.swapped = still

    def _swap_in_done(self, r: Request, epoch: int) -> None:
        if epoch != self._epoch:
            return
        self._swapping_in.remove(r)
        r.to(RState.QUEUED_DECODE, self.engine.now)
        self.running.append(r)
        self.kick()

    # ------------------------------------------------------------ failures --
    def fail(self, downtime: float) -> List[Request]:
        """Replica failure: running work is lost and must be re-routed."""
        self.failed = True
        self._epoch += 1      # invalidate any in-flight BATCH_DONE/swap
        self.busy = False
        lost = (self.waiting + self.running + self.swapped
                + self._swapping_out + self._swapping_in)
        self.waiting, self.running = [], []
        self.swapped, self._swapping_out, self._swapping_in = [], [], []
        if self.memory is not None:
            for r in lost:
                self.memory.free(r.rid, insert=False)
        self.engine.after(downtime, EV.REPLICA_RECOVERED,
                          lambda ev: self._recover(), replica=self.name)
        return lost

    def _recover(self) -> None:
        self.failed = False
        self.kick()

    # -------------------------------------------------------------- state --
    def load(self) -> float:
        mem = self.memory.utilization if self.memory is not None else 0.0
        return len(self.waiting) + len(self.running) + mem


class ClusterWorker:
    """A pool of replicas with a cluster-level scheduler."""

    def __init__(self, name: str, role: str, replicas: List[ReplicaWorker]):
        self.name = name
        self.role = role
        self.replicas = replicas
        for r in replicas:
            r.cluster = self

    # -- ClusterScheduler duties -------------------------------------------
    def route(self, r: Request) -> ReplicaWorker:
        healthy = [w for w in self.replicas if not w.failed and w.active]
        if not healthy:
            raise RuntimeError(f"cluster {self.name}: no healthy replicas")
        w = min(healthy, key=lambda w: (w.load(), w.name))
        return w

    def replica_with_memory(self, r: Request) -> Optional[ReplicaWorker]:
        """For pull-based KV transfer: who can host this request's KV?"""
        best, best_load = None, None
        for w in self.replicas:
            if w.failed or not w.active or w.memory is None:
                continue
            if w.memory.can_admit(r.context_len,
                                  max_tokens=r.prompt_len + r.output_len):
                l = w.load()
                if best is None or l < best_load:
                    best, best_load = w, l
        return best

    def active_replicas(self) -> List[ReplicaWorker]:
        return [w for w in self.replicas if w.active and not w.failed]

    def queue_depth(self) -> int:
        """Outstanding work resident in this pool (waiting + running)."""
        return sum(len(w.waiting) + len(w.running) for w in self.replicas)

    def utilization(self, now: float) -> float:
        if not self.replicas or now <= 0:
            return 0.0
        return sum(w.stats["busy_time"] for w in self.replicas) / (
            now * len(self.replicas))
