"""Observability subsystem: spans, counters, attribution, sinks.

Covers the PR's acceptance criteria: obs-off runs are untouched (the
golden suite pins that side), obs-on runs do not perturb any simulated
metric, per-request attribution sums to e2e (property-tested), the
chrome export is Perfetto-loadable with a pinned pid/tid map, the JSONL
span log round-trips, and a fleet run with obs enabled produces ONE
merged trace with instances as processes.
"""
import json
import os
from pathlib import Path

import pytest

from repro.api import SimSpec, run
from repro.obs import (
    CounterBoard, SPAN_CATEGORY, Span, Telemetry, attribution_for,
    engine_events_to_chrome, read_spans_jsonl, render_summary, run_traced,
    write_chrome_trace, write_spans_jsonl,
)
from repro.obs.sinks import chrome_trace_events

GOLDEN_DIR = Path(__file__).parent / "golden"

# burst arrivals so queue_wait spans (and the "sim" request track) are
# guaranteed to exist in the pinned trace fixture
TINY_PD = {
    "name": "obs-tiny-pd",
    "model": {"name": "qwen2-7b", "smoke": True},
    "topology": {"preset": "pd", "n_prefill": 1, "n_decode": 1},
    "workload": {"n_requests": 12, "arrival": "burst", "burst_size": 12,
                 "burst_period": 1.0, "prompt_mean": 4096,
                 "output_mean": 16, "seed": 7},
    "seed": 7,
}


def _tiny_pd():
    return SimSpec.from_dict(TINY_PD)


# ---------------------------------------------------------------- gating --
@pytest.mark.parametrize("preset", ["colocated", "pd_disagg", "memory_pd"])
def test_obs_on_does_not_perturb_summary(preset):
    """Tracing is read-only: every simulated metric is bit-identical
    with and without the recorder attached (the golden suite separately
    pins obs-off == pre-observability)."""
    from test_golden import SPECS
    rep_off = run(SimSpec.from_dict(SPECS[preset]))
    rep_on, tel = run_traced(SimSpec.from_dict(SPECS[preset]))
    common = {k: v for k, v in rep_on.summary.items()
              if not k.startswith(("attribution_", "obs_"))}
    assert common == rep_off.summary
    assert len(tel.records) == rep_off.summary["n_completed"]


def test_obs_off_spec_serializes_like_pre_obs_spec():
    spec = _tiny_pd()
    assert "obs" not in spec.to_dict()
    on = spec.with_(obs={"enabled": True})
    assert on.spec_hash() != spec.spec_hash()
    # dropping the section restores the exact pre-obs hash
    d = on.to_dict()
    d.pop("obs")
    assert SimSpec.from_dict(d).spec_hash() == spec.spec_hash()


def test_summary_obs_keys_only_when_enabled():
    rep_off = run(_tiny_pd())
    assert not any(k.startswith(("attribution_", "obs_"))
                   for k in rep_off.summary)
    rep_on, _ = run_traced(_tiny_pd())
    for k in ("attribution_queue_frac", "attribution_compute_frac",
              "attribution_comm_frac", "attribution_preempt_frac",
              "attribution_stall_frac", "obs_spans", "obs_dropped_spans",
              "obs_counter_series"):
        assert k in rep_on.summary
    fracs = [v for k, v in rep_on.summary.items()
             if k.startswith("attribution_")]
    assert abs(sum(fracs) - 1.0) < 1e-9


# ----------------------------------------------------------- attribution --
def test_attribution_components_sum_to_e2e_for_real_run():
    _, tel = run_traced(_tiny_pd())
    assert tel.records
    for rec in tel.records:
        assert abs(sum(rec.attribution.values()) - rec.e2e) < 1e-6
        assert all(v >= -1e-12 for v in rec.attribution.values())


def test_attribution_priority_and_stall():
    # compute over comm over queue on overlap; remainder is stall
    spans = [
        Span("queue_wait", 0, 0.0, 4.0),
        Span("kv_transfer", 0, 2.0, 6.0, "d0"),
        Span("prefill_chunk", 0, 3.0, 5.0, "d0"),
    ]
    a = attribution_for(spans, 0.0, 10.0)
    assert a["queue_s"] == pytest.approx(2.0)    # [0,2) unshadowed
    assert a["comm_s"] == pytest.approx(2.0)     # [2,3) + [5,6)
    assert a["compute_s"] == pytest.approx(2.0)  # [3,5)
    assert a["preempt_s"] == 0.0
    assert a["stall_s"] == pytest.approx(4.0)    # [6,10)
    assert sum(a.values()) == pytest.approx(10.0)


def test_attribution_clips_to_window():
    spans = [Span("prefill_chunk", 0, -5.0, 20.0, "d0")]
    a = attribution_for(spans, 1.0, 3.0)
    assert a["compute_s"] == pytest.approx(2.0)
    assert a["stall_s"] == 0.0


_KINDS = sorted(k for k, c in SPAN_CATEGORY.items() if c is not None)

try:                      # keep the rest of this module runnable without it
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    _span_st = st.tuples(
        st.sampled_from(_KINDS),
        st.floats(min_value=-10.0, max_value=100.0, allow_nan=False,
                  allow_infinity=False),
        st.floats(min_value=0.0, max_value=50.0, allow_nan=False,
                  allow_infinity=False))

    @settings(max_examples=200, deadline=None)
    @given(st.lists(_span_st, max_size=25),
           st.floats(min_value=0.0, max_value=30.0, allow_nan=False,
                     allow_infinity=False),
           st.floats(min_value=0.0, max_value=120.0, allow_nan=False,
                     allow_infinity=False))
    def test_attribution_sums_to_e2e_property(span_data, arrival, dur):
        finish = arrival + dur
        spans = [Span(kind, 0, s, s + d, "w") for kind, s, d in span_data]
        a = attribution_for(spans, arrival, finish)
        assert abs(sum(a.values()) - (finish - arrival)) < 1e-6
        assert all(v >= 0.0 for v in a.values())
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_attribution_sums_to_e2e_property():
        pass


# -------------------------------------------------------------- recorder --
def test_decode_spans_coalesce():
    tel = Telemetry()
    for i in range(5):
        tel.compute_span("decode", 3, i * 0.1, (i + 1) * 0.1, "d0")
    assert len(tel.spans) == 1
    s = tel.spans[0]
    assert s.meta["epochs"] == 5
    assert s.start == 0.0 and s.end == pytest.approx(0.5)
    # a gap starts a fresh span
    tel.compute_span("decode", 3, 1.0, 1.1, "d0")
    assert len(tel.spans) == 2


def test_span_cap_counts_drops():
    tel = Telemetry(max_spans=3)
    for i in range(10):
        tel.span("prefill_chunk", i, 0.0, 1.0, replica="p0")
    assert len(tel.spans) == 3
    assert tel.dropped_spans == 7
    assert tel.summary_fields()["obs_dropped_spans"] == 7


def test_counterboard_bounded_and_peak_preserving():
    cb = CounterBoard(max_points=32)
    for i in range(100_000):
        cb.sample("x", float(i), 1.0 if i != 54_321 else 999.0)
    pts = cb.series("x")
    assert len(pts) <= 64          # 2 * max_points
    assert pts[0][0] == 0.0        # first timestamp survives
    assert max(v for _, v in pts) == 999.0   # the spike survives
    ts = [t for t, _ in pts]
    assert ts == sorted(ts)


# ----------------------------------------------------------------- sinks --
def test_chrome_trace_matches_golden_structure():
    """Pinned trace fixture on the tiny PD spec: pid/tid naming and the
    per-phase event counts must not drift silently."""
    _, tel = run_traced(_tiny_pd())
    evs = chrome_trace_events(tel)
    pid_map = {e["args"]["name"]: e["pid"] for e in evs
               if e["ph"] == "M" and e["name"] == "process_name"}
    tid_map = {f'{e["pid"]}:{e["tid"]}': e["args"]["name"] for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"}
    n_by_phase = {}
    for e in evs:
        n_by_phase[e["ph"]] = n_by_phase.get(e["ph"], 0) + 1
    payload = {"pid_map": pid_map, "tid_map": tid_map,
               "n_by_phase": n_by_phase}
    path = GOLDEN_DIR / "obs_trace_pd.json"
    if os.environ.get("REPRO_UPDATE_GOLDENS"):
        path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        pytest.skip(f"golden updated: {path}")
    assert path.exists(), (f"missing golden fixture {path}; generate with "
                           f"REPRO_UPDATE_GOLDENS=1")
    assert json.loads(path.read_text()) == payload


def test_chrome_trace_valid_and_monotone(tmp_path):
    _, tel = run_traced(_tiny_pd())
    out = tmp_path / "t.trace.json"
    write_chrome_trace(tel, str(out))
    data = json.loads(out.read_text())     # strict JSON
    evs = data["traceEvents"]
    body = [e for e in evs if e["ph"] != "M"]
    assert body
    ts = [e["ts"] for e in body]
    assert ts == sorted(ts)
    assert all(t >= 0 for t in ts)
    assert all(e["dur"] >= 0 for e in body if e["ph"] == "X")
    # metadata precedes the body
    assert evs[0]["ph"] == "M"
    assert any(e["ph"] == "C" for e in body)           # counter tracks


def test_spans_jsonl_roundtrip(tmp_path):
    _, tel = run_traced(_tiny_pd())
    out = tmp_path / "t.spans.jsonl"
    write_spans_jsonl(tel, str(out))
    back = read_spans_jsonl(str(out))
    assert back["header"]["version"] == 1
    assert back["header"]["n_spans"] == len(tel.spans)
    assert len(back["spans"]) == len(tel.spans)
    for orig, rt in zip(tel.spans, back["spans"]):
        assert (rt.kind, rt.rid, rt.replica) == \
            (orig.kind, orig.rid, orig.replica)
        assert rt.start == orig.start and rt.end == orig.end
    assert len(back["requests"]) == len(tel.records)
    for req in back["requests"]:
        assert set(req["attribution"]) == {
            "queue_s", "compute_s", "comm_s", "preempt_s", "stall_s"}


def test_render_summary_lists_slowest():
    _, tel = run_traced(_tiny_pd())
    text = render_summary(tel, top_n=3)
    assert "top 3 slowest" in text
    worst = tel.slowest(1)[0]
    assert f"rid={worst.rid}" in text


# ------------------------------------------------- engine-trace shim fix --
def test_engine_events_to_chrome_clamps_negative_ts():
    evs = [
        (0.5, "batch_done", {"dur": 2.0, "replica": "w0", "n_prefill": 1,
                             "n_decode": 0}),
        (1.0, "kv_transfer_done", {"dur": 0.25}),   # dur honoured off-batch
        (0.1, "request_arrival", {"rid": 3}),
    ]
    out = engine_events_to_chrome(evs)
    assert all(e["ts"] >= 0 for e in out)
    ts = [e["ts"] for e in out]
    assert ts == sorted(ts)
    batch = next(e for e in out if e["name"].startswith("batch "))
    assert batch["ts"] == 0.0 and batch["dur"] == pytest.approx(0.5e6)
    kv = next(e for e in out if e["name"] == "kv_transfer_done")
    assert kv["ph"] == "X" and kv["dur"] == pytest.approx(0.25e6)


def test_event_trace_to_chrome_shim(tmp_path):
    from repro.core.events import EV
    from repro.core.trace import EventTrace
    tr = EventTrace(capacity=16)

    class _Ev:
        def __init__(self, time, kind, data):
            self.time, self.kind, self.data = time, kind, data

    tr(_Ev(0.2, EV.BATCH_DONE, {"dur": 1.0}))
    tr(_Ev(0.4, EV.TOKEN_GENERATED, {}))
    out = tmp_path / "shim.json"
    tr.to_chrome_trace(str(out))
    data = json.loads(out.read_text())
    assert all(e["ts"] >= 0 for e in data["traceEvents"])


# ----------------------------------------------------------------- fleet --
def test_fleet_trace_merges_instances_as_processes(tmp_path):
    from test_golden import SPECS
    spec = SimSpec.from_dict(SPECS["fleet_pd"])
    rep, tel = run_traced(spec)
    insts = {rec.instance for rec in tel.records}
    assert len(insts) > 1                  # work landed on several instances
    out = tmp_path / "fleet.trace.json"
    write_chrome_trace(tel, str(out))
    evs = json.loads(out.read_text())["traceEvents"]
    pnames = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert insts <= pnames                 # one process per instance
    cnames = {e["name"] for e in evs if e["ph"] == "C"}
    assert "fleet_outstanding" in cnames
    assert "fleet_dollars_per_hour" in cnames
    # per-instance counters are namespaced: no two instances share a series
    for inst in insts:
        assert any(n.startswith(f"{inst}/") for n in cnames)
    body = [e for e in evs if e["ph"] != "M"]
    ts = [e["ts"] for e in body]
    assert ts == sorted(ts) and all(t >= 0 for t in ts)


def test_af_ep_spans_trace_ranks():
    spec = SimSpec.from_dict({
        "name": "obs-tiny-af",
        "model": {"name": "mixtral-8x7b", "smoke": True},
        "topology": {"preset": "af", "n_prefill": 1, "n_decode": 1,
                     "m": 2, "ffn_ep": 4},
        "workload": {"n_requests": 4, "rate": 20.0, "prompt_mean": 128,
                     "output_mean": 8, "seed": 5},
        "obs": {"enabled": True, "ep_spans": True},
        "seed": 5,
    })
    rep, tel = run_traced(spec)
    kinds = {s.kind for s in tel.spans}
    assert {"ep_dispatch", "ep_rank", "ep_combine"} <= kinds
    ranks = {s.meta["rank"] for s in tel.spans if s.kind == "ep_rank"}
    assert len(ranks) == 4                 # every EP rank traced
    # rank spans are absolute sim time within their batch window
    for s in tel.spans:
        if s.kind == "ep_rank":
            assert s.end >= s.start >= 0.0
    evs = chrome_trace_events(tel)
    tids = {e["args"]["name"] for e in evs
            if e["ph"] == "M" and e["name"] == "thread_name"}
    assert any(":ep" in t for t in tids)   # ranks as threads
