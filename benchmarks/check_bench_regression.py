"""Bench-regression gate: fail CI when fleet events/s OR simulator
fidelity regresses.

Two gate modes, combinable:

- perf (``--results``): compares a fresh ``bench_sim_scale.py --json``
  result file against the last entry of the checked-in trajectory
  (repo-root ``BENCH_sim_scale.json``) and exits non-zero if the watched
  cell's ``events_per_s`` dropped more than ``--tolerance`` (default
  20%) below the baseline.  ``--cell`` accepts a dotted path into the
  results (``fleet``, ``fabric``, ``cells.af``); for trajectory entries
  that predate the ``events_per_s`` field it is derived from
  ``events / wall_s``.
- fidelity (``--fidelity-results``): compares a fresh calibration entry
  (``python -m repro calibrate --entry-out``) against the checked-in
  ``FIDELITY.json`` trajectory and fails if any operator's fitted MAPE
  grew more than ``--fidelity-tolerance`` (default 20%, relative).
- overhead (``--overhead-against``): compares ``--cell`` against a
  *sibling* cell within the same fresh results file — no trajectory
  involved — and fails if it is more than ``--max-overhead`` slower
  (fractional events/s drop).  Used to bound the cost of opt-in
  features, e.g. ``--cell cells.af_traced --overhead-against cells.af``
  bounds full observability (spans + counters + EP-rank spans) relative
  to the identical untraced run.

Baseline selection prefers the most recent trajectory entry measured
under a comparable configuration; if none matches it falls back to the
most recent entry at all and says so — cross-config comparison is
meaningful, just noisier.
"""
from __future__ import annotations

import argparse
import json
import sys

COMPARABLE_KEYS = ("n_requests", "instances", "engine_mode",
                   "predictor_backend")


def get_cell(entry: dict, cell: str):
    """Resolve a possibly-dotted cell path (``fleet``, ``cells.af``)."""
    cur = entry
    for part in cell.split("."):
        if not isinstance(cur, dict):
            return None
        cur = cur.get(part)
    return cur if isinstance(cur, dict) else None


def cell_events_per_s(c: dict):
    """events_per_s, derived from events/wall_s for older trajectory
    entries that predate the field."""
    if "events_per_s" in c:
        return c["events_per_s"]
    ev, wall = c.get("events"), c.get("wall_s")
    if ev is not None and wall:
        return ev / wall
    return None


def _cell_cfg(entry: dict, cell: str) -> dict:
    c = get_cell(entry, cell) or {}
    cfg = {k: c.get(k) for k in COMPARABLE_KEYS}
    cfg["smoke"] = entry.get("smoke")
    return cfg


def pick_baseline(trajectory: list, cell: str, fresh_cfg: dict):
    """Most recent comparable entry, else most recent with the cell."""
    with_cell = [e for e in trajectory
                 if (c := get_cell(e, cell)) is not None
                 and cell_events_per_s(c) is not None]
    if not with_cell:
        return None, False
    for e in reversed(with_cell):
        if _cell_cfg(e, cell) == fresh_cfg:
            return e, True
    return with_cell[-1], False


def check_fidelity(results_path: str, trajectory_path: str,
                   tolerance: float) -> int:
    from repro.calib.fidelity import (
        check_fidelity_regression, load_trajectory,
    )
    with open(results_path) as f:
        fresh = json.load(f)
    ok, lines = check_fidelity_regression(fresh,
                                          load_trajectory(trajectory_path),
                                          tolerance=tolerance)
    for line in lines:
        print(line)
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=None,
                    help="fresh bench_sim_scale.py --json output")
    ap.add_argument("--trajectory", default="BENCH_sim_scale.json",
                    help="checked-in cross-PR trajectory file")
    ap.add_argument("--cell", default="fleet",
                    help="which result cell to gate on")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="max allowed fractional drop in events_per_s")
    ap.add_argument("--fidelity-results", default=None,
                    help="fresh fidelity entry (repro calibrate "
                         "--entry-out output)")
    ap.add_argument("--fidelity-trajectory", default="FIDELITY.json",
                    help="checked-in fidelity trajectory file")
    ap.add_argument("--fidelity-tolerance", type=float, default=0.2,
                    help="max allowed relative fitted-MAPE increase")
    ap.add_argument("--overhead-against", default=None, metavar="CELL",
                    help="compare --cell against this sibling cell inside "
                         "the same fresh results (overhead gate; skips "
                         "the trajectory comparison)")
    ap.add_argument("--max-overhead", type=float, default=0.9,
                    help="max allowed fractional events_per_s drop of "
                         "--cell relative to --overhead-against")
    args = ap.parse_args(argv)

    if args.results is None and args.fidelity_results is None:
        ap.error("need --results and/or --fidelity-results")
    rc = 0
    if args.fidelity_results is not None:
        rc |= check_fidelity(args.fidelity_results,
                             args.fidelity_trajectory,
                             args.fidelity_tolerance)
    if args.results is None:
        return rc

    with open(args.results) as f:
        fresh = json.load(f)
    cell = get_cell(fresh, args.cell)
    fresh_eps = cell_events_per_s(cell) if cell is not None else None
    if fresh_eps is None:
        print(f"gate: results file has no '{args.cell}' cell with "
              f"events_per_s — nothing to gate")
        return 1

    if args.overhead_against is not None:
        against = get_cell(fresh, args.overhead_against)
        against_eps = (cell_events_per_s(against)
                       if against is not None else None)
        if against_eps is None:
            print(f"gate: results file has no '{args.overhead_against}' "
                  f"cell with events_per_s — nothing to compare against")
            return 1
        floor = (1.0 - args.max_overhead) * against_eps
        drop = 1.0 - fresh_eps / against_eps
        print(f"gate: overhead {args.cell} {fresh_eps:,.0f} ev/s vs "
              f"{args.overhead_against} {against_eps:,.0f} ev/s "
              f"(drop {drop:.1%}, floor {floor:,.0f}, "
              f"max {args.max_overhead:.0%})")
        if fresh_eps < floor:
            print(f"gate: FAIL — {args.cell} is {drop:.1%} slower than "
                  f"{args.overhead_against} "
                  f"(> {args.max_overhead:.0%} allowed)")
            return 1
        print("gate: OK")
        return rc

    with open(args.trajectory) as f:
        traj = json.load(f).get("trajectory", [])
    fresh_cfg = _cell_cfg(fresh, args.cell)
    base, comparable = pick_baseline(traj, args.cell, fresh_cfg)
    if base is None:
        print(f"gate: no trajectory entry has cell '{args.cell}' — "
              f"pass (nothing to compare against)")
        return rc

    base_eps = cell_events_per_s(get_cell(base, args.cell))
    floor = (1.0 - args.tolerance) * base_eps
    note = "" if comparable else (
        "  [non-comparable config: "
        f"baseline={_cell_cfg(base, args.cell)} fresh={fresh_cfg}]")
    print(f"gate: cell={args.cell} baseline={base.get('label', '?')} "
          f"{base_eps:,.0f} ev/s -> fresh {fresh_eps:,.0f} ev/s "
          f"(floor {floor:,.0f}, tolerance {args.tolerance:.0%}){note}")
    if fresh_eps < floor:
        print(f"gate: FAIL — events_per_s dropped "
              f"{1.0 - fresh_eps / base_eps:.1%} "
              f"(> {args.tolerance:.0%} allowed)")
        return 1
    print("gate: OK")
    return rc


if __name__ == "__main__":
    sys.exit(main())
