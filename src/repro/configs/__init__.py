"""Architecture config registry.

``get_config("yi-9b")`` returns the full assigned config;
``get_config("yi-9b", smoke=True)`` returns the reduced same-family variant.
"""
from __future__ import annotations

from repro.configs.base import (
    ModelConfig, MoEConfig, ShapeConfig, SHAPES, SMOKE_SHAPE,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    reduced, shape_applicable,
)

from repro.configs import (  # noqa: E402
    yi_9b, qwen3_32b, gemma2_27b, qwen3_8b, kimi_k2, mixtral_8x7b,
    rwkv6_1b6, pixtral_12b, seamless_m4t_v2, recurrentgemma_2b, qwen2_7b,
)

# The 10 assigned architectures (order matches the assignment table).
ASSIGNED = (
    yi_9b.CONFIG,
    qwen3_32b.CONFIG,
    gemma2_27b.CONFIG,
    qwen3_8b.CONFIG,
    kimi_k2.CONFIG,
    mixtral_8x7b.CONFIG,
    rwkv6_1b6.CONFIG,
    pixtral_12b.CONFIG,
    seamless_m4t_v2.CONFIG,
    recurrentgemma_2b.CONFIG,
)

REGISTRY = {c.name: c for c in ASSIGNED + (qwen2_7b.CONFIG,)}
ARCH_IDS = [c.name for c in ASSIGNED]


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    cfg = REGISTRY[name]
    return reduced(cfg) if smoke else cfg


__all__ = [
    "ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES", "SMOKE_SHAPE",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "reduced", "shape_applicable", "REGISTRY", "ARCH_IDS", "ASSIGNED",
    "get_config",
]
