"""Shared network fabric: contention pricing for inter-cluster traffic.

Until now every inter-cluster byte was priced on an isolated point-to-point
``LinkSpec`` (or the flat ``inter_node_bw``): two transfers into the same
decode pool never contended, and a collective's cost ignored the topology
it ran over.  This module adds the missing shared medium:

- ``Fabric`` — the runtime object.  Each cluster attaches a per-NIC uplink
  into the fabric; concurrent transfers sharing an uplink split its
  effective bandwidth processor-sharing style and are *re-priced* at every
  transfer start/finish event in the engine (epoch-guarded rescheduling —
  the event heap has no cancel).  A flow's instantaneous rate is

      min(per-flow link cap,
          tx_uplink / oversubscription / n_active_tx,
          rx_uplink / oversubscription / n_active_rx)

  which is deliberately *not* max-min fair (a capped flow's unused share is
  not redistributed): the math stays hand-computable and monotone — adding
  a concurrent flow or raising oversubscription never speeds anything up.

- ``FabricOps`` — an OperatorModelSet wrapper that re-prices the
  *inter-node* collectives topology-aware over the fabric (ring/tree
  all-reduce with per-hop latency, pairwise all-to-all, and the
  MegaScale-style M2N dispatch/combine) while delegating all compute
  operators to the wrapped model set, so refined/calibrated operator
  models keep working unchanged.

``fabric: none`` (the default everywhere) never constructs either object —
existing reports stay bit-identical.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.engine import SimEngine
from repro.core.events import EV
from repro.core.opmodels.analytical import OperatorModelSet

COLLECTIVES = ("ring", "tree")
FABRIC_MODES = ("none", "shared")


@dataclass(frozen=True)
class FabricConfig:
    """Resolved fabric parameters (built from ``api.spec.FabricSpec``)."""
    mode: str = "none"              # "none" | "shared"
    oversubscription: float = 1.0   # uplink sharing factor (>= 1 physical)
    latency_s: float = 0.0          # per-hop fabric latency
    collective: str = "ring"        # inter-node all-reduce algorithm
    # per-NIC uplink into the fabric; None -> each cluster's inter_node_bw
    uplink_bw: Optional[float] = None

    def validate(self) -> None:
        if self.mode not in FABRIC_MODES:
            raise ValueError(f"fabric mode must be one of {FABRIC_MODES}, "
                             f"got {self.mode!r}")
        if self.oversubscription <= 0:
            raise ValueError(f"fabric oversubscription must be > 0, got "
                             f"{self.oversubscription}")
        if self.latency_s < 0:
            raise ValueError(f"fabric latency_s must be >= 0, got "
                             f"{self.latency_s}")
        if self.collective not in COLLECTIVES:
            raise ValueError(f"fabric collective must be one of "
                             f"{COLLECTIVES}, got {self.collective!r}")
        if self.uplink_bw is not None and self.uplink_bw <= 0:
            raise ValueError(f"fabric uplink_bw must be > 0, got "
                             f"{self.uplink_bw}")


class _Flow:
    __slots__ = ("src", "dst", "remaining", "cap", "rate", "epoch",
                 "done", "t_submit", "nbytes")

    def __init__(self, src: Optional[str], dst: Optional[str],
                 nbytes: float, cap: Optional[float],
                 done: Optional[Callable[[], None]], t_submit: float):
        self.src = src
        self.dst = dst
        self.nbytes = nbytes
        self.remaining = nbytes
        self.cap = cap
        self.rate = 0.0
        self.epoch = 0
        self.done = done
        self.t_submit = t_submit


class Fabric:
    """Processor-sharing contention on per-cluster uplinks.

    Clusters ``attach`` an uplink capacity; ``start_transfer`` runs a flow
    through (latency phase, then the shared-bandwidth phase).  All active
    flows are drained and re-priced whenever one joins or finishes; stale
    completion events are recognized by a per-flow epoch counter and
    ignored (the engine's heap is append-only).
    """

    def __init__(self, engine: SimEngine, config: FabricConfig):
        config.validate()
        self.engine = engine
        self.config = config
        self._uplinks: Dict[str, float] = {}     # cluster -> capacity (B/s)
        self._flows: List[_Flow] = []            # active bandwidth-phase flows
        self._t_last = 0.0                       # last drain timestamp
        self.stats = {"transfers": 0, "bytes": 0.0,
                      "uncontended_s": 0.0, "actual_s": 0.0,
                      "collective_s": 0.0, "collective_uncontended_s": 0.0}
        # observability recorder (repro.obs.Telemetry); None = fully off
        self.telemetry = None

    # ------------------------------------------------------------ topology --
    def attach(self, cluster: str, uplink_bw: float) -> None:
        """Attach a cluster's NIC uplink; effective capacity is the raw
        uplink divided by the configured oversubscription factor."""
        bw = self.config.uplink_bw if self.config.uplink_bw is not None \
            else uplink_bw
        if bw <= 0:
            raise ValueError(f"fabric uplink for cluster {cluster!r} must "
                             f"be > 0, got {bw}")
        self._uplinks[cluster] = bw / self.config.oversubscription

    def capacity(self, cluster: Optional[str]) -> float:
        """Effective uplink capacity; unattached/unknown ends (e.g. an
        external KV source) are unconstrained."""
        if cluster is None:
            return math.inf
        return self._uplinks.get(cluster, math.inf)

    # ------------------------------------------------------------ transfers --
    def start_transfer(self, src: Optional[str], dst: Optional[str],
                       nbytes: float, *, cap: Optional[float] = None,
                       latency: float = 0.0,
                       done: Optional[Callable[[], None]] = None) -> None:
        """Run one transfer over the fabric and call ``done()`` at its
        (contention-dependent) completion time.  ``cap`` is the per-flow
        point-to-point link ceiling; ``latency`` the link's base latency,
        paid (together with the fabric hop latency) before the flow enters
        the shared-bandwidth phase."""
        now = self.engine.now
        flow = _Flow(src, dst, max(nbytes, 0.0), cap, done, now)
        self.stats["transfers"] += 1
        self.stats["bytes"] += flow.nbytes
        solo = self._solo_rate(flow)
        lat = latency + self.config.latency_s
        self.stats["uncontended_s"] += lat + (
            flow.nbytes / solo if solo < math.inf else 0.0)
        if lat > 0.0:
            self.engine.after(lat, EV.KV_TRANSFER_START,
                              lambda ev, f=flow: self._join(f))
        else:
            self._join(flow)

    def _solo_rate(self, flow: _Flow) -> float:
        r = min(self.capacity(flow.src), self.capacity(flow.dst))
        if flow.cap is not None:
            r = min(r, flow.cap)
        return r

    def _join(self, flow: _Flow) -> None:
        self._drain()
        if flow.remaining <= 0.0 or self._solo_rate(flow) == math.inf:
            # zero-byte or fully unconstrained: completes immediately
            self._finish(flow)
            self._reprice()
            return
        self._flows.append(flow)
        self._reprice()

    def _drain(self) -> None:
        """Advance all active flows' progress to ``engine.now`` at their
        current rates."""
        now = self.engine.now
        dt = now - self._t_last
        if dt > 0.0:
            for f in self._flows:
                f.remaining -= f.rate * dt
        self._t_last = now

    def _reprice(self) -> None:
        """Recompute every active flow's processor-sharing rate and
        (re)schedule its completion; prior completion events go stale via
        the epoch bump."""
        n_tx: Dict[str, int] = {}
        n_rx: Dict[str, int] = {}
        for f in self._flows:
            if f.src is not None:
                n_tx[f.src] = n_tx.get(f.src, 0) + 1
            if f.dst is not None:
                n_rx[f.dst] = n_rx.get(f.dst, 0) + 1
        for f in self._flows:
            rate = min(self.capacity(f.src) / n_tx.get(f.src, 1)
                       if f.src is not None else math.inf,
                       self.capacity(f.dst) / n_rx.get(f.dst, 1)
                       if f.dst is not None else math.inf)
            if f.cap is not None:
                rate = min(rate, f.cap)
            f.rate = rate
            f.epoch += 1
            eta = f.remaining / rate if rate > 0.0 else math.inf
            if eta < math.inf:
                self.engine.after(
                    eta, EV.FABRIC_TRANSFER_DONE,
                    lambda ev, ff=f, ep=f.epoch: self._maybe_finish(ff, ep))
        if self.telemetry is not None:
            # sampled at every repricing event: per-uplink concurrent
            # flows and the resulting effective per-flow bandwidth
            now = self.engine.now
            for cl, n in sorted(n_tx.items()):
                self.telemetry.counter(f"fabric_tx_flows/{cl}", now, n)
                cap = self.capacity(cl)
                if cap < math.inf:
                    self.telemetry.counter(
                        f"fabric_tx_eff_bw_gbps/{cl}", now,
                        cap / max(n, 1) / 1e9)
            for cl, n in sorted(n_rx.items()):
                self.telemetry.counter(f"fabric_rx_flows/{cl}", now, n)
            self.telemetry.counter("fabric_in_flight", now,
                                   len(self._flows))

    def _maybe_finish(self, flow: _Flow, epoch: int) -> None:
        if flow.epoch != epoch or flow not in self._flows:
            return                      # stale completion event: re-priced
        # epoch match => no re-price happened since this completion was
        # scheduled, so the flow ran at a constant rate for exactly its
        # remaining/rate — it is done (modulo float residue)
        self._drain()
        flow.remaining = 0.0
        self._flows.remove(flow)
        self._finish(flow)
        self._reprice()

    def _finish(self, flow: _Flow) -> None:
        self.stats["actual_s"] += self.engine.now - flow.t_submit
        if flow.done is not None:
            flow.done()

    # ------------------------------------------------------------ reporting --
    def in_flight(self) -> int:
        return len(self._flows)

    def exposed_comm_s(self) -> float:
        return self.stats["actual_s"] + self.stats["collective_s"]

    def uncontended_comm_s(self) -> float:
        return (self.stats["uncontended_s"]
                + self.stats["collective_uncontended_s"])


class FabricOps(OperatorModelSet):
    """OperatorModelSet that re-prices inter-node communication over the
    fabric (oversubscribed effective bandwidth, per-hop latency,
    topology-aware ring/tree algorithms) and delegates everything else —
    all compute operators and intra-node collectives — to the wrapped
    model set, so refined/calibrated models compose."""

    def __init__(self, inner: OperatorModelSet, config: FabricConfig,
                 fabric: Optional[Fabric] = None):
        super().__init__(inner.hw)
        self.inner = inner
        self.config = config
        self.fabric = fabric            # stats sink (may be None in tests)

    # effective inter-node bandwidth after oversubscription
    @property
    def _bw(self) -> float:
        return self.hw.inter_node_bw / self.config.oversubscription

    def _account(self, actual: float, uncontended: float) -> float:
        if self.fabric is not None:
            self.fabric.stats["collective_s"] += actual
            self.fabric.stats["collective_uncontended_s"] += uncontended
        return actual

    # ---- compute: pure delegation -----------------------------------------
    def gemm(self, m, n, k, dtype_bytes=2):
        return self.inner.gemm(m, n, k, dtype_bytes)

    def attention_prefill(self, q_lens, kv_lens, n_heads, n_kv_heads,
                          head_dim, causal=True, window=0):
        return self.inner.attention_prefill(q_lens, kv_lens, n_heads,
                                            n_kv_heads, head_dim,
                                            causal=causal, window=window)

    def attention_decode(self, context_lens, n_heads, n_kv_heads, head_dim,
                         window=0):
        return self.inner.attention_decode(context_lens, n_heads,
                                           n_kv_heads, head_dim,
                                           window=window)

    def grouped_gemm(self, tokens_per_group, d_in, d_out, dtype_bytes=2):
        return self.inner.grouped_gemm(tokens_per_group, d_in, d_out,
                                       dtype_bytes)

    def membound(self, nbytes):
        return self.inner.membound(nbytes)

    # ---- collectives: fabric-priced when inter-node -----------------------
    def all_reduce(self, nbytes: float, n: int, *,
                   inter_node: bool = False) -> float:
        if not inter_node or n <= 1:
            return self.inner.all_reduce(nbytes, n, inter_node=inter_node)
        lat = self.config.latency_s
        if self.config.collective == "tree":
            # reduce up + broadcast down a binary tree: ceil(log2 n) levels
            # each way, full payload per level
            hops = 2 * math.ceil(math.log2(n))
            t = hops * (nbytes / self._bw + lat) + self.hw.op_overhead
        else:
            # ring: 2(n-1) steps of nbytes/n each, one hop latency per step
            t = (2.0 * nbytes * (n - 1) / n / self._bw
                 + 2.0 * (n - 1) * lat + self.hw.op_overhead)
        return self._account(t, self.inner.all_reduce(nbytes, n,
                                                      inter_node=True))

    def all_gather(self, nbytes: float, n: int, *,
                   inter_node: bool = False) -> float:
        if not inter_node or n <= 1:
            return self.inner.all_gather(nbytes, n, inter_node=inter_node)
        t = (nbytes * (n - 1) / n / self._bw
             + (n - 1) * self.config.latency_s + self.hw.op_overhead)
        return self._account(t, self.inner.all_gather(nbytes, n,
                                                      inter_node=True))

    def all_to_all(self, nbytes_per_device: float, n: int, *,
                   inter_node: bool = False) -> float:
        if not inter_node or n <= 1:
            return self.inner.all_to_all(nbytes_per_device, n,
                                         inter_node=inter_node)
        t = (nbytes_per_device * (n - 1) / n / self._bw
             + (n - 1) * self.config.latency_s + self.hw.op_overhead)
        return self._account(t, self.inner.all_to_all(nbytes_per_device, n,
                                                      inter_node=True))

    def p2p(self, nbytes: float, *, inter_node: bool = True) -> float:
        if not inter_node:
            return self.inner.p2p(nbytes, inter_node=False)
        t = nbytes / self._bw + self.config.latency_s + self.hw.op_overhead
        return self._account(t, self.inner.p2p(nbytes, inter_node=True))

    def m2n(self, nbytes: float, m: int, n: int, *,
            inter_node: bool = True) -> float:
        """MegaScale-style M2N dispatch/combine: ``m`` senders fan
        ``nbytes`` into ``n`` receivers.  The narrow side's NICs bottleneck
        the aggregate, so the payload crosses min(m, n) parallel uplinks."""
        if not inter_node:
            return self.inner.m2n(nbytes, m, n, inter_node=False)
        lanes = max(min(m, n), 1)
        t = (nbytes / (lanes * self._bw) + self.config.latency_s
             + self.hw.op_overhead)
        return self._account(t, self.inner.m2n(nbytes, m, n,
                                               inter_node=True))
