"""mixtral-8x7b — 8 experts top-2 MoE with sliding-window attention.
[arXiv:2401.04088; hf]

SWA(4096) bounds the decode KV window => sub-quadratic => runs long_500k.
"""
from repro.configs.base import ModelConfig, MoEConfig, ATTN_LOCAL

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    block_pattern=(ATTN_LOCAL,),
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=14336),
)
