"""PD-disaggregated serving system (DistServe/Dynamo style).

Producer (prefill cluster) and consumer (decode cluster) are specialized
pools with independent parallelism; the GlobalController mediates KV-cache
transfers under decode-side memory backpressure.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.cluster import ClusterWorker, ReplicaWorker
from repro.core.controller import GlobalController
from repro.core.engine import SimEngine
from repro.core.hardware import HardwareSpec, ParallelismConfig
from repro.core.metrics import MetricsCollector
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.policies.batching import (
    BatchingPolicy, ContinuousBatching,
)
from repro.core.policies.memory import PagedKVManager
from repro.core.predictor import ExecutionPredictor
from repro.core.workflows.colocated import SystemHandle, _kv_budget


def build_pd(cfg: ModelConfig, hw: HardwareSpec, *,
             n_prefill: int = 1, n_decode: int = 1,
             prefill_par: Optional[ParallelismConfig] = None,
             decode_par: Optional[ParallelismConfig] = None,
             prefill_policy: Optional[BatchingPolicy] = None,
             decode_policy: Optional[BatchingPolicy] = None,
             ops: Optional[OperatorModelSet] = None,
             transfer_bw: Optional[float] = None,
             routing=None, seed: int = 0) -> SystemHandle:
    engine = SimEngine()
    prefill_par = prefill_par or ParallelismConfig(tp=1)
    decode_par = decode_par or ParallelismConfig(tp=1)
    ops = ops or OperatorModelSet(hw)
    metrics = MetricsCollector()

    pred0 = ExecutionPredictor(cfg, prefill_par, hw, ops, routing=routing)
    controller = GlobalController(
        engine, mode="pd", clusters={},
        kv_bytes_per_token=pred0.kv_bytes_per_token(),
        transfer_bw=transfer_bw if transfer_bw is not None else hw.inter_node_bw,
        metrics=metrics)
    hooks = controller.hooks()

    pre_replicas = []
    for i in range(n_prefill):
        pred = ExecutionPredictor(cfg, prefill_par, hw, ops, routing=routing,
                                  seed=seed + i)
        # prefill buffer holds produced KV until the pull-based transfer
        mem = PagedKVManager(_kv_budget(cfg, hw, prefill_par, pred),
                             pred.kv_bytes_per_token())
        pre_replicas.append(ReplicaWorker(
            engine, f"prefill{i}", pred,
            prefill_policy or ContinuousBatching(max_batched_tokens=16384),
            mem, hooks, role="prefill"))
    dec_replicas = []
    for i in range(n_decode):
        pred = ExecutionPredictor(cfg, decode_par, hw, ops, routing=routing,
                                  seed=seed + 100 + i)
        mem = PagedKVManager(_kv_budget(cfg, hw, decode_par, pred),
                             pred.kv_bytes_per_token())
        dec_replicas.append(ReplicaWorker(
            engine, f"decode{i}", pred,
            decode_policy or ContinuousBatching(max_num_seqs=512),
            mem, hooks, role="decode"))

    prefill = ClusterWorker("prefill", "prefill", pre_replicas)
    decode = ClusterWorker("decode", "decode", dec_replicas)
    controller.clusters.update({"prefill": prefill, "decode": decode})
    n_dev = n_prefill * prefill_par.devices + n_decode * decode_par.devices
    return SystemHandle(engine, controller,
                        {"prefill": prefill, "decode": decode}, n_dev)
