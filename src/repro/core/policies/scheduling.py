"""Queue-ordering policies for ClusterSchedulers."""
from __future__ import annotations

from typing import List, Optional

from repro.core.request import Request


class QueuePolicy:
    name = "base"

    def order(self, queue: List[Request], now: float) -> List[Request]:
        raise NotImplementedError


class FCFS(QueuePolicy):
    name = "fcfs"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (r.arrival, r.rid))


class SJF(QueuePolicy):
    """Shortest prompt first (reduces head-of-line blocking for prefill)."""
    name = "sjf"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (r.prompt_len, r.arrival, r.rid))


class Priority(QueuePolicy):
    """External priority in request.timestamps['priority'] (lower first)."""
    name = "priority"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (r.timestamps.get("priority", 0.0),
                                            r.arrival, r.rid))


POLICIES = {p.name: p for p in (FCFS(), SJF(), Priority())}

SCHEDULERS = {c.name: c for c in (FCFS, SJF, Priority)}


def resolve_scheduler(spec) -> Optional[QueuePolicy]:
    """Uniform queue-policy argument handling (mirrors resolve_router).

    Accepts an instance, a registered name ("fcfs", "sjf", "priority"),
    a mapping ``{"name": ..., **kwargs}``, or None.
    """
    if spec is None or isinstance(spec, QueuePolicy):
        return spec
    if isinstance(spec, str):
        spec = {"name": spec}
    if isinstance(spec, dict):
        kw = dict(spec)
        name = kw.pop("name", None)
        if name not in SCHEDULERS:
            raise KeyError(f"unknown queue policy {name!r}; "
                           f"registered: {sorted(SCHEDULERS)}")
        return SCHEDULERS[name](**kw)
    raise TypeError(f"scheduler must be None, a name, a mapping, or a "
                    f"QueuePolicy; got {type(spec).__name__}")
