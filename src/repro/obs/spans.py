"""Typed request spans.

A span is one closed interval of a request's lifecycle on one resource:
a prefill chunk on a replica, a KV transfer on a link, a swap to host
memory, an EP rank's GroupedGEMM inside one decode step.  Spans carry
the replica that produced them; cluster/instance identity is resolved
through the :class:`~repro.obs.telemetry.Telemetry` registry at export
time so the hot-path record stays small.

``SPAN_CATEGORY`` maps each span kind to the latency-attribution bucket
it occupies (queue / compute / comm / preempt).  Kinds mapped to
``None`` are nested detail — EP sub-graph markers live *inside* a decode
epoch, so counting them again would double-book compute time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

# span kind -> attribution category (None = nested detail, not attributed)
SPAN_CATEGORY: Dict[str, Optional[str]] = {
    "queue_wait": "queue",
    "fleet_route": "queue",
    "prefill_chunk": "compute",
    "decode": "compute",
    "preempt": "preempt",
    "recompute_requeue": "preempt",
    "swap_out": "preempt",
    "swap_in": "preempt",
    "kv_transfer": "comm",
    "ep_dispatch": None,
    "ep_rank": None,
    "ep_combine": None,
}

# category priority for the attribution sweep: when intervals overlap
# (a prefill chunk hiding a KV transfer), the highest-priority category
# owns the overlap
CATEGORY_PRIORITY = ("compute", "comm", "preempt", "queue")


@dataclass
class Span:
    """One typed interval.  ``rid < 0`` marks request-agnostic spans
    (EP sub-graph markers belong to a batch, not a single request)."""
    kind: str
    rid: int
    start: float
    end: float
    replica: str = ""
    meta: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.end - self.start

    @property
    def category(self) -> Optional[str]:
        return SPAN_CATEGORY.get(self.kind)

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "rid": self.rid, "start": self.start,
             "end": self.end, "replica": self.replica}
        if self.meta:
            d["meta"] = self.meta
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(kind=d["kind"], rid=d["rid"], start=d["start"],
                   end=d["end"], replica=d.get("replica", ""),
                   meta=d.get("meta") or {})
