"""SimSpec: the fully serializable description of one simulation experiment.

A spec is a plain dataclass tree — model reference, topology (preset or
inline StageGraph), workload, policies (all resolved by registry name),
operator models, SLOs, fault injections, seed — that round-trips through
dict/JSON/YAML and validates at build time with actionable errors.  It is
the single declarative front door to the simulator:

    spec = SimSpec(model=ModelRef("qwen2-7b"),
                   topology=TopologySpec(preset="pd", n_prefill=1,
                                         n_decode=2),
                   workload=WorkloadSpec(n_requests=200, rate=12.0))
    report = repro.api.run(spec)

or, from YAML::

    report = repro.api.run(SimSpec.load("examples/specs/quickstart.yaml"))

Everything in a spec is data (names, numbers, lists) so specs hash
(`spec_hash`), pickle across process pools (`repro.api.sweep`), and diff
in version control.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field, fields
from typing import Any, Dict, List, Mapping, Optional, Sequence, Union

from repro.configs import REGISTRY
from repro.core.hardware import HARDWARE, HardwareSpec, LinkSpec, \
    ParallelismConfig
from repro.core.opmodels import OPMODELS
from repro.core.pipeline import (
    AF_OVERLAP_MODES, PIPELINES, PipelineConfig, resolve_pipeline,
)
from repro.core.policies.batching import resolve_batching
from repro.core.policies.memory import PREEMPTION_MODES, resolve_memory
from repro.core.policies.scheduling import resolve_scheduler
from repro.core.routing import resolve_router
from repro.core.topology import ClusterSpec, ROLES, StageGraph
from repro.workload.generator import ARRIVALS, RATE_CURVES

PRESETS = ("colocated", "pd", "af")
LENGTH_KINDS = ("fixed", "uniform", "lognormal", "bimodal")
FAULT_KINDS = ("failure", "straggler")


class SpecError(ValueError):
    """A spec failed validation; the message names the offending path."""


def _from_mapping(cls, data: Any, path: str):
    """Build dataclass ``cls`` from a mapping, rejecting unknown keys."""
    if data is None or isinstance(data, cls):
        return data
    if not isinstance(data, Mapping):
        raise SpecError(f"{path}: expected a mapping for {cls.__name__}, "
                        f"got {type(data).__name__}")
    known = {f.name for f in fields(cls)}
    unknown = sorted(set(data) - known)
    if unknown:
        raise SpecError(f"{path}: unknown field(s) {unknown}; "
                        f"known: {sorted(known)}")
    return cls(**dict(data))


def _coerce(obj: Any, kind: type, *names: str) -> None:
    """Coerce numeric fields in place (YAML 1.1 reads '2.5e10' as a str)."""
    for n in names:
        v = getattr(obj, n)
        if v is None or isinstance(v, kind):
            continue
        try:
            setattr(obj, n, kind(v))
        except (TypeError, ValueError) as e:
            raise SpecError(f"{type(obj).__name__.lower()}.{n}: expected "
                            f"{kind.__name__}, got {v!r}") from e


def _resolve_hw(hw: Union[str, HardwareSpec], path: str) -> HardwareSpec:
    if isinstance(hw, HardwareSpec):
        return hw
    if hw not in HARDWARE:
        raise SpecError(f"{path}: unknown hardware {hw!r}; "
                        f"available: {sorted(HARDWARE)}")
    return HARDWARE[hw]


# --------------------------------------------------------------- model ----
@dataclass
class ModelRef:
    """A model architecture by registry name (see ``repro.configs``)."""
    name: str = "qwen2-7b"
    smoke: bool = False      # reduced same-family variant (CI-sized)

    def validate(self) -> None:
        if self.name not in REGISTRY:
            raise SpecError(f"model.name: unknown model {self.name!r}; "
                            f"available: {sorted(REGISTRY)}")


# ------------------------------------------------------------ topology ----
@dataclass
class FabricSpec:
    """Shared network fabric (see ``repro.core.fabric``).

    ``mode: none`` (the default) keeps the legacy isolated point-to-point
    link pricing bit-identically; ``mode: shared`` attaches every
    cluster's NIC uplink to a common fabric where concurrent transfers
    split effective bandwidth processor-sharing style and inter-node
    collectives are re-priced topology-aware (``collective``: ring or
    tree, with ``latency_s`` per hop).  ``oversubscription`` divides every
    uplink's capacity (2.0 = a 2:1 oversubscribed spine);
    ``uplink_bw`` overrides the per-cluster uplink (default: each
    cluster's ``inter_node_bw``).
    """
    mode: str = "none"
    oversubscription: float = 1.0
    latency_s: float = 0.0
    collective: str = "ring"
    uplink_bw: Optional[float] = None

    def __post_init__(self) -> None:
        _coerce(self, float, "oversubscription", "latency_s", "uplink_bw")

    def to_config(self):
        from repro.core.fabric import FabricConfig
        return FabricConfig(mode=self.mode,
                            oversubscription=self.oversubscription,
                            latency_s=self.latency_s,
                            collective=self.collective,
                            uplink_bw=self.uplink_bw)

    def validate(self) -> None:
        try:
            self.to_config().validate()
        except ValueError as e:
            raise SpecError(f"topology.fabric: {e}") from e


_CLUSTER_KEYS = {
    "name", "role", "n_replicas", "tp", "pp", "ep", "hardware", "step",
    "m", "attn_tp", "ffn_tp", "ffn_ep", "remote_expert_ranks",
    "expert_cluster_hw", "expert_link_bw", "expert_link_latency",
    "batching", "seed_offset", "replica_prefix", "memoize", "pipeline",
}
_LINK_KEYS = {"src", "dst", "bandwidth", "latency"}


@dataclass
class TopologySpec:
    """Preset topology with knobs, or an inline cluster/link graph.

    ``preset`` is one of "colocated" | "pd" | "af" (compiled through the
    corresponding ``build_*`` preset); ``preset=None`` takes the inline
    ``clusters``/``links`` dicts and compiles them to a ``StageGraph``.
    """
    preset: Optional[str] = "colocated"
    hardware: str = "A800-SXM4-80G"
    transfer_bw: Optional[float] = None   # flat KV-transfer fallback (B/s)
    memoize: bool = True                  # step-time memo cache (PR 1)
    # colocated knobs
    n_replicas: int = 1
    tp: int = 1
    pp: int = 1
    ep: int = 1
    # pd knobs (also the prefill side of "af")
    n_prefill: int = 1
    n_decode: int = 1
    prefill_tp: int = 1
    decode_tp: int = 1
    # af knobs
    m: int = 2
    attn_tp: int = 1
    ffn_tp: int = 1
    ffn_ep: int = 1
    remote_expert_ranks: List[int] = field(default_factory=list)
    expert_cluster_hw: Optional[str] = None
    expert_link_bw: Optional[float] = None
    expert_link_latency: float = 0.0
    # inline graph (preset=None)
    clusters: Optional[List[Dict[str, Any]]] = None
    links: Optional[List[Dict[str, Any]]] = None
    # shared-fabric contention (None == {"mode": "none"} == legacy pricing)
    fabric: Optional[FabricSpec] = None
    # per-hardware-name $/GPU-hr overrides, e.g. {"H100-SXM": 4.5};
    # None keeps each HardwareSpec's built-in dollars_per_hour
    dollars_per_hour: Optional[Dict[str, float]] = None

    def __post_init__(self) -> None:
        _coerce(self, float, "transfer_bw", "expert_link_bw",
                "expert_link_latency")
        _coerce(self, int, "n_replicas", "tp", "pp", "ep", "n_prefill",
                "n_decode", "prefill_tp", "decode_tp", "m", "attn_tp",
                "ffn_tp", "ffn_ep")
        self.remote_expert_ranks = [int(r) for r in self.remote_expert_ranks]
        if isinstance(self.fabric, str):
            self.fabric = FabricSpec(mode=self.fabric)
        elif isinstance(self.fabric, Mapping):
            self.fabric = _from_mapping(FabricSpec, self.fabric,
                                        "topology.fabric")

    def fabric_config(self):
        """The core ``FabricConfig`` for build time; None when unset or
        mode == "none" (the builders then skip fabric construction)."""
        if self.fabric is None or self.fabric.mode == "none":
            return None
        return self.fabric.to_config()

    def hw_pricing(self, hw: HardwareSpec) -> HardwareSpec:
        """Apply any ``dollars_per_hour`` override for this hardware."""
        if self.dollars_per_hour and hw.name in self.dollars_per_hour:
            return hw.with_(
                dollars_per_hour=float(self.dollars_per_hour[hw.name]))
        return hw

    # ------------------------------------------------------- validation --
    def validate(self) -> None:
        _resolve_hw(self.hardware, "topology.hardware")
        if self.fabric is not None:
            self.fabric.validate()
        if self.transfer_bw is not None and self.transfer_bw <= 0:
            raise SpecError(f"topology.transfer_bw: must be > 0 "
                            f"(a zero-bandwidth link would price KV "
                            f"transfers as free), got {self.transfer_bw}")
        if self.dollars_per_hour is not None:
            if not isinstance(self.dollars_per_hour, Mapping):
                raise SpecError(
                    "topology.dollars_per_hour: expected a mapping of "
                    "hardware name -> $/GPU-hr, got "
                    f"{type(self.dollars_per_hour).__name__}")
            for k, v in self.dollars_per_hour.items():
                _resolve_hw(k, f"topology.dollars_per_hour[{k!r}]")
                try:
                    rate = float(v)
                except (TypeError, ValueError):
                    raise SpecError(
                        f"topology.dollars_per_hour[{k!r}]: expected a "
                        f"number, got {v!r}") from None
                if rate < 0:
                    raise SpecError(f"topology.dollars_per_hour[{k!r}]: "
                                    f"must be >= 0, got {rate}")
        if self.preset is None:
            if not self.clusters:
                raise SpecError("topology: preset=None needs inline "
                                "'clusters' (or pick a preset from "
                                f"{PRESETS})")
            self.inline_graph().validate()
            return
        if self.preset not in PRESETS:
            raise SpecError(f"topology.preset: unknown preset "
                            f"{self.preset!r}; available: {PRESETS} "
                            f"(or None with inline clusters)")
        if self.clusters or self.links:
            raise SpecError("topology: inline 'clusters'/'links' require "
                            "preset=None (they are ignored by presets)")
        for knob in ("n_replicas", "tp", "pp", "ep", "n_prefill",
                     "n_decode", "prefill_tp", "decode_tp", "m",
                     "attn_tp", "ffn_tp", "ffn_ep"):
            if getattr(self, knob) < 1:
                raise SpecError(f"topology.{knob}: must be >= 1, "
                                f"got {getattr(self, knob)}")
        if self.expert_cluster_hw is not None:
            _resolve_hw(self.expert_cluster_hw, "topology.expert_cluster_hw")
        if self.remote_expert_ranks:
            if self.preset != "af":
                raise SpecError("topology.remote_expert_ranks: only the "
                                "'af' preset places experts remotely")
            ep = max(self.ffn_ep, self.ffn_tp, 1)
            bad = [r for r in self.remote_expert_ranks if not 0 <= r < ep]
            if bad:
                raise SpecError(f"topology.remote_expert_ranks: ranks {bad} "
                                f"out of range for ffn_ep={ep}")
        elif self.expert_cluster_hw or self.expert_link_bw:
            raise SpecError("topology: expert_cluster_hw/expert_link_bw "
                            "have no effect without remote_expert_ranks")
        if self.expert_link_bw is not None and self.expert_link_bw <= 0:
            raise SpecError(f"topology.expert_link_bw: must be > 0, "
                            f"got {self.expert_link_bw}")

    def cluster_names(self) -> List[str]:
        if self.preset == "colocated":
            return ["colocated"]
        if self.preset in ("pd", "af"):
            return ["prefill", "decode"]
        return [c.get("name", "?") for c in (self.clusters or [])]

    # ----------------------------------------------------- inline graph --
    def inline_graph(self, batching=None) -> StageGraph:
        """Compile inline cluster/link dicts to a core StageGraph.

        ``batching`` is an optional per-role/per-name resolver (see
        ``PolicySpec.batching_for``) applied where a cluster dict does not
        carry its own ``batching`` entry.
        """
        clusters = []
        for i, c in enumerate(self.clusters or []):
            path = f"topology.clusters[{i}]"
            if not isinstance(c, Mapping):
                raise SpecError(f"{path}: expected a mapping")
            unknown = sorted(set(c) - _CLUSTER_KEYS)
            if unknown:
                raise SpecError(f"{path}: unknown field(s) {unknown}; "
                                f"known: {sorted(_CLUSTER_KEYS)}")
            if "name" not in c or "role" not in c:
                raise SpecError(f"{path}: 'name' and 'role' are required")
            if c["role"] not in ROLES:
                raise SpecError(f"{path}.role: unknown role {c['role']!r}; "
                                f"available: {ROLES}")
            name = c["name"]
            par = ParallelismConfig(tp=int(c.get("tp", 1)),
                                    pp=int(c.get("pp", 1)),
                                    ep=int(c.get("ep", 1)))
            step = c.get("step", "dense")
            attn_par = (ParallelismConfig(tp=int(c["attn_tp"]))
                        if "attn_tp" in c else None)
            ffn_par = (ParallelismConfig(tp=int(c.get("ffn_tp", 1)),
                                         ep=int(c.get("ffn_ep", 1)))
                       if ("ffn_tp" in c or "ffn_ep" in c) else None)
            link = None
            if c.get("expert_link_bw") is not None:
                link = LinkSpec(name, f"{name}-experts",
                                bandwidth=float(c["expert_link_bw"]),
                                latency=float(c.get("expert_link_latency",
                                                    0.0)))
            try:
                policy = resolve_batching(
                    c["batching"] if "batching" in c
                    else (batching(c["role"], name) if batching else None))
            except (KeyError, TypeError) as e:
                raise SpecError(f"{path}.batching: {e}") from e
            try:
                pipe = resolve_pipeline(c.get("pipeline"))
            except (KeyError, TypeError, ValueError) as e:
                raise SpecError(f"{path}.pipeline: {e}") from e
            clusters.append(ClusterSpec(
                name=name, role=c["role"],
                n_replicas=int(c.get("n_replicas", 1)), par=par,
                hardware=(_resolve_hw(c["hardware"], f"{path}.hardware")
                          if "hardware" in c else None),
                policy=policy, step=step, m=int(c.get("m", 2)),
                attn_par=attn_par, ffn_par=ffn_par,
                remote_expert_ranks=tuple(
                    int(r) for r in c.get("remote_expert_ranks", ())),
                expert_cluster_hw=(
                    _resolve_hw(c["expert_cluster_hw"],
                                f"{path}.expert_cluster_hw")
                    if c.get("expert_cluster_hw") else None),
                expert_link=link,
                seed_offset=int(c.get("seed_offset", 100 * i)),
                replica_prefix=c.get("replica_prefix"),
                memoize=bool(c.get("memoize", self.memoize)),
                pipeline=pipe))
        links = []
        for i, l in enumerate(self.links or []):
            path = f"topology.links[{i}]"
            if not isinstance(l, Mapping):
                raise SpecError(f"{path}: expected a mapping")
            unknown = sorted(set(l) - _LINK_KEYS)
            if unknown:
                raise SpecError(f"{path}: unknown field(s) {unknown}; "
                                f"known: {sorted(_LINK_KEYS)}")
            if "src" not in l or "dst" not in l or "bandwidth" not in l:
                raise SpecError(f"{path}: 'src', 'dst' and 'bandwidth' are "
                                f"required")
            bw = float(l["bandwidth"])
            if bw <= 0:
                raise SpecError(
                    f"{path}.bandwidth: must be > 0 bytes/s, got {bw} — "
                    f"a zero-bandwidth link would silently price its "
                    f"transfers as free; use a large finite bandwidth to "
                    f"model a negligible-cost link")
            links.append(LinkSpec(l["src"], l["dst"], bandwidth=bw,
                                  latency=float(l.get("latency", 0.0))))
        graph = StageGraph(clusters=clusters, links=links,
                           fabric=self.fabric_config())
        try:
            graph.validate()
        except ValueError as e:
            raise SpecError(f"topology: {e}") from e
        return graph


# ------------------------------------------------------------ workload ----
@dataclass
class WorkloadSpec:
    """Wraps ``workload.generator.WorkloadConfig`` + trace-file replay."""
    n_requests: int = 100
    arrival: str = "poisson"       # poisson | uniform | burst | closed
    rate: float = 4.0
    prompt: str = "lognormal"      # fixed | uniform | lognormal | bimodal
    prompt_mean: int = 512
    prompt_max: int = 8192
    output: str = "lognormal"
    output_mean: int = 128
    output_max: int = 2048
    burst_size: int = 32           # arrival="burst": requests per burst
    burst_period: float = 1.0      # arrival="burst": seconds between bursts
    concurrency: Optional[int] = None   # arrival="closed": in-flight cap
    prefix_groups: int = 0         # shared-prefix trace: system-prompt pools
    prefix_len: int = 0            # shared tokens per group
    turns: int = 1                 # multi-turn conversations (growing prefix)
    turn_gap: float = 5.0          # seconds between a conversation's turns
    rate_curve: Optional[str] = None   # "diurnal": sinusoidal rate swing
    rate_period: float = 60.0      # seconds per diurnal cycle
    rate_amplitude: float = 0.5    # relative swing, in [0, 1)
    trace: Optional[str] = None    # JSONL replay path (overrides generator)
    seed: Optional[int] = None     # None -> SimSpec.seed

    def __post_init__(self) -> None:
        _coerce(self, float, "rate", "burst_period", "turn_gap",
                "rate_period", "rate_amplitude")
        _coerce(self, int, "n_requests", "prompt_mean", "prompt_max",
                "output_mean", "output_max", "burst_size", "concurrency",
                "prefix_groups", "prefix_len", "turns", "seed")

    def validate(self) -> None:
        if self.arrival not in ARRIVALS:
            raise SpecError(f"workload.arrival: unknown process "
                            f"{self.arrival!r}; available: {ARRIVALS}")
        if self.arrival == "closed" and (self.concurrency is None
                                         or self.concurrency < 1):
            raise SpecError(
                "workload.concurrency: closed-loop arrivals need a "
                "concurrency >= 1 (the in-flight request cap; the next "
                "request arrives when a slot frees)")
        if self.arrival in ("poisson", "uniform") and self.rate <= 0:
            raise SpecError(f"workload.rate: open-loop arrivals need "
                            f"rate > 0, got {self.rate}")
        for fld in ("prompt", "output"):
            if getattr(self, fld) not in LENGTH_KINDS:
                raise SpecError(f"workload.{fld}: unknown length "
                                f"distribution {getattr(self, fld)!r}; "
                                f"available: {LENGTH_KINDS}")
        if self.n_requests < 1:
            raise SpecError(f"workload.n_requests: must be >= 1, "
                            f"got {self.n_requests}")
        if self.prefix_groups < 0 or self.prefix_len < 0:
            raise SpecError("workload.prefix_groups/prefix_len: must be "
                            ">= 0")
        if self.prefix_groups > 0 and self.prefix_len < 1:
            raise SpecError("workload.prefix_len: shared-prefix workloads "
                            "(prefix_groups > 0) need prefix_len >= 1")
        if self.turns < 1:
            raise SpecError(f"workload.turns: must be >= 1, got {self.turns}")
        if self.turns > 1 and self.prefix_groups > 0:
            raise SpecError("workload: turns > 1 and prefix_groups > 0 are "
                            "mutually exclusive (conversation prefixes "
                            "already share)")
        if self.rate_curve is not None:
            if self.rate_curve not in RATE_CURVES:
                raise SpecError(f"workload.rate_curve: unknown curve "
                                f"{self.rate_curve!r}; available: "
                                f"{RATE_CURVES}")
            if self.arrival != "poisson":
                raise SpecError("workload.rate_curve: rate curves modulate "
                                "the poisson arrival process; got "
                                f"arrival={self.arrival!r}")
            if not 0.0 <= self.rate_amplitude < 1.0:
                raise SpecError(f"workload.rate_amplitude: must be in "
                                f"[0, 1), got {self.rate_amplitude}")
            if self.rate_period <= 0:
                raise SpecError(f"workload.rate_period: must be > 0, "
                                f"got {self.rate_period}")
        if self.turns > 1 and self.arrival == "closed":
            raise SpecError(
                "workload.arrival: closed-loop injection re-stamps arrivals "
                "in queue order, putting a conversation's later turns in "
                "flight before their history is generated — use an "
                "open-loop arrival process with turns > 1")

    def build_requests(self, default_seed: int = 0):
        from repro.workload.generator import WorkloadConfig, generate, \
            load_trace
        if self.trace is not None:
            return load_trace(self.trace, n_requests=self.n_requests)
        return generate(WorkloadConfig(
            n_requests=self.n_requests, arrival=self.arrival,
            rate=self.rate, prompt=self.prompt,
            prompt_mean=self.prompt_mean, prompt_max=self.prompt_max,
            output=self.output, output_mean=self.output_mean,
            output_max=self.output_max, burst_size=self.burst_size,
            burst_period=self.burst_period, concurrency=self.concurrency,
            prefix_groups=self.prefix_groups, prefix_len=self.prefix_len,
            turns=self.turns, turn_gap=self.turn_gap,
            rate_curve=self.rate_curve, rate_period=self.rate_period,
            rate_amplitude=self.rate_amplitude,
            seed=self.seed if self.seed is not None else default_seed))


# ------------------------------------------------------------ policies ----
@dataclass
class PolicySpec:
    """Registry-name policy selection, resolved uniformly at build time.

    ``batching`` is either one policy for every cluster (name or
    ``{"name": ..., **kwargs}``) or a mapping keyed by role
    (``{"prefill": "continuous", "decode": {"name": "chunked_prefill",
    "chunk": 256}}``).  ``router`` picks the MoE routing module,
    ``scheduler`` the queue-ordering policy, ``memory`` the KV manager.
    """
    router: Union[None, str, Dict[str, Any]] = None
    batching: Union[None, str, Dict[str, Any]] = None
    scheduler: Union[None, str, Dict[str, Any]] = None
    memory: Union[None, str, Dict[str, Any]] = None

    def _role_keyed(self) -> bool:
        return (isinstance(self.batching, Mapping)
                and "name" not in self.batching)

    def batching_for(self, role: str, name: str = "") \
            -> Union[None, str, Dict[str, Any]]:
        if self._role_keyed():
            return self.batching.get(name, self.batching.get(role))
        return self.batching

    def validate(self) -> None:
        try:
            resolve_router(self.router)
        except (KeyError, TypeError) as e:
            raise SpecError(f"policy.router: {e}") from e
        try:
            if self._role_keyed():
                # keys are roles (or cluster names for inline graphs);
                # every value must itself resolve
                for v in self.batching.values():
                    resolve_batching(v)
            else:
                resolve_batching(self.batching)
        except (KeyError, TypeError) as e:
            raise SpecError(f"policy.batching: {e}") from e
        try:
            resolve_scheduler(self.scheduler)
        except (KeyError, TypeError) as e:
            raise SpecError(f"policy.scheduler: {e}") from e
        try:
            resolve_memory(self.memory)
        except (KeyError, TypeError) as e:
            raise SpecError(f"policy.memory: {e}") from e


@dataclass
class PipelineSpec:
    """Latency-hiding pipelining strategy (see ``repro.core.pipeline``).

    ``preset`` starts from a registered strategy (``"serial"``,
    ``"two_batch"``, ``"chunked_prefill"``, ``"ep_overlap"``,
    ``"full_overlap"``); explicitly-set fields override it.  With no
    preset the fields stand alone.  A spec with ``pipeline: null`` (the
    default) keeps the legacy serial-per-micro-batch model bit-for-bit.

    - ``af_overlap``: AF decode-step resource model — ``"none"`` (legacy),
      ``"serial"`` (no-latency-hiding baseline), ``"two_batch"``
      (ping-pong with per-direction NIC lanes).
    - ``chunked_prefill`` / ``prefill_chunk``: Sarathi-style chunked
      prefill with piggybacked decode on colocated and PD prefill pools.
    - ``ep_overlap``: EP dispatch/combine comm-compute overlap efficiency.
    """
    preset: Optional[str] = None
    af_overlap: Optional[str] = None      # None -> preset / "none"
    nic_lanes: Optional[int] = None
    chunked_prefill: Optional[bool] = None
    prefill_chunk: Optional[int] = None
    ep_overlap: Optional[float] = None

    def __post_init__(self) -> None:
        _coerce(self, int, "nic_lanes", "prefill_chunk")
        _coerce(self, float, "ep_overlap")

    def to_config(self) -> PipelineConfig:
        overrides = {k: v for k, v in (
            ("af_overlap", self.af_overlap),
            ("nic_lanes", self.nic_lanes),
            ("chunked_prefill", self.chunked_prefill),
            ("prefill_chunk", self.prefill_chunk),
            ("ep_overlap", self.ep_overlap)) if v is not None}
        # one merge implementation: resolve_pipeline raises on unknown
        # presets rather than silently compiling to the no-op config
        if self.preset is not None:
            return resolve_pipeline({"name": self.preset, **overrides})
        return resolve_pipeline(overrides) if overrides \
            else PipelineConfig()

    def validate(self) -> None:
        if self.preset is not None and self.preset not in PIPELINES:
            raise SpecError(f"pipeline.preset: unknown preset "
                            f"{self.preset!r}; available: "
                            f"{sorted(PIPELINES)}")
        if self.af_overlap is not None \
                and self.af_overlap not in AF_OVERLAP_MODES:
            raise SpecError(f"pipeline.af_overlap: unknown mode "
                            f"{self.af_overlap!r}; available: "
                            f"{AF_OVERLAP_MODES}")
        try:
            self.to_config().validate()
        except (KeyError, ValueError) as e:
            raise SpecError(f"pipeline: {e}") from e


@dataclass
class MemorySpec:
    """The KV-cache memory subsystem: manager, preemption, transfer.

    - ``manager``: registered KV manager — ``"paged"`` (vLLM-style blocks),
      ``"prefix"`` (radix prefix cache with block sharing + LRU eviction),
      ``"monolithic"`` (per-request max-bound reservation) — or a mapping
      ``{"name": ..., **kwargs}`` (block_tokens, watermark, ...).
    - ``preemption``: what a decode OOM does to the evicted request —
      ``"recompute"`` (drop KV, re-prefill the context through an entry
      cluster) or ``"swap"`` (move KV to host over ``swap_bw`` and restore
      in place when blocks free).
    - ``transfer_overlap``: layer-wise streamed PD KV transfer — the
      fraction of the streaming opportunity realized; 0 keeps the legacy
      lump-sum transfer bit-for-bit.
    - ``capacity_frac``: fraction of post-weight HBM given to the KV cache
      (the cache-size knob for memory-pressure sweeps; default 0.9).
    """
    manager: Union[None, str, Dict[str, Any]] = None
    preemption: str = "recompute"
    swap_bw: float = 32e9
    transfer_overlap: float = 0.0
    capacity_frac: float = 0.9

    def __post_init__(self) -> None:
        _coerce(self, float, "swap_bw", "transfer_overlap", "capacity_frac")

    def manager_mapping(self) -> Dict[str, Any]:
        """The mapping build_system's ``memory=`` argument takes (manager
        name + kwargs + the preemption policy that travels with it)."""
        m = self.manager
        if m is None:
            m = {"name": "paged"}
        elif isinstance(m, str):
            m = {"name": m}
        else:
            m = dict(m)
        m.setdefault("preemption", self.preemption)
        m.setdefault("swap_bw", self.swap_bw)
        return m

    def validate(self) -> None:
        if self.preemption not in PREEMPTION_MODES:
            raise SpecError(f"memory.preemption: unknown mode "
                            f"{self.preemption!r}; available: "
                            f"{PREEMPTION_MODES}")
        if not 0.0 <= self.transfer_overlap <= 1.0:
            raise SpecError(f"memory.transfer_overlap: must be in [0, 1], "
                            f"got {self.transfer_overlap}")
        if not 0.0 < self.capacity_frac <= 1.0:
            raise SpecError(f"memory.capacity_frac: must be in (0, 1], "
                            f"got {self.capacity_frac}")
        if self.swap_bw <= 0:
            raise SpecError(f"memory.swap_bw: must be > 0, "
                            f"got {self.swap_bw}")
        try:
            resolve_memory(self.manager_mapping())
        except (KeyError, TypeError) as e:
            raise SpecError(f"memory.manager: {e}") from e


PREDICTOR_BACKENDS = ("python", "numpy", "jit")


@dataclass
class OpModelSpec:
    """Operator-model family for the ExecutionPredictor.

    ``backend`` selects the step-cost evaluation path: ``python`` (default)
    walks the operator graph per step with a full parts breakdown;
    ``numpy`` prices cache-miss steps through the vectorized fused
    roofline kernel; ``jit`` additionally compiles that kernel with
    ``jax.jit`` (float32 — totals match python to ~1e-9 relative, not
    bitwise).  Models the kernel can't reproduce (MoE routing draws,
    refined operator models) silently fall back to python.

    ``calibration`` points at a directory of fitted artifacts produced by
    ``python -m repro calibrate`` (the calib root or a ``<hardware>/``
    subdirectory); steps are then priced by the fitted forest models.
    Requires ``name: refined`` — the fitted models slot into the refined
    model set, with virtual kernels as the out-of-domain fallback.
    """
    name: str = "analytical"
    backend: str = "python"
    calibration: Optional[str] = None

    def validate(self) -> None:
        if self.name not in OPMODELS:
            raise SpecError(f"opmodel.name: unknown operator model "
                            f"{self.name!r}; available: {sorted(OPMODELS)}")
        if self.backend not in PREDICTOR_BACKENDS:
            raise SpecError(f"opmodel.backend: unknown predictor backend "
                            f"{self.backend!r}; available: "
                            f"{list(PREDICTOR_BACKENDS)}")
        if self.calibration is not None:
            if not isinstance(self.calibration, str) or not self.calibration:
                raise SpecError("opmodel.calibration: expected a path to a "
                                "calibration artifact directory (see "
                                "`python -m repro calibrate`)")
            if self.name != "refined":
                raise SpecError(
                    f"opmodel.calibration: fitted artifacts load into the "
                    f"refined model set; set opmodel.name: refined "
                    f"(got {self.name!r})")


@dataclass
class SLOSpec:
    """Service-level objectives; enables goodput/attainment in the Report."""
    ttft_s: float = 1.0
    tpot_s: float = 0.1

    def __post_init__(self) -> None:
        _coerce(self, float, "ttft_s", "tpot_s")

    def validate(self) -> None:
        if self.ttft_s <= 0 or self.tpot_s <= 0:
            raise SpecError(f"slo: ttft_s/tpot_s must be > 0, got "
                            f"({self.ttft_s}, {self.tpot_s})")


@dataclass
class FaultSpec:
    """One injected fault: a replica failure or a chronic straggler."""
    kind: str = "failure"          # "failure" | "straggler"
    cluster: str = "colocated"
    replica: int = 0
    at: float = 0.0                # failure: injection time (s)
    downtime: float = 10.0         # failure: recovery delay (s)
    slowdown: float = 1.0          # straggler: step-time multiplier
    instance: Optional[str] = None  # fleet runs: target instance (default:
    #                                 the first instance of the fleet)

    def __post_init__(self) -> None:
        _coerce(self, float, "at", "downtime", "slowdown")
        _coerce(self, int, "replica")

    def validate(self, cluster_names: Sequence[str], path: str) -> None:
        if self.kind not in FAULT_KINDS:
            raise SpecError(f"{path}.kind: unknown fault kind "
                            f"{self.kind!r}; available: {FAULT_KINDS}")
        if self.cluster not in cluster_names:
            raise SpecError(f"{path}.cluster: unknown cluster "
                            f"{self.cluster!r}; topology has "
                            f"{list(cluster_names)}")
        if self.replica < 0:
            raise SpecError(f"{path}.replica: must be >= 0")
        if self.kind == "straggler" and self.slowdown <= 0:
            raise SpecError(f"{path}.slowdown: must be > 0, "
                            f"got {self.slowdown}")


# ------------------------------------------------------------------ obs ----
@dataclass
class ObsSpec:
    """Observability (see ``repro.obs``): request spans, sim-time
    counters, and trace export.

    Off unless the spec carries this section (``obs: {}`` enables
    everything but EP spans).  ``ep_spans`` additionally records the
    per-EP-rank dispatch/rank/combine markers of AF decode steps by
    running cache-miss steps through the traced inner engine
    (bit-identical timings, slower stepping).  ``max_spans`` /
    ``max_counter_points`` bound recorder memory: beyond the span cap
    new spans are counted as dropped, and counter series are windowed
    down by merging adjacent samples.
    """
    enabled: bool = True
    spans: bool = True
    counters: bool = True
    ep_spans: bool = False
    max_spans: int = 500_000
    max_counter_points: int = 4096
    top_n: int = 5                 # summary sink: top-N slowest requests

    def __post_init__(self) -> None:
        _coerce(self, int, "max_spans", "max_counter_points", "top_n")

    def validate(self) -> None:
        if self.max_spans < 0:
            raise SpecError(f"obs.max_spans: must be >= 0, "
                            f"got {self.max_spans}")
        if self.max_counter_points < 2:
            raise SpecError(f"obs.max_counter_points: must be >= 2, "
                            f"got {self.max_counter_points}")
        if self.top_n < 1:
            raise SpecError(f"obs.top_n: must be >= 1, got {self.top_n}")

    @classmethod
    def parse(cls, data: Any) -> Optional["ObsSpec"]:
        """``obs: true`` / ``obs: off`` booleans are accepted as YAML
        shorthand for the default-enabled / absent section."""
        if isinstance(data, bool):
            return cls() if data else None
        return _from_mapping(cls, data, "obs")


# ---------------------------------------------------------------- fleet ----
@dataclass
class InstanceSpec:
    """A group of identical serving instances inside a fleet.

    Each of the ``count`` instances is a FULL deployment (its own
    GlobalController, clusters, replicas, KV managers) built from
    ``topology`` — or the SimSpec's top-level topology when None — so a
    fleet mixes heterogeneous instance shapes freely (a PD pool next to
    colocated pools on different hardware).  ``pipeline``/``memory``
    override the spec-level sections for this group only.
    """
    name: str = "inst"
    count: int = 1
    topology: Optional[TopologySpec] = None
    pipeline: Optional[PipelineSpec] = None
    memory: Optional[MemorySpec] = None

    def __post_init__(self) -> None:
        _coerce(self, int, "count")


@dataclass
class TenantSpec:
    """One tenant class: traffic share, per-class SLOs, and priority.

    ``weight`` is the relative share of arrivals assigned to this class;
    ``priority`` (lower = more urgent) lands in the request's
    ``timestamps['priority']`` slot, so ``policy.scheduler: priority``
    makes tenant priority effective inside every instance.
    """
    name: str = "default"
    weight: float = 1.0
    ttft_s: Optional[float] = None     # per-class SLOs; None -> spec.slo
    tpot_s: Optional[float] = None
    priority: int = 0

    def __post_init__(self) -> None:
        _coerce(self, float, "weight", "ttft_s", "tpot_s")
        _coerce(self, int, "priority")


@dataclass
class AutoscalerSpec:
    """SLO-driven fleet autoscaling (see ``repro.fleet.autoscaler``).

    Every ``interval_s`` the autoscaler compares mean outstanding requests
    per active instance against ``up_queue_depth`` / ``down_queue_depth``
    and — when the spec carries an SLO — recent TTFT-SLO attainment against
    ``slo_attainment_floor``.  Scale-up provisions a clone of ``template``
    (an InstanceSpec name; default: the first group) with a modeled cold
    start: per-device weight bytes loaded over ``provision_bw`` plus
    ``startup_base_s``.  Scale-down drains: the victim stops receiving
    traffic, finishes its residents, then releases its GPUs.
    ``pd_rebalance`` additionally shifts replicas between the prefill and
    decode pools of disaggregated instances (via pre-provisioned standby
    replicas, ``pd_spares`` per pool) when one pool's queue pressure
    exceeds ``rebalance_ratio`` times the other's.
    """
    interval_s: float = 5.0
    min_instances: int = 1
    max_instances: int = 8
    up_queue_depth: float = 8.0
    down_queue_depth: float = 1.0
    slo_attainment_floor: Optional[float] = None
    cooldown_s: float = 10.0
    provision_bw: float = 16e9        # weight-load bandwidth (B/s/device)
    startup_base_s: float = 2.0       # container/runtime bring-up floor
    template: Optional[str] = None    # InstanceSpec name cloned on scale-up
    pd_rebalance: bool = False
    pd_spares: int = 1                # standby replicas per P/D pool
    rebalance_ratio: float = 4.0
    reconfigure_s: float = 1.0        # pool-move weight-load time

    def __post_init__(self) -> None:
        _coerce(self, float, "interval_s", "up_queue_depth",
                "down_queue_depth", "slo_attainment_floor", "cooldown_s",
                "provision_bw", "startup_base_s", "rebalance_ratio",
                "reconfigure_s")
        _coerce(self, int, "min_instances", "max_instances", "pd_spares")


@dataclass
class FleetSpec:
    """A multi-instance serving fleet behind one global router.

    ``instances`` lists heterogeneous instance groups; ``router`` names a
    registered fleet routing policy (``repro.fleet.FLEET_ROUTERS``:
    round_robin | least_outstanding | power_of_two | prefix_affinity,
    optionally ``{"name": ..., **kwargs}``); ``autoscaler`` enables
    SLO-driven scaling; ``tenants`` declares tenant classes with per-class
    SLOs/priorities (requests are assigned by weighted draw).

    ``engine`` selects the fleet execution mode: ``serial`` (default)
    interleaves every instance on one event heap; ``windowed`` runs each
    instance on its own sub-engine, advancing all of them in conservative
    time windows of ``window_s`` seconds between fleet-level barriers —
    same arrivals, same routing decisions, deterministic given the window
    (``window_s == 0`` reproduces serial results exactly; larger windows
    trade cross-instance signal freshness for synchronization cost).
    """
    instances: List[InstanceSpec] = field(default_factory=list)
    router: Union[str, Dict[str, Any]] = "least_outstanding"
    autoscaler: Optional[AutoscalerSpec] = None
    tenants: List[TenantSpec] = field(default_factory=list)
    engine: str = "serial"
    window_s: float = 0.0

    def __post_init__(self) -> None:
        _coerce(self, float, "window_s")

    # ----------------------------------------------------------- parsing --
    @classmethod
    def parse(cls, data: Any, path: str = "fleet") -> Optional["FleetSpec"]:
        if data is None or isinstance(data, cls):
            return data
        if not isinstance(data, Mapping):
            raise SpecError(f"{path}: expected a mapping for FleetSpec, "
                            f"got {type(data).__name__}")
        d = dict(data)
        instances = []
        for i, inst in enumerate(d.get("instances") or []):
            ipath = f"{path}.instances[{i}]"
            inst = _from_mapping(InstanceSpec, inst, ipath)
            if isinstance(inst.topology, Mapping):
                inst.topology = _from_mapping(TopologySpec, inst.topology,
                                              f"{ipath}.topology")
            if isinstance(inst.pipeline, str):
                inst.pipeline = PipelineSpec(preset=inst.pipeline)
            elif isinstance(inst.pipeline, Mapping):
                inst.pipeline = _from_mapping(PipelineSpec, inst.pipeline,
                                              f"{ipath}.pipeline")
            if isinstance(inst.memory, str):
                inst.memory = MemorySpec(manager=inst.memory)
            elif isinstance(inst.memory, Mapping):
                inst.memory = _from_mapping(MemorySpec, inst.memory,
                                            f"{ipath}.memory")
            instances.append(inst)
        d["instances"] = instances
        d["autoscaler"] = _from_mapping(AutoscalerSpec, d.get("autoscaler"),
                                        f"{path}.autoscaler")
        d["tenants"] = [_from_mapping(TenantSpec, t, f"{path}.tenants[{i}]")
                        for i, t in enumerate(d.get("tenants") or [])]
        return _from_mapping(cls, d, path)

    # -------------------------------------------------------------- views --
    def instance_by_name(self, name: Optional[str]) -> InstanceSpec:
        if name is None:
            return self.instances[0]
        for inst in self.instances:
            if inst.name == name:
                return inst
        raise SpecError(f"fleet: unknown instance group {name!r}; "
                        f"groups: {[i.name for i in self.instances]}")

    def total_instances(self) -> int:
        return sum(i.count for i in self.instances)

    # --------------------------------------------------------- validation --
    def validate(self, default_topology: TopologySpec) -> None:
        from repro.fleet.router import resolve_fleet_router
        if not self.instances:
            raise SpecError("fleet.instances: a fleet needs at least one "
                            "instance group")
        names = [i.name for i in self.instances]
        if len(set(names)) != len(names):
            raise SpecError(f"fleet.instances: duplicate group names "
                            f"{names}")
        for i, inst in enumerate(self.instances):
            if inst.count < 1:
                raise SpecError(f"fleet.instances[{i}].count: must be >= 1, "
                                f"got {inst.count}")
            (inst.topology or default_topology).validate()
            if inst.pipeline is not None:
                inst.pipeline.validate()
            if inst.memory is not None:
                inst.memory.validate()
        try:
            resolve_fleet_router(self.router)
        except (KeyError, TypeError) as e:
            raise SpecError(f"fleet.router: {e}") from e
        if self.engine not in ("serial", "windowed"):
            raise SpecError(f"fleet.engine: unknown engine mode "
                            f"{self.engine!r}; available: "
                            f"['serial', 'windowed']")
        if self.window_s < 0:
            raise SpecError(f"fleet.window_s: must be >= 0, "
                            f"got {self.window_s}")
        if self.autoscaler is not None:
            a = self.autoscaler
            if a.min_instances < 1 or a.max_instances < a.min_instances:
                raise SpecError(
                    f"fleet.autoscaler: need 1 <= min_instances <= "
                    f"max_instances, got ({a.min_instances}, "
                    f"{a.max_instances})")
            if a.interval_s <= 0 or a.cooldown_s < 0:
                raise SpecError("fleet.autoscaler: interval_s must be > 0 "
                                "and cooldown_s >= 0")
            if a.provision_bw <= 0:
                raise SpecError(f"fleet.autoscaler.provision_bw: must be "
                                f"> 0, got {a.provision_bw}")
            if a.slo_attainment_floor is not None \
                    and not 0.0 < a.slo_attainment_floor <= 1.0:
                raise SpecError(f"fleet.autoscaler.slo_attainment_floor: "
                                f"must be in (0, 1], got "
                                f"{a.slo_attainment_floor}")
            if a.pd_spares < 0 or a.rebalance_ratio <= 1.0:
                raise SpecError("fleet.autoscaler: pd_spares must be >= 0 "
                                "and rebalance_ratio > 1")
            if a.template is not None:
                self.instance_by_name(a.template)
        tnames = [t.name for t in self.tenants]
        if len(set(tnames)) != len(tnames):
            raise SpecError(f"fleet.tenants: duplicate tenant names "
                            f"{tnames}")
        for i, t in enumerate(self.tenants):
            if t.weight <= 0:
                raise SpecError(f"fleet.tenants[{i}].weight: must be > 0, "
                                f"got {t.weight}")


# -------------------------------------------------------------- SimSpec ----
@dataclass
class SimSpec:
    """One fully-described simulation experiment (see module docstring)."""
    model: ModelRef = field(default_factory=ModelRef)
    topology: TopologySpec = field(default_factory=TopologySpec)
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    policy: PolicySpec = field(default_factory=PolicySpec)
    opmodel: OpModelSpec = field(default_factory=OpModelSpec)
    pipeline: Optional[PipelineSpec] = None
    memory: Optional[MemorySpec] = None
    slo: Optional[SLOSpec] = None
    faults: List[FaultSpec] = field(default_factory=list)
    fleet: Optional[FleetSpec] = None
    obs: Optional[ObsSpec] = None   # observability; None -> fully off
    seed: int = 0
    until: Optional[float] = None   # sim horizon (s); None -> completion
    name: str = ""

    def __post_init__(self) -> None:
        _coerce(self, int, "seed")
        _coerce(self, float, "until")

    # ---------------------------------------------------------- validate --
    def validate(self) -> "SimSpec":
        self.model.validate()
        self.topology.validate()
        self.workload.validate()
        self.policy.validate()
        self.opmodel.validate()
        if self.pipeline is not None:
            self.pipeline.validate()
        if self.memory is not None:
            self.memory.validate()
            if self.policy.memory is not None:
                raise SpecError(
                    "memory/policy.memory: both select a KV manager — use "
                    "the 'memory' section (policy.memory is the legacy "
                    "manager-only knob)")
            if self.memory.transfer_overlap > 0.0 \
                    and self.topology.fabric_config() is not None:
                raise SpecError(
                    "topology.fabric/memory.transfer_overlap: layer-"
                    "streamed KV transfer prices chunks against a "
                    "dedicated link and cannot be combined with shared-"
                    "fabric contention — set one of them to its default")
        if self.slo is not None:
            self.slo.validate()
        if self.obs is not None:
            self.obs.validate()
        if self.fleet is not None:
            self.fleet.validate(self.topology)
            if self.workload.arrival == "closed":
                raise SpecError(
                    "workload.arrival: closed-loop injection is per-"
                    "instance; fleet runs route open-loop arrivals through "
                    "the global router — use poisson/uniform/burst")
            if self.workload.turns > 1:
                raise SpecError(
                    "workload.turns: multi-turn conversations pin a growing "
                    "prefix to one instance's cache; fleet routing of "
                    "conversation turns is not modeled yet — use "
                    "prefix_groups for shared-prefix fleet workloads")
        names = self.topology.cluster_names()
        if self.fleet is not None:
            # the policy section is shared by EVERY instance, so a
            # cluster-keyed batching key must exist in every group's
            # topology (roles always resolve) — the intersection, not the
            # union, or one group's build would reject the key mid-run
            shared = None
            for inst in self.fleet.instances:
                cn = set((inst.topology or self.topology).cluster_names())
                shared = cn if shared is None else shared & cn
            names = sorted(shared or set())
        if self.policy._role_keyed():
            # role-keyed batching: a misspelled key would silently fall
            # back to the default policy, so reject unknown keys here
            # (where the topology's cluster names are known)
            bad = sorted(set(self.policy.batching)
                         - set(ROLES) - set(names))
            if bad:
                raise SpecError(
                    f"policy.batching: unknown role/cluster key(s) {bad}; "
                    f"roles: {sorted(ROLES)}, clusters: {names} (or give "
                    f"one policy for all clusters as {{'name': ...}})")
        for i, f in enumerate(self.faults):
            if self.fleet is not None:
                # the fault lands on ONE instance group (named, or the
                # first) — validate the cluster against THAT group's
                # topology, not the union, so a group/cluster mismatch
                # fails here and not mid-build
                group = self.fleet.instance_by_name(f.instance)
                f.validate((group.topology or self.topology)
                           .cluster_names(), f"faults[{i}]")
            else:
                if f.instance is not None:
                    raise SpecError(f"faults[{i}].instance: only fleet "
                                    f"specs have named instances")
                f.validate(names, f"faults[{i}]")
        if self.until is not None and self.until <= 0:
            raise SpecError(f"until: must be > 0 seconds, got {self.until}")
        return self

    # ------------------------------------------------------ serialization --
    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        # an unset calibration must hash/serialize exactly like specs that
        # predate the field, so spec hashes and goldens stay bit-identical
        if d.get("opmodel", {}).get("calibration") is None:
            d["opmodel"].pop("calibration", None)
        # same rule for the fabric/cost fields: unset must serialize like
        # specs that predate them
        topo = d.get("topology", {})
        for k in ("fabric", "dollars_per_hour"):
            if topo.get(k) is None:
                topo.pop(k, None)
        for inst in (d.get("fleet") or {}).get("instances") or []:
            it = inst.get("topology")
            if isinstance(it, dict):
                for k in ("fabric", "dollars_per_hour"):
                    if it.get(k) is None:
                        it.pop(k, None)
        # observability off must hash/serialize exactly like pre-obs specs
        if d.get("obs") is None:
            d.pop("obs", None)
        return d

    @classmethod
    def from_dict(cls, data: Mapping[str, Any]) -> "SimSpec":
        if not isinstance(data, Mapping):
            raise SpecError(f"spec: expected a mapping, "
                            f"got {type(data).__name__}")
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(data) - known)
        if unknown:
            raise SpecError(f"spec: unknown field(s) {unknown}; "
                            f"known: {sorted(known)}")
        d = dict(data)
        spec = cls(
            model=_from_mapping(ModelRef, d.get("model"), "model")
            or ModelRef(),
            topology=_from_mapping(TopologySpec, d.get("topology"),
                                   "topology") or TopologySpec(),
            workload=_from_mapping(WorkloadSpec, d.get("workload"),
                                   "workload") or WorkloadSpec(),
            policy=_from_mapping(PolicySpec, d.get("policy"), "policy")
            or PolicySpec(),
            opmodel=_from_mapping(OpModelSpec, d.get("opmodel"), "opmodel")
            or OpModelSpec(),
            pipeline=(PipelineSpec(preset=d["pipeline"])
                      if isinstance(d.get("pipeline"), str) else
                      _from_mapping(PipelineSpec, d.get("pipeline"),
                                    "pipeline")),
            memory=(MemorySpec(manager=d["memory"])
                    if isinstance(d.get("memory"), str) else
                    _from_mapping(MemorySpec, d.get("memory"), "memory")),
            slo=_from_mapping(SLOSpec, d.get("slo"), "slo"),
            faults=[_from_mapping(FaultSpec, f, f"faults[{i}]")
                    for i, f in enumerate(d.get("faults") or [])],
            fleet=FleetSpec.parse(d.get("fleet")),
            obs=ObsSpec.parse(d.get("obs")),
            seed=int(d.get("seed", 0)),
            until=d.get("until"),
            name=d.get("name", ""))
        return spec

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "SimSpec":
        return cls.from_dict(json.loads(text))

    def to_yaml(self) -> str:
        import yaml
        return yaml.safe_dump(self.to_dict(), sort_keys=True)

    @classmethod
    def from_yaml(cls, text: str) -> "SimSpec":
        import yaml
        data = yaml.safe_load(text)
        if data is None:
            raise SpecError("spec: empty YAML document")
        return cls.from_dict(data)

    @classmethod
    def load(cls, path: str) -> "SimSpec":
        """Load a spec from a .yaml/.yml/.json file."""
        with open(path) as f:
            text = f.read()
        if str(path).endswith(".json"):
            return cls.from_json(text)
        return cls.from_yaml(text)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() if str(path).endswith(".json")
                    else self.to_yaml())

    # ----------------------------------------------------------- identity --
    def spec_hash(self) -> str:
        """Deterministic 16-hex-digit digest of the canonical spec dict."""
        blob = json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"), default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def with_(self, **updates: Any) -> "SimSpec":
        """Copy with dotted-path updates, e.g. ``with_(**{"workload.rate":
        8.0, "seed": 3})`` — the mechanism sweeps use for axis points."""
        d = self.to_dict()
        for key, value in updates.items():
            set_path(d, key, value)
        return SimSpec.from_dict(d)


def set_path(d: Dict[str, Any], path: str, value: Any) -> None:
    """Set a dotted path in a nested spec dict, with shorthand resolution:
    a bare field name (``tp``) is searched in the spec root, then in
    topology / workload / policy."""
    parts = path.split(".")
    if len(parts) == 1 and parts[0] not in d \
            and parts[0] not in {f.name for f in fields(SimSpec)}:
        # (a real SimSpec field absent from the dict is an UNSET optional
        # section — to_dict strips those — so it is still a top-level set)
        for section in ("topology", "workload", "policy", "pipeline",
                        "memory", "fleet", "obs"):
            sub = d.get(section)
            if isinstance(sub, Mapping) and parts[0] in sub:
                parts = [section, parts[0]]
                break
        else:
            raise SpecError(
                f"axis/path {path!r}: not a spec field and not found in "
                f"topology/workload/policy; use a dotted path like "
                f"'workload.rate'")
    cur: Any = d
    for p in parts[:-1]:
        if not isinstance(cur, dict):
            raise SpecError(f"axis/path {path!r}: {p!r} is not a mapping")
        if not isinstance(cur.get(p), dict):
            if cur.get(p) is not None:
                raise SpecError(
                    f"axis/path {path!r}: {p!r} holds "
                    f"{cur[p]!r}, not a mapping — replace the whole "
                    f"field instead")
            cur[p] = {}     # e.g. slo: None -> slo.ttft_s=... creates it
        cur = cur[p]
    if not isinstance(cur, dict):
        raise SpecError(f"axis/path {path!r}: parent is not a mapping")
    cur[parts[-1]] = value
