"""PD-disaggregated serving system (DistServe/Dynamo style).

Producer (prefill cluster) and consumer (decode cluster) are specialized
pools with independent parallelism; the GlobalController mediates KV-cache
transfers under decode-side memory backpressure.  A thin preset over the
StageGraph topology layer.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.hardware import HardwareSpec, ParallelismConfig
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.policies.batching import BatchingPolicy
from repro.core.topology import (
    ClusterSpec, StageGraph, SystemHandle, build_system,
)


def build_pd(cfg: ModelConfig, hw: HardwareSpec, *,
             n_prefill: int = 1, n_decode: int = 1,
             prefill_par: Optional[ParallelismConfig] = None,
             decode_par: Optional[ParallelismConfig] = None,
             prefill_policy: Optional[BatchingPolicy] = None,
             decode_policy: Optional[BatchingPolicy] = None,
             ops: Optional[OperatorModelSet] = None,
             transfer_bw: Optional[float] = None,
             engine=None,
             routing=None, seed: int = 0,
             memory=None, queue_policy=None,
             memoize: bool = True,
             pipeline=None, transfer_overlap: float = 0.0,
             kv_frac: float = 0.9, fabric=None) -> SystemHandle:
    """PD-disaggregation preset.

    .. deprecated::
        ``build_pd`` is kept as a thin shim over the declarative experiment
        API; prefer ``repro.api.SimSpec`` with
        ``TopologySpec(preset="pd", ...)`` and ``repro.api.run`` — specs
        serialize, validate, and sweep.
    """
    graph = StageGraph(clusters=[
        ClusterSpec("prefill", "prefill", n_replicas=n_prefill,
                    par=prefill_par or ParallelismConfig(tp=1),
                    policy=prefill_policy, seed_offset=0, memoize=memoize),
        ClusterSpec("decode", "decode", n_replicas=n_decode,
                    par=decode_par or ParallelismConfig(tp=1),
                    policy=decode_policy, seed_offset=100, memoize=memoize),
    ], fabric=fabric)
    return build_system(cfg, hw, graph, ops=ops, routing=routing,
                        engine=engine,
                        transfer_bw=transfer_bw, memory=memory,
                        queue_policy=queue_policy, seed=seed,
                        pipeline=pipeline, transfer_overlap=transfer_overlap,
                        kv_frac=kv_frac)
