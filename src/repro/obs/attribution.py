"""Per-request latency attribution.

Partition a request's end-to-end latency ``[arrival, finish]`` into
queue / compute / comm / preempt / stall seconds that sum (within float
rounding) to e2e:

- each recorded span contributes its interval to its category
  (``SPAN_CATEGORY``), clipped to ``[arrival, finish]``;
- overlaps are resolved by a sweep with category priority
  compute > comm > preempt > queue — a KV transfer hidden under a
  prefill chunk books as compute, not twice;
- the uncovered remainder is *stall*: time the request existed but no
  recorded activity owned (head-of-line blocking behind another
  request's batch, waiting for a transfer slot, scheduler gaps).

``stall`` is computed as ``e2e - covered`` so the five components sum
to e2e exactly up to accumulation rounding (property-tested at 1e-6).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

from repro.obs.spans import CATEGORY_PRIORITY, Span

ATTRIBUTION_KEYS = ("queue_s", "compute_s", "comm_s", "preempt_s", "stall_s")

_PRIO = {c: i for i, c in enumerate(CATEGORY_PRIORITY)}


def attribution_for(spans: Iterable[Span], arrival: float,
                    finish: float) -> Dict[str, float]:
    """Attribution dict for one request from its recorded spans."""
    e2e = max(finish - arrival, 0.0)
    out = {k: 0.0 for k in ATTRIBUTION_KEYS}
    if e2e <= 0.0:
        return out
    # clipped (start, end, priority) intervals
    ivals: List[Tuple[float, float, int]] = []
    for s in spans:
        cat = s.category
        if cat is None:
            continue
        a = s.start if s.start > arrival else arrival
        b = s.end if s.end < finish else finish
        if b > a:
            ivals.append((a, b, _PRIO[cat]))
    if not ivals:
        out["stall_s"] = e2e
        return out
    # sweep over elementary intervals between all boundaries; each
    # elementary interval is owned by the highest-priority category
    # covering it
    bounds = sorted({v for a, b, _ in ivals for v in (a, b)})
    sums = [0.0] * len(CATEGORY_PRIORITY)
    covered = 0.0
    for i in range(len(bounds) - 1):
        lo, hi = bounds[i], bounds[i + 1]
        best = -1
        for a, b, p in ivals:
            if a <= lo and b >= hi and (best < 0 or p < best):
                best = p
                if p == 0:
                    break
        if best >= 0:
            w = hi - lo
            sums[best] += w
            covered += w
    out["compute_s"] = sums[_PRIO["compute"]]
    out["comm_s"] = sums[_PRIO["comm"]]
    out["preempt_s"] = sums[_PRIO["preempt"]]
    out["queue_s"] = sums[_PRIO["queue"]]
    out["stall_s"] = max(e2e - covered, 0.0)
    return out


def aggregate_fractions(records) -> Dict[str, float]:
    """Fleet/run-level attribution fractions over all finished requests:
    per-category seconds summed across requests, divided by total e2e."""
    tot = {k: 0.0 for k in ATTRIBUTION_KEYS}
    e2e = 0.0
    for rec in records:
        e2e += rec.e2e
        for k in ATTRIBUTION_KEYS:
            tot[k] += rec.attribution[k]
    if e2e <= 0.0:
        return {k.replace("_s", "_frac"): 0.0 for k in ATTRIBUTION_KEYS}
    return {k.replace("_s", "_frac"): tot[k] / e2e for k in ATTRIBUTION_KEYS}
