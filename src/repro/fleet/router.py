"""Pluggable global routing policies for multi-instance fleets.

A :class:`FleetRouter` picks which serving *instance* (a full deployment —
its own controller, clusters, KV caches) receives each arriving request.
This is the layer above intra-instance routing: once an instance is
chosen, its GlobalController still load-balances across its own entry
replicas.  Policies are registered in ``FLEET_ROUTERS`` and resolved with
:func:`resolve_fleet_router` (mirroring the MoE-router / batching /
scheduler registries), so specs select them by name::

    fleet:
      router: prefix_affinity            # or {"name": "power_of_two"}

Instances expose two signals routers may read: ``outstanding()`` (requests
submitted and not yet complete) and ``prefix_probe(r)`` (cached-prefix
tokens the instance's entry caches would serve this request).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

import numpy as np


class FleetRouter:
    """Chooses an instance for each arrival; stateful policies allowed
    (state must be driven only by the deterministic event order).

    The FleetController sets ``self.fleet`` after construction; routers
    may read its O(1) aggregate load signals (``outstanding_total``,
    ``all_active()``) instead of summing per-instance state on every
    arrival — bit-identical when every instance is routable.
    """

    name = "base"
    fleet = None                # set by FleetController.__init__

    def select(self, r, instances: Sequence, now: float,
               rng: np.random.Generator):
        """Return one of ``instances`` (never empty, all routable)."""
        raise NotImplementedError


class RoundRobinRouter(FleetRouter):
    """Cycle through routable instances in stable (creation) order."""

    name = "round_robin"

    def __init__(self):
        self._i = 0

    def select(self, r, instances, now, rng):
        inst = instances[self._i % len(instances)]
        self._i += 1
        return inst


class LeastOutstandingRouter(FleetRouter):
    """Global least-loaded: fewest submitted-but-incomplete requests."""

    name = "least_outstanding"

    def select(self, r, instances, now, rng):
        return min(instances, key=lambda i: (i.outstanding(), i.name))


class PowerOfTwoRouter(FleetRouter):
    """Power-of-two-choices: sample two instances, keep the less loaded —
    near-optimal balance at O(1) state (Mitzenmacher), and the standard
    production compromise when polling every instance is too chatty."""

    name = "power_of_two"

    def select(self, r, instances, now, rng):
        if len(instances) < 2:
            return instances[0]
        a, b = rng.choice(len(instances), size=2, replace=False)
        return min((instances[int(a)], instances[int(b)]),
                   key=lambda i: (i.outstanding(), i.name))


class PrefixAffinityRouter(FleetRouter):
    """Cache-aware routing: requests of a shared-prefix group stick to the
    instance whose prefix cache holds (or will hold) their prefix.

    The first request of a group is placed least-loaded and recorded as the
    group's home; later members follow it — unless the home is gone
    (drained/stopped) or overloaded past ``overload_factor`` times the
    fleet mean, in which case they divert least-loaded *without* moving the
    home (a temporary spill, not a cache migration).  When no home is
    recorded the router probes actual caches (``prefix_probe``) so it
    re-discovers prefixes that outlive their routing state.
    """

    name = "prefix_affinity"

    def __init__(self, overload_factor: float = 2.0):
        if overload_factor <= 1.0:
            raise ValueError(f"overload_factor must be > 1, "
                             f"got {overload_factor}")
        self.overload_factor = overload_factor
        self._home: Dict[int, str] = {}      # prefix_id -> instance name

    def _least(self, instances):
        return min(instances, key=lambda i: (i.outstanding(), i.name))

    def select(self, r, instances, now, rng):
        pid = getattr(r, "prefix_id", None)
        if pid is None:
            return self._least(instances)
        by_name = {i.name: i for i in instances}
        home = by_name.get(self._home.get(pid))
        if home is None:
            hits = [(i.prefix_probe(r), i.name, i) for i in instances]
            best = max(hits, key=lambda h: (h[0], h[1]))
            home = best[2] if best[0] > 0 else self._least(instances)
            self._home[pid] = home.name
            return home
        fleet = self.fleet
        if fleet is not None and fleet.all_active():
            # candidates == all instances: the maintained total replaces
            # the O(n_instances) sum (exact, not approximate)
            mean = fleet.outstanding_total / len(instances)
        else:
            mean = sum(i.outstanding() for i in instances) / len(instances)
        if home.outstanding() > self.overload_factor * (mean + 1.0):
            return self._least(instances)
        return home


FLEET_ROUTERS = {
    "round_robin": RoundRobinRouter,
    "least_outstanding": LeastOutstandingRouter,
    "power_of_two": PowerOfTwoRouter,
    "prefix_affinity": PrefixAffinityRouter,
}


def resolve_fleet_router(spec: Union[None, str, dict, FleetRouter],
                         ) -> FleetRouter:
    """Uniform fleet-router argument handling (mirrors resolve_router).

    Accepts an instance (returned as-is), a registered name, a mapping
    ``{"name": ..., **kwargs}`` whose kwargs go to the constructor (e.g.
    ``{"name": "prefix_affinity", "overload_factor": 3.0}``), or None
    (the least_outstanding default).
    """
    if spec is None:
        return LeastOutstandingRouter()
    if isinstance(spec, FleetRouter):
        return spec
    if isinstance(spec, str):
        spec = {"name": spec}
    if isinstance(spec, dict):
        kw = dict(spec)
        name = kw.pop("name", None)
        try:
            cls = FLEET_ROUTERS[name]
        except KeyError:
            raise KeyError(f"unknown fleet router {name!r}; registered: "
                           f"{sorted(FLEET_ROUTERS)}")
        try:
            return cls(**kw)
        except (TypeError, ValueError) as e:
            raise TypeError(f"fleet router {name!r} could not be "
                            f"constructed from {kw!r} ({e})") from e
    raise TypeError(f"fleet router must be None, a name, a mapping, or a "
                    f"FleetRouter; got {type(spec).__name__}")
