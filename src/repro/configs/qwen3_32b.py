"""qwen3-32b — dense, qk_norm, GQA kv=8.  [hf:Qwen/Qwen3-8B family; hf]

HF-faithful head_dim=128 (so q-proj is 5120 -> 64*128=8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
