"""Calibration & fidelity: close the sim-to-real loop.

Measure per-operator latency against an oracle (real Pallas kernels,
virtual-kernel simulator, or HLO-cost proxy), fit the refined forest
models, persist them as versioned artifacts, load them into ``run(spec)``
via ``OpModelSpec.calibration``, and track simulator-vs-oracle error as a
CI-gated trajectory (repo-root ``FIDELITY.json``).

    python -m repro calibrate --oracle kernelsim --model qwen2-7b
"""
from repro.calib.artifacts import (
    ARTIFACT_VERSION, CalibrationArtifact, CalibrationError, artifact_path,
    discover_artifacts, load_artifact, load_calibrated_ops, save_artifact,
)
from repro.calib.fidelity import (
    append_fidelity, check_fidelity_regression, entry_from_result,
    load_trajectory,
)
from repro.calib.fit import CalibrationResult, calibrate
from repro.calib.grid import (
    AttentionSample, CalibGrid, GroupedGemmSample, attention_grid,
    build_grid, geometry_of, grouped_gemm_grid, moe_geometry_of,
)
from repro.calib.oracle import (
    ORACLES, HLOCostOracle, KernelSimOracle, Oracle, PallasOracle,
    default_oracle_name, resolve_oracle,
)

__all__ = [
    "ARTIFACT_VERSION", "AttentionSample", "CalibGrid",
    "CalibrationArtifact", "CalibrationError", "CalibrationResult",
    "GroupedGemmSample", "HLOCostOracle", "KernelSimOracle", "ORACLES",
    "Oracle", "PallasOracle", "append_fidelity", "artifact_path",
    "attention_grid", "build_grid", "calibrate",
    "check_fidelity_regression", "default_oracle_name",
    "discover_artifacts", "entry_from_result", "geometry_of",
    "grouped_gemm_grid", "load_artifact", "load_calibrated_ops",
    "load_trajectory", "moe_geometry_of", "resolve_oracle",
    "save_artifact",
]
