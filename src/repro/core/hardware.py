"""Hardware profiles for the execution predictor and operator models."""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # bf16/fp16 dense FLOP/s per device
    hbm_bw: float              # bytes/s per device
    hbm_capacity: float        # bytes per device
    intra_node_bw: float       # bytes/s per device (NVLink / ICI all links)
    inter_node_bw: float       # bytes/s per device (IB / DCN)
    devices_per_node: int
    # kernel-launch / framework overhead floor per operator invocation
    op_overhead: float = 3e-6
    # tile geometry used by the virtual-kernel simulator (kernelsim)
    n_cores: int = 108         # SMs (GPU) or tensor-cores (TPU)
    mxu_tile: int = 128
    # provisioning cost per device (on-demand $/GPU-hr); 0.0 = unpriced
    dollars_per_hour: float = 0.0

    def with_(self, **kw) -> "HardwareSpec":
        return replace(self, **kw)


# NVIDIA A800-SXM4-80G: A100 silicon, NVLink capped at 400 GB/s (paper setup)
A800_SXM4_80G = HardwareSpec(
    name="A800-SXM4-80G",
    peak_flops=312e12,
    hbm_bw=2.039e12,
    hbm_capacity=80e9,
    intra_node_bw=400e9,
    inter_node_bw=25e9,
    devices_per_node=8,
    n_cores=108,
    dollars_per_hour=1.90,
)

H100_SXM = HardwareSpec(
    name="H100-SXM",
    peak_flops=989e12,
    hbm_bw=3.35e12,
    hbm_capacity=80e9,
    intra_node_bw=900e9,
    inter_node_bw=50e9,
    devices_per_node=8,
    n_cores=132,
    dollars_per_hour=3.90,
)

# TPU v5e: the dry-run/roofline target (197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s per ICI link; 2D torus, 4 links/chip).
TPU_V5E = HardwareSpec(
    name="TPU-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    hbm_capacity=16e9,
    intra_node_bw=4 * 50e9,
    inter_node_bw=25e9,
    devices_per_node=256,      # one pod
    n_cores=2,                 # tensor cores per chip
    mxu_tile=128,
    dollars_per_hour=1.20,
)

HARDWARE = {h.name: h for h in (A800_SXM4_80G, H100_SXM, TPU_V5E)}


@dataclass(frozen=True)
class LinkSpec:
    """A directed inter-cluster link (asymmetric bandwidths are two links)."""
    src: str                   # source cluster name
    dst: str                   # destination cluster name
    bandwidth: float           # bytes/s
    latency: float = 0.0       # base latency per transfer (s)

    def transfer_time(self, nbytes: float) -> float:
        if self.bandwidth <= 0:
            # previously bandwidth=0 silently priced the transfer as free;
            # spec-level validation rejects it up front, and this guard
            # catches programmatic LinkSpec construction
            raise ValueError(
                f"link {self.src}->{self.dst}: bandwidth must be > 0 "
                f"(got {self.bandwidth}); a free link is almost certainly "
                f"a spec mistake — use a large finite bandwidth instead")
        return self.latency + nbytes / self.bandwidth


@dataclass(frozen=True)
class ParallelismConfig:
    """Per-replica parallelism degrees (a replica = one model instance)."""
    tp: int = 1                # tensor parallel
    pp: int = 1                # pipeline parallel
    dp: int = 1                # data parallel (replica count handled above)
    ep: int = 1                # expert parallel (within tp*... group)
    # AF disaggregation: attention/FFN device splits (MegaScale/Step-3)
    attn_devices: int = 0
    ffn_devices: int = 0

    @property
    def devices(self) -> int:
        return self.tp * self.pp
