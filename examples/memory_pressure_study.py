"""Memory & KV-cache study: prefix caching, preemption, streamed transfer.

Three sweeps over the first-class memory subsystem (PR: KVCacheManager +
preemption/restore + layer-wise streamed KV transfer):

1. **Prefix caching under pressure** — cache size (``capacity_frac``) x
   prefix-share ratio for a PD system on a shared-system-prompt fleet:
   the radix prefix cache reports its hit-token fraction and beats the
   plain paged manager on tail TTFT because cached prefill compute is
   skipped.

2. **Layer-wise streamed KV transfer** — transfer overlap x preset
   (PD / AF): per-layer KV chunks pipeline behind remaining prefill
   layers, shrinking the exposed-transfer fraction; ``overlap=0``
   reproduces the legacy lump-sum timings bit-for-bit.

3. **Preemption policies** — decode OOM under shrinking cache sizes:
   recompute vs swap restore, with zero stalled/leaked requests and block
   conservation at every point (also run colocated for coverage).

    PYTHONPATH=src python examples/memory_pressure_study.py
"""
from repro.api import SimSpec, run


def _pd_spec(**overrides):
    d = {
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "pd", "n_prefill": 1, "n_decode": 1},
        "workload": {"n_requests": 60, "rate": 120.0, "prompt_mean": 512,
                     "output_mean": 32, "seed": 5},
        "seed": 5,
        "name": "memory-study",
    }
    d.update(overrides)
    return SimSpec.from_dict(d)


def prefix_caching_study():
    print("== Prefix caching under memory pressure (PD, shared prompts) ==")
    print(f"{'manager':>8s} {'cap_frac':>9s} {'groups':>7s} "
          f"{'hit_frac':>9s} {'ttft_p99(ms)':>13s} {'prefill_toks':>13s}")
    for cap in (0.01, 0.001):
        for groups in (2, 8):
            base_wl = {"n_requests": 60, "rate": 120.0, "prompt_mean": 512,
                       "output_mean": 32, "prefix_groups": groups,
                       "prefix_len": 2048, "seed": 5}
            reps = {}
            for mgr in ("paged", "prefix"):
                spec = _pd_spec(
                    workload=base_wl,
                    memory={"manager": mgr, "capacity_frac": cap})
                reps[mgr] = run(spec)
                assert reps[mgr].all_complete, reps[mgr].conservation
                hit = reps[mgr].summary.get("prefix_hit_token_frac")
                toks = sum(
                    r["prefill_tokens"] for r in
                    reps[mgr].clusters["prefill"]["replicas"].values())
                print(f"{mgr:>8s} {cap:9.4f} {groups:7d} "
                      f"{'-' if hit is None else f'{hit:.1%}':>9s} "
                      f"{reps[mgr]['ttft_p99_s'] * 1e3:13.2f} {toks:13d}")
            assert reps["prefix"].summary["prefix_hit_token_frac"] > 0, \
                "shared-prefix workload must produce cache hits"
            assert reps["prefix"]["ttft_p99_s"] <= reps["paged"]["ttft_p99_s"], \
                "prefix caching must not lose on tail TTFT under pressure"
    print("Reading: fewer prompt groups -> hotter prefixes -> higher hit "
          "fractions; skipped prefill compute shows up directly in tail "
          "TTFT.\n")


def streamed_transfer_study():
    print("== Layer-wise streamed KV transfer: overlap x preset ==")
    print(f"{'preset':>6s} {'overlap':>8s} {'exposed_frac':>13s} "
          f"{'exposed(ms)':>12s} {'serial(ms)':>11s}")
    for preset, model in (("pd", "qwen2-7b"), ("af", "mixtral-8x7b")):
        legacy = None
        for ov in (0.0, 0.5, 1.0):
            spec = _pd_spec(
                model={"name": model, "smoke": True},
                topology={"preset": preset, "n_prefill": 1, "n_decode": 1},
                memory={"manager": "paged", "transfer_overlap": ov})
            rep = run(spec)
            assert rep.all_complete
            s = rep.summary
            print(f"{preset:>6s} {ov:8.1f} "
                  f"{s['kv_transfer_exposed_frac']:13.1%} "
                  f"{s['kv_transfer_exposed_s'] * 1e3:12.3f} "
                  f"{s['kv_transfer_serial_s'] * 1e3:11.3f}")
            if ov == 0.0:
                legacy = _pd_spec(
                    model={"name": model, "smoke": True},
                    topology={"preset": preset, "n_prefill": 1,
                              "n_decode": 1})
                lump = run(legacy)
                same = {k: v for k, v in rep.summary.items()
                        if not k.startswith("kv_transfer")}
                lump_cmp = {k: v for k, v in lump.summary.items()
                            if not k.startswith("kv_transfer")}
                assert same == lump_cmp, \
                    "overlap=0 must reproduce legacy timings bit-for-bit"
                assert s["kv_transfer_exposed_frac"] == 1.0
            else:
                assert s["kv_transfer_exposed_frac"] < 1.0, \
                    "streaming must hide part of the transfer"
    print("Reading: streaming hides all but the last layer's chunk; the "
          "AF preset moves less KV per token, so its absolute win is "
          "smaller.\n")


def preemption_study():
    print("== Preemption/restore: recompute vs swap across cache sizes ==")
    print(f"{'preset':>9s} {'policy':>10s} {'cap_frac':>9s} "
          f"{'preempts':>9s} {'swaps':>6s} {'e2e_p99(s)':>11s} "
          f"{'complete':>9s}")
    wl = {"n_requests": 40, "arrival": "burst", "burst_size": 40,
          "burst_period": 1.0, "prompt": "fixed", "prompt_mean": 64,
          "output": "fixed", "output_mean": 2048, "seed": 7}
    for preset in ("pd", "colocated"):
        topo = {"preset": preset}
        if preset == "pd":
            topo.update(n_prefill=1, n_decode=1)
        decode_cluster = "decode" if preset == "pd" else "colocated"
        for mode in ("recompute", "swap"):
            for cap in (0.001, 0.0002):
                spec = _pd_spec(
                    topology=topo, workload=wl, seed=7,
                    memory={"manager": "paged", "capacity_frac": cap,
                            "preemption": mode})
                rep = run(spec)
                # zero stalled/leaked requests, whatever the pressure
                assert rep.all_complete, (preset, mode, cap,
                                          rep.conservation)
                mem = rep.clusters[decode_cluster]["memory"]
                print(f"{preset:>9s} {mode:>10s} {cap:9.4f} "
                      f"{rep.summary['preemptions']:9d} "
                      f"{mem['swap_outs']:6d} "
                      f"{rep['e2e_p99_s']:11.2f} "
                      f"{str(rep.all_complete):>9s}")
    print("Reading: swap trades PCIe restore time for recompute FLOPs — "
          "under tight memory both finish, with different tail latency "
          "costs.")


def main():
    prefix_caching_study()
    streamed_transfer_study()
    preemption_study()


if __name__ == "__main__":
    main()
