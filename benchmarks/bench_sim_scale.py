"""Simulator performance & feature coverage.

- events/second and simulated-vs-wall time for large serving simulations
  (the practicality argument: exploring an 18k-GPU-hour config space needs
  a fast simulator);
- Table-1 feature matrix exercised programmatically (PD, AF, PP/TP/DP/EP,
  cross-cluster EP, pluggable scheduling) — each cell is an actual
  simulation run.

``--smoke`` shrinks the workloads for CI (same code paths, seconds not
minutes).
"""
from __future__ import annotations

import argparse
import time
from typing import List

from repro.configs import get_config
from repro.core import A800_SXM4_80G, LinkSpec, ParallelismConfig
from repro.core.policies.batching import ChunkedPrefill, ContinuousBatching
from repro.core.routing import ZipfRouting
from repro.core.workflows.af_disagg import build_af
from repro.core.workflows.colocated import build_colocated
from repro.core.workflows.pd_disagg import build_pd
from repro.workload.generator import WorkloadConfig, generate


def run(smoke: bool = False) -> List[str]:
    hw = A800_SXM4_80G
    cfg = get_config("qwen2-7b")
    lines = []

    # ---- scale: 16-replica cluster, 2000 requests --------------------------
    n_scale = 200 if smoke else 2000
    wl = WorkloadConfig(n_requests=n_scale, rate=200.0, prompt_mean=512,
                        output_mean=128, seed=0)
    sys = build_colocated(cfg, hw, n_replicas=16,
                          par=ParallelismConfig(tp=4))
    t0 = time.perf_counter()
    rep = sys.run(generate(wl))
    wall = time.perf_counter() - t0
    ev = sys.engine.processed
    lines.append(
        f"sim_scale_16replica_{n_scale}req,{wall * 1e6 / max(ev, 1):.2f},"
        f"events={ev};events_per_s={ev / wall:,.0f};"
        f"sim_speedup={rep['duration_s'] / wall:.1f}x;"
        f"completed={rep['n_completed']}")

    # ---- Table-1 feature matrix --------------------------------------------
    mcfg = get_config("mixtral-8x7b")
    cells = {
        "pd": lambda: build_pd(cfg, hw, n_prefill=2, n_decode=2,
                               prefill_par=ParallelismConfig(tp=2),
                               decode_par=ParallelismConfig(tp=2)),
        "af": lambda: build_af(mcfg, hw, m=2,
                               attn_par=ParallelismConfig(tp=2),
                               ffn_par=ParallelismConfig(tp=1, ep=8),
                               routing=ZipfRouting(1.1)),
        "af_cross_cluster_ep": lambda: build_af(
            mcfg, hw, m=2,
            attn_par=ParallelismConfig(tp=2),
            ffn_par=ParallelismConfig(tp=1, ep=8),
            remote_expert_ranks=(6, 7),
            expert_link=LinkSpec("decode", "experts", bandwidth=25e9,
                                 latency=5e-6),
            routing=ZipfRouting(1.1)),
        "tp_pp": lambda: build_colocated(cfg, hw,
                                         par=ParallelismConfig(tp=4, pp=2)),
        "dp": lambda: build_colocated(cfg, hw, n_replicas=4),
        "ep": lambda: build_colocated(mcfg, hw,
                                      par=ParallelismConfig(tp=8, ep=8),
                                      routing="zipf"),
        "sched_chunked_prefill": lambda: build_colocated(
            cfg, hw, policy=ChunkedPrefill(chunk=256)),
        "sched_continuous": lambda: build_colocated(
            cfg, hw, policy=ContinuousBatching()),
    }
    n_cell = 20 if smoke else 100
    for name, builder in cells.items():
        wl = WorkloadConfig(n_requests=n_cell, rate=20.0, seed=1)
        t0 = time.perf_counter()
        rep = builder().run(generate(wl))
        wall = time.perf_counter() - t0
        ok = rep["n_completed"] == n_cell
        lines.append(
            f"table1_{name},{wall * 1e6:.0f},"
            f"supported={'yes' if ok else 'NO'};"
            f"tok_s_dev={rep['throughput_tok_s_per_device']:.1f};"
            f"ttft_p50={rep['ttft_p50_s'] * 1e3:.1f}ms")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workloads for CI")
    args = ap.parse_args()
    for l in run(smoke=args.smoke):
        print(l)
