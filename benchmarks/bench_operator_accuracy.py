"""Paper Fig. 2: CDF of relative error in simulated operator runtime.

Frontier's fitted RF models vs the Vidur sqrt-proxy vs the analytical
roofline, on held-out heterogeneous batches — driven by the calibration
subsystem (``repro.calib.calibrate``), so the bench measures exactly the
models ``run(spec)`` would price steps with.

    PYTHONPATH=src python benchmarks/bench_operator_accuracy.py \
        --json bench_accuracy.json

``--json`` emits the same shape as ``bench_sim_scale.py --json`` (a
``smoke`` flag + per-cell dicts), one cell per operator.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

from repro.calib import calibrate

# attention comes from the paper's eval model (qwen2-7b); mixtral supplies
# the MoE expert dims for the GroupedGEMM cell
ATTENTION_MODEL = "qwen2-7b"
MOE_MODEL = "mixtral-8x7b"


def run_bench(n_train: int = 900, n_eval: int = 150, seed: int = 0,
              oracle: str = "kernelsim", smoke: bool = False,
              ) -> Tuple[List[str], Dict]:
    results: Dict = {"smoke": smoke, "oracle": oracle, "n_train": n_train,
                     "n_eval": n_eval}
    lines: List[str] = []
    # one calibration per source model; no artifacts written (bench mode)
    for model, op in ((ATTENTION_MODEL, "attention"),
                      (MOE_MODEL, "grouped_gemm")):
        res = calibrate(model=model, oracle=oracle, smoke=smoke,
                        n_train=n_train, n_eval=n_eval, seed=seed,
                        out_root=None)
        fams = res.fidelity[op]
        results[op] = {
            "model": res.model, "hardware": res.hardware,
            "oracle": res.oracle, "n_train": n_train, "n_eval": n_eval,
            "wall_s": round(res.wall_s, 3),
            "families": {f: {k: round(v, 6) for k, v in s.items()}
                         for f, s in fams.items()},
        }
        for fam in ("fitted", "analytical", "vidur_proxy"):
            s = fams[fam]
            lines.append(
                f"fig2_{op}_{fam},mape={s['mape']:.4f};p50={s['p50']:.4f};"
                f"p99={s['p99']:.4f}")
    return lines, results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results (bench_sim_scale "
                         "shape) to PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model geometry + grid for CI")
    ap.add_argument("--oracle", default="kernelsim",
                    help="ground-truth backend (default kernelsim)")
    ap.add_argument("--n-train", type=int, default=None)
    ap.add_argument("--n-eval", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n_train = args.n_train or (160 if args.smoke else 900)
    n_eval = args.n_eval or (60 if args.smoke else 150)
    lines, results = run_bench(n_train=n_train, n_eval=n_eval,
                               seed=args.seed, oracle=args.oracle,
                               smoke=args.smoke)
    for line in lines:
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"results -> {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
