"""Sim-time counter timeseries with bounded memory.

Counters are sampled at simulation events (never wall clock): batch
occupancy when a batch is planned, KV blocks at batch boundaries, fabric
flow counts at every repricing, $-burn at scale events.  Long runs would
otherwise accumulate unbounded points, so each series is *windowed
down*: when a series exceeds ``2 * max_points`` it is decimated by
merging adjacent sample pairs (keeping the first timestamp and the
max value — counters here are gauges, and the max preserves the peaks
that diagnosis cares about).  The result is at most ``2 * max_points``
samples per series at any moment, with uniform-in-index coverage of the
whole run.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Tuple


class CounterBoard:
    """Named (t, value) series keyed by counter name."""

    def __init__(self, max_points: int = 4096):
        self.max_points = max(int(max_points), 2)
        self._series: Dict[str, List[Tuple[float, float]]] = {}
        # per-series downsampling stride (grows by 2x each decimation)
        self._stride: Dict[str, int] = {}
        self._skip: Dict[str, int] = {}
        # per-series identity scope for export: (replica, instance)
        self._scope: Dict[str, Tuple[str, str]] = {}

    def sample(self, name: str, t: float, value: float, *,
               replica: str = "", instance: str = "") -> None:
        pts = self._series.get(name)
        if pts is None:
            pts = self._series[name] = []
            self._stride[name] = 1
            self._skip[name] = 0
            self._scope[name] = (replica, instance)
        stride = self._stride[name]
        if stride > 1:
            # drop (stride - 1) of every stride incoming samples, but
            # fold their value into the kept point's max so peaks survive
            skip = self._skip[name]
            if skip:
                self._skip[name] = skip - 1
                last = pts[-1]
                if value > last[1]:
                    pts[-1] = (last[0], value)
                return
            self._skip[name] = stride - 1
        pts.append((t, value))
        if len(pts) > 2 * self.max_points:
            self._decimate(name)

    def _decimate(self, name: str) -> None:
        pts = self._series[name]
        merged = []
        for i in range(0, len(pts) - 1, 2):
            t0, v0 = pts[i]
            v1 = pts[i + 1][1]
            merged.append((t0, v0 if v0 >= v1 else v1))
        if len(pts) % 2:
            merged.append(pts[-1])
        self._series[name] = merged
        self._stride[name] *= 2
        self._skip[name] = 0

    def series(self, name: str) -> List[Tuple[float, float]]:
        return list(self._series.get(name, ()))

    def scope(self, name: str) -> Tuple[str, str]:
        return self._scope.get(name, ("", ""))

    def names(self) -> List[str]:
        return sorted(self._series)

    def __len__(self) -> int:
        return len(self._series)

    def items(self) -> Iterator[Tuple[str, List[Tuple[float, float]]]]:
        for name in self.names():
            yield name, self._series[name]

    def last(self, name: str) -> Optional[float]:
        pts = self._series.get(name)
        return pts[-1][1] if pts else None

    def to_dict(self) -> dict:
        return {name: [[t, v] for t, v in pts] for name, pts in self.items()}
