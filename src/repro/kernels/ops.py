"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute via ``interpret=True`` — the
kernel body runs in Python per grid step, which validates correctness
against ref.py.  On TPU the same ``pl.pallas_call`` compiles natively
(``interpret=False`` is selected automatically).

Head dims that are not MXU-lane aligned (kimi's 112) are zero-padded to the
next multiple of 128 here, not inside the kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import grouped_gemm as _gg


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_hd(x: jax.Array, align: int = 128):
    hd = x.shape[-1]
    pad = (-hd) % align
    if pad == 0:
        return x, hd
    cfgpad = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, cfgpad), hd


def flash_attention(q, k, v, *, causal=True, window=0, bq=128, bk=128):
    qp, hd = _pad_hd(q)
    kp, _ = _pad_hd(k)
    vp, _ = _pad_hd(v)
    # note: padding v's head dim just widens the output; sliced below.
    # scale must use the true head dim:
    out = _fa.flash_attention(qp * (hd ** -0.5) / (qp.shape[-1] ** -0.5),
                              kp, vp, causal=causal, window=window,
                              bq=bq, bk=bk, interpret=_interpret())
    return out[..., :hd]


def decode_attention(q, k, v, lengths, *, bk=256):
    qp, hd = _pad_hd(q)
    kp, _ = _pad_hd(k)
    vp, _ = _pad_hd(v)
    out = _dec.decode_attention(qp * (hd ** -0.5) / (qp.shape[-1] ** -0.5),
                                kp, vp, lengths, bk=bk,
                                interpret=_interpret())
    return out[..., :hd]


def grouped_gemm(x, w, group_sizes, *, bm=128, bn=128, bkk=512):
    return _gg.grouped_gemm(x, w, group_sizes, bm=bm, bn=bn, bkk=bkk,
                            interpret=_interpret())


def wkv_chunked(r, k, v, w, u, *, chunk=16):
    from repro.kernels import wkv_chunk as _wkv
    return _wkv.wkv_chunked(r, k, v, w, u, chunk=chunk,
                            interpret=_interpret())
