"""Per-architecture reduced smoke tests + decode-path consistency.

Every assigned arch instantiates its reduced config and runs one forward /
train step on CPU (shape + finiteness).  For a representative subset
covering every block family we additionally assert PREFILL+DECODE ==
TEACHER-FORCED FORWARD — the strongest correctness property of the cache
path (ring buffers, RoPE positions, recurrent states, cross-attention).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import NO_RULES, build_model, init_tree

B, S = 2, 16


def _batch(cfg, rng, S=S):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.encoder_layers:
        frames = jnp.asarray(rng.normal(size=(B, S, cfg.frontend_dim)),
                             jnp.float32)
        return {"frames": frames, "tokens": toks, "labels": toks}
    if cfg.frontend == "patch":
        Sp = 4
        emb = jnp.asarray(rng.normal(size=(B, Sp, cfg.d_model)), jnp.float32)
        return {"tokens": toks[:, Sp:], "embeds": emb, "labels": toks}
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, NO_RULES)
    params = init_tree(jax.random.PRNGKey(0), model.pds(), jnp.float32)
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    # one gradient step: grads finite, shapes preserved
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", [
    "yi-9b",                  # plain GQA global attention
    "qwen3-8b",               # qk_norm
    "gemma2-27b",             # local/global alternation + softcaps + postnorm
    "mixtral-8x7b",           # MoE + sliding window (ring cache)
    "rwkv6-1.6b",             # rwkv recurrence
    "recurrentgemma-2b",      # RG-LRU + conv + local attn (period-3 + tail)
    "seamless-m4t-large-v2",  # enc-dec with cross-attention
    "pixtral-12b",            # patch-embed frontend
])
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, NO_RULES)
    params = init_tree(jax.random.PRNGKey(1), model.pds(), jnp.float32)
    rng = np.random.default_rng(1)
    S_all = 12
    batch = _batch(cfg, rng, S=S_all)
    toks = batch["tokens"]
    S_txt = toks.shape[1]
    k = S_txt - 3   # prefill prefix length (text tokens)

    if cfg.encoder_layers:
        full_logits, _ = jax.jit(
            lambda p, b: model.prefill(p, b, all_logits=True))(
                params, {"frames": batch["frames"], "tokens": toks})
        pre = {"frames": batch["frames"], "tokens": toks[:, :k]}
    elif cfg.frontend == "patch":
        full_logits, _ = jax.jit(
            lambda p, b: model.prefill(p, b, all_logits=True))(
                params, {"tokens": toks, "embeds": batch["embeds"]})
        pre = {"tokens": toks[:, :k], "embeds": batch["embeds"]}
    else:
        full_logits, _ = jax.jit(
            lambda p, b: model.prefill(p, b, all_logits=True))(
                params, {"tokens": toks})
        pre = {"tokens": toks[:, :k]}

    # prefill prefix, then decode the remaining tokens teacher-forced
    patch_off = batch["embeds"].shape[1] if cfg.frontend == "patch" else 0
    _, cache = model.prefill(params, pre, cache_len=S_txt + patch_off)
    decode = jax.jit(model.decode)
    for t in range(k, S_txt):
        pos = jnp.int32(t + patch_off)
        logits, cache = decode(params, cache, toks[:, t:t + 1], pos)
        want = full_logits[:, t + patch_off]
        got = logits[:, 0]
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=2e-3, rtol=2e-3)


def test_per_row_positions_match_uniform():
    """Vector pos with equal entries must equal scalar pos decode."""
    cfg = get_config("yi-9b", smoke=True)
    model = build_model(cfg, NO_RULES)
    params = init_tree(jax.random.PRNGKey(2), model.pds(), jnp.float32)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 8)), jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks}, cache_len=16)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    l1, _ = model.decode(params, cache, nxt, jnp.int32(8))
    l2, _ = model.decode(params, cache, nxt, jnp.full((B,), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-5, rtol=1e-5)


def test_param_counts_match_full_configs():
    """Full-config param counts are in the advertised ballpark."""
    expect = {
        "yi-9b": (8.0e9, 10.5e9),
        "qwen3-8b": (7.5e9, 9.5e9),
        "qwen3-32b": (31e9, 36e9),
        "gemma2-27b": (26e9, 30e9),
        "mixtral-8x7b": (45e9, 49e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"
