"""Quickstart: simulate colocated vs PD-disaggregated serving of qwen2-7b.

Runs in seconds on CPU.  Shows the core Frontier workflow through the
declarative experiment API: describe the system as a `SimSpec`, `run` it,
read the typed `Report`.  The same specs serialize to YAML — see
`examples/specs/quickstart.yaml` and `python -m repro run`.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import ModelRef, SimSpec, TopologySpec, WorkloadSpec, run


def main():
    wl = WorkloadSpec(n_requests=200, rate=12.0, prompt_mean=1024,
                      output_mean=128)
    colo = SimSpec(name="colocated-2xTP1", model=ModelRef("qwen2-7b"),
                   topology=TopologySpec(preset="colocated", n_replicas=2,
                                         tp=1),
                   workload=wl, seed=0)
    pd = colo.with_(**{"name": "pd-1P1D",
                       "topology": {"preset": "pd", "n_prefill": 1,
                                    "n_decode": 1}})

    rep_c = run(colo)
    rep_p = run(pd)

    print(f"{'metric':28s} {'colocated(2xTP1)':>18s} {'PD(1P+1D)':>14s}")
    for k in ("throughput_tok_s_per_device", "ttft_p50_s", "ttft_p99_s",
              "tpot_p50_s", "tpot_p99_s", "e2e_p50_s", "queue_p99_s"):
        print(f"{k:28s} {rep_c[k]:18.4f} {rep_p[k]:14.4f}")
    print("\nPD decouples decode interactivity from long prefills "
          "(compare tpot_p99).")
    print(f"provenance: spec {rep_p.spec_hash}, {rep_p.sim_events} events "
          f"in {rep_p.wall_clock_s:.2f}s wall clock")


if __name__ == "__main__":
    main()
