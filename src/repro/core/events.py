"""Event definitions for the stage-centric simulation.

Events are the *native primitives* of Frontier's abstraction: requests flow
through a distributed system as a graph of timed events (arrival, batch
execution, KV transfer, memory signals, micro-batch pipeline stages), not as
monolithic replica-level steps.
"""
from __future__ import annotations

import enum
import itertools
from typing import Any, Callable, Dict, Optional


class EV(enum.Enum):
    # request lifecycle
    REQUEST_ARRIVAL = "request_arrival"
    PREFILL_ENQUEUE = "prefill_enqueue"
    PREFILL_COMPLETE = "prefill_complete"
    KV_TRANSFER_START = "kv_transfer_start"
    KV_TRANSFER_DONE = "kv_transfer_done"
    DECODE_ENQUEUE = "decode_enqueue"
    TOKEN_GENERATED = "token_generated"
    REQUEST_COMPLETE = "request_complete"
    # cluster-level
    BATCH_START = "batch_start"
    BATCH_DONE = "batch_done"
    MEMORY_AVAILABLE = "memory_available"
    # preemption/restore (KV swapped to host memory and back)
    SWAP_OUT_DONE = "swap_out_done"
    SWAP_IN_DONE = "swap_in_done"
    SCHEDULE_TICK = "schedule_tick"
    REPLICA_FAILURE = "replica_failure"
    REPLICA_RECOVERED = "replica_recovered"
    # AF-disaggregation micro-pipeline
    ATTN_COMPUTE_DONE = "attn_compute_done"
    A2F_TRANSFER_DONE = "a2f_transfer_done"
    FFN_COMPUTE_DONE = "ffn_compute_done"
    F2A_TRANSFER_DONE = "f2a_transfer_done"
    # expert-parallel micro-workflow (per-EP-rank dispatch/compute/combine)
    EXPERT_DISPATCH_DONE = "expert_dispatch_done"
    EXPERT_RANK_DONE = "expert_rank_done"
    EXPERT_COMBINE_DONE = "expert_combine_done"
    # shared-fabric transfers (epoch-guarded completion; stale ones no-op)
    FABRIC_TRANSFER_DONE = "fabric_transfer_done"
    # fleet control plane (multi-instance serving)
    AUTOSCALE_TICK = "autoscale_tick"
    INSTANCE_READY = "instance_ready"          # cold start finished
    POOL_RECONFIGURED = "pool_reconfigured"    # P:D rebalance weight load


_seq = itertools.count()


class Event:
    """One scheduled event.  A plain ``__slots__`` class (not a dataclass):
    the event heap is the simulator's single hottest allocation site, and a
    per-event ``__dict__`` plus dataclass ``__lt__`` dispatch dominated the
    profile at ~70k events/s.  The engine orders heap entries by a
    ``(time, seq)`` tuple key at the C level; ``__lt__`` here only backs
    direct comparisons in user code/tests.

    ``data`` is ``None`` when the event carries no payload (the common
    case) — consumers that iterate payloads use ``ev.data or {}``.
    """

    __slots__ = ("time", "seq", "kind", "fn", "data")

    def __init__(self, time: float, kind: EV = EV.SCHEDULE_TICK,
                 fn: Optional[Callable[["Event"], None]] = None,
                 data: Optional[Dict[str, Any]] = None,
                 seq: Optional[int] = None):
        self.time = time
        self.seq = next(_seq) if seq is None else seq
        self.kind = kind
        self.fn = fn
        self.data = data

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)

    def __eq__(self, other: object) -> bool:
        return self is other

    def __hash__(self) -> int:
        return id(self)

    def __repr__(self) -> str:  # compact trace line
        return f"Event(t={self.time:.6f}, {self.kind.value}, " \
               f"{self.data or {}})"
